"""Print the top collective contributors (wire bytes × loop multiplicity)
for one dry-run cell — thin CLI over ``repro.obs.collectives.top``. Usage:
  XLA_FLAGS=--xla_force_host_platform_device_count=512 \
  PYTHONPATH=src python scripts/top_collectives.py <arch> <shape> [multi]
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")
import sys

from repro.obs.collectives import top

if __name__ == "__main__":
    arch, shape = sys.argv[1], sys.argv[2]
    multi = "multi" in sys.argv[3:]
    ov = {"layout": "dp"} if "dp" in sys.argv[3:] else None
    top(arch, shape, multi, overrides=ov)
