"""Print the top collective contributors (wire bytes × loop multiplicity)
for one dry-run cell. Usage:
  XLA_FLAGS=--xla_force_host_platform_device_count=512 \
  PYTHONPATH=src python scripts/top_collectives.py <arch> <shape> [multi]
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")
import re
import sys

from repro.launch.dryrun import build_cell
from repro import roofline


def top(arch, shape, multi=False, n=10, overrides=None):
    lowered, n_dev, aux = build_cell(arch, shape, multi, overrides)
    text = lowered.compile().as_text()
    comps = roofline.parse_hlo(text)
    ename = re.match(r"ENTRY\s+%?([\w\.\-]+)",
                     [l for l in text.splitlines()
                      if l.startswith("ENTRY")][0]).group(1)
    mult = roofline.multiplicities(comps, ename)
    items = []
    for name, comp in comps.items():
        m = mult.get(name, 0)
        if m <= 0:
            continue
        for line in comp.lines:
            mo = roofline._OP_DEF.match(line)
            if not mo:
                continue
            kind = mo.group(3)
            if kind.endswith("-start"):
                kind = kind[:-6]
            if kind not in roofline._COLL_KINDS:
                continue
            size = roofline.shape_bytes(mo.group(2))
            meta = re.search(r'op_name="([^"]*)"', line)
            items.append((m * size, m, size, kind,
                          meta.group(1)[-90:] if meta else line.strip()[:90]))
    items.sort(reverse=True)
    total = sum(i[0] for i in items)
    print(f"total payload×mult: {total:.3e} bytes/chip "
          f"(~{total/50e9*1e3:.0f} ms at ICI)")
    for it in items[:n]:
        print(f"{it[0]:.2e}  mult={it[1]:5.0f} size={it[2]:.2e} {it[3]:13s} "
              f"{it[4]}")
    return items


if __name__ == "__main__":
    arch, shape = sys.argv[1], sys.argv[2]
    multi = "multi" in sys.argv[3:]
    ov = {"layout": "dp"} if "dp" in sys.argv[3:] else None
    top(arch, shape, multi, overrides=ov)
