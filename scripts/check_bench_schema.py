"""Assert the machine-readable benchmark JSON keeps its schema.

The harness diffs BENCH_<module>.json across PRs; a module that silently
drops a derived column (or stops emitting a row family) corrupts the perf
trajectory without failing any test. This checker pins the contract for
the records downstream tooling reads:

  BENCH_traffic.json
    - ≥2 traffic_load_r* rows (a latency CURVE needs at least two offered
      loads), each with p50/p99 TTFT, p50/p99 TPOT, goodput, offered_rps
    - exactly one traffic_steady_sync and one traffic_steady_ahead row
      (the dispatch-ahead comparison), each with toks_per_s; the ahead
      row carries the speedup column

  BENCH_decode_throughput.json
    - the chained-vs-fused pair (decode_packed_chained_lockstep /
      decode_packed_fused_lockstep), each with toks_per_s,
      roofline_bound_toks_per_s and the roofline_gap column; the fused
      row carries speedup_vs_chained
    - ≥1 fused_step_T* and ≥1 fused_scan_T* kernel row (the launch-
      amortisation curve); every scan row carries weights_fit_vmem

  BENCH_pipeline.json
    - exactly one pipeline_dense row (the baseline) with ppl
    - ≥4 pipeline_sx* grid rows (a (Spar_x, Spar_h) × scheme × Θ grid),
      each with ppl, ppl_delta_pct, weight_bytes, toks_per_s, spar_x,
      spar_h, theta, scheme; ≥1 quantized (scheme != fp32) and ≥1
      delta-gated (theta > 0) point so both legs of the grid exist
    - exactly one pipeline_serve_parity row with bitwise == 1 — the
      served-equals-retrained invariant held at every grid point

  BENCH_spec.json
    - exactly one spec_target_only baseline row with toks_per_s
    - ≥2 spec_k* speculative rows at ≥2 DISTINCT k values, each with
      acceptance_rate, accepted_per_round, toks_per_s, speedup, k
    - exactly one spec_draft_cost row with draft_toks_per_s + cost_ratio

  BENCH_obs.json
    - the obs_overhead_disabled / obs_overhead_enabled pair, each with
      toks_per_s; the enabled row carries overhead_pct (the ≤5% target)
    - exactly one obs_counter_parity row with fired_match == 1 and
      spec_match == 1 — the on-device counters equal the offline
      reductions exactly
    - exactly one obs_scorecard row with effective_gops,
      bound_effective_gops, bytes_per_token

  every BENCH_*.json
    - top-level benchmark/smoke/wall_time_s/rows keys, rows a list of
      dicts each with name + us_per_call

Usage: python scripts/check_bench_schema.py [dir-with-BENCH-json]
"""
import glob
import json
import os
import sys


def fail(msg):
    print(f"check_bench_schema: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def check_common(path, payload):
    for key in ("benchmark", "smoke", "wall_time_s", "rows"):
        if key not in payload:
            fail(f"{path}: missing top-level key {key!r}")
    if not isinstance(payload["rows"], list):
        fail(f"{path}: rows is not a list")
    for r in payload["rows"]:
        if "name" not in r or "us_per_call" not in r:
            fail(f"{path}: row missing name/us_per_call: {r}")


def check_traffic(path, payload):
    rows = {r["name"]: r for r in payload["rows"]}
    load_rows = [r for n, r in rows.items() if n.startswith("traffic_load_r")]
    if len(load_rows) < 2:
        fail(f"{path}: latency curve needs >=2 traffic_load_r* rows, "
             f"got {len(load_rows)}")
    need = ("p50_ttft_ms", "p90_ttft_ms", "p99_ttft_ms", "p50_tpot_ms",
            "p99_tpot_ms", "goodput_tps", "offered_rps", "completed",
            "expired", "rejected")
    for r in load_rows:
        for k in need:
            if k not in r:
                fail(f"{path}: {r['name']} missing {k!r}")
        if r["p99_ttft_ms"] < r["p50_ttft_ms"]:
            fail(f"{path}: {r['name']} p99_ttft_ms < p50_ttft_ms")
    for name in ("traffic_steady_sync", "traffic_steady_ahead"):
        if name not in rows:
            fail(f"{path}: missing {name} row")
        if "toks_per_s" not in rows[name]:
            fail(f"{path}: {name} missing toks_per_s")
    if "speedup" not in rows["traffic_steady_ahead"]:
        fail(f"{path}: traffic_steady_ahead missing speedup column")


def check_decode(path, payload):
    rows = {r["name"]: r for r in payload["rows"]}
    for name in ("decode_packed_chained_lockstep",
                 "decode_packed_fused_lockstep"):
        if name not in rows:
            fail(f"{path}: missing {name} row")
        for k in ("toks_per_s", "roofline_bound_toks_per_s",
                  "roofline_gap"):
            if k not in rows[name]:
                fail(f"{path}: {name} missing {k!r}")
    if "speedup_vs_chained" not in rows["decode_packed_fused_lockstep"]:
        fail(f"{path}: decode_packed_fused_lockstep missing "
             "speedup_vs_chained column")
    steps = [n for n in rows if n.startswith("fused_step_T")]
    scans = [n for n in rows if n.startswith("fused_scan_T")]
    if not steps or not scans:
        fail(f"{path}: launch-amortisation curve needs fused_step_T* and "
             f"fused_scan_T* rows (got {len(steps)}/{len(scans)})")
    for n in scans:
        if "weights_fit_vmem" not in rows[n]:
            fail(f"{path}: {n} missing weights_fit_vmem flag")


def check_pipeline(path, payload):
    rows = {r["name"]: r for r in payload["rows"]}
    if "pipeline_dense" not in rows:
        fail(f"{path}: missing pipeline_dense baseline row")
    if "ppl" not in rows["pipeline_dense"]:
        fail(f"{path}: pipeline_dense missing ppl")
    grid = [r for n, r in rows.items() if n.startswith("pipeline_sx")]
    if len(grid) < 4:
        fail(f"{path}: quality grid needs >=4 pipeline_sx* rows "
             f"(scheme x theta at >=1 dual-ratio tuple), got {len(grid)}")
    need = ("ppl", "ppl_delta_pct", "weight_bytes", "toks_per_s",
            "spar_x", "spar_h", "theta", "scheme")
    for r in grid:
        for k in need:
            if k not in r:
                fail(f"{path}: {r['name']} missing {k!r}")
    if not any(r["scheme"] != "fp32" for r in grid):
        fail(f"{path}: no quantized grid point (every scheme is fp32)")
    if not any(r["theta"] > 0 for r in grid):
        fail(f"{path}: no delta-gated grid point (every theta is 0)")
    if "pipeline_serve_parity" not in rows:
        fail(f"{path}: missing pipeline_serve_parity row")
    parity = rows["pipeline_serve_parity"]
    if parity.get("bitwise") != 1:
        fail(f"{path}: serve parity not bitwise: {parity}")
    if parity.get("points", 0) < len(grid):
        fail(f"{path}: parity checked at {parity.get('points')} points "
             f"but the grid has {len(grid)}")


def check_spec(path, payload):
    rows = {r["name"]: r for r in payload["rows"]}
    if "spec_target_only" not in rows:
        fail(f"{path}: missing spec_target_only baseline row")
    if "toks_per_s" not in rows["spec_target_only"]:
        fail(f"{path}: spec_target_only missing toks_per_s")
    spec_rows = [r for n, r in rows.items() if n.startswith("spec_k")]
    need = ("acceptance_rate", "accepted_per_round", "toks_per_s",
            "speedup", "k")
    for r in spec_rows:
        for k in need:
            if k not in r:
                fail(f"{path}: {r['name']} missing {k!r}")
        if not 0.0 <= r["acceptance_rate"] <= 1.0:
            fail(f"{path}: {r['name']} acceptance_rate out of [0, 1]")
    ks = {r["k"] for r in spec_rows}
    if len(ks) < 2:
        fail(f"{path}: speculative curve needs spec_k* rows at >=2 "
             f"distinct k values, got k={sorted(ks)}")
    if "spec_draft_cost" not in rows:
        fail(f"{path}: missing spec_draft_cost row")
    for k in ("draft_toks_per_s", "cost_ratio"):
        if k not in rows["spec_draft_cost"]:
            fail(f"{path}: spec_draft_cost missing {k!r}")


def check_obs(path, payload):
    rows = {r["name"]: r for r in payload["rows"]}
    for name in ("obs_overhead_disabled", "obs_overhead_enabled"):
        if name not in rows:
            fail(f"{path}: missing {name} row")
        if "toks_per_s" not in rows[name]:
            fail(f"{path}: {name} missing toks_per_s")
    if "overhead_pct" not in rows["obs_overhead_enabled"]:
        fail(f"{path}: obs_overhead_enabled missing overhead_pct column")
    if "obs_counter_parity" not in rows:
        fail(f"{path}: missing obs_counter_parity row")
    parity = rows["obs_counter_parity"]
    if parity.get("fired_match") != 1:
        fail(f"{path}: on-device fired-column counters diverged from the "
             f"offline occupancy_report reduction: {parity}")
    if parity.get("spec_match") != 1:
        fail(f"{path}: on-device spec counters diverged from "
             f"spec_stats(): {parity}")
    if "obs_scorecard" not in rows:
        fail(f"{path}: missing obs_scorecard row")
    for k in ("effective_gops", "bound_effective_gops", "bytes_per_token"):
        if k not in rows["obs_scorecard"]:
            fail(f"{path}: obs_scorecard missing {k!r}")


def main():
    out_dir = sys.argv[1] if len(sys.argv) > 1 else "."
    paths = sorted(glob.glob(os.path.join(out_dir, "BENCH_*.json")))
    if not paths:
        fail(f"no BENCH_*.json found in {out_dir!r}")
    saw_traffic = saw_decode = saw_pipeline = saw_spec = saw_obs = False
    for path in paths:
        with open(path) as f:
            payload = json.load(f)
        check_common(path, payload)
        if payload["benchmark"] == "traffic":
            check_traffic(path, payload)
            saw_traffic = True
        if payload["benchmark"] == "decode_throughput":
            check_decode(path, payload)
            saw_decode = True
        if payload["benchmark"] == "pipeline":
            check_pipeline(path, payload)
            saw_pipeline = True
        if payload["benchmark"] == "spec":
            check_spec(path, payload)
            saw_spec = True
        if payload["benchmark"] == "obs":
            check_obs(path, payload)
            saw_obs = True
    if not saw_traffic:
        fail("BENCH_traffic.json not produced (traffic module not "
             "registered in benchmarks/run.py?)")
    if not saw_decode:
        fail("BENCH_decode_throughput.json not produced (decode module "
             "not registered in benchmarks/run.py?)")
    if not saw_pipeline:
        fail("BENCH_pipeline.json not produced (pipeline module not "
             "registered in benchmarks/run.py?)")
    if not saw_spec:
        fail("BENCH_spec.json not produced (spec module not registered "
             "in benchmarks/run.py?)")
    if not saw_obs:
        fail("BENCH_obs.json not produced (obs module not registered "
             "in benchmarks/run.py?)")
    print(f"check_bench_schema: OK ({len(paths)} files, traffic + decode "
          "+ pipeline + spec + obs schemas verified)")


if __name__ == "__main__":
    main()
