"""Run the docstring examples (doctests) of the documented public modules.

``python -m doctest src/...py`` imports files as top-level modules, which
breaks the package's relative imports — so this runner imports the modules
through the package and feeds them to doctest.testmod. Add modules here
when their docstrings grow runnable examples.

  PYTHONPATH=src python scripts/run_doctests.py
"""
from __future__ import annotations

import doctest
import importlib
import sys

MODULES = (
    "repro.sparse.temporal",
    "repro.sparse.policy",
    "repro.sparse.backend",
    "repro.quant.scheme",
    "repro.quant.calibrate",
    "repro.dist.partition",
)


def main() -> int:
    failed = attempted = 0
    for name in MODULES:
        mod = importlib.import_module(name)
        res = doctest.testmod(mod, verbose=False)
        print(f"{name}: {res.attempted} examples, {res.failed} failures")
        failed += res.failed
        attempted += res.attempted
    if not attempted:
        print("ERROR: no doctest examples found — listed modules lost "
              "their examples?")
        return 1
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
