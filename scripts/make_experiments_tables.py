"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from
reports/dryrun/*.json. Usage: python scripts/make_experiments_tables.py"""
import glob
import json
import sys


def load(out="reports/dryrun"):
    recs = []
    for f in sorted(glob.glob(f"{out}/*.json")):
        recs.append(json.load(open(f)))
    return recs


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if b >= div:
            return f"{b/div:.2f} {unit}"
    return f"{b:.0f} B"


def dryrun_table(recs):
    print("| arch | shape | mesh | status | compile s | args/dev | temp/dev |"
          " collective kinds |")
    print("|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r.get("status") == "ok":
            ma = r.get("memory_analysis", {})
            nd = r["n_devices"]
            args = fmt_bytes(ma.get("argument_size_in_bytes", 0) / nd * nd
                             and ma.get("argument_size_in_bytes", 0) / nd)
            temp = fmt_bytes(ma.get("temp_size_in_bytes", 0) / nd)
            colls = ",".join(k for k, v in r.get("collectives", {}).items()
                             if v.get("count"))
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | OK | "
                  f"{r.get('compile_s', 0):.1f} | {args} | {temp} | {colls} |")
        elif r.get("status") == "n/a":
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | N/A | - | - |"
                  f" - | {r['reason'][:48]} |")
        else:
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ERROR | - |"
                  f" - | - | {r.get('error', '')[:48]} |")


def roofline_table(recs):
    print("| arch | shape | compute ms | memory ms | collective ms | bound |"
          " MODEL_FLOPs | HLO_FLOPs(glob) | useful | one-line diagnosis |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r.get("status") != "ok" or r.get("mesh") != "pod16x16":
            continue
        t = r["roofline"]
        mf = r["model_flops"]["total"]
        hf = r.get("hlo_flops_global") or r.get("hlo_flops", 0) * r["n_devices"]
        u = r.get("useful_flops_ratio")
        diag = _diagnose(r)
        print(f"| {r['arch']} | {r['shape']} | {t['compute_s']*1e3:.2f} | "
              f"{t['memory_s']*1e3:.2f} | {t['collective_s']*1e3:.2f} | "
              f"{t['bound']} | {mf:.2e} | {hf:.2e} | "
              f"{u:.2f} | {diag} |" if u else
              f"| {r['arch']} | {r['shape']} | - | - | - | - | - | - | - | |")


def _diagnose(r):
    t = r["roofline"]
    colls = r.get("collectives", {})
    if t["bound"] == "collective":
        big = max(colls, key=lambda k: colls[k]["wire"]) if colls else "?"
        return (f"{big} dominates ({fmt_bytes(colls[big]['wire'])}/chip); "
                "shrink TP activations / overlap DP grads")
    if t["bound"] == "memory":
        hb = r.get("hbm_bytes", {})
        w = hb.get("weights", 0)
        tot = hb.get("total_per_chip", 1)
        if w / max(tot, 1) > 0.5:
            return "weight streaming dominates → BRDS packing cuts it"
        return "KV-cache streaming dominates → cache quantization/windowing"
    return "MXU-bound — healthy"


if __name__ == "__main__":
    recs = load(sys.argv[1] if len(sys.argv) > 1 else "reports/dryrun")
    print("## §Dry-run\n")
    dryrun_table(recs)
    print("\n## §Roofline (single pod, 16x16)\n")
    roofline_table(recs)
