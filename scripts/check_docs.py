"""Link-check the repo's markdown docs (stdlib only, CI-friendly).

Walks README.md + docs/**/*.md, extracts markdown links and inline code
paths, and verifies that:

  - relative link targets exist on disk (anchors are stripped);
  - intra-repo anchor links (#section) point at a heading in the target
    file (GitHub slug rules, simplified);
  - repo paths named in the docs' code spans (src/..., benchmarks/...,
    docs/..., examples/..., scripts/..., tests/...) exist.

External (http/https/mailto) targets are skipped — CI must not depend on
the network. Exits non-zero listing every broken reference.

  python scripts/check_docs.py
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
CODESPAN = re.compile(r"`([A-Za-z0-9_./-]+)`")
CODE_PREFIXES = ("src/", "benchmarks/", "docs/", "examples/", "scripts/",
                 "tests/")


def _slug(heading: str) -> str:
    """GitHub-style anchor slug (simplified: lowercase, drop punctuation,
    spaces → dashes)."""
    s = heading.strip().lower()
    s = re.sub(r"[`*_]", "", s)
    s = re.sub(r"[^\w\s-]", "", s)
    return re.sub(r"\s+", "-", s).strip("-")


def _anchors(md: pathlib.Path) -> set[str]:
    out = set()
    for line in md.read_text().splitlines():
        if line.startswith("#"):
            out.add(_slug(line.lstrip("#")))
    return out


def check_file(md: pathlib.Path) -> list[str]:
    errors = []
    text = md.read_text()
    for target in LINK.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, anchor = target.partition("#")
        dest = (md.parent / path_part).resolve() if path_part else md
        if path_part and not dest.exists():
            errors.append(f"{md.relative_to(ROOT)}: broken link → {target}")
            continue
        if anchor and dest.suffix == ".md" and dest.exists():
            if anchor not in _anchors(dest):
                errors.append(f"{md.relative_to(ROOT)}: missing anchor "
                              f"#{anchor} in {dest.relative_to(ROOT)}")
    for span in CODESPAN.findall(text):
        if span.startswith(CODE_PREFIXES):
            if not (ROOT / span).exists():
                errors.append(f"{md.relative_to(ROOT)}: named path does not "
                              f"exist → {span}")
    return errors


def main() -> int:
    files = [ROOT / "README.md"] + sorted((ROOT / "docs").glob("**/*.md"))
    errors = []
    for md in files:
        if md.exists():
            errors.extend(check_file(md))
    for e in errors:
        print(f"ERROR: {e}")
    print(f"check_docs: {len(files)} files, {len(errors)} errors")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
