"""Training substrate: optimizers, schedules, grad accumulation, data
determinism, gradient compression (error feedback)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:     # container ships no hypothesis
    from _hypothesis_fallback import given, settings, strategies as st

from repro.training import OptConfig, init_state
from repro.training.optim import apply_update, lr_at, global_norm
from repro.training.data import ZipfInduction, CharCorpus, ShardedLoader
from repro.training import compression as C


def _quad_problem(opt_name):
    """Minimize ||x - t||^2 with each optimizer; must converge."""
    oc = OptConfig(name=opt_name, lr=0.05, weight_decay=0.0,
                   warmup_steps=1, total_steps=500, schedule="constant")
    t = jnp.asarray([1.0, -2.0, 3.0])
    params = {"x": jnp.zeros(3)}
    state = init_state(oc, params)
    loss = lambda p: jnp.sum((p["x"] - t) ** 2)
    g = jax.grad(loss)
    for i in range(300):
        params, state, _ = apply_update(oc, params, g(params), state)
    return float(loss(params))


@pytest.mark.parametrize("opt", ["adamw", "sgdm", "lion"])
def test_optimizers_converge(opt):
    assert _quad_problem(opt) < 1e-2


def test_lr_schedule_shape():
    oc = OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                   min_lr_frac=0.1, schedule="cosine")
    assert float(lr_at(oc, 0)) == 0.0
    assert float(lr_at(oc, 10)) == pytest.approx(1.0)
    assert float(lr_at(oc, 100)) == pytest.approx(0.1, abs=1e-3)
    assert float(lr_at(oc, 55)) > float(lr_at(oc, 90))


def test_grad_clip():
    oc = OptConfig(grad_clip=1.0)
    params = {"x": jnp.zeros(4)}
    st = init_state(oc, params)
    big = {"x": jnp.full(4, 100.0)}
    _, _, m = apply_update(oc, params, big, st)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_grad_accum_equivalence():
    """accum=2 over a split batch == accum=1 over the full batch."""
    from repro.configs import smoke_config
    from repro.models import build_model
    from repro.training import make_train_step
    cfg = smoke_config("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    oc = OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    st = init_state(oc, params)
    rng = jax.random.key(1)
    tokens = jax.random.randint(rng, (4, 16), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    p1, _, m1 = jax.jit(make_train_step(model, cfg.with_(grad_accum=1), oc))(
        params, st, batch, jnp.int32(0))
    p2, _, m2 = jax.jit(make_train_step(model, cfg.with_(grad_accum=2), oc))(
        params, st, batch, jnp.int32(0))
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
    d = max(float(jnp.abs(a - b).max())
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert d < 1e-5


def test_data_determinism_and_sharding():
    """Restart invariant: batch k is a pure function of (seed, step);
    shards partition the global batch."""
    ds = ZipfInduction(vocab_size=100, seed=7)
    b1 = ds.batch(5, 8, 16)
    b2 = ds.batch(5, 8, 16)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    full = ShardedLoader(ds, 8, 16, shard_idx=0, num_shards=1).batch(3)
    parts = [ShardedLoader(ds, 8, 16, shard_idx=i, num_shards=4).batch(3)
             for i in range(4)]
    np.testing.assert_array_equal(
        np.concatenate([p["tokens"] for p in parts]), full["tokens"])


def test_induction_structure_learnable():
    """The planted bigram rules are real: rule transitions are frequent."""
    ds = ZipfInduction(vocab_size=50, rule_frac=1.0, seed=0)
    b = ds.batch(0, 4, 64)
    t = b["tokens"]
    hits = (t[:, 1:] == ds.rules[t[:, :-1]]).mean()
    assert hits > 0.95


def test_char_corpus():
    ds = CharCorpus()
    b = ds.batch(0, 4, 32)
    assert b["tokens"].shape == (4, 32)
    assert b["tokens"].max() < ds.vocab_size


# ------------------------------------------------------------ compression

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31), scale=st.floats(1e-3, 1e3))
def test_quantize_bounded_error(seed, scale):
    g = jnp.asarray(np.random.default_rng(seed).normal(size=64) * scale,
                    jnp.float32)
    q, s, res = C.quantize(g)
    err = jnp.abs(C.dequantize(q, s) + res - g)
    assert float(err.max()) < 1e-5          # q*s + residual == g exactly-ish
    assert float(jnp.abs(res).max()) <= float(s) * 0.5 + 1e-6


def test_error_feedback_preserves_sum():
    """Across steps, error feedback means quantization error doesn't
    accumulate: sum of dequantized ≈ sum of true gradients."""
    rng = np.random.default_rng(0)
    res = jnp.zeros(32)
    total_true = jnp.zeros(32)
    total_sent = jnp.zeros(32)
    for i in range(50):
        g = jnp.asarray(rng.normal(size=32), jnp.float32)
        q, s, res = C.quantize(g, res)
        total_true += g
        total_sent += C.dequantize(q, s)
    # residual bounds the divergence
    np.testing.assert_allclose(np.asarray(total_sent + res),
                               np.asarray(total_true), atol=1e-4)


def test_wire_bytes_accounting():
    tree = {"a": jnp.zeros((4, 4), jnp.float32), "b": jnp.zeros(8, jnp.bfloat16)}
    assert C.wire_bytes(tree, compressed=False) == 64 + 16
    assert C.wire_bytes(tree, compressed=True) == 16 + 8
