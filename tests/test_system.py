"""End-to-end system behaviour: training improves loss; BRDS prune+retrain
recovers; the serve engine generates; paper-claim orderings hold at toy
scale (the Fig. 9 relative claim)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import build_model, LSTMModel, LSTMConfig
from repro.training import (OptConfig, init_state, make_train_step,
                            CharCorpus, brds_masks)
from repro.training.masked import apply_masks
from repro.training.optim import apply_update
from repro.core import metrics as M
from repro.core.sparsity import (row_balanced_mask, bank_balanced_mask,
                                 block_mask, apply_mask)


def _train(model, cfg, params, ds, steps, seq=32, bs=8, masks=None, seed=0):
    oc = OptConfig(lr=5e-3, warmup_steps=2, total_steps=steps,
                   schedule="constant")
    st = init_state(oc, params)
    step = jax.jit(make_train_step(model, cfg, oc, masks))
    losses = []
    for i in range(steps):
        b = ds.batch(seed * 1000 + i, bs, seq)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        params, st, m = step(params, st, batch, jnp.int32(i))
        losses.append(float(m["loss"]))
    return params, losses


def test_end_to_end_char_lm_learns():
    ds = CharCorpus()
    cfg = smoke_config("llama3.2-3b").with_(vocab_size=ds.vocab_size,
                                            num_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    params, losses = _train(model, cfg, params, ds, steps=50)
    assert min(losses[-5:]) < losses[0] * 0.8, losses[::10]


def test_serve_engine_generates():
    from repro.serving import ServeEngine
    cfg = smoke_config("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    eng = ServeEngine(model, cfg, max_len=24, batch=2)
    prompt = jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab_size)
    out = eng.generate(params, prompt, steps=6)
    assert out.shape == (2, 6)
    assert int(out.max()) < cfg.vocab_size


def test_prune_retrain_recovers_lstm():
    """Paper §3.2: retraining after pruning restores most of the loss."""
    cfg = LSTMConfig("t", input_size=16, hidden=48, num_layers=1,
                     vocab_size=30)
    model = LSTMModel(cfg)
    ds = CharCorpus()

    class TokDs:
        def batch(self, step, bs, seq):
            b = ds.batch(step, bs, seq)
            t = b["tokens"] % 30
            return {"inputs": t, "labels": t}

    tds = TokDs()
    params = model.init(jax.random.key(0))
    oc = OptConfig(lr=5e-3, warmup_steps=2, total_steps=400,
                   schedule="constant")
    st = init_state(oc, params)
    lg = jax.jit(jax.value_and_grad(lambda p, b: model.loss(p, b)))

    def run(p, st, n, masks=None, off=0):
        last = None
        for i in range(n):
            b = {k: jnp.asarray(v) for k, v in tds.batch(off + i, 8, 24).items()}
            last, g = lg(p, b)
            if masks is not None:
                g = model.mask_grads(g, masks)
            p, st, _ = apply_update(oc, p, g, st)
        return p, st, float(last)

    params, st, base = run(params, st, 60)
    pruned, masks = model.prune(params, 0.6, 0.4)
    b0 = {k: jnp.asarray(v) for k, v in tds.batch(5000, 8, 24).items()}
    loss_pruned = float(model.loss(pruned, b0))
    retrained, st, _ = run(pruned, st, 60, masks=masks, off=100)
    loss_retrained = float(model.loss(retrained, b0))
    assert loss_pruned > base * 0.99            # pruning hurts
    assert loss_retrained < loss_pruned          # retraining recovers


def test_row_balanced_beats_block_at_matched_sparsity():
    """Fig. 9 RELATIVE claim at toy scale: immediately after pruning a
    trained LSTM at matched sparsity, finer-grained patterns lose less:
    unstructured ≤ row-balanced ≲ bank-balanced < block."""
    cfg = LSTMConfig("t", input_size=16, hidden=64, num_layers=1,
                     vocab_size=30)
    model = LSTMModel(cfg)
    ds = CharCorpus()
    params = model.init(jax.random.key(3))
    oc = OptConfig(lr=5e-3, warmup_steps=2, total_steps=400,
                   schedule="constant")
    st = init_state(oc, params)
    lg = jax.jit(jax.value_and_grad(lambda p, b: model.loss(p, b)))
    for i in range(80):
        t = ds.batch(i, 8, 24)["tokens"] % 30
        b = {"inputs": jnp.asarray(t), "labels": jnp.asarray(t)}
        _, g = lg(params, b)
        params, st, _ = apply_update(oc, params, g, st)

    t = ds.batch(7777, 16, 24)["tokens"] % 30
    eval_b = {"inputs": jnp.asarray(t), "labels": jnp.asarray(t)}
    spar = 0.6

    def loss_with(maskfn, **kw):
        p2 = jax.tree.map(lambda x: x, params)
        new_layers = []
        for lp in p2["layers"]:
            nl = dict(lp)
            for key in ("w_x", "w_h"):
                m = maskfn(lp[key], spar, **kw)
                nl[key] = apply_mask(lp[key], m)
            new_layers.append(nl)
        p2["layers"] = new_layers
        return float(model.loss(p2, eval_b))

    l_row = loss_with(row_balanced_mask)
    l_block = loss_with(block_mask, block=(4, 4))
    assert l_row < l_block, (l_row, l_block)


def test_cross_entropy_matches_naive():
    rng = jax.random.key(0)
    logits = jax.random.normal(rng, (4, 8, 50)) * 3
    labels = jax.random.randint(rng, (4, 8), 0, 50)
    got = M.cross_entropy(logits, labels)
    naive = -jnp.mean(jnp.take_along_axis(
        jax.nn.log_softmax(logits, -1), labels[..., None], -1))
    assert float(jnp.abs(got - naive)) < 1e-5
    assert M.perplexity(0.0) == 1.0
