"""Property-based tests for the row-balance invariant at the FORMAT and
POLICY layers (the registry surface the pipeline deploys through).

test_sparsity.py proves the core-level mask/pack/unpack; these push the
same invariant through ``get_format('row_balanced')`` /
``'row_balanced_q8'`` and through compiled dual-ratio policies: for
random shapes and ratios every pack keeps exactly k survivors per row,
pack → unpack round-trips (to quantization tolerance for q8), and
``lstm_policy`` applies Spar_x / Spar_h to the correct weight families.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:     # container ships no hypothesis
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import apply_mask, keep_count
from repro.models import LSTMConfig, LSTMModel
from repro.sparse import get_format, lstm_policy

dims = st.integers(min_value=2, max_value=40)
spars = st.floats(min_value=0.0, max_value=0.95)
seeds = st.integers(0, 2**31)


def _w(rows, cols, seed):
    return jnp.asarray(
        np.random.default_rng(seed).normal(size=(rows, cols)), jnp.float32)


@settings(max_examples=20, deadline=None)
@given(rows=dims, cols=dims, spar=spars, seed=seeds)
def test_format_row_balanced_exact_k(rows, cols, spar, seed):
    """Registry pack keeps exactly k = keep_count(ncols, ratio) per row:
    values/deltas are (rows, k) and every unpacked row has ≤ k non-zeros
    (< k only when a kept weight is exactly 0)."""
    fmt = get_format("row_balanced")
    w = _w(rows, cols, seed)
    mask = fmt.mask(w, spar)
    k = keep_count(cols, spar)
    assert (np.asarray(mask.sum(axis=1)) == k).all()
    packed = fmt.pack(w, mask)
    assert packed.values.shape == (rows, k)
    assert packed.deltas.shape == (rows, k)
    cols_idx = np.asarray(packed.col_indices())
    assert (np.diff(cols_idx, axis=1) > 0).all()
    assert cols_idx.min() >= 0 and cols_idx.max() < cols


@settings(max_examples=20, deadline=None)
@given(rows=dims, cols=dims, spar=spars, seed=seeds)
def test_format_row_balanced_roundtrip(rows, cols, spar, seed):
    fmt = get_format("row_balanced")
    w = _w(rows, cols, seed)
    mask = fmt.mask(w, spar)
    assert jnp.allclose(fmt.unpack(fmt.pack(w, mask)), apply_mask(w, mask))


@settings(max_examples=20, deadline=None)
@given(rows=dims, cols=dims, spar=spars, seed=seeds)
def test_format_q8_exact_k_and_roundtrip(rows, cols, spar, seed):
    """The quantized format preserves the structural invariant exactly —
    same k survivors at the same columns — and round-trips values to
    within one per-row quantization step."""
    fmt = get_format("row_balanced_q8")
    w = _w(rows, cols, seed)
    mask = fmt.mask(w, spar)
    k = keep_count(cols, spar)
    assert (np.asarray(mask.sum(axis=1)) == k).all()
    q = fmt.pack(w, mask)
    assert q.values.shape == (rows, k)
    ref_cols = np.asarray(get_format("row_balanced").pack(w, mask)
                          .col_indices())
    np.testing.assert_array_equal(np.asarray(q.col_indices()), ref_cols)
    dense = np.asarray(fmt.unpack(q))
    target = np.asarray(apply_mask(w, mask))
    # int8 absmax: error ≤ scale/2, scale = rowmax/127
    step = np.abs(target).max(axis=1, keepdims=True) / 127.0
    assert (np.abs(dense - target) <= step / 2 + 1e-7).all()


@settings(max_examples=8, deadline=None)
@given(spar_x=st.floats(0.1, 0.9), spar_h=st.floats(0.1, 0.9),
       hidden=st.integers(2, 12), seed=seeds)
def test_dual_ratio_policy_targets_families(spar_x, spar_h, hidden, seed):
    """lstm_policy(Spar_x, Spar_h) prunes w_x at Spar_x and w_h at Spar_h
    — and nothing else: every row of every gate matrix keeps exactly the
    family's keep_count, embeddings/head/biases stay dense."""
    cfg = LSTMConfig("prop", input_size=8, hidden=hidden, num_layers=2,
                     vocab_size=17)
    model = LSTMModel(cfg)
    params = model.init(jax.random.key(seed % 1000))
    plan = lstm_policy(spar_x, spar_h).compile(params)
    pruned, masks = plan.prune(params)
    assert set(masks) == {"layers/0/w_x", "layers/0/w_h",
                          "layers/1/w_x", "layers/1/w_h"}
    for path, mask in masks.items():
        spar = spar_x if path.endswith("w_x") else spar_h
        ncols = mask.shape[-1]     # layout out_in: rows = 4H gate rows
        k = keep_count(ncols, spar)
        assert (np.asarray(mask.sum(axis=-1)) == k).all(), path
    # pruned tree: masked where matched, untouched elsewhere
    for li in range(2):
        for fam in ("w_x", "w_h"):
            m = masks[f"layers/{li}/{fam}"]
            np.testing.assert_array_equal(
                np.asarray(pruned["layers"][li][fam]),
                np.asarray(params["layers"][li][fam] * m))
        np.testing.assert_array_equal(np.asarray(pruned["layers"][li]["b"]),
                                      np.asarray(params["layers"][li]["b"]))
    np.testing.assert_array_equal(np.asarray(pruned["head"]["w"]),
                                  np.asarray(params["head"]["w"]))


@settings(max_examples=10, deadline=None)
@given(rows=dims, cols=dims, spar=spars, seed=seeds)
def test_pack_preserves_zero_valued_survivors(rows, cols, spar, seed):
    """Packing from an explicit mask must keep the mask's structure even
    where the weight is 0 (retrained weights can cross zero) — survivor
    columns come from the mask, not from the values."""
    fmt = get_format("row_balanced")
    w = _w(rows, cols, seed)
    mask = fmt.mask(w, spar)
    w_zeroed = w.at[:, 0].set(0.0)   # zero a column; mask unchanged
    packed = fmt.pack(w_zeroed, mask)
    np.testing.assert_array_equal(
        np.asarray(packed.col_indices()),
        np.asarray(fmt.pack(w, mask).col_indices()))
