"""Distributed correctness on simulated multi-device meshes.

jax locks the device count at first init, so each scenario runs in a
subprocess with XLA_FLAGS=--xla_force_host_platform_device_count set.
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = REPO_SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


def test_sharded_train_step_matches_single_device():
    """(data=2, model=2) sharded train step == single-device step."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import smoke_config
    from repro.models import build_model
    from repro.training import (OptConfig, init_state, make_train_step,
                                jit_train_step)
    from repro.launch.mesh import make_host_mesh

    cfg = smoke_config('llama3.2-3b')
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    oc = OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    st = init_state(oc, params)
    tokens = jax.random.randint(jax.random.key(1), (4, 16), 0, cfg.vocab_size)
    batch = {'tokens': tokens, 'labels': tokens}

    p_ref, st_ref, m_ref = jax.jit(make_train_step(model, cfg, oc))(
        params, st, batch, jnp.int32(0))

    mesh = make_host_mesh(data=2, model=2)
    batch_abs = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch)
    with mesh:
        step = jit_train_step(mesh, model, cfg, oc, batch_abs, donate=False)
        p_sh, st_sh, m_sh = step(params, st, batch, jnp.int32(0))
    assert abs(float(m_ref['loss']) - float(m_sh['loss'])) < 1e-4, \\
        (float(m_ref['loss']), float(m_sh['loss']))
    d = max(float(jnp.abs(a - b).max()) for a, b in
            zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_sh)))
    assert d < 2e-3, d
    print('parity ok', d)
    """)


def test_compressed_psum_matches_exact():
    """int8 compressed all-reduce ≈ exact mean across 8 shards; error
    feedback keeps the running sum unbiased."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.training.compression import compressed_psum
    from repro.launch.mesh import make_mesh   # owns the AxisType shim

    mesh = make_mesh((8,), ('data',))
    g = jnp.asarray(np.random.default_rng(0).normal(size=(8, 64)), jnp.float32)

    def f(gl, res):
        mean, new_res = compressed_psum(gl[0], 'data', res[0])
        return mean[None], new_res[None]

    sm = shard_map(f, mesh=mesh, in_specs=(P('data'), P('data')),
                   out_specs=(P('data'), P('data')))
    res = jnp.zeros((8, 64), jnp.float32)
    mean_c, res = sm(g, res)
    exact = jnp.mean(g, axis=0)
    # every shard holds the same mean; compare with exact
    err = float(jnp.abs(mean_c[0] - exact).max())
    scale = float(jnp.abs(g).max()) / 127.0
    assert err <= scale + 1e-6, (err, scale)
    print('compressed psum ok', err)
    """)


def test_elastic_restore_across_mesh_sizes():
    """Checkpoint written under an 8-device mesh restores onto a 4-device
    mesh (elastic scale-down) with identical values."""
    _run("""
    import os, tempfile
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import smoke_config
    from repro.models import build_model
    from repro.training import CheckpointManager
    from repro.training.train_loop import param_shardings
    from repro.launch.mesh import make_host_mesh

    cfg = smoke_config('qwen3-0.6b')
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    tmp = tempfile.mkdtemp()
    mesh8 = make_host_mesh(data=2, model=4)
    sh8 = param_shardings(mesh8, model)
    p8 = jax.tree.map(lambda a, s: jax.device_put(a, s), params, sh8)
    ck = CheckpointManager(tmp, async_save=False)
    ck.save(3, p8)

    mesh4 = make_host_mesh(data=2, model=2)
    sh4 = param_shardings(mesh4, model)
    from repro.training.fault import elastic_restore
    p4, meta = elastic_restore(ck, params, sh4)
    assert meta['step'] == 3
    d = max(float(jnp.abs(a - b).max()) for a, b in
            zip(jax.tree.leaves(params), jax.tree.leaves(p4)))
    assert d == 0.0, d
    print('elastic restore ok')
    """, devices=8)


def test_decode_step_sharded_matches_host():
    """Sharded decode (split-KV cache) == unsharded decode."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import smoke_config
    from repro.models import build_model
    from repro.launch.mesh import make_host_mesh
    from repro.training.train_loop import param_shardings
    from repro.serving.engine import cache_shardings

    cfg = smoke_config('llama3.2-3b')
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, S, MAX = 4, 12, 16
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    lp, cache = model.prefill(params, tokens, MAX)
    lg_ref, _ = model.decode_step(params, cache, tokens[:, :1], S)

    mesh = make_host_mesh(data=2, model=4)
    p_sh = param_shardings(mesh, model)
    c_sh = cache_shardings(mesh, model, B, MAX)
    from jax.sharding import NamedSharding, PartitionSpec as P
    with mesh:
        fn = jax.jit(model.decode_step,
                     in_shardings=(p_sh, c_sh,
                                   NamedSharding(mesh, P('data')),
                                   NamedSharding(mesh, P())))
        lg_sh, _ = fn(params, cache, tokens[:, :1], S)
    err = float(jnp.abs(lg_ref - lg_sh).max())
    assert err < 1e-3, err
    print('sharded decode ok', err)
    """)
