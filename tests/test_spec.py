"""repro.spec: speculative decoding with BRDS-packed recurrent drafts.

The load-bearing invariant is LOSSLESSNESS: greedy speculative decode is
bitwise identical to target-only greedy decode — for every draft serving
variant (dense, packed, delta Θ=0, calibrated q8), every tested k, every
target family (LSTM, transformer, RG-LRU hybrid, RWKV), and through the
continuous-batching scheduler. Plus the DecodeStep rewind-contract
regression (decode, roll back, decode different tokens, bitwise-match a
fresh-from-prefill trajectory) and unit tests for the sampling
distributions and acceptance rules.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import build_model, LSTMModel, LSTMConfig
from repro.serving import (ContinuousBatchingEngine, SamplingConfig,
                           ServeEngine, sample, sample_dist,
                           sample_from_dist, sample_with_dist)
from repro.spec import (DraftModel, greedy_accept, accept_length,
                        rejection_accept, residual_dist, rollback,
                        spec_decode_loop, verify_chain)
from repro.sparse import (DeltaGateConfig, QuantConfig, lstm_policy,
                          use_backend)

MAX_LEN = 40
GREEDY = SamplingConfig(eos_id=-1)


@pytest.fixture(scope="module")
def lstm():
    cfg = LSTMConfig("t", input_size=16, hidden=32, num_layers=2,
                     vocab_size=50)
    model = LSTMModel(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _draft(lstm, variant):
    """Build one draft serving variant from the SAME LSTM weights."""
    cfg, model, params = lstm
    calib = jax.random.randint(jax.random.key(9), (2, 8), 0, cfg.vocab_size)
    if variant == "dense":
        return DraftModel(model, params)
    if variant == "packed":
        plan = lstm_policy(0.6, 0.4, backend="ref").compile(params)
        pruned, masks = plan.prune(params)
        packed, _ = plan.pack(pruned, masks)
        return DraftModel(model, packed)
    if variant == "delta0":
        eng = ServeEngine(model, cfg, max_len=MAX_LEN, batch=3,
                          sparsity=lstm_policy(
                              0.6, 0.4, backend="ref",
                              delta=DeltaGateConfig(theta_x=0.0,
                                                    theta_h=0.0)))
        dparams, _ = eng.prepare(params)
        return DraftModel(eng.model, dparams)
    if variant == "q8":
        eng = ServeEngine(model, cfg, max_len=MAX_LEN, batch=3,
                          sparsity=lstm_policy(0.6, 0.4, backend="ref",
                                               quant=QuantConfig("int8")))
        dparams, _ = eng.prepare(params, calib=calib)
        return DraftModel(eng.model, dparams)
    raise AssertionError(variant)


# ---------------------------------------------------------------- sampling
def test_sample_with_dist_greedy_one_hot():
    logits = jax.random.normal(jax.random.key(0), (4, 11))
    ids, dist = sample_with_dist(jax.random.key(1), logits, GREEDY)
    np.testing.assert_array_equal(np.asarray(ids),
                                  np.argmax(np.asarray(logits), -1))
    # greedy distribution is exactly one-hot at the argmax
    np.testing.assert_array_equal(
        np.asarray(dist), np.eye(11, dtype=np.float32)[np.asarray(ids)])
    # and existing callers are unchanged: sample() returns the same ids
    np.testing.assert_array_equal(
        np.asarray(sample(jax.random.key(1), logits, GREEDY)),
        np.asarray(ids))


def test_sample_with_dist_temperature():
    cfg = SamplingConfig(temperature=0.7, top_k=4)
    logits = jax.random.normal(jax.random.key(0), (5, 16))
    ids, dist = sample_with_dist(jax.random.key(1), logits, cfg)
    d = np.asarray(dist)
    np.testing.assert_allclose(d.sum(-1), 1.0, rtol=1e-5)
    # top-k filtering: at most k tokens carry mass
    assert ((d > 1e-9).sum(-1) <= 4).all()
    # ids are bitwise what the un-split sample() draws with the same key
    np.testing.assert_array_equal(
        np.asarray(ids), np.asarray(sample(jax.random.key(1), logits, cfg)))
    # sampling from the returned distribution lands only on carried mass
    ids2 = sample_from_dist(jax.random.key(2), dist, cfg)
    assert (np.take_along_axis(d, np.asarray(ids2)[:, None], -1) > 0).all()


def test_sample_from_dist_greedy_argmax():
    dist = jnp.asarray([[0.1, 0.7, 0.2], [0.5, 0.2, 0.3]])
    ids = sample_from_dist(jax.random.key(0), dist, GREEDY)
    np.testing.assert_array_equal(np.asarray(ids), [1, 0])


# ---------------------------------------------------------------- accept
def test_accept_length_stops_at_first_reject():
    ok = jnp.asarray([[1, 1, 0, 1], [1, 1, 1, 1], [0, 1, 1, 1]], bool)
    np.testing.assert_array_equal(np.asarray(accept_length(ok)), [2, 4, 0])


def test_greedy_accept_counts_argmax_matches():
    logits = jnp.zeros((1, 3, 5)).at[0, 0, 2].set(1.0).at[0, 1, 4].set(
        1.0).at[0, 2, 1].set(1.0)
    # target argmax chain is [2, 4, 1]; draft got the first two right
    a = greedy_accept(jnp.asarray([[2, 4, 0]]), logits)
    np.testing.assert_array_equal(np.asarray(a), [2])


def test_rejection_accepts_everything_when_q_equals_p():
    V, k = 7, 4
    p = jax.nn.softmax(jax.random.normal(jax.random.key(0), (3, k + 1, V)))
    toks = jnp.argmax(p[:, :k], -1).astype(jnp.int32)
    a = rejection_accept(jax.random.key(1), toks, p, p[:, :k])
    np.testing.assert_array_equal(np.asarray(a), [k, k, k])


def test_residual_dist_one_hot_reduces_to_target():
    # greedy one-hots: residual at a rejection is one-hot(target argmax)
    V = 6
    p = jax.nn.one_hot(jnp.asarray([[1, 3, 5]]), V)          # (1, 3, V)
    q = jax.nn.one_hot(jnp.asarray([[1, 2]]), V)             # (1, 2, V)
    res = residual_dist(p, q, jnp.asarray([1]))              # rejected at 1
    np.testing.assert_array_equal(np.asarray(res),
                                  np.asarray(jax.nn.one_hot([3], V)))
    # full acceptance: the bonus distribution p_k comes back untouched
    res = residual_dist(p, q, jnp.asarray([2]))
    np.testing.assert_array_equal(np.asarray(res), np.asarray(p[:, 2]))


# ----------------------------------------------------- verify + rewind
def test_verify_chain_block_bitwise_matches_sequential(lstm):
    """Chain decomposition: scoring a (B, 3) block in one dispatch is
    three sequential single-token verifies — same argmax everywhere
    (the greedy-losslessness carrier) and logits equal to fusion
    re-association tolerance (XLA compiles different scan trip counts
    with different fusions, so 1e-9-level drift is expected; the
    token-stream bitwise tests below are the real invariant)."""
    cfg, model, params = lstm
    prompt = jax.random.randint(jax.random.key(1), (3, 5), 0,
                                cfg.vocab_size)
    block = jax.random.randint(jax.random.key(2), (3, 3), 0,
                               cfg.vocab_size)
    pos = jnp.full((3,), 5, jnp.int32)
    with use_backend("ref"):
        _, cache = model.prefill(params, prompt, MAX_LEN)
        v_logits, _, _ = verify_chain(model, params, cache, block, pos)
        _, cache = model.prefill(params, prompt, MAX_LEN)
        seq = []
        for j in range(3):
            if j == 2:
                ref_logits, _ = model.decode_step(params, cache,
                                                  block[:, 2:], pos + 2)
            lj, cache, _ = verify_chain(model, params, cache,
                                        block[:, j:j + 1], pos + j)
            seq.append(lj[:, 0])
    seq = np.asarray(jnp.stack(seq, axis=1))
    got = np.asarray(v_logits)
    np.testing.assert_allclose(got, seq, atol=1e-6)
    np.testing.assert_array_equal(got.argmax(-1), seq.argmax(-1))
    np.testing.assert_allclose(got[:, 2],
                               np.asarray(ref_logits[:, 0], np.float32),
                               atol=1e-6)


@pytest.mark.parametrize("family", ["transformer", "hybrid", "lstm"])
def test_rewind_decode_matches_fresh_from_prefill(family, lstm):
    """The DecodeStep rewind contract: decode k tokens, roll back, decode
    DIFFERENT tokens — bitwise the fresh-from-prefill trajectory.
    Positional (KV) caches rewind by pos alone (entries ≥ pos are dead);
    recurrent leaves restore from verify_chain checkpoints."""
    if family == "lstm":
        cfg, model, params = lstm
        vocab = cfg.vocab_size
    else:
        cfg = smoke_config("qwen3-0.6b" if family == "transformer"
                           else "recurrentgemma-9b")
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        vocab = cfg.vocab_size
    B, S = 2, 5
    prompt = jax.random.randint(jax.random.key(1), (B, S), 0, vocab)
    A = jax.random.randint(jax.random.key(2), (B, 3), 0, vocab)
    Bt = jax.random.randint(jax.random.key(3), (B, 3), 0, vocab)
    pos = jnp.full((B,), S, jnp.int32)
    with use_backend("ref"):
        # decode 3 tokens of A, roll all the way back, decode B instead
        _, cache = model.prefill(params, prompt, MAX_LEN)
        _, cacheA, states = verify_chain(model, params, cache, A, pos)
        cache_r = rollback(model, cacheA, states,
                           jnp.zeros((B,), jnp.int32))
        got, _, _ = verify_chain(model, params, cache_r, Bt, pos)
        # the fresh trajectory that never saw A
        _, cache2 = model.prefill(params, prompt, MAX_LEN)
        want, _, _ = verify_chain(model, params, cache2, Bt, pos)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

        # partial rewind: keep A's first token, replace the tail
        _, cache = model.prefill(params, prompt, MAX_LEN)
        _, cacheA, states = verify_chain(model, params, cache, A, pos)
        cache_r = rollback(model, cacheA, states,
                           jnp.ones((B,), jnp.int32))
        got, _, _ = verify_chain(model, params, cache_r, Bt, pos + 1)
        _, cache2 = model.prefill(params, prompt, MAX_LEN)
        want, _, _ = verify_chain(
            model, params, cache2,
            jnp.concatenate([A[:, :1], Bt], axis=1), pos)
        # different scan trip counts (3 vs 4) re-associate fusions, so
        # argmax-bitwise + tight allclose rather than float-bitwise here
        g, w = np.asarray(got), np.asarray(want[:, 1:])
        np.testing.assert_allclose(g, w, atol=1e-5)
        np.testing.assert_array_equal(g.argmax(-1), w.argmax(-1))


# ------------------------------------------------------------ losslessness
@pytest.mark.parametrize("variant", ["dense", "packed", "delta0", "q8"])
@pytest.mark.parametrize("k", [1, 4, 8])
def test_greedy_spec_is_bitwise_lossless(lstm, variant, k):
    cfg, model, params = lstm
    prompt = jax.random.randint(jax.random.key(1), (3, 7), 0,
                                cfg.vocab_size)
    with use_backend("ref"):
        eng = ServeEngine(model, cfg, max_len=MAX_LEN, batch=3)
        base = np.asarray(eng.generate(params, prompt, 8))
        draft = _draft(lstm, variant)
        spec = np.asarray(eng.generate(params, prompt, 8, draft=draft,
                                       spec_k=k))
    np.testing.assert_array_equal(base, spec)


def test_greedy_spec_lossless_with_eos(lstm):
    """EOS/pad emission discipline matches decode_loop exactly: pick an
    eos id the greedy continuation actually emits mid-stream."""
    cfg, model, params = lstm
    prompt = jax.random.randint(jax.random.key(1), (3, 7), 0,
                                cfg.vocab_size)
    with use_backend("ref"):
        eng = ServeEngine(model, cfg, max_len=MAX_LEN, batch=3)
        free = np.asarray(eng.generate(params, prompt, 8))
        samp = SamplingConfig(eos_id=int(free[0, 2]))
        base = np.asarray(eng.generate(params, prompt, 8, sampling=samp))
        draft = _draft(lstm, "packed")
        spec = np.asarray(eng.generate(params, prompt, 8, sampling=samp,
                                       draft=draft, spec_k=4))
    assert (base[0] == samp.pad_id).any()      # the eos actually fired
    np.testing.assert_array_equal(base, spec)


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "rwkv6-7b"])
def test_greedy_spec_lossless_transformer_target(arch, lstm):
    """Cross-family: a recurrent LSTM draft speculating for a KV-cache
    transformer / RWKV target, rollback by pos-rewind + checkpoints."""
    cfg = smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    dcfg = LSTMConfig("d", input_size=16, hidden=32, num_layers=1,
                      vocab_size=cfg.vocab_size)
    dmodel = LSTMModel(dcfg)
    draft = DraftModel(dmodel, dmodel.init(jax.random.key(1)))
    prompt = jax.random.randint(jax.random.key(2), (2, 5), 0,
                                cfg.vocab_size)
    with use_backend("ref"):
        eng = ServeEngine(model, cfg, max_len=32, batch=2)
        base = np.asarray(eng.generate(params, prompt, 6))
        spec = np.asarray(eng.generate(params, prompt, 6, draft=draft,
                                       spec_k=3))
    np.testing.assert_array_equal(base, spec)


def test_greedy_spec_lossless_through_scheduler(lstm):
    """Continuous batching with per-slot draft state: ragged prompts,
    chunked rounds, joins and evictions — token streams bitwise match the
    draft-free scheduler."""
    cfg, model, params = lstm
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
               for n in (5, 3, 9, 6, 4)]

    def run(draft):
        with use_backend("ref"):
            eng = ContinuousBatchingEngine(
                model, params, slots=3, max_len=32, chunk=4,
                sampling=GREEDY, draft=draft, spec_k=3)
            for p in prompts:
                eng.submit(p, 10)
            return eng.run(), eng.spec_stats()

    base, none_stats = run(None)
    spec, stats = run(_draft(lstm, "packed"))
    assert none_stats is None
    assert set(base) == set(spec)
    for uid in base:
        np.testing.assert_array_equal(base[uid], spec[uid])
    assert stats["drafted"] > 0 and stats["rounds"] > 0
    assert 0.0 <= stats["acceptance_rate"] <= 1.0


def test_spec_acceptance_accounting(lstm):
    """A draft sharing the target's exact weights accepts everything:
    acceptance-rate 1 and one round per k+1 tokens."""
    cfg, model, params = lstm
    prompt = jax.random.randint(jax.random.key(1), (2, 7), 0,
                                cfg.vocab_size)
    with use_backend("ref"):
        eng = ServeEngine(model, cfg, max_len=MAX_LEN, batch=2)
        draft = DraftModel(model, params)          # the target itself
        toks, st = eng.generate(params, prompt, 8, draft=draft, spec_k=3,
                                return_state=True, rng=jax.random.key(5))
    drafted = np.asarray(st["drafted"])
    accepted = np.asarray(st["accepted"])
    np.testing.assert_array_equal(accepted,
                                  np.minimum(drafted, accepted))
    # every proposal that had room to commit was accepted (8 steps = two
    # full rounds of 1+3 committed tokens each)
    np.testing.assert_array_equal(np.asarray(st["rounds"]), [2, 2])
    np.testing.assert_array_equal(np.asarray(st["emitted"]), [8, 8])
    np.testing.assert_array_equal(accepted, [6, 6])


def test_temperature_spec_decodes_valid_tokens(lstm):
    """The rejection-sampling path: not bitwise (different rng consumption
    than decode_loop) but shape/vocab/accounting-sound."""
    cfg, model, params = lstm
    prompt = jax.random.randint(jax.random.key(1), (3, 7), 0,
                                cfg.vocab_size)
    samp = SamplingConfig(temperature=0.8, top_k=10)
    with use_backend("ref"):
        eng = ServeEngine(model, cfg, max_len=MAX_LEN, batch=3)
        draft = _draft(lstm, "packed")
        toks, st = eng.generate(params, prompt, 8, sampling=samp,
                                draft=draft, spec_k=4, return_state=True,
                                rng=jax.random.key(6))
    t = np.asarray(toks)
    assert t.shape == (3, 8)
    assert ((t >= 0) & (t < cfg.vocab_size)).all()
    assert (np.asarray(st["emitted"]) == 8).all()
    a = np.asarray(st["accepted"])
    assert (a >= 0).all() and (a <= np.asarray(st["drafted"])).all()


# ------------------------------------------------------------------ draft
def test_draft_rejects_positional_cache_model():
    cfg = smoke_config("qwen3-0.6b")
    model = build_model(cfg)
    with pytest.raises(TypeError, match="positional"):
        DraftModel(model, None)


def test_draft_scan_prefill_matches_stepwise_state(lstm):
    """The fused multi-token scan prefill primes the same (c, h) state as
    the model's own masked prefill (same packed ref kernels → bitwise on
    the ref backend)."""
    cfg, model, params = lstm
    plan = lstm_policy(0.6, 0.4, backend="ref").compile(params)
    pruned, masks = plan.prune(params)
    packed, _ = plan.pack(pruned, masks)
    prompt = jax.random.randint(jax.random.key(1), (3, 7), 0,
                                cfg.vocab_size)
    with use_backend("ref"):
        draft = DraftModel(model, packed, scan_prefill=True)
        l_scan, s_scan = draft.prefill(packed, prompt, MAX_LEN)
        l_ref, s_ref = model.prefill(packed, prompt, MAX_LEN)
    for got, want in zip(jax.tree.leaves(s_scan), jax.tree.leaves(s_ref)):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)
    np.testing.assert_allclose(np.asarray(l_scan), np.asarray(l_ref),
                               atol=1e-4)
