"""Golden-trajectory regression tests: checked-in greedy token
trajectories for a fixed-seed tiny LSTM-LM across the five deployment
variants (dense, packed chained, packed fused, Θ=0 delta, calibrated q8).

The pairwise bitwise parities elsewhere in the suite prove variants agree
WITH EACH OTHER — these goldens pin the absolute numerics, so silent
drift from a kernel edit or an XLA/jax version bump fails loudly even if
every variant drifts in lockstep. The checked-in seed was selected so
every greedy argmax margin exceeds ~3.7e-3 (recorded in the JSON) —
orders of magnitude above cross-platform ulp noise, so a token mismatch
means real numeric change, not reassociation jitter. Regenerate the JSON
only for an INTENTIONAL numeric change, and say why in the commit.
"""
import json
import os

import jax
import numpy as np
import pytest

from repro.models import LSTMConfig, LSTMModel
from repro.serving import ServeEngine
from repro.sparse import DeltaGateConfig, QuantConfig, lstm_policy

GOLDEN = os.path.join(os.path.dirname(__file__), "golden_trajectories.json")

with open(GOLDEN) as f:
    G = json.load(f)

SX, SH = G["spar"]


def _variant(name):
    if name == "dense":
        return False, None, False
    if name == "packed_chained":
        return False, lstm_policy(SX, SH), False
    if name == "packed_fused":
        return True, lstm_policy(SX, SH), False
    if name == "delta_theta0":
        return False, lstm_policy(
            SX, SH, delta=DeltaGateConfig(theta_x=0.0, theta_h=0.0)), False
    if name == "calibrated_q8":
        return False, lstm_policy(SX, SH, quant=QuantConfig("int8")), True
    raise KeyError(name)


def _fixtures():
    cfg = LSTMConfig(f"golden{G['seed']}", **G["model"])
    params = LSTMModel(cfg).init(jax.random.key(G["seed"]))
    prompt = jax.random.randint(jax.random.key(G["seed"] + 1000),
                                (G["batch"], G["prompt_len"]), 0,
                                G["model"]["vocab_size"])
    calib = jax.random.randint(jax.random.key(G["seed"] + 2000), (2, 8),
                               0, G["model"]["vocab_size"])
    return cfg, params, prompt, calib


@pytest.mark.parametrize("name", sorted(G["trajectories"]))
def test_golden_trajectory(name):
    cfg, params, prompt, calib = _fixtures()
    fused, policy, needs_calib = _variant(name)
    eng = ServeEngine(LSTMModel(cfg, fused=fused), cfg,
                      max_len=G["prompt_len"] + G["steps"],
                      batch=G["batch"], sparsity=policy)
    p = params
    if policy is not None:
        p, _ = eng.prepare(params, calib=calib if needs_calib else None)
    toks = np.asarray(eng.generate(p, prompt, G["steps"]))
    expect = np.asarray(G["trajectories"][name], np.int32)
    np.testing.assert_array_equal(
        toks, expect,
        err_msg=f"{name}: greedy trajectory drifted from the golden — "
                "a kernel/XLA numeric change; regenerate the golden only "
                "if the change is intentional")


def test_goldens_cover_all_variants():
    assert set(G["trajectories"]) == {"dense", "packed_chained",
                                      "packed_fused", "delta_theta0",
                                      "calibrated_q8"}
    # the established bitwise parities must hold inside the goldens too
    assert (G["trajectories"]["packed_chained"]
            == G["trajectories"]["packed_fused"]
            == G["trajectories"]["delta_theta0"])
    assert G["min_argmax_margin"] > 1e-3
