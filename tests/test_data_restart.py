"""Restart reproducibility for training/data.py — the documented
fault-tolerance invariant: every loader is a deterministic function of
(seed, step), so a job restarted at step k regenerates batch k exactly,
and the held-out eval stream can never alias a training step."""
import numpy as np
import pytest

from repro.training.data import (CharCorpus, EVAL_STEP_BASE, FrameCorpus,
                                 ShardedLoader, ZipfInduction)

CORPORA = [
    ("zipf", lambda: ZipfInduction(vocab_size=64, seed=3)),
    ("char", lambda: CharCorpus(seed=3)),
    ("frame", lambda: FrameCorpus(input_size=12, num_classes=7, seed=3)),
]


def _assert_batches_equal(a, b):
    assert set(a) == set(b)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])


@pytest.mark.parametrize("name,make", CORPORA, ids=[c[0] for c in CORPORA])
def test_restart_reproduces_batches(name, make):
    """A fresh corpus instance (the restarted job) reproduces the exact
    batch sequence of the original at every step — including a cold
    restart jumping straight to a late step."""
    first = make()
    stream = [first.batch(step, 4, 10) for step in range(5)]
    restarted = make()
    for step in (4, 2, 0, 3, 1):        # arbitrary resume order
        _assert_batches_equal(restarted.batch(step, 4, 10), stream[step])
    # restart at a late step without replaying earlier ones
    late = make().batch(9_999, 4, 10)
    _assert_batches_equal(late, first.batch(9_999, 4, 10))


@pytest.mark.parametrize("name,make", CORPORA, ids=[c[0] for c in CORPORA])
def test_distinct_steps_differ(name, make):
    """(seed, step) determinism must not collapse to constants: different
    steps give different batches (else 'deterministic' is vacuous)."""
    c = make()
    a, b = c.batch(0, 4, 10), c.batch(1, 4, 10)
    key = "tokens" if "tokens" in a else "inputs"
    assert not np.array_equal(a[key], b[key])


@pytest.mark.parametrize("name,make", CORPORA, ids=[c[0] for c in CORPORA])
def test_eval_stream_never_aliases_training(name, make):
    """eval_batches draws from the EVAL_STEP_BASE step namespace: no
    training step a realistic job can reach produces the same batch, and
    the eval stream itself is reproducible across restarts."""
    c = make()
    evals = c.eval_batches(3, 4, 10)
    assert len(evals) == 3
    _assert_batches_equal(evals[1], c.batch(EVAL_STEP_BASE + 1, 4, 10))
    _assert_batches_equal(evals[0], make().eval_batches(1, 4, 10)[0])
    key = "tokens" if "tokens" in evals[0] else "inputs"
    for step in (0, 1, 10_000):         # 10_000 was the old collision
        train = c.batch(step, 4, 10)
        assert not np.array_equal(train[key], evals[0][key]), (
            f"eval batch aliases training step {step}")
    assert EVAL_STEP_BASE > 10**9       # out of reach of any real run


def test_sharded_loader_tiles_global_batch():
    """Shards partition the global batch exactly: concatenating every
    shard's slice at step k reproduces the unsharded batch k, for every
    (shard_idx, num_shards) — the elastic-resharding invariant."""
    corpus = ZipfInduction(vocab_size=32, seed=5)
    for num_shards in (1, 2, 4):
        shards = [ShardedLoader(corpus, 8, 6, shard_idx=i,
                                num_shards=num_shards)
                  for i in range(num_shards)]
        for step in (0, 3):
            full = corpus.batch(step, 8, 6)
            got = {k: np.concatenate([s.batch(step)[k] for s in shards])
                   for k in full}
            _assert_batches_equal(got, full)


def test_sharded_loader_restart_mid_epoch():
    corpus = FrameCorpus(input_size=10, num_classes=5, seed=7)
    loader = ShardedLoader(corpus, 8, 6, shard_idx=1, num_shards=2)
    want = loader.batch(11)
    fresh = ShardedLoader(FrameCorpus(input_size=10, num_classes=5, seed=7),
                          8, 6, shard_idx=1, num_shards=2)
    _assert_batches_equal(fresh.batch(11), want)
