"""The paper's LSTM + BRDS search algorithm, end to end at toy scale."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import LSTMModel, LSTMConfig
from repro.core import brds_search, execution_time_model
from repro.core.sparsity import sparsity_of
from repro.training import OptConfig, init_state
from repro.training.optim import apply_update
from repro.training.data import FrameCorpus


@pytest.fixture(scope="module")
def setup():
    cfg = LSTMConfig("toy", input_size=24, hidden=32, num_layers=2,
                     num_classes=8, framewise=True)
    model = LSTMModel(cfg)
    params = model.init(jax.random.key(0))
    ds = FrameCorpus(input_size=24, num_classes=8)
    return cfg, model, params, ds


def test_lstm_trains(setup):
    cfg, model, params, ds = setup
    oc = OptConfig(lr=1e-2, total_steps=60, warmup_steps=2,
                   schedule="constant")
    st = init_state(oc, params)
    loss_g = jax.jit(jax.value_and_grad(
        lambda p, b: model.loss(p, b)))
    losses = []
    for i in range(50):
        b = {k: jnp.asarray(v) for k, v in ds.batch(i, 8, 16).items()}
        l, g = loss_g(params, b)
        params, st, _ = apply_update(oc, params, g, st)
        losses.append(float(l))
    assert min(losses[-5:]) < losses[0] * 0.92, losses[::10]


def test_dense_sparse_step_equivalence(setup):
    cfg, model, params, ds = setup
    pruned, masks = model.prune(params, 0.7, 0.4)
    packed = model.pack(pruned, masks)
    # sparsity of packed matches requested ratios (within rounding)
    assert abs(packed[0]["sx"].sparsity - 0.7) < 0.05
    assert abs(packed[0]["sh"].sparsity - 0.4) < 0.05
    x = jnp.asarray(np.random.default_rng(0).normal(size=(3, 24)), jnp.float32)
    st0 = model.init_state(3)
    hd, sd = model.dense_step(pruned, x, st0)
    hs, ss = model.sparse_step(packed, x, st0)
    np.testing.assert_allclose(np.asarray(hd), np.asarray(hs), atol=1e-5)
    for (cd, hd_), (cs, hs_) in zip(sd, ss):
        np.testing.assert_allclose(np.asarray(cd), np.asarray(cs), atol=1e-5)


def test_brds_search_runs_and_respects_os(setup):
    """Fig.-5 algorithm: explores dual ratios, returns the best tuple with
    overall sparsity ≥ target (phase-2/3 walks keep OS by construction)."""
    cfg, model, params, ds = setup
    oc = OptConfig(lr=3e-3, total_steps=200, warmup_steps=1)

    def prune_fn(p, sx, sh):
        return model.prune(p, sx, sh)

    def retrain_fn(p, masks):
        st = init_state(oc, p)
        loss_g = jax.jit(jax.value_and_grad(lambda pp, b: model.loss(pp, b)))
        for i in range(4):
            b = {k: jnp.asarray(v) for k, v in ds.batch(i, 8, 16).items()}
            _, g = loss_g(p, b)
            g = model.mask_grads(g, masks)
            p, st, _ = apply_update(oc, p, g, st)
            p, _ = model.prune(p, 0.0, 0.0) if False else (p, None)
        # re-apply masks to keep pruned weights at 0
        pruned, _ = model.prune(p, 0.0, 0.0)
        return p

    def eval_fn(p):
        b = {k: jnp.asarray(v) for k, v in ds.batch(999, 8, 16).items()}
        return -float(model.loss(p, b))

    res = brds_search(params, overall_sparsity=0.5, prune_fn=prune_fn,
                      retrain_fn=retrain_fn, eval_fn=eval_fn,
                      alpha=0.25, delta_x=0.25, delta_h=0.25)
    assert len(res.history) >= 3
    phases = {h["phase"] for h in res.history}
    assert "init" in phases and ("x_up" in phases or "h_up" in phases)
    # best ratios from the explored set
    assert 0.0 <= res.best_spar_x <= 0.99
    assert 0.0 <= res.best_spar_h <= 0.99


def test_execution_time_model_matches_paper_eqs():
    """eqs (3)-(6): ex1 = OS/α·ept·n, ex2/ex3 = min(...)·ept·n."""
    t = execution_time_model(0.875, 0.25, 0.05, 0.05, ept=2.0, n_re=3)
    assert t["ex1"] == pytest.approx(0.875 / 0.25 * 6.0)
    assert t["ex2"] == pytest.approx(min(0.125 / 0.05, 0.875 / 0.05) * 6.0)
    assert t["total"] == pytest.approx(t["ex1"] + t["ex2"] + t["ex3"])


def test_pwl_lstm_close_to_exact(setup):
    cfg, model, params, ds = setup
    from repro.models.lstm import LSTMConfig as LC, LSTMModel as LM
    import dataclasses
    cfg_pwl = dataclasses.replace(cfg, pwl_activations=True)
    m2 = LM(cfg_pwl)
    b = ds.batch(0, 4, 12)
    out_exact = model.forward(params, jnp.asarray(b["inputs"]))
    out_pwl = m2.forward(params, jnp.asarray(b["inputs"]))
    # PWL is an approximation: close but not identical
    diff = float(jnp.abs(out_exact - out_pwl).max())
    assert 0 < diff < 0.5
