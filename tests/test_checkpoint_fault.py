"""Fault tolerance: checkpoint atomicity, corruption recovery, resilient
loop restart, straggler detection."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training import CheckpointManager, ResilientLoop, StragglerMonitor


def _state(v: float):
    return {"w": jnp.full((4, 4), v), "step_count": jnp.asarray(v)}


def test_save_restore_roundtrip(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    ckpt.save(10, _state(1.5), extra={"note": "x"})
    got, meta = ckpt.restore(_state(0.0))
    assert meta["step"] == 10
    np.testing.assert_array_equal(np.asarray(got["w"]),
                                  np.full((4, 4), 1.5, np.float32))


def test_keep_k_pruning(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        ckpt.save(s, _state(float(s)))
    assert ckpt.all_steps() == [3, 4]


def test_corrupted_checkpoint_skipped(tmp_path):
    """A node dying mid-save must not poison the restore path."""
    ckpt = CheckpointManager(str(tmp_path), keep=5, async_save=False)
    ckpt.save(1, _state(1.0))
    ckpt.save(2, _state(2.0))
    # corrupt step 2's payload
    p = os.path.join(str(tmp_path), "step_00000002", "arrays_p0.npz")
    with open(p, "wb") as f:
        f.write(b"garbage")
    assert ckpt.latest_step() == 1
    got, meta = ckpt.restore(_state(0.0))
    assert meta["step"] == 1
    assert float(got["w"][0, 0]) == 1.0


def test_tmp_dir_never_committed(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), keep=5, async_save=False)
    os.makedirs(os.path.join(str(tmp_path), "step_00000009.tmp"))
    assert ckpt.all_steps() == []


def test_async_save(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    ckpt.save(5, _state(5.0))
    ckpt.wait()
    assert ckpt.latest_step() == 5


def test_resilient_loop_recovers(tmp_path):
    """Step function raises twice; loop restores from checkpoint and
    replays to completion with deterministic results."""
    ckpt = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    fail_at = {7: 2}   # step 7 fails twice

    def step_fn(state, step):
        if fail_at.get(step, 0) > 0:
            fail_at[step] -= 1
            raise RuntimeError("simulated node failure")
        return {"w": state["w"] + 1.0,
                "step_count": state["step_count"] + 1}

    loop = ResilientLoop(ckpt, save_every=2, max_failures=5)
    state, end = loop.run(_state(0.0), step_fn, 0, 10)
    assert end == 10
    assert loop.failures == 2
    # every one of the 10 increments happened exactly once
    assert float(state["w"][0, 0]) == 10.0


def test_resilient_loop_gives_up(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), keep=3, async_save=False)

    def step_fn(state, step):
        raise RuntimeError("permanent failure")

    loop = ResilientLoop(ckpt, save_every=2, max_failures=2)
    with pytest.raises(RuntimeError):
        loop.run(_state(0.0), step_fn, 0, 5)


def test_straggler_monitor():
    mon = StragglerMonitor(threshold=2.0, alpha=0.5)
    for _ in range(10):
        mon.record(1.0)
    assert not mon.record(1.5)
    assert mon.record(5.0)       # 5x EMA → flagged
    assert mon.flagged == 1
    # stragglers don't pollute the EMA
    assert mon.ema == pytest.approx(1.0, abs=0.3)


def test_resilient_loop_straggler_hook(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    clock = {"t": 0.0}
    times = iter([1.0] * 8 + [30.0] + [1.0] * 3)

    def fake_clock():
        return clock["t"]

    def step_fn(state, step):
        clock["t"] += next(times)
        return state

    events = []
    loop = ResilientLoop(ckpt, save_every=100,
                         straggler=StragglerMonitor(threshold=3.0),
                         on_straggler=lambda s, m: events.append(s),
                         clock=fake_clock)
    loop.run(_state(0.0), step_fn, 0, 12)
    assert events == [8]
