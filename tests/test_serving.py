"""The unified decode runtime: DecodeStep conformance, dense↔packed serving
parity, the on-device scan loop vs the old per-token Python loop, sampling,
and continuous-batching admission/eviction under ragged request lengths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import build_model, LSTMModel, LSTMConfig
from repro.serving import (ServeEngine, ContinuousBatchingEngine,
                           SamplingConfig, conforms, sample)
from repro.sparse import lstm_policy, use_backend


@pytest.fixture(scope="module")
def lstm():
    cfg = LSTMConfig("t", input_size=16, hidden=32, num_layers=2,
                     vocab_size=50)
    model = LSTMModel(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def transformer():
    cfg = smoke_config("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def test_decode_contract_conformance(lstm, transformer):
    """Every served family implements cache_defs/prefill/decode_step."""
    from repro.models import EncDecLM
    from repro.configs import get_arch
    assert conforms(lstm[1])
    assert conforms(transformer[1])
    assert conforms(EncDecLM(smoke_config("seamless-m4t-medium")))
    assert not conforms(object())


def test_lstm_dense_vs_packed_serving_parity(lstm):
    """BRDS-packed params produce the same greedy tokens as dense through
    the engine — the packed rb kernels are the serve-time datapath."""
    cfg, model, params = lstm
    plan = lstm_policy(0.6, 0.4, backend="ref").compile(params)
    pruned, masks = plan.prune(params)
    packed, report = plan.pack(pruned, masks)
    assert report["packed_bytes"] < report["dense_bytes"]
    prompt = jax.random.randint(jax.random.key(1), (3, 7), 0, cfg.vocab_size)
    with use_backend("ref"):
        eng = ServeEngine(model, cfg, max_len=20, batch=3)
        out_dense = np.asarray(eng.generate(pruned, prompt, 5))
        out_packed = np.asarray(eng.generate(packed, prompt, 5))
    np.testing.assert_array_equal(out_dense, out_packed)


def test_engine_prepare_packs_lstm(lstm):
    """prepare() on a packed-decode model prunes AND packs."""
    from repro.core.packing import RowBalancedSparse
    cfg, model, params = lstm
    eng = ServeEngine(model, cfg, max_len=16, batch=2,
                      sparsity=lstm_policy(0.5, 0.5, backend="ref"))
    prepared, report = eng.prepare(params)
    assert isinstance(prepared["layers"][0]["w_x"], RowBalancedSparse)
    assert report["sparsity"] > 0.4
    prompt = jax.random.randint(jax.random.key(2), (2, 4), 0, cfg.vocab_size)
    with use_backend("ref"):
        out = eng.generate(prepared, prompt, 3)
    assert out.shape == (2, 3)


def test_scan_loop_matches_python_loop_and_single_dispatch(transformer):
    """The on-device scan decode reproduces the old per-token host loop
    greedily, while tracing decode_step once (no per-token host round
    trips — a Python loop would call it `steps` times)."""
    cfg, model, params = transformer
    calls = {"n": 0}
    real_step = model.decode_step

    def counting_step(p, cache, toks, pos):
        calls["n"] += 1
        return real_step(p, cache, toks, pos)

    model.decode_step = counting_step
    try:
        eng = ServeEngine(model, cfg, max_len=24, batch=2)
        prompt = jax.random.randint(jax.random.key(1), (2, 8), 0,
                                    cfg.vocab_size)
        steps = 6
        out = np.asarray(eng.generate(params, prompt, steps))
    finally:
        model.decode_step = real_step
    assert calls["n"] == 1, "decode loop is not on-device"

    lp, cache = model.prefill(params, prompt, 24)
    ref = []
    for i in range(steps):
        nxt = jnp.argmax(lp[:, -1], -1)[:, None].astype(jnp.int32)
        ref.append(np.asarray(nxt))
        lp, cache = model.decode_step(params, cache, nxt, prompt.shape[1] + i)
    np.testing.assert_array_equal(out, np.concatenate(ref, axis=1))


def test_eos_stops_per_sequence(lstm):
    cfg, model, params = lstm
    prompt = jax.random.randint(jax.random.key(3), (2, 5), 0, cfg.vocab_size)
    eng = ServeEngine(model, cfg, max_len=20, batch=2)
    greedy = np.asarray(eng.generate(params, prompt, 6))
    eos = int(greedy[0, 2])                 # force an early stop on row 0
    out = np.asarray(eng.generate(
        params, prompt, 6,
        sampling=SamplingConfig(eos_id=eos, pad_id=-7)))
    row0 = out[0]
    hit = np.argmax(row0 == eos)
    assert row0[hit] == eos
    assert (row0[hit + 1:] == -7).all()     # padding after EOS
    # a row that never hits EOS keeps generating
    for r in range(2):
        if eos not in greedy[r]:
            assert -7 not in out[r]


def test_encdec_serves_through_engine():
    """The enc-dec joins the contract via extra= (no special-case engine
    branching)."""
    from repro.models import EncDecLM
    cfg = smoke_config("seamless-m4t-medium")
    model = EncDecLM(cfg)
    params = model.init(jax.random.key(0))
    eng = ServeEngine(model, cfg, max_len=20, batch=2)
    prompt = jax.random.randint(jax.random.key(1), (2, 6), 0, cfg.vocab_size)
    frames = jax.random.normal(jax.random.key(2), (2, 16, cfg.d_model),
                               dtype=jnp.float32)
    out = eng.generate(params, prompt, 4, extra=frames)
    assert out.shape == (2, 4)
    assert int(out.max()) < cfg.vocab_size


def test_sampling_modes():
    rng = jax.random.key(0)
    logits = jax.random.normal(rng, (4, 32)) * 3
    greedy = sample(rng, logits, SamplingConfig())
    np.testing.assert_array_equal(np.asarray(greedy),
                                  np.asarray(jnp.argmax(logits, -1)))
    # top_k=1 is greedy regardless of temperature
    k1 = sample(rng, logits, SamplingConfig(temperature=2.0, top_k=1))
    np.testing.assert_array_equal(np.asarray(k1), np.asarray(greedy))
    # top-k sampling only ever emits top-k ids
    topk = 4
    allowed = np.asarray(jax.lax.top_k(logits, topk)[1])
    for i in range(20):
        s = sample(jax.random.key(i), logits,
                   SamplingConfig(temperature=1.0, top_k=topk))
        for b in range(4):
            assert int(s[b]) in allowed[b]


def test_top_p_sampling():
    """Nucleus sampling emits only ids inside the smallest top-p mass."""
    rng = jax.random.key(0)
    # row 0: one dominant token (p≈0.97) → top_p=0.5 must always pick it;
    # row 1: near-uniform → top_p≈1 keeps everything
    logits = jnp.stack([
        jnp.concatenate([jnp.array([6.0]), jnp.zeros(31)]),
        jnp.linspace(0.0, 0.1, 32),
    ])
    seen1 = set()
    for i in range(25):
        s = sample(jax.random.key(i), logits,
                   SamplingConfig(temperature=1.0, top_p=0.5))
        assert int(s[0]) == 0
        seen1.add(int(s[1]))
    assert len(seen1) > 1          # row 1's nucleus is wide at p=0.5
    # the nucleus is the prob-sorted prefix: with top_p=0.3 on row 1,
    # only the highest-probability ids (the tail of the linspace) survive
    probs = np.asarray(jax.nn.softmax(logits[1]))
    order = np.argsort(-probs)
    keep = order[np.cumsum(probs[order]) - probs[order] < 0.3]
    for i in range(25):
        s = sample(jax.random.key(100 + i), logits,
                   SamplingConfig(temperature=1.0, top_p=0.3))
        assert int(s[1]) in set(int(k) for k in keep)
    # top_p composes with top_k, greedy path ignores it, validation works
    s = sample(rng, logits, SamplingConfig(temperature=1.0, top_k=2,
                                           top_p=0.9))
    assert s.shape == (2,)
    np.testing.assert_array_equal(
        np.asarray(sample(rng, logits, SamplingConfig(top_p=0.5))),
        np.asarray(jnp.argmax(logits, -1)))
    with pytest.raises(ValueError):
        SamplingConfig(top_p=-0.1)


@pytest.mark.parametrize("family", ["lstm", "transformer", "hybrid"])
def test_continuous_batching_matches_lockstep(family, lstm, transformer,
                                              request):
    """Ragged prompts through 2 shared slots reproduce per-request lockstep
    decode exactly (per-slot cache positions, incl. windowed attention and
    recurrent state); slots admit from the queue and evict on completion."""
    if family == "hybrid":                  # RG-LRU + local attention
        cfg = smoke_config("recurrentgemma-9b")
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
    else:
        cfg, model, params = lstm if family == "lstm" else transformer
    vocab = cfg.vocab_size
    with use_backend("ref"):
        sched = ContinuousBatchingEngine(model, params, slots=2, max_len=24,
                                         chunk=4)
        prompts, budgets = {}, {}
        for i, (plen, gen) in enumerate([(5, 6), (9, 3), (3, 7), (7, 5)]):
            p = jax.random.randint(jax.random.key(10 + i), (1, plen), 0,
                                   vocab)
            uid = sched.submit(p, gen)
            prompts[uid], budgets[uid] = p, gen
        assert sched.pending == 4           # nothing admitted before step()
        fin = sched.step()                  # admits 2, decodes one chunk
        assert sched.pending == 2
        assert len(sched.active_slots) + len(fin) == 2
        results = {f.uid: f.tokens for f in fin}
        results.update(sched.run())
        assert sched.pending == 0 and not sched.active_slots
        eng = ServeEngine(model, cfg, max_len=24, batch=1)
        for uid, p in prompts.items():
            want = np.asarray(eng.generate(params, p, budgets[uid]))[0]
            np.testing.assert_array_equal(results[uid], want)


def test_scheduler_budget_and_capacity(lstm):
    """Budgets are capped by cache capacity; oversize prompts are rejected."""
    cfg, model, params = lstm
    sched = ContinuousBatchingEngine(model, params, slots=1, max_len=12,
                                     chunk=4)
    with pytest.raises(ValueError):
        sched.submit(jnp.zeros((1, 12), jnp.int32), 4)
    uid = sched.submit(jax.random.randint(jax.random.key(0), (1, 8), 0,
                                          cfg.vocab_size), 100)
    results = sched.run()
    assert len(results[uid]) == 4           # 12 - 8 capacity, not 100


def test_packed_continuous_batching(lstm):
    """The scheduler serves SparsityPlan.pack'd LSTM params."""
    cfg, model, params = lstm
    plan = lstm_policy(0.6, 0.4, backend="ref").compile(params)
    pruned, masks = plan.prune(params)
    packed, _ = plan.pack(pruned, masks)
    with use_backend("ref"):
        sched = ContinuousBatchingEngine(model, packed, slots=2, max_len=16,
                                         chunk=4)
        uids = [sched.submit(jax.random.randint(jax.random.key(i), (1, 3 + i),
                                                0, cfg.vocab_size), 4)
                for i in range(3)]
        results = sched.run()
        eng = ServeEngine(model, cfg, max_len=16, batch=1)
        for i, uid in enumerate(uids):
            p = jax.random.randint(jax.random.key(i), (1, 3 + i), 0,
                                   cfg.vocab_size)
            want = np.asarray(eng.generate(packed, p, 4))[0]
            np.testing.assert_array_equal(results[uid], want)


def test_slot_reuse_resets_position_and_eos(lstm):
    """Evict-then-readmit into the SAME slot: the readmitted request must
    start from its own prompt's cache position with fresh EOS state (a
    slot whose previous occupant hit EOS mid-chunk must not bleed its
    done flag or cache position into the next occupant)."""
    cfg, model, params = lstm
    eng = ServeEngine(model, cfg, max_len=24, batch=1)
    p_a = jax.random.randint(jax.random.key(20), (1, 5), 0, cfg.vocab_size)
    p_b = jax.random.randint(jax.random.key(21), (1, 9), 0, cfg.vocab_size)
    greedy_a = np.asarray(eng.generate(params, p_a, 8))[0]
    eos = int(greedy_a[1])                  # A hits EOS on its 2nd token,
    sampling = SamplingConfig(eos_id=eos)   # mid-chunk (chunk=4 below)

    sched = ContinuousBatchingEngine(model, params, slots=1, max_len=24,
                                     chunk=4, sampling=sampling)
    uid_a = sched.submit(p_a, 8)
    uid_b = sched.submit(p_b, 6)
    fin = sched.step()                      # A admitted alone (1 slot)
    assert [f.uid for f in fin] == [uid_a]  # EOS inside the first chunk
    assert sched._slot_uid[0] is None       # slot 0 evicted...
    results = {fin[0].uid: fin[0].tokens}
    results.update(sched.run())             # ...and reused by B

    # B decoded from ITS position with fresh EOS state: exact lockstep
    # parity (same eos_id so any natural EOS matches too)
    want_b = np.asarray(eng.generate(params, p_b, 6, sampling=sampling))[0]
    np.testing.assert_array_equal(results[uid_b], want_b)
    # A's tokens end at EOS and the readmit reset the slot's accounting
    assert int(results[uid_a][-1]) == eos and len(results[uid_a]) == 2
    assert sched.slot_steps[0] >= p_b.shape[1]  # restarted at B's join


def test_pack_preserves_zero_survivors(lstm):
    """Satellite regression: a surviving weight that is exactly zero must
    stay in the packed representation (w != 0 packing dropped it and broke
    the per-row nnz balance)."""
    cfg, model, params = lstm
    pruned, masks = model.prune(params, 0.5, 0.5)
    # zero one SURVIVING w_x weight (simulates retraining through zero)
    m0 = np.asarray(masks["layers/0/w_x"])
    r, c = np.argwhere(m0)[0]
    layers = [dict(lp) for lp in pruned["layers"]]
    layers[0]["w_x"] = layers[0]["w_x"].at[r, c].set(0.0)
    pruned = {**pruned, "layers": layers}
    # mask-less fallback keeps rows balanced (top-K re-selection)
    sx = model.pack(pruned)[0]["sx"]
    assert sx.values.shape[1] * 2 == m0.shape[1]
    # packing from the plan's masks keeps the exact zero survivor
    sx = model.pack(pruned, masks)[0]["sx"]
    assert sx.values.shape[1] * 2 == m0.shape[1]
    cols = np.asarray(sx.col_indices())
    assert c in cols[r]


def test_fused_decode_trajectory_parity(lstm):
    """ISSUE 7 parity bar: the single-launch fused decode produces a
    BITWISE-identical trajectory (tokens AND final cache) to the chained
    per-kernel path, end to end through ServeEngine's jitted decode loop."""
    cfg, _, params = lstm
    plan = lstm_policy(0.6, 0.4).compile(params)
    pruned, masks = plan.prune(params)
    packed, _ = plan.pack(pruned, masks)
    prompt = jax.random.randint(jax.random.key(3), (3, 6), 0, cfg.vocab_size)
    outs = {}
    for fused in (False, True):
        model = LSTMModel(cfg, fused=fused)
        assert model._use_fused is fused
        eng = ServeEngine(model, cfg, max_len=20, batch=3)
        outs[fused] = eng.generate(packed, prompt, 6, return_state=True)
    toks_c, state_c = outs[False]
    toks_f, state_f = outs[True]
    np.testing.assert_array_equal(np.asarray(toks_f), np.asarray(toks_c))
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        state_f["cache"], state_c["cache"])


def test_fused_decode_trajectory_parity_delta_quant(lstm):
    """Fused-vs-chained bitwise trajectory parity holds when the policy
    layers on temporal deltas and int8 weights (the full BRDS stack)."""
    from repro.quant import QuantConfig
    from repro.sparse import DeltaGateConfig
    cfg, _, params = lstm
    policy = lstm_policy(0.6, 0.4, delta=DeltaGateConfig(0.05, 0.05),
                         quant=QuantConfig("int8"))
    prompt = jax.random.randint(jax.random.key(4), (2, 5), 0, cfg.vocab_size)
    outs = {}
    for fused in (False, True):
        eng = ServeEngine(LSTMModel(cfg, fused=fused), cfg, max_len=16,
                          batch=2, sparsity=policy)
        prepared, _ = eng.prepare(params)
        outs[fused] = np.asarray(eng.generate(prepared, prompt, 4))
    np.testing.assert_array_equal(outs[True], outs[False])
