"""The closed accuracy loop (repro.launch.pipeline).

Fast tests cover the pieces (score == loss on the dense path, task
construction, CLI gate semantics, BENCH payload schema); the slow-marked
tests run the full train → prune → retrain → calibrate → pack → serve arc
(CI's quality-smoke job; tier-1 skips them via pytest.ini's
``-m "not slow"``), including sharded masked training over a forced
(2, 4) host mesh in a subprocess (jax locks the device count at first
init — same pattern as test_dist.py).
"""
import importlib.util
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.launch import pipeline as pl
from repro.models import LSTMModel

REPO = os.path.join(os.path.dirname(__file__), "..")


def _load_schema_checker():
    spec = importlib.util.spec_from_file_location(
        "check_bench_schema",
        os.path.join(REPO, "scripts", "check_bench_schema.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ------------------------------------------------------------ fast pieces

def test_build_task_all_corpora():
    for corpus_name, lm in (("char", True), ("zipf", True),
                            ("frame", False)):
        cfg = pl.PipelineConfig(corpus=corpus_name)
        corpus, lcfg = pl.build_task(cfg)
        assert bool(lcfg.vocab_size) == lm
        batches = corpus.eval_batches(2, 4, 8)
        assert len(batches) == 2
        b = pl._as_model_batch(batches[0])
        assert b["inputs"].shape[:2] == (4, 8)
    with pytest.raises(ValueError):
        pl.build_task(pl.PipelineConfig(corpus="imagenet"))


def test_score_matches_loss_on_dense_lm():
    """The serving-path scorer (model.score) computes the same NLL as the
    training loss on dense params — the quantity the pipeline gates on is
    the quantity training optimized."""
    cfg = pl.PipelineConfig()
    corpus, lcfg = pl.build_task(cfg)
    model = LSTMModel(lcfg)
    params = model.init(jax.random.key(0))
    batch = pl._as_model_batch(corpus.batch(7, 4, 12))
    nll_score = float(model.score(params, batch["inputs"], batch["labels"]))
    nll_loss = float(model.loss(params, batch))
    np.testing.assert_allclose(nll_score, nll_loss, rtol=1e-5)


def test_evaluate_perplexity_is_exp_nll():
    cfg = pl.PipelineConfig()
    corpus, lcfg = pl.build_task(cfg)
    model = LSTMModel(lcfg)
    params = model.init(jax.random.key(1))
    out = pl.evaluate(model, params, corpus.eval_batches(2, 4, 8))
    np.testing.assert_allclose(out["ppl"], np.exp(out["nll"]), rtol=1e-6)


def test_parse_grid():
    assert pl._parse_grid("0.75:0.5") == ((0.75, 0.5),)
    assert pl._parse_grid("0.75:0.5,0.875:0.625") == ((0.75, 0.5),
                                                      (0.875, 0.625))


def test_cli_gate_semantics(monkeypatch, tmp_path):
    """--gate fails the process (exit 1) when the primary point's ppl
    delta exceeds it, passes otherwise, and negative disables the gate."""
    fake = {"benchmark": "pipeline", "smoke": True, "wall_time_s": 0.1,
            "rows": [], "gate": {"spar_x": 0.75, "spar_h": 0.5,
                                 "ppl_dense": 1.2, "ppl_sparse": 1.32,
                                 "ppl_delta_pct": 10.0}}
    monkeypatch.setattr(pl, "run_pipeline", lambda cfg, smoke: fake)
    argv = ["--smoke", "--out", str(tmp_path)]
    assert pl.main(argv + ["--gate", "5"]) == 1
    assert pl.main(argv + ["--gate", "15"]) == 0
    assert pl.main(argv + ["--gate", "-1"]) == 0
    payload = json.loads((tmp_path / "BENCH_pipeline.json").read_text())
    assert payload["gate"]["ppl_delta_pct"] == 10.0


# --------------------------------------------------------- the full arc

@pytest.mark.slow
def test_accuracy_loop_end_to_end_char():
    """Full arc on the CharCorpus PTB stand-in at the primary dual-ratio
    point: the gate holds, serving parity is bitwise at every grid point,
    and the payload satisfies the pinned BENCH schema."""
    cfg = pl.PipelineConfig(spar_grid=((0.75, 0.5),))
    payload = pl.run_pipeline(cfg, smoke=True, log=lambda *_: None)
    gate = payload["gate"]
    # the smoke-scale analogue of the paper's <=1.4% PTB claim: CI's
    # quality-smoke threshold
    assert gate["ppl_delta_pct"] <= 5.0, gate
    rows = {r["name"]: r for r in payload["rows"]}
    parity = rows["pipeline_serve_parity"]
    assert parity["bitwise"] == 1 and parity["points"] == 4
    grid = [r for n, r in rows.items() if n.startswith("pipeline_sx")]
    assert len(grid) == 4  # {fp32, int8} x {theta 0, theta > 0}
    for r in grid:
        if r["scheme"] == "int8":   # q8 packs smaller than fp32
            assert r["weight_bytes"] < rows[
                "pipeline_sx0.75_sh0.5_fp32_t0.0"]["weight_bytes"]
    checker = _load_schema_checker()
    checker.check_pipeline("payload", payload)


@pytest.mark.slow
def test_accuracy_loop_frame_corpus():
    """The speech-claim stand-in (framewise classifier) closes the same
    loop — quality measured through the serving scorer, parity bitwise."""
    cfg = pl.PipelineConfig(corpus="frame", train_steps=120,
                            retrain_steps=80, spar_grid=((0.75, 0.5),))
    payload = pl.run_pipeline(cfg, smoke=True, log=lambda *_: None)
    rows = {r["name"]: r for r in payload["rows"]}
    assert rows["pipeline_serve_parity"]["bitwise"] == 1
    assert "acc" in rows["pipeline_dense"]


@pytest.mark.slow
def test_serving_parity_detects_quality_change():
    """The parity assertion actually fires: deploying at a DIFFERENT
    sparsity than the manual reference must raise PipelineError."""
    cfg = pl.PipelineConfig(train_steps=40)
    corpus, lcfg = pl.build_task(cfg)
    model = LSTMModel(lcfg)
    params, _ = pl.train_lstm(model, corpus, cfg, steps=40, lr=cfg.lr)
    eval_set = corpus.eval_batches(2, 8, 16)
    gen_raw = corpus.batch(1 << 42, 4, 16)
    orig = pl.prepare_manual
    def skewed(model_, policy, params_, calib=None):
        # the manual route deploys at a harsher Spar_x than the engine:
        # a genuinely different deployment, so evals must differ
        return orig(model_, pl._policy_at(cfg, 0.9, 0.5, None, 0.0),
                    params_, calib=calib)
    pl.prepare_manual, saved = skewed, pl.prepare_manual
    try:
        with pytest.raises(pl.PipelineError):
            pl.run_point(model, lcfg, params, cfg, 0.75, 0.5, None, 0.0,
                         eval_set, None, gen_raw)
    finally:
        pl.prepare_manual = saved


@pytest.mark.slow
def test_sharded_masked_training_2x4():
    """Sharded training OF a masked model — both phases through
    jit_train_step over a (data, model) mesh — ends in the same packed
    deployment invariants (bitwise parity, schema-complete payload)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    code = textwrap.dedent("""
        import jax
        from repro.launch import pipeline as pl
        assert len(jax.devices()) == 8
        cfg = pl.PipelineConfig(mesh=(2, 4), train_steps=60,
                                retrain_steps=40,
                                spar_grid=((0.75, 0.5),))
        payload = pl.run_pipeline(cfg, smoke=True, log=lambda *_: None)
        rows = {r["name"]: r for r in payload["rows"]}
        assert rows["pipeline_serve_parity"]["bitwise"] == 1
        assert rows["pipeline_serve_parity"]["points"] == 4
        print("SHARDED_OK", rows["pipeline_dense"]["ppl"])
    """)
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True, timeout=420,
                         env=env)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    assert "SHARDED_OK" in out.stdout
