"""The HLO roofline analyzer: loop-aware flop/collective accounting,
validated against a hand-computable compiled function."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import roofline


def test_shape_parsing():
    assert roofline.shape_bytes("bf16[16,4096]{1,0}") == 16 * 4096 * 2
    assert roofline.shape_bytes("f32[8]{0}") == 32
    assert roofline.shape_bytes("(f32[4,4]{1,0}, s32[2]{0})") == 64 + 8
    assert roofline.shape_elems("f32[3,5]{1,0}") == 15
    assert roofline.shape_bytes("pred[]") == 1


def test_scan_trip_count_multiplies_flops():
    """A scan of N matmuls must report ≈ N × the single-matmul flops —
    the exact failure mode of raw cost_analysis this module exists to fix."""
    N, M = 12, 128

    def one(x, w):
        return x @ w

    def scanned(x, ws):
        y, _ = jax.lax.scan(lambda c, w: (c @ w, None), x, ws)
        return y

    x = jnp.zeros((M, M), jnp.float32)
    w = jnp.zeros((M, M), jnp.float32)
    ws = jnp.zeros((N, M, M), jnp.float32)

    t1 = jax.jit(one).lower(x, w).compile().as_text()
    tN = jax.jit(scanned).lower(x, ws).compile().as_text()
    f1 = roofline.analyze_hlo(t1, 1).flops_hlo
    fN = roofline.analyze_hlo(tN, 1).flops_hlo
    assert f1 == pytest.approx(2 * M ** 3, rel=0.01)
    assert fN == pytest.approx(N * 2 * M ** 3, rel=0.05), (fN, N * f1)


def test_int8_dots_counted_at_int8_peak():
    """Quantized dots — s8 operands (TPU builds) or the s32-accumulator
    form XLA CPU normalizes them to — land in the int8 bucket and are
    costed at hw.PEAK_INT8_OPS, not the bf16 peak; float dots stay in the
    bf16 bucket. Keeps the quant benchmark's derived GOPS honest."""
    from repro import hw

    def program(dot_line):
        return "\n".join([
            "ENTRY %main (a: s8[64,128], b: s8[128,32]) -> f32[64,32] {",
            "  %a = s8[64,128]{1,0} parameter(0)",
            "  %b = s8[128,32]{1,0} parameter(1)",
            "  %e = s32[64,128]{1,0} convert(%a)",
            "  %f = s32[128,32]{1,0} convert(%b)",
            dot_line,
            "  %c = f32[64,128]{1,0} convert(%a)",
            "  %d = f32[128,32]{1,0} convert(%b)",
            "  ROOT %r = f32[64,32]{1,0} dot(f32[64,128]{1,0} %c, "
            "f32[128,32]{1,0} %d), lhs_contracting_dims={1}, "
            "rhs_contracting_dims={0}",
            "}",
        ])

    one_dot = 2 * 64 * 32 * 128
    for qdot in (
        # pre-optimization / TPU form: s8 operands into the MXU
        "  %q = s32[64,32]{1,0} dot(s8[64,128]{1,0} %a, s8[128,32]{1,0} "
        "%b), lhs_contracting_dims={1}, rhs_contracting_dims={0}",
        # XLA-CPU normalized form: convert→s32 dot (operand signal gone,
        # integer accumulator type remains)
        "  %q = s32[64,32]{1,0} dot(s32[64,128]{1,0} %e, s32[128,32]{1,0} "
        "%f), lhs_contracting_dims={1}, rhs_contracting_dims={0}",
    ):
        rep = roofline.analyze_hlo(program(qdot), 1)
        assert rep.flops_hlo == pytest.approx(2 * one_dot)
        assert rep.flops_int8 == pytest.approx(one_dot)
        t = rep.terms(hbm_bytes_per_chip=0, chips=1)
        expect = one_dot / hw.PEAK_BF16_FLOPS + one_dot / hw.PEAK_INT8_OPS
        assert t["compute_s"] == pytest.approx(expect)


def test_quantized_ref_decode_lands_in_int8_bucket():
    """End to end: the compiled q8 reference SpMV (the formulation the
    dry-run/roofline path analyzes) is classified as integer dot flops."""
    import numpy as np
    from repro.core import pack_from_dense
    from repro.quant import quantize_packed
    from repro.kernels import ops as K
    rng = np.random.default_rng(0)
    s = pack_from_dense(
        jnp.asarray(rng.normal(size=(128, 64)).astype(np.float32)), 0.75)
    q = quantize_packed(s, "int8")
    x = jnp.asarray(rng.normal(size=(3, 64)).astype(np.float32))
    hlo = jax.jit(lambda xx: K.rb_spmv_q8(q, xx, backend="ref")) \
        .lower(x).compile().as_text()
    rep = roofline.analyze_hlo(hlo, 1)
    assert rep.flops_int8 > 0
    assert rep.flops_int8 == pytest.approx(rep.flops_hlo)


def test_known_trip_regex():
    line = ('%while.345 = (s32[]) while(%t), condition=%c, body=%b, '
            'backend_config={"known_trip_count":{"n":"24"},"other":1}')
    m = roofline._KNOWN_TRIP.search(line)
    assert m and int(m.group(1)) == 24


def test_replica_group_parsing():
    assert roofline._group_size("replica_groups={{0,1,2,3}}", 8) == 4
    assert roofline._group_size("replica_groups=[16,16]<=[256]", 8) == 16
    assert roofline._group_size("no groups here", 8) == 8


def test_model_flops_sanity():
    """6ND for dense training; MoE counts active params only."""
    from repro.configs import get_arch, SHAPES
    arch = get_arch("llama3.2-3b")
    mf = roofline.model_flops(arch, SHAPES["train_4k"])
    # llama3.2-3b ≈ 3.6B params, 1.05M tokens → 6ND ≈ 2.3e16 ± attention
    assert 1.5e16 < mf["total"] < 4e16
    moe = get_arch("qwen3-moe-235b-a22b")
    mfm = roofline.model_flops(moe, SHAPES["train_4k"])
    assert mfm["n_active"] < 0.25 * mfm["n_params"]


def test_analytic_hbm_decode_dominated_by_weights_and_cache():
    from repro.configs import get_arch, SHAPES
    arch = get_arch("llama3.2-3b")
    hbm = roofline.analytic_hbm_bytes(arch, SHAPES["decode_32k"], 256)
    # 3B bf16 params ≈ 6.4e9 bytes; kv cache 128seq × 32k × 28L × 2 × 8 × 128
    assert hbm["global_total"] > 6e9
    assert hbm["weights"] == pytest.approx(6.4e9 / 256, rel=0.3)
