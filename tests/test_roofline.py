"""The HLO roofline analyzer: loop-aware flop/collective accounting,
validated against a hand-computable compiled function."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import roofline


def test_shape_parsing():
    assert roofline.shape_bytes("bf16[16,4096]{1,0}") == 16 * 4096 * 2
    assert roofline.shape_bytes("f32[8]{0}") == 32
    assert roofline.shape_bytes("(f32[4,4]{1,0}, s32[2]{0})") == 64 + 8
    assert roofline.shape_elems("f32[3,5]{1,0}") == 15
    assert roofline.shape_bytes("pred[]") == 1


def test_scan_trip_count_multiplies_flops():
    """A scan of N matmuls must report ≈ N × the single-matmul flops —
    the exact failure mode of raw cost_analysis this module exists to fix."""
    N, M = 12, 128

    def one(x, w):
        return x @ w

    def scanned(x, ws):
        y, _ = jax.lax.scan(lambda c, w: (c @ w, None), x, ws)
        return y

    x = jnp.zeros((M, M), jnp.float32)
    w = jnp.zeros((M, M), jnp.float32)
    ws = jnp.zeros((N, M, M), jnp.float32)

    t1 = jax.jit(one).lower(x, w).compile().as_text()
    tN = jax.jit(scanned).lower(x, ws).compile().as_text()
    f1 = roofline.analyze_hlo(t1, 1).flops_hlo
    fN = roofline.analyze_hlo(tN, 1).flops_hlo
    assert f1 == pytest.approx(2 * M ** 3, rel=0.01)
    assert fN == pytest.approx(N * 2 * M ** 3, rel=0.05), (fN, N * f1)


def test_known_trip_regex():
    line = ('%while.345 = (s32[]) while(%t), condition=%c, body=%b, '
            'backend_config={"known_trip_count":{"n":"24"},"other":1}')
    m = roofline._KNOWN_TRIP.search(line)
    assert m and int(m.group(1)) == 24


def test_replica_group_parsing():
    assert roofline._group_size("replica_groups={{0,1,2,3}}", 8) == 4
    assert roofline._group_size("replica_groups=[16,16]<=[256]", 8) == 16
    assert roofline._group_size("no groups here", 8) == 8


def test_model_flops_sanity():
    """6ND for dense training; MoE counts active params only."""
    from repro.configs import get_arch, SHAPES
    arch = get_arch("llama3.2-3b")
    mf = roofline.model_flops(arch, SHAPES["train_4k"])
    # llama3.2-3b ≈ 3.6B params, 1.05M tokens → 6ND ≈ 2.3e16 ± attention
    assert 1.5e16 < mf["total"] < 4e16
    moe = get_arch("qwen3-moe-235b-a22b")
    mfm = roofline.model_flops(moe, SHAPES["train_4k"])
    assert mfm["n_active"] < 0.25 * mfm["n_params"]


def test_analytic_hbm_decode_dominated_by_weights_and_cache():
    from repro.configs import get_arch, SHAPES
    arch = get_arch("llama3.2-3b")
    hbm = roofline.analytic_hbm_bytes(arch, SHAPES["decode_32k"], 256)
    # 3B bf16 params ≈ 6.4e9 bytes; kv cache 128seq × 32k × 28L × 2 × 8 × 128
    assert hbm["global_total"] > 6e9
    assert hbm["weights"] == pytest.approx(6.4e9 / 256, rel=0.3)
