"""Per-kernel allclose vs the pure-jnp oracles, swept over shapes/dtypes
(interpret mode on CPU per the assignment)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pack_from_dense
from repro.kernels import (rb_spmv, rb_dual_spmv, lstm_gates, flash_attention,
                           decode_attention)
from repro.kernels import ref


def _rand(rng, shape, dtype):
    x = rng.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("rows,cols,spar,B", [
    (128, 64, 0.5, 1), (256, 96, 0.75, 4), (512, 256, 0.875, 2),
    (96, 33, 0.3, 3),
])
def test_rb_spmv_matches_ref(rng, rows, cols, spar, B, dtype):
    w = _rand(rng, (rows, cols), jnp.float32)
    s = pack_from_dense(w, spar)
    s = type(s)(values=s.values.astype(dtype), deltas=s.deltas, ncols=s.ncols)
    x = _rand(rng, (B, cols), dtype)
    got = rb_spmv(s, x, block_rows=64)
    want = ref.rb_spmv_ref(s, x)
    tol = 1e-5 if dtype == jnp.float32 else 0.05
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("H,X,sx,sh", [
    (64, 48, 0.875, 0.5), (128, 200, 0.6, 0.8),
])
def test_rb_dual_spmv_matches_ref(rng, H, X, sx, sh):
    """The fused dual-ratio gate preactivation (paper's Large/Small MAs)."""
    wx = _rand(rng, (4 * H, X), jnp.float32)
    wh = _rand(rng, (4 * H, H), jnp.float32)
    sx_p = pack_from_dense(wx, sx)
    sh_p = pack_from_dense(wh, sh)
    x = _rand(rng, (2, X), jnp.float32)
    h = _rand(rng, (2, H), jnp.float32)
    b = _rand(rng, (4 * H,), jnp.float32)
    got = rb_dual_spmv(sx_p, x, sh_p, h, b, block_rows=64)
    want = ref.rb_dual_spmv_ref(sx_p, x, sh_p, h, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


@pytest.mark.parametrize("pwl", [False, True])
@pytest.mark.parametrize("B,H", [(2, 128), (4, 512), (1, 64)])
def test_lstm_gates_matches_ref(rng, B, H, pwl):
    zs = [_rand(rng, (B, H), jnp.float32) * 3 for _ in range(4)]
    c = _rand(rng, (B, H), jnp.float32)
    ck, hk = lstm_gates(*zs, c, pwl=pwl)
    cr, hr = ref.lstm_cell_ref(*zs, c, pwl=pwl)
    np.testing.assert_allclose(np.asarray(ck), np.asarray(cr), atol=1e-5)
    np.testing.assert_allclose(np.asarray(hk), np.asarray(hr), atol=1e-5)


def test_pwl_approximates_exact(rng):
    """The paper's 16-segment PWL activations track the exact ones."""
    x = jnp.linspace(-10, 10, 1001)
    assert float(jnp.abs(ref.pwl_sigmoid_ref(x)
                         - jax.nn.sigmoid(x)).max()) < 0.02
    assert float(jnp.abs(ref.pwl_tanh_ref(x) - jnp.tanh(x)).max()) < 0.1


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Hq,Hkv,S,D,win", [
    (1, 4, 4, 128, 64, None),
    (2, 8, 2, 256, 64, None),
    (1, 4, 1, 128, 32, 48),
    (2, 6, 2, 192, 64, None),   # non-pow2 seq
])
def test_flash_attention_matches_ref(rng, B, Hq, Hkv, S, D, win, dtype):
    q = _rand(rng, (B, Hq, S, D), dtype)
    k = _rand(rng, (B, Hkv, S, D), dtype)
    v = _rand(rng, (B, Hkv, S, D), dtype)
    got = flash_attention(q, k, v, causal=True, window=win, block_q=64,
                          block_kv=64)
    want = ref.mha_ref(q, k, v, causal=True, window=win)
    tol = 2e-5 if dtype == jnp.float32 else 0.05
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("B,Hq,Hkv,S,D", [
    (2, 8, 2, 256, 64), (1, 4, 4, 512, 128), (3, 6, 2, 128, 64),
])
def test_decode_attention_matches_ref(rng, B, Hq, Hkv, S, D):
    q = _rand(rng, (B, Hq, D), jnp.float32)
    k = _rand(rng, (B, Hkv, S, D), jnp.float32)
    v = _rand(rng, (B, Hkv, S, D), jnp.float32)
    lengths = jnp.asarray(np.random.default_rng(0).integers(1, S, B),
                          jnp.int32)
    got = decode_attention(q, k, v, lengths, block_kv=64)
    want = ref.decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


# -------------------------------------------------- fused single-launch step
# Parity bar (ISSUE 7): the fused kernels are BITWISE-identical to the
# chained-kernel decode trajectories — array_equal, not allclose. Odd
# H=40 exercises the block-padding path (R=160 → 192 at block_rows=64).

def _packed_pair(rng, H, X, sx, sh):
    wx = _rand(rng, (4 * H, X), jnp.float32)
    wh = _rand(rng, (4 * H, H), jnp.float32)
    return pack_from_dense(wx, sx), pack_from_dense(wh, sh)


def _gates_split(z, H, c, *, pwl):
    return lstm_gates(z[:, :H], z[:, H:2 * H], z[:, 2 * H:3 * H],
                      z[:, 3 * H:], c, pwl=pwl)


@pytest.mark.parametrize("pwl", [False, True])
@pytest.mark.parametrize("B,X,H", [(3, 24, 40), (2, 16, 64)])
def test_fused_step_bitwise_vs_chained(rng, pwl, B, X, H):
    from repro.kernels import rb_dual_spmv, fused_brds_lstm_step
    sx_p, sh_p = _packed_pair(rng, H, X, 0.75, 0.5)
    x = _rand(rng, (B, X), jnp.float32)
    h = _rand(rng, (B, H), jnp.float32)
    b = _rand(rng, (4 * H,), jnp.float32)
    c = _rand(rng, (B, H), jnp.float32)
    z = rb_dual_spmv(sx_p, x, sh_p, h, b, block_rows=64)
    cc, hc = _gates_split(z, H, c, pwl=pwl)
    cf, hf = fused_brds_lstm_step(sx_p, x, sh_p, h, b, c, pwl=pwl,
                                  block_rows=64)
    np.testing.assert_array_equal(np.asarray(cf), np.asarray(cc))
    np.testing.assert_array_equal(np.asarray(hf), np.asarray(hc))


@pytest.mark.parametrize("theta", [0.0, 0.1])
@pytest.mark.parametrize("pwl", [False, True])
def test_fused_delta_step_bitwise_vs_chained(rng, pwl, theta):
    from repro.kernels import delta_rb_dual_spmv, fused_brds_delta_lstm_step
    from repro.sparse.temporal import delta_threshold
    B, X, H = 3, 24, 40
    sx_p, sh_p = _packed_pair(rng, H, X, 0.75, 0.5)
    b = _rand(rng, (4 * H,), jnp.float32)
    c = _rand(rng, (B, H), jnp.float32)
    m0 = _rand(rng, (B, 4 * H), jnp.float32)
    dx, fx, _ = delta_threshold(_rand(rng, (B, X), jnp.float32),
                                jnp.zeros((B, X)), theta)
    dh, fh, _ = delta_threshold(_rand(rng, (B, H), jnp.float32),
                                jnp.zeros((B, H)), theta)
    fx, fh = fx.astype(jnp.float32), fh.astype(jnp.float32)
    mc = delta_rb_dual_spmv(sx_p, dx, fx, sh_p, dh, fh, m0, block_rows=64)
    zc = mc.astype(jnp.float32) + b.astype(jnp.float32)[None, :]
    cc, hc = _gates_split(zc, H, c, pwl=pwl)
    cf, hf, mf = fused_brds_delta_lstm_step(sx_p, dx, fx, sh_p, dh, fh, m0,
                                            b, c, pwl=pwl, block_rows=64)
    np.testing.assert_array_equal(np.asarray(mf), np.asarray(mc))
    np.testing.assert_array_equal(np.asarray(cf), np.asarray(cc))
    np.testing.assert_array_equal(np.asarray(hf), np.asarray(hc))


@pytest.mark.parametrize("pwl", [False, True])
def test_fused_q8_step_bitwise_vs_chained(rng, pwl):
    from repro.kernels import rb_dual_spmv_q8, fused_brds_lstm_step_q8
    from repro.quant import quantize_packed
    B, X, H = 3, 24, 40
    sx_p, sh_p = _packed_pair(rng, H, X, 0.75, 0.5)
    qsx, qsh = quantize_packed(sx_p, "int8"), quantize_packed(sh_p, "int8")
    x = _rand(rng, (B, X), jnp.float32)
    h = _rand(rng, (B, H), jnp.float32)
    b = _rand(rng, (4 * H,), jnp.float32)
    c = _rand(rng, (B, H), jnp.float32)
    ax, ah = 0.04, 0.03
    z = rb_dual_spmv_q8(qsx, x, qsh, h, b, act_scale_x=ax, act_scale_h=ah,
                        block_rows=64)
    cc, hc = _gates_split(z, H, c, pwl=pwl)
    cf, hf = fused_brds_lstm_step_q8(qsx, x, qsh, h, b, c, act_scale_x=ax,
                                     act_scale_h=ah, pwl=pwl, block_rows=64)
    np.testing.assert_array_equal(np.asarray(cf), np.asarray(cc))
    np.testing.assert_array_equal(np.asarray(hf), np.asarray(hc))


def test_fused_delta_q8_step_bitwise_vs_chained(rng):
    from repro.kernels import (delta_rb_dual_spmv_q8,
                               fused_brds_delta_lstm_step_q8)
    from repro.quant import quantize_packed
    from repro.sparse.temporal import delta_threshold
    B, X, H = 3, 24, 40
    sx_p, sh_p = _packed_pair(rng, H, X, 0.75, 0.5)
    qsx, qsh = quantize_packed(sx_p, "int8"), quantize_packed(sh_p, "int8")
    b = _rand(rng, (4 * H,), jnp.float32)
    c = _rand(rng, (B, H), jnp.float32)
    m0 = _rand(rng, (B, 4 * H), jnp.float32)
    dx, fx, _ = delta_threshold(_rand(rng, (B, X), jnp.float32),
                                jnp.zeros((B, X)), 0.1)
    dh, fh, _ = delta_threshold(_rand(rng, (B, H), jnp.float32),
                                jnp.zeros((B, H)), 0.1)
    ax, ah = 0.08, 0.06
    mc = delta_rb_dual_spmv_q8(qsx, dx, fx, qsh, dh, fh, m0, act_scale_x=ax,
                               act_scale_h=ah, block_rows=64)
    zc = mc + b.astype(jnp.float32)[None, :]
    cc, hc = _gates_split(zc, H, c, pwl=False)
    cf, hf, mf = fused_brds_delta_lstm_step_q8(
        qsx, dx, fx, qsh, dh, fh, m0, b, c, act_scale_x=ax, act_scale_h=ah,
        block_rows=64)
    np.testing.assert_array_equal(np.asarray(mf), np.asarray(mc))
    np.testing.assert_array_equal(np.asarray(cf), np.asarray(cc))
    np.testing.assert_array_equal(np.asarray(hf), np.asarray(hc))


@pytest.mark.parametrize("pwl", [False, True])
def test_fused_scan_bitwise_vs_repeated_step(rng, pwl):
    """T in-kernel steps == T separate fused launches, bitwise."""
    from repro.kernels import fused_brds_lstm_step, fused_brds_lstm_scan
    B, X, H, T = 3, 24, 40, 4
    sx_p, sh_p = _packed_pair(rng, H, X, 0.75, 0.5)
    b = _rand(rng, (4 * H,), jnp.float32)
    xs = _rand(rng, (T, B, X), jnp.float32)
    h = h0 = _rand(rng, (B, H), jnp.float32)
    c = c0 = _rand(rng, (B, H), jnp.float32)
    hs_steps = []
    for t in range(T):
        c, h = fused_brds_lstm_step(sx_p, xs[t], sh_p, h, b, c, pwl=pwl,
                                    block_rows=64)
        hs_steps.append(h)
    hs, cT = fused_brds_lstm_scan(sx_p, xs, sh_p, h0, b, c0, pwl=pwl,
                                  block_rows=64)
    np.testing.assert_array_equal(np.asarray(hs),
                                  np.asarray(jnp.stack(hs_steps)))
    np.testing.assert_array_equal(np.asarray(cT), np.asarray(c))


def test_fused_delta_scan_bitwise_vs_repeated_step(rng):
    """In-kernel thresholding + reference tracking + partial sums over T
    steps == the host-thresholded per-step launches, bitwise."""
    from repro.kernels import (fused_brds_delta_lstm_step,
                               fused_brds_delta_lstm_scan)
    from repro.sparse.temporal import delta_threshold
    B, X, H, T = 3, 24, 40, 4
    th_x, th_h = 0.1, 0.08
    sx_p, sh_p = _packed_pair(rng, H, X, 0.75, 0.5)
    b = _rand(rng, (4 * H,), jnp.float32)
    xs = _rand(rng, (T, B, X), jnp.float32)
    h = h0 = _rand(rng, (B, H), jnp.float32)
    c = c0 = _rand(rng, (B, H), jnp.float32)
    xr, hr = jnp.zeros((B, X)), jnp.zeros((B, H))
    m = m0 = jnp.zeros((B, 4 * H), jnp.float32)
    hs_steps = []
    for t in range(T):
        dx, fx, xr = delta_threshold(xs[t], xr, th_x)
        dh, fh, hr = delta_threshold(h, hr, th_h)
        c, h, m = fused_brds_delta_lstm_step(
            sx_p, dx, fx.astype(jnp.float32), sh_p, dh,
            fh.astype(jnp.float32), m, b, c, block_rows=64)
        hs_steps.append(h)
    hs, cT, xrT, hrT, mT = fused_brds_delta_lstm_scan(
        sx_p, xs, sh_p, h0, c0, jnp.zeros((B, X)), jnp.zeros((B, H)), m0,
        b, theta_x=th_x, theta_h=th_h, block_rows=64)
    np.testing.assert_array_equal(np.asarray(hs),
                                  np.asarray(jnp.stack(hs_steps)))
    np.testing.assert_array_equal(np.asarray(cT), np.asarray(c))
    np.testing.assert_array_equal(np.asarray(xrT), np.asarray(xr))
    np.testing.assert_array_equal(np.asarray(hrT), np.asarray(hr))
    np.testing.assert_array_equal(np.asarray(mT), np.asarray(m))


def test_fused_step_prepadded_struct_bitwise(rng):
    """pad_packed'd structs (the pack/prepare-time hoist) produce the same
    bits as the wrapper's internal padding of logical structs."""
    from repro.core.packing import pad_packed
    from repro.kernels import fused_brds_lstm_step
    B, X, H = 3, 24, 40
    sx_p, sh_p = _packed_pair(rng, H, X, 0.75, 0.5)
    x = _rand(rng, (B, X), jnp.float32)
    h = _rand(rng, (B, H), jnp.float32)
    b = _rand(rng, (4 * H,), jnp.float32)
    c = _rand(rng, (B, H), jnp.float32)
    ca, ha = fused_brds_lstm_step(sx_p, x, sh_p, h, b, c, block_rows=64)
    cb, hb = fused_brds_lstm_step(pad_packed(sx_p, 64), x,
                                  pad_packed(sh_p, 64), h, b, c,
                                  block_rows=64)
    assert pad_packed(sx_p, 64).pad == 32   # 160 rows → 192
    np.testing.assert_array_equal(np.asarray(ca), np.asarray(cb))
    np.testing.assert_array_equal(np.asarray(ha), np.asarray(hb))


@pytest.mark.parametrize("B,H", [(2, 100), (3, 40), (1, 200)])
def test_lstm_gates_odd_hidden_matches_ref(rng, B, H):
    """H not divisible by 64 pads to the nearest supported block and
    slices (no silent one-giant-block fallback)."""
    zs = [_rand(rng, (B, H), jnp.float32) * 3 for _ in range(4)]
    c = _rand(rng, (B, H), jnp.float32)
    ck, hk = lstm_gates(*zs, c, pwl=False)
    cr, hr = ref.lstm_cell_ref(*zs, c, pwl=False)
    assert ck.shape == (B, H) and hk.shape == (B, H)
    np.testing.assert_allclose(np.asarray(ck), np.asarray(cr), atol=1e-5)
    np.testing.assert_allclose(np.asarray(hk), np.asarray(hr), atol=1e-5)
