"""Per-kernel allclose vs the pure-jnp oracles, swept over shapes/dtypes
(interpret mode on CPU per the assignment)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pack_from_dense
from repro.kernels import (rb_spmv, rb_dual_spmv, lstm_gates, flash_attention,
                           decode_attention)
from repro.kernels import ref


def _rand(rng, shape, dtype):
    x = rng.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("rows,cols,spar,B", [
    (128, 64, 0.5, 1), (256, 96, 0.75, 4), (512, 256, 0.875, 2),
    (96, 33, 0.3, 3),
])
def test_rb_spmv_matches_ref(rng, rows, cols, spar, B, dtype):
    w = _rand(rng, (rows, cols), jnp.float32)
    s = pack_from_dense(w, spar)
    s = type(s)(values=s.values.astype(dtype), deltas=s.deltas, ncols=s.ncols)
    x = _rand(rng, (B, cols), dtype)
    got = rb_spmv(s, x, block_rows=64)
    want = ref.rb_spmv_ref(s, x)
    tol = 1e-5 if dtype == jnp.float32 else 0.05
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("H,X,sx,sh", [
    (64, 48, 0.875, 0.5), (128, 200, 0.6, 0.8),
])
def test_rb_dual_spmv_matches_ref(rng, H, X, sx, sh):
    """The fused dual-ratio gate preactivation (paper's Large/Small MAs)."""
    wx = _rand(rng, (4 * H, X), jnp.float32)
    wh = _rand(rng, (4 * H, H), jnp.float32)
    sx_p = pack_from_dense(wx, sx)
    sh_p = pack_from_dense(wh, sh)
    x = _rand(rng, (2, X), jnp.float32)
    h = _rand(rng, (2, H), jnp.float32)
    b = _rand(rng, (4 * H,), jnp.float32)
    got = rb_dual_spmv(sx_p, x, sh_p, h, b, block_rows=64)
    want = ref.rb_dual_spmv_ref(sx_p, x, sh_p, h, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


@pytest.mark.parametrize("pwl", [False, True])
@pytest.mark.parametrize("B,H", [(2, 128), (4, 512), (1, 64)])
def test_lstm_gates_matches_ref(rng, B, H, pwl):
    zs = [_rand(rng, (B, H), jnp.float32) * 3 for _ in range(4)]
    c = _rand(rng, (B, H), jnp.float32)
    ck, hk = lstm_gates(*zs, c, pwl=pwl)
    cr, hr = ref.lstm_cell_ref(*zs, c, pwl=pwl)
    np.testing.assert_allclose(np.asarray(ck), np.asarray(cr), atol=1e-5)
    np.testing.assert_allclose(np.asarray(hk), np.asarray(hr), atol=1e-5)


def test_pwl_approximates_exact(rng):
    """The paper's 16-segment PWL activations track the exact ones."""
    x = jnp.linspace(-10, 10, 1001)
    assert float(jnp.abs(ref.pwl_sigmoid_ref(x)
                         - jax.nn.sigmoid(x)).max()) < 0.02
    assert float(jnp.abs(ref.pwl_tanh_ref(x) - jnp.tanh(x)).max()) < 0.1


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Hq,Hkv,S,D,win", [
    (1, 4, 4, 128, 64, None),
    (2, 8, 2, 256, 64, None),
    (1, 4, 1, 128, 32, 48),
    (2, 6, 2, 192, 64, None),   # non-pow2 seq
])
def test_flash_attention_matches_ref(rng, B, Hq, Hkv, S, D, win, dtype):
    q = _rand(rng, (B, Hq, S, D), dtype)
    k = _rand(rng, (B, Hkv, S, D), dtype)
    v = _rand(rng, (B, Hkv, S, D), dtype)
    got = flash_attention(q, k, v, causal=True, window=win, block_q=64,
                          block_kv=64)
    want = ref.mha_ref(q, k, v, causal=True, window=win)
    tol = 2e-5 if dtype == jnp.float32 else 0.05
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("B,Hq,Hkv,S,D", [
    (2, 8, 2, 256, 64), (1, 4, 4, 512, 128), (3, 6, 2, 128, 64),
])
def test_decode_attention_matches_ref(rng, B, Hq, Hkv, S, D):
    q = _rand(rng, (B, Hq, D), jnp.float32)
    k = _rand(rng, (B, Hkv, S, D), jnp.float32)
    v = _rand(rng, (B, Hkv, S, D), jnp.float32)
    lengths = jnp.asarray(np.random.default_rng(0).integers(1, S, B),
                          jnp.int32)
    got = decode_attention(q, k, v, lengths, block_kv=64)
    want = ref.decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5,
                               rtol=2e-5)
