"""repro.obs: span tracer, on-device counters, metrics registry,
effective-GOPS scorecard, and the collective inventory.

The load-bearing invariants:

- DISABLED IS EXACT: with ``counters=False`` the scheduler jits the
  unmodified chunk functions and the disabled tracer hands back one
  shared no-op span — trajectories are bitwise those of the
  uninstrumented stack (and with counters ON they must not change
  either: the counter folds only read the chunk state).
- PARITY: harvested on-device counters equal the offline reductions the
  repo already trusts — fired-column gauges == the delta cache's
  ``nx``/``nh`` sums (``occupancy_report``'s input), spec counters ==
  ``spec_stats()``, scorecard executed MACs == ``occupancy_report``'s
  ``effective_macs`` on the same cache.
- ONE ALL-GATHER per layer per decode step on a sharded mesh
  (docs/architecture.md's repro.dist table), measured from compiled HLO.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import LSTMModel, LSTMConfig
from repro.obs import collectives as OC
from repro.obs import counters as C
from repro.obs import metrics as M
from repro.obs import scorecard as S
from repro.obs import trace as T
from repro.serving import (ContinuousBatchingEngine, SamplingConfig,
                           ServeEngine)
from repro.sparse import DeltaGateConfig, lstm_policy, occupancy_report
from repro.spec import DraftModel
from repro.traffic import RequestRecord, summarize

CFG = LSTMConfig("t", input_size=16, hidden=32, num_layers=2,
                 vocab_size=48)
GREEDY = SamplingConfig(eos_id=-1)


def _prep(theta):
    """Delta-gated packed LSTM serving variant (ref backend)."""
    model = LSTMModel(CFG)
    params = model.init(jax.random.key(0))
    pol = lstm_policy(0.5, 0.5, backend="ref",
                      delta=DeltaGateConfig(theta_x=theta, theta_h=theta))
    eng = ServeEngine(model, CFG, max_len=32, batch=3, sparsity=pol)
    packed, _ = eng.prepare(params)
    return eng, packed


def _submit_all(sched, lens, gen=8):
    for i, plen in enumerate(lens):
        prompt = jax.random.randint(jax.random.fold_in(jax.random.key(1), i),
                                    (1, plen), 0, CFG.vocab_size)
        sched.submit(prompt, gen)


# ----------------------------------------------------------------- tracer
def test_disabled_tracer_is_one_shared_null_span():
    T.disable()
    s1, s2 = T.span("a"), T.span("b", cat="x", k=3)
    assert s1 is s2                     # no per-call allocation
    with s1:
        pass
    assert T.get_tracer().events == []


def test_tracer_spans_nest_and_export_validates(tmp_path):
    T.enable()
    try:
        with T.span("outer", phase="p"):
            with T.span("inner"):
                pass
        T.instant("mark", note=1)

        @T.traced("decorated")
        def f(x):
            return x + 1

        assert f(1) == 2
    finally:
        T.disable()
    payload = T.get_tracer().export()
    assert T.validate(payload) == []
    names = [e["name"] for e in payload["traceEvents"]]
    assert set(names) == {"outer", "inner", "mark", "decorated"}
    evs = {e["name"]: e for e in payload["traceEvents"]}
    # inner nests inside outer: starts later, ends no later
    assert evs["inner"]["ts"] >= evs["outer"]["ts"]
    assert (evs["inner"]["ts"] + evs["inner"]["dur"]
            <= evs["outer"]["ts"] + evs["outer"]["dur"] + 1e-6)
    assert evs["outer"]["args"] == {"phase": "p"}
    # export is ts-sorted, survives a save/validate-file round trip + CLI
    ts = [e["ts"] for e in payload["traceEvents"]]
    assert ts == sorted(ts)
    path = tmp_path / "trace.json"
    T.get_tracer().save(str(path))
    assert T.validate_file(str(path)) == []
    assert T.main([str(path)]) == 0
    T.get_tracer().clear()


def test_trace_validator_catches_malformed(tmp_path):
    ev = dict(name="a", ph="X", ts=1.0, dur=1.0, pid=1, tid=1)
    assert T.validate([ev]) == []
    assert T.validate({"traceEvents": "nope"})
    assert T.validate([dict(ev, ph="Q")])            # unknown phase
    assert T.validate([dict(ev, dur=-2.0)])          # negative dur
    assert T.validate([{k: v for k, v in ev.items() if k != "ts"}])
    assert T.validate([dict(ev, ts=5.0), dict(ev, ts=1.0)])  # unsorted
    b = dict(name="a", ph="B", ts=1.0, pid=1, tid=1)
    e = dict(name="a", ph="E", ts=2.0, pid=1, tid=1)
    assert T.validate([b, e]) == []
    assert T.validate([b])                           # unclosed B
    assert T.validate([e])                           # E without B
    # CLI: empty trace and unreadable file both fail the gate
    empty = tmp_path / "empty.json"
    empty.write_text('{"traceEvents": []}')
    assert T.main([str(empty)]) != 0
    assert T.main([str(tmp_path / "missing.json")]) != 0


# ---------------------------------------------------------------- metrics
def test_metrics_registry_kinds_and_exports(tmp_path):
    reg = M.MetricsRegistry()
    reg.counter("req_total", "requests").inc()
    reg.counter("req_total").inc(2)
    with pytest.raises(ValueError):
        reg.counter("req_total").inc(-1)
    with pytest.raises(TypeError):
        reg.gauge("req_total")                       # kind clash
    reg.gauge("depth").set(2.5)
    h = reg.histogram("lat_ms", buckets=(1, 10))
    h.observe(0.5)
    h.observe(5.0)
    h.observe(50.0)
    h.observe(float("nan"))                          # dropped, not summed
    assert h.count == 3 and h.sum == 55.5
    text = reg.to_prometheus()
    assert "# TYPE req_total counter" in text and "req_total 3" in text
    assert 'lat_ms_bucket{le="+Inf"} 3' in text
    assert "nan" not in text.lower()
    js = reg.to_json()
    assert js["req_total"]["value"] == 3
    assert js["lat_ms"]["buckets"][-1] == {"le": "+Inf", "count": 3}
    # both dump formats land on disk; JSON is strict (allow_nan=False)
    reg.dump(str(tmp_path / "m.prom"))
    reg.dump(str(tmp_path / "m.json"))
    assert json.load(open(tmp_path / "m.json"))["depth"]["value"] == 2.5


def test_metrics_absorbers():
    recs = [RequestRecord(0, scheduled=0.0, first_token=0.5, finished=1.0,
                          tokens=6, reason="done"),
            RequestRecord(1, scheduled=0.0, tokens=0, reason="rejected")]
    summary = summarize(recs, wall=2.0, offered_rps=4.0)
    reg = M.MetricsRegistry()
    reg.absorb_traffic(recs, summary)
    reg.absorb_spec({"rounds": 3, "drafted": 9, "accepted": 6,
                     "acceptance_rate": 2 / 3})
    reg.absorb_counters({"tokens": 6.0, "fired_x_l0": 11.0})
    js = reg.to_json()
    assert js["serve_requests_done"]["value"] == 1
    assert js["serve_requests_rejected"]["value"] == 1
    assert js["serve_tokens_total"]["value"] == 6
    assert js["spec_accepted_total"]["value"] == 6
    assert js["dev_fired_x_l0"]["value"] == 11.0
    # absorb is total-function on empty/None inputs
    reg2 = M.MetricsRegistry()
    reg2.absorb_traffic([], summarize([], wall=0.0))
    reg2.absorb_spec(None)
    reg2.absorb_counters(None)
    json.dumps(reg2.to_json(), allow_nan=False)


# --------------------------------------------- traffic summary edge cases
def test_summarize_empty_and_one_token_have_no_nan():
    s = summarize([], wall=0.0)
    assert s["requests"] == 0 and s["toks_per_s"] == 0.0
    for key in ("p50_ttft_ms", "p90_ttft_ms", "p99_ttft_ms",
                "p50_tpot_ms", "p99_tpot_ms"):
        assert s[key] is None
    json.dumps(s, allow_nan=False)      # NaN would corrupt BENCH records
    # a 1-token completion has no inter-token gap: tpot is None, and a
    # batch of only such requests must not push NaN into the summary
    one = RequestRecord(0, scheduled=0.0, first_token=0.25, finished=0.25,
                        tokens=1, reason="done")
    assert one.tpot is None and one.ttft == 0.25
    s1 = summarize([one], wall=1.0)
    assert s1["p50_tpot_ms"] is None
    assert s1["p50_ttft_ms"] == pytest.approx(250.0)
    json.dumps(s1, allow_nan=False)


# ----------------------------------------------------- on-device counters
def test_counter_names_and_layout():
    model = LSTMModel(CFG)                              # no delta
    assert C.counter_names(model) == C.BASE_COUNTERS
    eng, _ = _prep(0.1)
    names = C.counter_names(eng.model)
    assert names[:len(C.BASE_COUNTERS)] == C.BASE_COUNTERS
    assert names[len(C.BASE_COUNTERS):] == ("fired_x_l0", "fired_h_l0",
                                            "fired_x_l1", "fired_h_l1")
    vec = C.zeros(names)
    assert vec.shape == (len(names),) and vec.dtype == jnp.float32
    d = C.harvest(names, vec)
    assert set(d) == set(names) and all(v == 0.0 for v in d.values())
    assert C.fired_totals(d) == ([0.0, 0.0], [0.0, 0.0])


@pytest.mark.parametrize("theta", [0.0, 0.1])
def test_scheduler_counters_match_occupancy_report(theta):
    """The tentpole parity: counters harvested at the scheduler's own
    syncs == the offline reductions on the drained cache, exactly."""
    eng, packed = _prep(theta)
    sched = ContinuousBatchingEngine(eng.model, packed, slots=3,
                                     max_len=32, sampling=GREEDY, chunk=4,
                                     counters=True)
    _submit_all(sched, [5, 7, 9], gen=8)
    results = sched.run()
    c = sched.counters()
    assert c is not None
    # fired gauges == the cache sums occupancy_report reads
    for i, lp in enumerate(sched.cache["layers"]):
        assert c[f"fired_x_l{i}"] == float(np.asarray(jnp.sum(lp["nx"])))
        assert c[f"fired_h_l{i}"] == float(np.asarray(jnp.sum(lp["nh"])))
    # emitted-token and step counters match the scheduler's own books
    assert c["tokens"] == sum(len(v) for v in results.values())
    assert c["decode_steps"] == sched.steps_dispatched * sched.chunk
    # scorecard's fired-weighted MACs == occupancy_report, same cache
    occ = occupancy_report(sched.cache, steps=sched.slot_steps,
                           packed=packed)
    card = S.build(packed, c, 1.0, batch=3,
                   step_sum=float(np.sum(sched.slot_steps)))
    assert card["executed_macs"] == pytest.approx(occ["effective_macs"])
    assert card["occupancy_x"] == pytest.approx(occ["occupancy_x"],
                                                abs=1e-4)
    assert card["occupancy_h"] == pytest.approx(occ["occupancy_h"],
                                                abs=1e-4)
    # (Θ=0 makes the TRAJECTORY exact, not occupancy 1.0 — exact-zero
    # deltas, e.g. repeated tokens, legitimately never fire)


def test_counters_do_not_change_tokens():
    """Instrumented and uninstrumented schedulers serve identical tokens
    (counters only read the chunk state; disabled jits the original
    chunk fn, so golden trajectories stay bitwise untouched)."""
    eng, packed = _prep(0.1)
    outs = []
    for flag in (False, True):
        sched = ContinuousBatchingEngine(eng.model, packed, slots=3,
                                         max_len=32, sampling=GREEDY,
                                         chunk=4, counters=flag)
        _submit_all(sched, [5, 7, 9], gen=8)
        outs.append(sched.run())
    assert outs[0].keys() == outs[1].keys()
    for uid in outs[0]:
        assert np.array_equal(np.asarray(outs[0][uid]),
                              np.asarray(outs[1][uid]))
    # and the uninstrumented scheduler reports no counters
    assert ContinuousBatchingEngine(
        eng.model, packed, slots=2, max_len=32).counters() is None


def test_spec_counters_match_spec_stats():
    model = LSTMModel(CFG)
    params = model.init(jax.random.key(0))
    draft = DraftModel(model, params)   # the target drafts for itself
    sched = ContinuousBatchingEngine(model, params, slots=2, max_len=32,
                                     sampling=GREEDY, chunk=4,
                                     draft=draft, spec_k=3, counters=True)
    _submit_all(sched, [5, 8], gen=8)
    results = sched.run()
    st = sched.spec_stats()
    c = sched.counters()
    assert st["drafted"] > 0
    assert c["spec_rounds"] == st["rounds"]
    assert c["spec_drafted"] == st["drafted"]
    assert c["spec_accepted"] == st["accepted"]
    assert c["tokens"] == sum(len(v) for v in results.values())


def test_lockstep_from_state_matches_occupancy_report():
    eng, packed = _prep(0.1)
    prompt = jax.random.randint(jax.random.key(2), (3, 6), 0,
                                CFG.vocab_size)
    out, st = eng.generate(packed, prompt, 8, sampling=GREEDY,
                           rng=jax.random.key(3), return_state=True)
    c = C.from_state(eng.model, st, steps=8)
    assert c["tokens"] == float(np.sum(np.asarray(st["emitted"]))) == 24.0
    for i, lp in enumerate(st["cache"]["layers"]):
        assert c[f"fired_x_l{i}"] == float(np.asarray(jnp.sum(lp["nx"])))
        assert c[f"fired_h_l{i}"] == float(np.asarray(jnp.sum(lp["nh"])))
    occ = occupancy_report(st["cache"], steps=6 + 8, packed=packed)
    card = S.build(packed, c, 1.0, batch=3, step_sum=3.0 * (6 + 8))
    assert card["executed_macs"] == pytest.approx(occ["effective_macs"])
    assert card["occupancy_x"] == pytest.approx(occ["occupancy_x"],
                                                abs=1e-4)


# -------------------------------------------------------------- scorecard
def test_scorecard_geometry_and_bounds_dense():
    from repro import hw
    model = LSTMModel(CFG)
    params = model.init(jax.random.key(0))
    geo = S.layer_geometry(params)
    assert len(geo) == CFG.num_layers
    assert geo[0]["ncols_x"] == CFG.input_size
    assert geo[1]["ncols_x"] == CFG.hidden          # stacked layers
    dense = sum(g["dense_macs"] for g in geo)
    assert dense == sum(g["packed_macs"] for g in geo)  # dense: K = ncols
    nbytes = S.weight_stream_bytes(params)
    assert nbytes == sum(params["layers"][i][k].nbytes
                         for i in range(CFG.num_layers)
                         for k in ("w_x", "w_h"))
    card = S.build(params, {"tokens": 100.0, "decode_steps": 100.0},
                   wall_s=2.0, batch=4)
    assert card["toks_per_s"] == 50.0
    assert card["executed_macs"] == 100.0 * dense   # no fired gauges
    assert card["effective_gops"] == pytest.approx(
        2.0 * dense * 50.0 / 1e9, abs=1e-6)       # card rounds to 6 dp
    assert card["bound_toks_per_s"] == pytest.approx(
        4 * hw.HBM_BW / nbytes, rel=1e-3)
    assert "occupancy_x" not in card                # needs step_sum
    text = S.render(card)
    assert "effective GOPS" in text and "roofline bound" in text


def test_scorecard_packed_counts_packed_bytes():
    eng, packed = _prep(0.0)
    geo = S.layer_geometry(packed)
    assert all(g["k_x"] < g["ncols_x"] for g in geo)    # actually pruned
    nbytes = S.weight_stream_bytes(packed)
    expect = sum(int(packed["layers"][i][k].memory_bytes()["total"])
                 for i in range(CFG.num_layers) for k in ("w_x", "w_h"))
    assert nbytes == expect


# ------------------------------------------------------------ collectives
def test_collective_inventory_summarize():
    items = [{"kind": "all-gather", "mult": 2, "bytes": 64,
              "wire_bytes": 128, "where": "a"},
             {"kind": "all-gather", "mult": 1, "bytes": 32,
              "wire_bytes": 32, "where": "b"},
             {"kind": "all-reduce", "mult": 1, "bytes": 8,
              "wire_bytes": 8, "where": "c"}]
    s = OC.summarize_inventory(items)
    assert s == {"counts": {"all-gather": 3, "all-reduce": 1},
                 "wire_bytes": 168}
    with pytest.raises(ValueError):
        OC.inventory_from_text("no entry computation here")


REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = REPO_SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


def test_decode_step_has_one_allgather_per_layer():
    """docs/architecture.md's repro.dist table, measured: a sharded
    decode step's compiled HLO contains exactly ``num_layers``
    all-gathers (of h over the model axis) and no other collective."""
    out = _run("""
    import jax, jax.numpy as jnp
    from repro.models import LSTMModel, LSTMConfig
    from repro.serving import ServeEngine
    from repro.sparse import lstm_policy
    from repro.launch.mesh import make_host_mesh
    from repro.obs import collectives as OC

    cfg = LSTMConfig('t', input_size=16, hidden=64, num_layers=2,
                     vocab_size=50)
    model = LSTMModel(cfg)
    params = model.init(jax.random.key(0))
    mesh = make_host_mesh(1, 8)
    eng = ServeEngine(model, cfg, max_len=20, batch=4,
                      sparsity=lstm_policy(0.75, 0.5, backend='ref'),
                      mesh=mesh)
    p, _ = eng.prepare(params)
    assert eng._dist, 'engine did not take the repro.dist path'
    prompt = jax.random.randint(jax.random.key(1), (4, 7), 0,
                                cfg.vocab_size)
    logits, cache = eng.model.prefill(p, prompt, 20)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    pos = jnp.full((4,), 7, jnp.int32)
    items = OC.decode_step_inventory(eng.model, p, cache, tok, pos)
    s = OC.summarize_inventory(items)
    print('COUNTS', s['counts'])
    assert s['counts'] == {'all-gather': cfg.num_layers}, s
    """)
    assert "COUNTS" in out
