"""Sharded packed-sparse decode (repro.dist) parity on forced host meshes.

The paper's row-balance invariant lifted to devices: packed gate rows
shard perfectly evenly over the mesh's ``model`` axis, each shard closes
the LSTM cell for its hidden slice locally, and the only per-step
collective is the h all-gather. These tests assert the sharded decode is
*the same computation*: per data-replica group, trajectories are BITWISE
the single-device ``backend="ref"`` trajectories of that group's
sub-batch (at Θ=0 and for the calibrated q8 path; Θ>0 fired sets derive
from replicated thresholding, so they agree too).

jax locks the device count at first init, so each scenario runs in a
subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8 (the
same pattern as test_distributed.py).
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_PRELUDE = """
import jax, jax.numpy as jnp, numpy as np
from repro.models import LSTMModel, LSTMConfig
from repro.serving import ServeEngine, ContinuousBatchingEngine
from repro.sparse import (DeltaGateConfig, QuantConfig, lstm_policy,
                          use_backend)
from repro.launch.mesh import make_host_mesh

CFG = LSTMConfig('t', input_size=16, hidden=64, num_layers=2, vocab_size=50)
MODEL = LSTMModel(CFG)
PARAMS = MODEL.init(jax.random.key(0))
B = 4
PROMPT = jax.random.randint(jax.random.key(1), (B, 7), 0, CFG.vocab_size)
CALIB = jax.random.randint(jax.random.key(3), (2, 6), 0, CFG.vocab_size)

def serve(policy, mesh, batch, prompt, calib=None):
    eng = ServeEngine(MODEL, CFG, max_len=20, batch=batch, sparsity=policy,
                      mesh=mesh)
    p, _ = eng.prepare(PARAMS, calib=calib)
    if mesh is not None:
        assert eng._dist, 'engine did not take the repro.dist path'
    toks, st = eng.generate(p, prompt, 6, return_state=True)
    return np.asarray(toks), np.asarray(st['logits'])

def group_ref(policy_fn, d, calib=None):
    # single-device reference per data-replica group: DP means each group
    # decodes its sub-batch exactly as one device would decode it alone
    g = B // d
    toks, logits = [], []
    for r in range(d):
        t, l = serve(policy_fn(), None, g, PROMPT[r * g:(r + 1) * g],
                     calib=calib)
        toks.append(t)
        logits.append(l)
    return np.concatenate(toks), np.concatenate(logits)
"""


def _run(code: str, devices: int = 8, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = REPO_SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


def test_sharded_kernel_parity():
    """shard_map kernel wrappers == the unsharded ops, bitwise on a
    model-only mesh (every output row is computed by exactly one shard
    with unchanged per-row arithmetic); partition validation errors."""
    _run(_PRELUDE + """
    from repro import dist
    from repro.core.packing import pack_from_dense
    from repro.kernels import ops as K
    from repro.quant import quantize_packed

    mesh = make_host_mesh(1, 8)
    sx = pack_from_dense(jax.random.normal(jax.random.key(0), (256, 48)), .75)
    sh = pack_from_dense(jax.random.normal(jax.random.key(1), (256, 64)), .5)
    x = jax.random.normal(jax.random.key(2), (4, 48))
    h = jax.random.normal(jax.random.key(3), (4, 64))
    b = jax.random.normal(jax.random.key(4), (256,))
    m = jax.random.normal(jax.random.key(5), (4, 256))
    fx, fh = jnp.abs(x) > 0.5, jnp.abs(h) > 0.5

    ref = K.rb_dual_spmv(sx, x, sh, h, b, backend='ref')
    out = dist.sharded_rb_dual_spmv(mesh, sx, x, sh, h, b, backend='ref')
    assert np.array_equal(np.asarray(ref), np.asarray(out))

    ref = K.delta_rb_dual_spmv(sx, x, fx, sh, h, fh, m, backend='ref')
    out = dist.sharded_delta_rb_dual_spmv(mesh, sx, x, fx, sh, h, fh, m,
                                          backend='ref')
    assert np.array_equal(np.asarray(ref), np.asarray(out))

    qx, qh = quantize_packed(sx, 'int8'), quantize_packed(sh, 'int8')
    ref = K.rb_dual_spmv_q8(qx, x, qh, h, b, backend='ref')
    out = dist.sharded_rb_dual_spmv_q8(mesh, qx, x, qh, h, b, backend='ref')
    assert np.array_equal(np.asarray(ref), np.asarray(out))

    # the Pallas kernels run inside the shard_map region too
    ref = K.rb_dual_spmv(sx, x, sh, h, b, backend='pallas')
    out = dist.sharded_rb_dual_spmv(mesh, sx, x, sh, h, b, backend='pallas')
    assert np.allclose(np.asarray(ref), np.asarray(out), atol=1e-5)

    # gate-aligned permutation: shard j's block is [f_j; i_j; g_j; o_j]
    perm = dist.gate_row_permutation(64, 8)
    assert sorted(perm.tolist()) == list(range(256))
    assert perm[:8].tolist() == list(range(8))            # f_0
    assert perm[8:16].tolist() == list(range(64, 72))     # i_0

    # validation: non-divisible hidden / rows are rejected
    try:
        dist.gate_row_permutation(30, 4)
        assert False, 'expected ValueError'
    except ValueError:
        pass
    plan = lstm_policy(0.5, 0.5).compile(PARAMS)
    packed, _ = plan.pack(*plan.prune(PARAMS))
    try:
        dist.partition_lstm_params({'layers': [{'w_x': 1}]}, mesh)
        assert False, 'expected ValueError'
    except ValueError:
        pass
    # partitioned tree keeps structure; leaves land sharded
    pp = dist.partition_lstm_params(packed, mesh)
    assert jax.tree.structure(pp) == jax.tree.structure(packed)
    spec = pp['layers'][0]['w_x'].values.sharding.spec
    assert spec[0] == 'model', spec
    # packed-but-UNPARTITIONED params are rejected before they decode
    # garbage through the sharded step (the permutation is invisible in
    # the tree structure — the row sharding is the witness)
    dist.check_partitioned(pp, mesh)
    try:
        ContinuousBatchingEngine(MODEL, packed, slots=2, max_len=16,
                                 mesh=mesh)
        assert False, 'expected ValueError'
    except ValueError:
        pass
    eng = ServeEngine(MODEL.with_mesh(mesh), CFG, max_len=16, batch=2)
    try:
        eng.generate(packed, PROMPT[:2, :4], 2)
        assert False, 'expected ValueError'
    except ValueError:
        pass
    print('kernel parity ok')
    """)


@pytest.mark.parametrize("d,m", [(1, 8), (2, 4), (4, 2)])
def test_sharded_decode_trajectory_parity(d, m):
    """Packed, delta (Θ=0 / Θ>0 / capped), and calibrated q8 sharded
    decode == single-device ref trajectories per replica group, bitwise
    at Θ=0 (and everywhere thresholding is deterministic)."""
    _run(_PRELUDE + f"""
    D, M = {d}, {m}
    mesh = make_host_mesh(D, M)
    cases = {{
        'packed': (lambda: lstm_policy(0.75, 0.5), None),
        'delta0': (lambda: lstm_policy(
            0.75, 0.5, delta=DeltaGateConfig()), None),
        'delta+': (lambda: lstm_policy(
            0.75, 0.5, delta=DeltaGateConfig(0.05, 0.02)), None),
        'delta_cap': (lambda: lstm_policy(
            0.75, 0.5, delta=DeltaGateConfig(0.05, 0.05, cap_x=0.5,
                                             cap_h=0.5)), None),
        'q8': (lambda: lstm_policy(
            0.75, 0.5, quant=QuantConfig('int8')), CALIB),
        'delta_q8': (lambda: lstm_policy(
            0.75, 0.5, delta=DeltaGateConfig(),
            quant=QuantConfig('int8')), CALIB),
    }}
    with use_backend('ref'):
        for name, (polf, calib) in cases.items():
            toks_sh, logits_sh = serve(polf(), mesh, B, PROMPT, calib=calib)
            toks_ref, logits_ref = group_ref(polf, D, calib=calib)
            assert np.array_equal(toks_ref, toks_sh), (name, D, M)
            assert np.array_equal(logits_ref, logits_sh), \\
                (name, D, M, np.abs(logits_ref - logits_sh).max())
            print(name, 'bitwise ok')
    """)


def test_sharded_continuous_batching_parity():
    """The scheduler's mesh path (data-parallel slot batch around
    model-parallel shards) reproduces per-request single-device decode."""
    _run(_PRELUDE + """
    pol = lambda: lstm_policy(0.75, 0.5)
    with use_backend('ref'):
        mesh = make_host_mesh(2, 4)
        eng = ServeEngine(MODEL, CFG, max_len=24, batch=2, sparsity=pol(),
                          mesh=mesh)
        packed, _ = eng.prepare(PARAMS)
        # eng.model carries the mesh; mesh= is exercised for the
        # build-it-yourself path
        sched = ContinuousBatchingEngine(eng.model, packed, slots=2,
                                         max_len=24, chunk=4, mesh=mesh)
        ref_eng = ServeEngine(MODEL, CFG, max_len=24, batch=1,
                              sparsity=pol())
        ref_packed, _ = ref_eng.prepare(PARAMS)
        prompts, budgets = {}, {}
        for i, (plen, gen) in enumerate([(5, 6), (9, 3), (3, 7), (7, 5)]):
            p = jax.random.randint(jax.random.key(10 + i), (1, plen), 0,
                                   CFG.vocab_size)
            uid = sched.submit(p, gen)
            prompts[uid], budgets[uid] = p, gen
        results = sched.run()
        assert sched.pending == 0 and not sched.active_slots
        for uid, p in prompts.items():
            want = np.asarray(ref_eng.generate(ref_packed, p,
                                               budgets[uid]))[0]
            np.testing.assert_array_equal(results[uid], want)
    print('sharded continuous batching ok')
    """)
