"""The unified sparsity API: format-registry round-trips, policy→plan
equivalence with the legacy surfaces, and pallas↔ref backend parity."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sparsity as S
from repro.core import packing as P
from repro.kernels import ops as K
from repro.kernels import ref
from repro.sparse import (SparsityPolicy, available_formats, brds_search,
                          get_format, lstm_policy, transformer_policy,
                          use_backend, dual_matvec)


def _rand(shape, seed=0):
    return jnp.asarray(np.random.default_rng(seed).normal(size=shape),
                       jnp.float32)


# ------------------------------------------------------------- registry

def test_registry_lists_the_four_formats():
    assert {"row_balanced", "bank_balanced", "block",
            "unstructured"} <= set(available_formats())
    with pytest.raises(KeyError):
        get_format("no_such_format")


@pytest.mark.parametrize("name,opts", [
    ("row_balanced", {}),
    ("bank_balanced", {"num_banks": 4}),
    ("block", {"block": (4, 4)}),
    ("unstructured", {}),
])
@pytest.mark.parametrize("spar", [0.25, 0.75])
def test_format_roundtrip_prune_pack_unpack(name, opts, spar):
    """For every registered format: unpack(pack(w, mask)) == masked dense."""
    fmt = get_format(name)
    w = _rand((16, 32), seed=3)
    m = fmt.mask(w, spar, **opts)
    dense = S.apply_mask(w, m)
    packed = fmt.pack(w, m)
    np.testing.assert_allclose(np.asarray(fmt.unpack(packed)),
                               np.asarray(dense))
    # matvec agrees with the dense product of the masked matrix
    x = _rand((2, 32), seed=4)
    got = fmt.matvec(packed, x, backend="ref" if name == "row_balanced"
                     else None)
    want = x @ dense.T
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


@pytest.mark.parametrize("name,opts", [
    ("row_balanced", {}),
    ("bank_balanced", {"num_banks": 4}),
    ("block", {"block": (4, 4)}),
    ("unstructured", {}),
])
def test_format_memory_accounting(name, opts):
    """Packed bytes beat dense at high sparsity; the analytic model tracks
    the concrete accounting."""
    fmt = get_format(name)
    w = _rand((32, 64), seed=5)
    m = fmt.mask(w, 0.75, **opts)
    mem = fmt.memory_bytes(fmt.pack(w, m))
    assert mem["total"] < mem["dense_equiv"]
    analytic = fmt.packed_bytes(32, 64, 0.75, jnp.float32, **opts)
    assert analytic == pytest.approx(mem["total"], rel=0.35)


def test_bank_balanced_wide_bank_index_width():
    """Banks wider than 256 need 2-byte in-bank indices — analytic and
    concrete accounting must agree on that."""
    fmt = get_format("bank_balanced")
    w = _rand((4, 2048), seed=6)
    m = fmt.mask(w, 0.5, num_banks=4)
    mem = fmt.memory_bytes(fmt.pack(w, m), num_banks=4)
    assert mem["total"] == fmt.packed_bytes(4, 2048, 0.5, jnp.float32,
                                            num_banks=4)


# ------------------------------------------------------- policy ↔ legacy

def test_lstm_plan_matches_legacy_prune_and_pack():
    """The compiled plan reproduces the old LSTMModel.prune/pack outputs
    exactly (same masks, same packed values/deltas)."""
    from repro.models import LSTMModel, LSTMConfig
    cfg = LSTMConfig("t", input_size=24, hidden=32, num_layers=2,
                     num_classes=8, framewise=True)
    model = LSTMModel(cfg)
    params = model.init(jax.random.key(0))
    sx, sh = 0.7, 0.4

    plan = lstm_policy(sx, sh).compile(params)
    pruned, masks = plan.prune(params)

    for i, lp in enumerate(params["layers"]):
        # legacy implementation: row_balanced_mask directly on each weight
        mx = S.row_balanced_mask(lp["w_x"], sx)
        mh = S.row_balanced_mask(lp["w_h"], sh)
        np.testing.assert_array_equal(np.asarray(masks[f"layers/{i}/w_x"]),
                                      np.asarray(mx))
        np.testing.assert_array_equal(np.asarray(masks[f"layers/{i}/w_h"]),
                                      np.asarray(mh))
        np.testing.assert_allclose(
            np.asarray(pruned["layers"][i]["w_x"]),
            np.asarray(S.apply_mask(lp["w_x"], mx)))

    packed_tree, _ = plan.pack(pruned, masks=masks)
    legacy = model.pack(pruned)
    for i in range(cfg.num_layers):
        new_sx = packed_tree["layers"][i]["w_x"]
        np.testing.assert_allclose(np.asarray(new_sx.values),
                                   np.asarray(legacy[i]["sx"].values))
        np.testing.assert_array_equal(np.asarray(new_sx.deltas),
                                      np.asarray(legacy[i]["sx"].deltas))


def test_transformer_plan_matches_legacy_brds_masks():
    """transformer_policy reproduces training.brds_masks (the shim now
    delegates, so assert the row-balance invariant independently too)."""
    from repro.configs import smoke_config
    from repro.models import build_model
    cfg = smoke_config("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.key(1))

    plan = transformer_policy(0.875, 0.5).compile(params)
    masks = plan.masks(params)
    assert masks, "policy matched no transformer weights"
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        from repro.training import brds_masks
        legacy = brds_masks(params, 0.875, 0.5)
    assert set(masks) == set(legacy)
    for ps in masks:
        np.testing.assert_array_equal(np.asarray(masks[ps]),
                                      np.asarray(legacy[ps]))

    # row-balance invariant: equal keep-count along every output's fan-in
    for ps, site in plan.sites.items():
        m_oi = np.asarray(site.to_oi(masks[ps]))      # (L1, out, in)
        counts = m_oi.sum(axis=-1)
        assert (counts == counts.flat[0]).all(), ps


def test_plan_pack_abstract_matches_concrete():
    from repro.models import LSTMModel, LSTMConfig
    cfg = LSTMConfig("t", input_size=16, hidden=16, num_layers=1,
                     num_classes=4)
    model = LSTMModel(cfg)
    params = model.init(jax.random.key(0))
    plan = lstm_policy(0.5, 0.5).compile(params)
    concrete, rep_c = plan.pack(params)
    abstract, rep_a = plan.pack(params, abstract=True)
    c = concrete["layers"][0]["w_x"]
    a = abstract["layers"][0]["w_x"]
    assert a.values.shape == c.values.shape
    assert a.deltas.dtype == c.deltas.dtype
    assert a.ncols == c.ncols
    assert rep_a == rep_c


# ------------------------------------------------------- backend parity

@pytest.mark.parametrize("rows,cols,spar,B", [(128, 64, 0.5, 2),
                                              (96, 33, 0.75, 3)])
def test_rb_spmv_backend_parity(rows, cols, spar, B):
    s = P.pack_from_dense(_rand((rows, cols), seed=7), spar)
    x = _rand((B, cols), seed=8)
    got_k = K.rb_spmv(s, x, block_rows=64, backend="pallas")
    got_r = K.rb_spmv(s, x, backend="ref")
    np.testing.assert_allclose(np.asarray(got_k), np.asarray(got_r),
                               atol=1e-5, rtol=1e-5)


def test_rb_dual_spmv_backend_parity():
    H, X = 64, 48
    sx = P.pack_from_dense(_rand((4 * H, X), seed=9), 0.875)
    sh = P.pack_from_dense(_rand((4 * H, H), seed=10), 0.5)
    x, h, b = _rand((2, X), 11), _rand((2, H), 12), _rand((4 * H,), 13)
    got_k = K.rb_dual_spmv(sx, x, sh, h, b, block_rows=64, backend="pallas")
    got_r = K.rb_dual_spmv(sx, x, sh, h, b, backend="ref")
    np.testing.assert_allclose(np.asarray(got_k), np.asarray(got_r),
                               atol=2e-5, rtol=2e-5)


def test_default_backend_context():
    s = P.pack_from_dense(_rand((32, 16), seed=14), 0.5)
    x = _rand((1, 16), seed=15)
    want = ref.rb_spmv_ref(s, x)
    with use_backend("ref"):
        got = K.rb_spmv(s, x)       # no per-call flag: default applies
        # "auto" defers to the default too, so policies left at
        # backend="auto" follow set_default_backend/use_backend
        got_auto = K.rb_spmv(s, x, backend="auto")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))
    np.testing.assert_allclose(np.asarray(got_auto), np.asarray(want))


def test_use_kernel_is_deprecated_but_works():
    s = P.pack_from_dense(_rand((32, 16), seed=16), 0.5)
    x = _rand((1, 16), seed=17)
    with pytest.warns(DeprecationWarning):
        got = K.rb_spmv(s, x, use_kernel=False)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.rb_spmv_ref(s, x)))


def test_mixed_format_dual_matvec():
    fa, fb = get_format("row_balanced"), get_format("unstructured")
    wx, wh = _rand((32, 16), 18), _rand((32, 8), 19)
    ma = fa.mask(wx, 0.5)
    mb = fb.mask(wh, 0.5)
    pa, pb = fa.pack(wx, ma), fb.pack(wh, mb)
    x, h = _rand((2, 16), 20), _rand((2, 8), 21)
    bias = _rand((32,), 22)
    got = dual_matvec(fa, pa, x, fb, pb, h, bias, backend="ref")
    want = (x @ S.apply_mask(wx, ma).T + h @ S.apply_mask(wh, mb).T
            + bias[None, :])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


# ----------------------------------------------------------- the search

def test_policy_search_end_to_end():
    """brds_search walks SparsityPolicy objects and returns the best tuple
    with its policy."""
    from repro.models import LSTMModel, LSTMConfig
    from repro.training import OptConfig, init_state
    from repro.training.optim import apply_update
    from repro.training.data import FrameCorpus
    cfg = LSTMConfig("s", input_size=12, hidden=16, num_layers=1,
                     num_classes=4, framewise=True)
    model = LSTMModel(cfg)
    params = model.init(jax.random.key(0))
    ds = FrameCorpus(input_size=12, num_classes=4)
    oc = OptConfig(lr=3e-3, total_steps=100, warmup_steps=1)
    lg = jax.jit(jax.value_and_grad(lambda p, b: model.loss(p, b)))

    def retrain_fn(p, plan, masks):
        st = init_state(oc, p)
        for i in range(2):
            b = {k: jnp.asarray(v) for k, v in ds.batch(i, 4, 8).items()}
            _, g = lg(p, b)
            g = plan.mask_grads(g, masks)
            p, st, _ = apply_update(oc, p, g, st)
        return p

    def eval_fn(p):
        b = {k: jnp.asarray(v) for k, v in ds.batch(99, 4, 8).items()}
        return -float(model.loss(p, b))

    res = brds_search(params, overall_sparsity=0.5, policy_at=lstm_policy,
                      retrain_fn=retrain_fn, eval_fn=eval_fn,
                      alpha=0.25, delta_x=0.25, delta_h=0.25)
    assert len(res.history) >= 3
    assert {h["phase"] for h in res.history} >= {"init"}
    assert res.best_policy is not None
    # the winning policy re-applies cleanly
    plan = res.best_policy.compile(res.best_params)
    _, masks = plan.prune(res.best_params)
    assert plan.summary(masks)["sparsity"] > 0.0
