"""Minimal deterministic stand-in for the hypothesis API surface these
tests use (@settings/@given + st.integers/st.floats), for containers
without the real package. Draws are seeded (reproducible), boundary
values are always exercised first, and ``max_examples`` is honored.

When hypothesis IS installed the test modules import it instead — this
shim never shadows the real thing.
"""
from __future__ import annotations

import inspect
import random
import types


class _Strategy:
    def __init__(self, lo, hi, draw):
        self.lo = lo
        self.hi = hi
        self._draw = draw

    def draw(self, rng: random.Random, i: int):
        if i == 0:
            return self.lo
        if i == 1:
            return self.hi
        return self._draw(rng)


def _integers(min_value, max_value):
    return _Strategy(min_value, max_value,
                     lambda r: r.randint(min_value, max_value))


def _floats(min_value, max_value, **_):
    return _Strategy(min_value, max_value,
                     lambda r: r.uniform(min_value, max_value))


strategies = types.SimpleNamespace(integers=_integers, floats=_floats)


def settings(max_examples: int = 20, deadline=None, **_):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


def given(**strategy_kwargs):
    def deco(fn):
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_max_examples", 20)
            rng = random.Random(0xB2D5)
            for i in range(n):
                drawn = {k: s.draw(rng, i)
                         for k, s in strategy_kwargs.items()}
                fn(*args, **drawn, **kwargs)
        # Present a signature WITHOUT the strategy-drawn params (and no
        # __wrapped__), so pytest doesn't look for fixtures named after
        # them — mirroring hypothesis's own signature rewriting.
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items()
            if name not in strategy_kwargs])
        return wrapper
    return deco
