"""repro.traffic: pool/admission/dispatch machinery, the load generator,
bucketed prefill parity, streaming, deadlines, and the scheduler fuzz.

The parity bar throughout: the dispatch-ahead scheduler must reproduce
the batch=1 lockstep ``ServeEngine`` trajectory token for token under
greedy sampling — for dense weights, packed BRDS weights, Θ=0 temporal
delta, and calibrated-int8 packed weights — regardless of pipeline
depth, prompt bucketing, arrival interleave, or forced evictions.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import LSTMModel, LSTMConfig
from repro.serving import (ContinuousBatchingEngine, SamplingConfig,
                           ServeEngine, prefill_accepts_length)
from repro.sparse import (DeltaGateConfig, QuantConfig, lstm_policy,
                          use_backend)
from repro.traffic import (AdmissionQueue, Arrival, DispatchQueue,
                           LoadConfig, QueuedRequest, RequestRecord,
                           SlotInfo, SlotPool, make_prompts, percentile,
                           poisson_trace, serve_trace, summarize)


@pytest.fixture(scope="module")
def lstm():
    cfg = LSTMConfig("t", input_size=8, hidden=16, num_layers=2,
                     vocab_size=32)
    model = LSTMModel(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


# ---------------------------------------------------------------- loadgen
def test_poisson_trace_deterministic():
    lc = LoadConfig(rate=10.0, num_requests=40, deadline=1.5,
                    priorities=(0, 1), seed=3)
    a, b = poisson_trace(lc), poisson_trace(lc)
    assert a == b                       # same seed → identical schedule
    c = poisson_trace(LoadConfig(rate=10.0, num_requests=40, deadline=1.5,
                                 priorities=(0, 1), seed=4))
    assert a != c                       # seed actually drives the draw
    ts = [x.t for x in a]
    assert ts == sorted(ts) and ts[0] > 0
    for x in a:
        assert lc.prompt_short[0] <= x.prompt_len <= lc.prompt_long[1]
        assert lc.output_lens[0] <= x.max_new <= lc.output_lens[1]
        assert x.deadline == 1.5 and x.priority in (0, 1)
    p1, p2 = make_prompts(a, vocab=32, seed=3), make_prompts(a, 32, seed=3)
    assert all(np.array_equal(x, y) for x, y in zip(p1, p2))
    with pytest.raises(ValueError):
        poisson_trace(LoadConfig(rate=0.0, num_requests=1))


# ------------------------------------------------------------------- pool
def test_slot_pool_lifecycle():
    pool = SlotPool(3)
    assert pool.free_count == 3 and len(pool) == 0
    s0, s1 = pool.alloc(), pool.alloc()
    pool.seat(s0, SlotInfo(uid=7, prompt_len=4, remaining=2))
    pool.seat(s1, SlotInfo(uid=8, prompt_len=5, remaining=3))
    assert pool.owner(s0) == 7 and pool.info(s0).slot == s0
    assert sorted(pool.active()) == sorted([s0, s1])
    snapshot = pool.owners()
    with pytest.raises(RuntimeError):   # double-seat is a bug
        pool.seat(s0, SlotInfo(uid=9, prompt_len=1, remaining=1))
    freed = pool.free(s0)
    assert freed.uid == 7 and pool.owner(s0) is None
    assert snapshot[s0] == 7            # snapshots don't mutate
    with pytest.raises(RuntimeError):
        pool.free(s0)
    assert pool.alloc() == s0           # LIFO: freed slot reused first
    pool.release_unseated(s0)
    got = pool.alloc_many(5)            # capped at what's free
    assert len(got) == 2 and pool.alloc() is None
    with pytest.raises(ValueError):
        SlotPool(0)


# -------------------------------------------------------------- admission
def test_admission_queue_ordering_and_shedding():
    q = AdmissionQueue(max_queue=3)

    def req(uid, *, deadline=None, priority=0, arrival=0.0):
        return QueuedRequest(uid, None, 4, 4, deadline=deadline,
                             priority=priority, arrival=arrival)

    assert q.push(req(0, deadline=9.0, arrival=0.0)) is None
    assert q.push(req(1, deadline=2.0, arrival=0.1)) is None
    assert q.push(req(2, priority=1, arrival=0.2)) is None
    # full: worst = lowest priority, latest deadline → uid 0 is shed
    shed = q.push(req(3, deadline=1.0, arrival=0.3))
    assert shed.uid == 0
    # priority band first, then deadline-monotonic
    assert [r.uid for r in q.pop(3)] == [2, 3, 1]
    # an incoming request that is itself the worst bounces straight back
    q2 = AdmissionQueue(max_queue=1)
    q2.push(req(5, priority=5))
    assert q2.push(req(6, priority=0)).uid == 6
    # queued expiry
    q3 = AdmissionQueue()
    q3.push(req(7, deadline=1.0))
    q3.push(req(8, deadline=5.0))
    q3.push(req(9))
    gone = q3.expire(now=2.0)
    assert [r.uid for r in gone] == [7] and len(q3) == 2
    with pytest.raises(ValueError):
        AdmissionQueue(max_queue=0)


# ---------------------------------------------------------------- metrics
def test_metrics_records_and_summary():
    recs = [
        RequestRecord(0, scheduled=0.0, deadline=2.0, first_token=0.5,
                      finished=1.0, tokens=6, reason="done"),
        RequestRecord(1, scheduled=0.0, deadline=0.8, first_token=0.4,
                      finished=1.0, tokens=4, reason="done"),   # late
        RequestRecord(2, scheduled=0.1, tokens=0, reason="expired"),
        RequestRecord(3, scheduled=0.2, tokens=0, reason="rejected"),
    ]
    assert recs[0].ttft == 0.5
    assert recs[0].tpot == pytest.approx(0.1)    # (1.0-0.5)/(6-1)
    assert recs[2].ttft is None and recs[2].tpot is None
    assert recs[0].in_deadline and not recs[1].in_deadline
    s = summarize(recs, wall=2.0, offered_rps=5.0)
    assert s["requests"] == 4 and s["completed"] == 2
    assert s["expired"] == 1 and s["rejected"] == 1
    assert s["tokens"] == 10 and s["offered_rps"] == 5.0
    assert s["toks_per_s"] == pytest.approx(5.0)
    assert s["goodput_tps"] == pytest.approx(3.0)   # late tokens excluded
    assert s["p50_ttft_ms"] == pytest.approx(450.0)
    assert math.isnan(percentile([], 50))


# ------------------------------------------------- bucketed prefill parity
def test_bucketed_prefill_bitwise(lstm):
    """Padded-to-bucket prefill with length= is BITWISE the unpadded
    prefill — logits and every cache leaf — for dense, packed, and Θ=0
    delta params (the one compiled scan body serves all widths)."""
    cfg, model, params = lstm
    plan = lstm_policy(0.75, 0.5, backend="ref").compile(params)
    pruned, masks = plan.prune(params)
    packed, _ = plan.pack(pruned, masks)
    dmodel = model.with_delta(DeltaGateConfig(theta_x=0.0, theta_h=0.0))
    cases = [(model, params), (model, packed), (dmodel, packed)]
    rng = np.random.default_rng(0)
    with use_backend("ref"):
        for m, p in cases:
            assert prefill_accepts_length(m)
            for L, W in ((3, 4), (5, 8), (6, 16)):
                toks = np.zeros((1, W), np.int32)
                toks[0, :L] = rng.integers(0, cfg.vocab_size, size=L)
                lgp, cp = m.prefill(p, jnp.asarray(toks), max_len=24,
                                    length=jnp.asarray([L], jnp.int32))
                lgr, cr = m.prefill(p, jnp.asarray(toks[:, :L]), max_len=24)
                np.testing.assert_array_equal(np.asarray(lgp),
                                              np.asarray(lgr))
                eq = jax.tree.map(
                    lambda a, b: np.array_equal(np.asarray(a),
                                                np.asarray(b)), cp, cr)
                assert all(jax.tree.leaves(eq))


def test_bucketing_compiles_once_per_bucket(lstm):
    """Distinct prompt lengths inside one bucket share a single prefill
    trace; only new bucket widths retrace (the recompile hazard the
    pow-2 padding removes)."""
    cfg, model, params = lstm
    calls = []
    real = model.prefill

    class Probe:
        def __getattr__(self, name):
            return getattr(model, name)

        def prefill(self, p, toks, max_len, extra=None, length=None):
            calls.append(toks.shape[1])
            return real(p, toks, max_len, extra=extra, length=length)

    sched = ContinuousBatchingEngine(Probe(), params, slots=2, max_len=32,
                                     chunk=4)
    rng = np.random.default_rng(1)
    for plen in (3, 4, 5, 6, 7, 8, 9):   # buckets: 4, 8, 16
        sched.submit(rng.integers(0, cfg.vocab_size, size=(1, plen)), 2)
        sched.run()
    assert sorted(set(calls)) == [4, 8, 16]
    # jit retraces once per shape: 3 bucket widths → 3 traced widths,
    # even though 7 distinct prompt lengths were served
    assert len(set(calls)) == 3


def test_unbucketed_fallback_without_length_support(lstm):
    """A DecodeStep model whose prefill has no ``length`` parameter still
    serves — at exact-length batch=1 prefill (old numerics)."""
    cfg, model, params = lstm
    widths = []

    class NoLen:
        def cache_defs(self, b, m):
            return model.cache_defs(b, m)

        def init_cache(self, b, m):
            return model.init_cache(b, m)

        def prefill(self, p, toks, max_len, extra=None):
            widths.append(toks.shape[1])
            return model.prefill(p, toks, max_len, extra=extra)

        def decode_step(self, p, c, t, pos):
            return model.decode_step(p, c, t, pos)

    nl = NoLen()
    assert not prefill_accepts_length(nl)
    sched = ContinuousBatchingEngine(nl, params, slots=2, max_len=32,
                                     chunk=4)
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab_size, size=(1, n))
               for n in (3, 5, 6)]
    uids = [sched.submit(p, 4) for p in prompts]
    got = sched.run()
    assert widths == [3, 5, 6]          # exact lengths, one per request
    eng = ServeEngine(model, cfg, max_len=32, batch=1)
    for uid, p in zip(uids, prompts):
        np.testing.assert_array_equal(
            got[uid], np.asarray(eng.generate(params, jnp.asarray(p), 4))[0])
    # the ragged lockstep engine path refuses outright instead of
    # silently changing numerics
    eng_nl = ServeEngine(nl, cfg, max_len=32, batch=2)
    with pytest.raises(TypeError):
        eng_nl.generate(params, jnp.zeros((2, 4), jnp.int32), 2,
                        lengths=[3, 4])


def test_ragged_lockstep_generate(lstm):
    """ServeEngine.generate(lengths=) serves a ragged batch in ONE
    lockstep call, each row matching its unpadded batch=1 decode."""
    cfg, model, params = lstm
    rng = np.random.default_rng(3)
    lens = [3, 7, 5, 8]
    toks = np.zeros((4, 8), np.int32)
    for i, L in enumerate(lens):
        toks[i, :L] = rng.integers(0, cfg.vocab_size, size=L)
    eng = ServeEngine(model, cfg, max_len=32, batch=4)
    out = np.asarray(eng.generate(params, jnp.asarray(toks), 6,
                                  lengths=np.asarray(lens)))
    for i, L in enumerate(lens):
        ref = np.asarray(eng.generate(params, jnp.asarray(toks[i:i+1, :L]),
                                      6))[0]
        np.testing.assert_array_equal(out[i], ref)


# -------------------------------------------------- streaming + deadlines
def test_streaming_callbacks_and_events(lstm):
    cfg, model, params = lstm
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab_size, size=(1, n))
               for n in (3, 6, 4)]
    streamed: dict[int, list] = {}
    firsts: dict[int, int] = {}

    def on_token(uid, toks, first):
        streamed.setdefault(uid, []).extend(toks)
        firsts[uid] = firsts.get(uid, 0) + bool(first)

    sched = ContinuousBatchingEngine(model, params, slots=2, max_len=32,
                                     chunk=3, on_token=on_token)
    uids = [sched.submit(p, 7) for p in prompts]
    finished = {}
    from repro.serving import TokenEvent, Finished
    for ev in sched.events():
        if isinstance(ev, TokenEvent):
            assert ev.tokens                 # no empty events
        elif isinstance(ev, Finished):
            finished[ev.uid] = ev
    eng = ServeEngine(model, cfg, max_len=32, batch=1)
    for uid, p in zip(uids, prompts):
        ref = np.asarray(eng.generate(params, jnp.asarray(p), 7))[0]
        np.testing.assert_array_equal(np.asarray(streamed[uid], np.int32),
                                      ref)
        np.testing.assert_array_equal(finished[uid].tokens, ref)
        assert firsts[uid] == 1              # exactly one first=True
    # run() stays the thin wrapper over the same event stream
    sched2 = ContinuousBatchingEngine(model, params, slots=2, max_len=32,
                                      chunk=3)
    uids2 = [sched2.submit(p, 7) for p in prompts]
    got = sched2.run()
    for uid, uid2 in zip(uids, uids2):
        np.testing.assert_array_equal(got[uid2], finished[uid].tokens)


def test_deadlines_expire_evict_and_shed(lstm):
    """The three overload outcomes: queued requests past deadline expire
    un-prefilled; in-slot overruns are evicted (tokens so far kept, a
    prefix of the reference); a bounded queue sheds the worst request."""
    cfg, model, params = lstm
    rng = np.random.default_rng(5)
    clk = [0.0]
    sched = ContinuousBatchingEngine(model, params, slots=1, max_len=64,
                                     chunk=4, clock=lambda: clk[0],
                                     max_queue=2)
    p_hog = rng.integers(0, cfg.vocab_size, size=(1, 4))
    p_exp = rng.integers(0, cfg.vocab_size, size=(1, 5))
    # priority 1 → admitted first despite the later deadline; holds the
    # one slot until evicted at clk > 9
    hog = sched.submit(p_hog, 40, deadline=9.0, priority=1)
    fin = {}
    for f in sched.step():                # admit the hog into the slot
        fin[f.uid] = f
    exp = sched.submit(p_exp, 4, deadline=5.0)        # rots behind the hog
    filler = sched.submit(rng.integers(0, cfg.vocab_size, size=(1, 3)), 2)
    # queue full (exp + filler): pushing a better request sheds the worst
    vip = sched.submit(rng.integers(0, cfg.vocab_size, size=(1, 3)), 2,
                       priority=1)
    while sched.busy:
        for f in sched.step():
            fin[f.uid] = f
        clk[0] += 2.0
    assert fin[filler].reason == "rejected" and not len(fin[filler].tokens)
    assert fin[exp].reason == "expired" and not len(fin[exp].tokens)
    assert fin[hog].reason == "expired"       # evicted mid-decode
    eng = ServeEngine(model, cfg, max_len=64, batch=1)
    ref = np.asarray(eng.generate(params, jnp.asarray(p_hog), 40))[0]
    n = len(fin[hog].tokens)
    assert 0 < n < 40
    np.testing.assert_array_equal(fin[hog].tokens, ref[:n])
    # the evicted slot was re-armed cleanly for the VIP (fresh EOS/budget)
    assert fin[vip].reason == "done" and len(fin[vip].tokens) == 2


# ------------------------------------------------------------------- fuzz
def _fuzz_round(model, params, ref_model, ref_params, cfg, *, seed, slots,
                chunk, depth, n_req, prefill_batch=1):
    """Random arrival interleave + ragged lengths through a small pool;
    returns ({uid: tokens}, {uid: (prompt, budget, reason)})."""
    rng = np.random.default_rng(seed)
    max_len = 48
    sched = ContinuousBatchingEngine(
        model, params, slots=slots, max_len=max_len, chunk=chunk,
        dispatch_depth=depth, prefill_batch=prefill_batch,
        clock=lambda: 0.0)
    reqs, fin = {}, {}
    submitted = 0
    while submitted < n_req or sched.busy:
        # bursty arrivals interleaved with decode steps
        for _ in range(int(rng.integers(0, 3))):
            if submitted >= n_req:
                break
            plen = int(rng.integers(2, 12))
            budget = int(rng.integers(1, 9))
            prompt = rng.integers(0, cfg.vocab_size,
                                  size=(1, plen)).astype(np.int32)
            uid = sched.submit(prompt, budget)
            reqs[uid] = (prompt, budget)
            submitted += 1
        for f in sched.step():
            fin[f.uid] = f
    eng = ServeEngine(ref_model, cfg, max_len=max_len, batch=1)
    for uid, (prompt, budget) in reqs.items():
        assert fin[uid].reason == "done"
        ref = np.asarray(eng.generate(ref_params, jnp.asarray(prompt),
                                      budget))[0]
        np.testing.assert_array_equal(
            fin[uid].tokens, ref,
            err_msg=f"uid {uid} (plen={prompt.shape[1]}, gen={budget}, "
                    f"slots={slots}, chunk={chunk}, depth={depth})")


def test_scheduler_fuzz_dense_and_packed(lstm):
    """Random arrivals, ragged prompts, tiny pools (forced queueing and
    slot reuse), dispatch depths 1-3: every request reproduces its
    batch=1 lockstep decode exactly — dense and packed BRDS weights."""
    cfg, model, params = lstm
    plan = lstm_policy(0.75, 0.5, backend="ref").compile(params)
    pruned, masks = plan.prune(params)
    packed, _ = plan.pack(pruned, masks)
    with use_backend("ref"):
        for seed, slots, chunk, depth in ((0, 2, 4, 2), (1, 3, 5, 1),
                                          (2, 2, 3, 3)):
            _fuzz_round(model, params, model, params, cfg, seed=seed,
                        slots=slots, chunk=chunk, depth=depth, n_req=8)
        _fuzz_round(model, packed, model, packed, cfg, seed=3, slots=2,
                    chunk=4, depth=2, n_req=8, prefill_batch=2)


def test_scheduler_fuzz_delta_and_quant(lstm):
    """Θ=0 temporal delta and calibrated-int8 packed params hold the same
    parity bar under the dispatch-ahead fuzz."""
    cfg, model, params = lstm
    with use_backend("ref"):
        # Θ=0 delta over packed weights
        deng = ServeEngine(model, cfg, max_len=48, batch=1,
                           sparsity=lstm_policy(
                               0.75, 0.5,
                               delta=DeltaGateConfig(theta_x=0.0,
                                                     theta_h=0.0)))
        dpacked, _ = deng.prepare(params)
        _fuzz_round(deng.model, dpacked, deng.model, dpacked, cfg, seed=4,
                    slots=2, chunk=4, depth=2, n_req=6)
        # calibrated int8 (static scales: exact at any prefill batch)
        calib = jax.random.randint(jax.random.key(9), (2, 12), 0,
                                   cfg.vocab_size)
        qeng = ServeEngine(model, cfg, max_len=48, batch=1,
                           sparsity=lstm_policy(0.75, 0.5,
                                                quant=QuantConfig("int8")))
        qpacked, _ = qeng.prepare(params, calib=calib)
        _fuzz_round(qeng.model, qpacked, qeng.model, qpacked, cfg, seed=5,
                    slots=2, chunk=4, depth=2, n_req=6, prefill_batch=2)


# ------------------------------------------------------------ serve_trace
def test_serve_trace_closed_loop_deterministic(lstm):
    """Closed-loop trace serving: every request completes, token outputs
    are reproducible, and the summary counts add up."""
    cfg, model, params = lstm
    lc = LoadConfig(rate=100.0, num_requests=9, prompt_short=(2, 5),
                    prompt_long=(6, 10), output_lens=(2, 6), seed=11)
    trace = poisson_trace(lc)
    prompts = make_prompts(trace, cfg.vocab_size, seed=11)
    outs = []
    for _ in range(2):
        sched = ContinuousBatchingEngine(model, params, slots=3,
                                         max_len=32, chunk=4)
        collected = {}
        sched.on_token = (lambda uid, t, f:
                          collected.setdefault(uid, []).extend(t))
        recs, s = serve_trace(sched, trace, prompts, realtime=False,
                              offered_rps=lc.rate)
        assert s["requests"] == 9 and s["completed"] == 9
        assert s["expired"] == 0 and s["rejected"] == 0
        assert s["tokens"] == sum(r.tokens for r in recs)
        assert s["offered_rps"] == 100.0
        for r in recs:
            assert r.first_token is not None and r.finished is not None
            assert r.ttft >= 0
        outs.append({u: list(v) for u, v in collected.items()})
    assert outs[0] == outs[1]           # same trace → same tokens
