"""Fixed-point quantization (repro.quant + the q8 kernels).

Covers the ISSUE-4 acceptance criteria: quantize→dequantize error within
the scheme bound, per-row scales surviving pack/format round-trips, q8
kernel pallas↔ref EXACT parity (integer accumulation), and quant=int8
Θ=0 decode reproducing the quantized reference trajectory step for step.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pack_from_dense
from repro.core.packing import RowBalancedSparse
from repro.kernels import ops as K
from repro.models import LSTMModel, LSTMConfig
from repro.quant import (QuantConfig, QuantPlan, RowBalancedSparseQ8,
                         calibrate_lstm, default_plan, dequantize,
                         dequantize_packed, packed_bytes_q, parse_scheme,
                         quantize, quantize_packed, row_scales)
from repro.serving import ServeEngine
from repro.sparse import (DeltaGateConfig, get_format, lstm_policy,
                          use_backend)


def _rand(rng, shape, dtype=jnp.float32):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32), dtype)


# ------------------------------------------------------------- schemes

def test_parse_scheme():
    s = parse_scheme("int8")
    assert s.qmax == 127 and s.frac_bits is None and s.bits == 8
    assert s.storage == jnp.dtype(jnp.int8)
    q = parse_scheme("q1.11")
    assert q.qmax == 4095 and q.frac_bits == 11
    assert q.storage == jnp.dtype(jnp.int16)
    assert q.fixed_scale == 2.0 ** -11
    assert parse_scheme(q) is q
    for bad in ("int4", "q1.0", "q9.9", "garbage"):
        with pytest.raises(ValueError):
            parse_scheme(bad)


def test_quant_config_validation():
    assert QuantConfig("int8").resolved.qmax == 127
    with pytest.raises(ValueError):
        QuantConfig("nope")
    with pytest.raises(ValueError):
        QuantConfig("int8", method="median")
    with pytest.raises(ValueError):
        QuantConfig("int8", method="percentile", percentile=0.0)


# --------------------------------------------------- round-trip bounds

@pytest.mark.parametrize("scheme,scale_mag", [
    ("int8", 1.0), ("int8", 0.01), ("q1.11", 1.0), ("q4.8", 3.0),
])
def test_quantize_dequantize_error_within_bound(rng, scheme, scale_mag):
    """Property: for in-range values, |deq(q(x)) − x| ≤ scale/2 (round to
    nearest); out-of-range fixed-point values saturate to ±qmax·scale."""
    s = parse_scheme(scheme)
    w = _rand(rng, (64, 32)) * scale_mag
    scales = row_scales(w, s)
    assert scales.shape == (64,)
    q = quantize(w, scales[:, None], s)
    deq = dequantize(q, scales[:, None])
    lim = np.asarray(scales)[:, None] * s.qmax
    in_range = np.abs(np.asarray(w)) <= lim
    err = np.abs(np.asarray(deq) - np.asarray(w))
    bound = np.asarray(scales)[:, None] / 2 * (1 + 1e-6)
    assert (err[in_range] <= bound.repeat(32, 1)[in_range]).all()
    # saturated values clip to the representable edge
    assert (np.abs(np.asarray(deq)) <= lim * (1 + 1e-6)).all()


def test_row_scales_scaled_vs_fixed(rng):
    w = _rand(rng, (16, 8))
    s_int8 = row_scales(w, parse_scheme("int8"))
    np.testing.assert_allclose(
        np.asarray(s_int8),
        np.abs(np.asarray(w)).max(axis=1) / 127, rtol=1e-6)
    s_fix = row_scales(w, parse_scheme("q1.11"))
    assert (np.asarray(s_fix) == 2.0 ** -11).all()
    # all-zero rows get a safe scale
    z = row_scales(jnp.zeros((4, 8)), parse_scheme("int8"))
    assert (np.asarray(z) == 1.0).all()


# ------------------------------------------------- packed round-trips

@pytest.mark.parametrize("scheme", ["int8", "q1.11"])
def test_quantize_packed_roundtrip(rng, scheme):
    """Codes + per-row scales reconstruct the float packing within the
    scheme bound; the sparsity pattern (deltas, ncols) is untouched."""
    s = pack_from_dense(_rand(rng, (128, 64)), 0.75)
    q = quantize_packed(s, scheme)
    assert isinstance(q, RowBalancedSparseQ8)
    np.testing.assert_array_equal(np.asarray(q.deltas), np.asarray(s.deltas))
    assert q.ncols == s.ncols and q.rows == s.rows and q.K == s.K
    np.testing.assert_array_equal(np.asarray(q.col_indices()),
                                  np.asarray(s.col_indices()))
    d = dequantize_packed(q)
    assert isinstance(d, RowBalancedSparse)
    err = np.abs(np.asarray(d.values) - np.asarray(s.values))
    bound = np.asarray(q.scales)[:, None] / 2 * (1 + 1e-6)
    if parse_scheme(scheme).frac_bits is None:       # no clipping by design
        assert (err <= bound.repeat(s.K, 1)).all()
    mem = q.memory_bytes()
    assert mem["total"] == mem["values"] + mem["indices"] + mem["scales"]
    assert mem["total"] < s.memory_bytes()["total"]


def test_plan_pack_emits_q8_and_scales_survive(rng):
    """SparsityPolicy(quant=...) packs RowBalancedSparseQ8 leaves whose
    scales/pattern match quantizing the float pack directly."""
    model = LSTMModel(LSTMConfig("t", input_size=24, hidden=32,
                                 vocab_size=64))
    params = model.init(jax.random.key(0))
    fplan = lstm_policy(0.75, 0.5).compile(params)
    pruned, masks = fplan.prune(params)
    fpacked, frep = fplan.pack(pruned, masks)
    qplan = lstm_policy(0.75, 0.5, quant=QuantConfig("int8")).compile(params)
    qpacked, qrep = qplan.pack(pruned, masks)
    for i in range(1):
        for key in ("w_x", "w_h"):
            fq = quantize_packed(fpacked["layers"][i][key], "int8")
            got = qpacked["layers"][i][key]
            assert isinstance(got, RowBalancedSparseQ8)
            np.testing.assert_array_equal(np.asarray(got.values),
                                          np.asarray(fq.values))
            np.testing.assert_array_equal(np.asarray(got.scales),
                                          np.asarray(fq.scales))
            np.testing.assert_array_equal(np.asarray(got.deltas),
                                          np.asarray(fq.deltas))
    assert qrep["packed_bytes"] < frep["packed_bytes"]
    # abstract (dry-run) pack mirrors the concrete shapes/dtypes
    abs_packed, _ = qplan.pack(params, abstract=True)
    a = abs_packed["layers"][0]["w_x"]
    c = qpacked["layers"][0]["w_x"]
    assert a.values.shape == c.values.shape
    assert a.values.dtype == c.values.dtype
    assert a.scales.shape == c.scales.shape


def test_registered_q8_format_roundtrip(rng):
    fmt = get_format("row_balanced_q8")
    w = _rand(rng, (64, 32))
    mask = fmt.mask(w, 0.5)
    packed = fmt.pack(w, mask, scheme="q2.9")
    assert packed.qmax == 2 ** 11 - 1 and packed.frac_bits == 9
    dense = fmt.unpack(packed)
    assert dense.shape == w.shape
    # matvec agrees with the dequantized float path to quant tolerance
    x = _rand(rng, (3, 32))
    got = fmt.matvec(packed, x, backend="ref")
    want = x @ np.asarray(dense).T
    np.testing.assert_allclose(np.asarray(got), want, atol=0.1)
    assert fmt.packed_bytes(64, 32, 0.5, jnp.float32, scheme="q2.9") \
        == packed.memory_bytes()["total"]


def test_packed_bytes_reduction_at_matched_sparsity():
    """≥2x weight-bytes cut for int8 vs the f32 packing at matched
    sparsity (the fig_quant_tradeoff acceptance bar), measured over the
    dual-ratio family pair: values shrink 4x, indices/scales dilute it."""
    fmt = get_format("row_balanced")
    X, H, sx, sh = 128, 256, 0.875, 0.75
    f32 = (fmt.packed_bytes(4 * H, X, sx, jnp.float32)
           + fmt.packed_bytes(4 * H, H, sh, jnp.float32))
    q8 = packed_bytes_q(4 * H, X, sx, "int8") \
        + packed_bytes_q(4 * H, H, sh, "int8")
    assert f32 / q8 >= 2.0


# -------------------------------------------------- kernel parity (exact)

@pytest.mark.parametrize("scheme", ["int8", "q1.11"])
@pytest.mark.parametrize("rows,cols,spar,B", [
    (128, 64, 0.5, 1), (256, 96, 0.75, 4), (96, 33, 0.3, 3),
])
def test_rb_spmv_q8_pallas_matches_ref_exactly(rng, scheme, rows, cols,
                                               spar, B):
    q = quantize_packed(pack_from_dense(_rand(rng, (rows, cols)), spar),
                        scheme)
    x = _rand(rng, (B, cols))
    got = K.rb_spmv_q8(q, x, backend="pallas", block_rows=64)
    want = K.rb_spmv_q8(q, x, backend="ref")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("scheme", ["int8", "q1.11"])
def test_rb_dual_spmv_q8_pallas_matches_ref_exactly(rng, scheme):
    H, X = 64, 48
    sx = quantize_packed(pack_from_dense(_rand(rng, (4 * H, X)), 0.875),
                         scheme)
    sh = quantize_packed(pack_from_dense(_rand(rng, (4 * H, H)), 0.5),
                         scheme)
    x, h = _rand(rng, (2, X)), _rand(rng, (2, H))
    bias = _rand(rng, (4 * H,))
    got = K.rb_dual_spmv_q8(sx, x, sh, h, bias, backend="pallas",
                            block_rows=64)
    want = K.rb_dual_spmv_q8(sx, x, sh, h, bias, backend="ref")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("scheme", ["int8", "q1.11"])
def test_delta_rb_dual_spmv_q8_pallas_matches_ref_exactly(rng, scheme):
    """The quantized fused partial-sum update m' = m + dq(Sx@q(fx·dx)) +
    dq(Sh@q(fh·dh)) is bitwise identical across backends."""
    H, X = 64, 48
    sx = quantize_packed(pack_from_dense(_rand(rng, (4 * H, X)), 0.875),
                         scheme)
    sh = quantize_packed(pack_from_dense(_rand(rng, (4 * H, H)), 0.5),
                         scheme)
    dx, dh = _rand(rng, (2, X)), _rand(rng, (2, H))
    fx = jnp.asarray(rng.random((2, X)) > 0.3)
    fh = jnp.asarray(rng.random((2, H)) > 0.3)
    m = _rand(rng, (2, 4 * H))
    got = K.delta_rb_dual_spmv_q8(sx, dx, fx, sh, dh, fh, m,
                                  backend="pallas", block_rows=64)
    want = K.delta_rb_dual_spmv_q8(sx, dx, fx, sh, dh, fh, m, backend="ref")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_q8_unfired_columns_contribute_nothing(rng):
    """Masked-then-quantized deltas carry exact 0 codes: the delta q8
    matvec equals the plain q8 matvec over the masked delta."""
    H, X = 32, 24
    sx = quantize_packed(pack_from_dense(_rand(rng, (4 * H, X)), 0.5),
                         "int8")
    sh = quantize_packed(pack_from_dense(_rand(rng, (4 * H, H)), 0.5),
                         "int8")
    dx, dh = _rand(rng, (2, X)), _rand(rng, (2, H))
    fx = jnp.asarray(rng.random((2, X)) > 0.7)
    fh = jnp.zeros((2, H), bool)                    # nothing fired on h
    m = jnp.zeros((2, 4 * H), jnp.float32)
    got = K.delta_rb_dual_spmv_q8(sx, dx, fx, sh, dh, fh, m,
                                  act_scale_x=0.01, act_scale_h=0.01,
                                  backend="ref")
    want = K.rb_spmv_q8(sx, jnp.where(fx, dx, 0.0), act_scale=0.01,
                        backend="ref")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_q8_matvec_approximates_float_matvec(rng):
    """Sanity on the semantics (not just self-consistency): the int8 path
    tracks the float packed matvec to quantization tolerance."""
    s = pack_from_dense(_rand(rng, (128, 64)), 0.75)
    q = quantize_packed(s, "int8")
    x = _rand(rng, (3, 64))
    got = np.asarray(K.rb_spmv_q8(q, x, backend="ref"))
    want = np.asarray(K.rb_spmv(s, x, backend="ref"))
    denom = np.abs(want).mean()
    assert np.abs(got - want).mean() / denom < 0.02


# ------------------------------------------------------- calibration

def _lm(num_layers=1, hidden=64, input_size=48, vocab=128):
    cfg = LSTMConfig("t", input_size=input_size, hidden=hidden,
                     num_layers=num_layers, vocab_size=vocab)
    model = LSTMModel(cfg)
    return cfg, model, model.init(jax.random.key(0))


def test_calibrate_lstm_scales():
    cfg, model, params = _lm(num_layers=2)
    tokens = jax.random.randint(jax.random.key(1), (4, 12), 0,
                                cfg.vocab_size)
    plan = calibrate_lstm(model, params, tokens, QuantConfig("int8"))
    assert plan.num_layers == 2
    for s_x, s_h in plan.act_scales:
        assert s_x > 0 and s_h > 0
    pplan = calibrate_lstm(model, params, tokens,
                           QuantConfig("int8", method="percentile",
                                       percentile=90.0))
    # percentile clips outliers → never larger than the max-abs scale
    for (ax, ah), (px, ph) in zip(plan.act_scales, pplan.act_scales):
        assert px <= ax * (1 + 1e-6) and ph <= ah * (1 + 1e-6)
    fplan = calibrate_lstm(model, params, tokens, QuantConfig("q1.11"))
    assert all(s == (2.0 ** -11, 2.0 ** -11) for s in fplan.act_scales)
    d = default_plan(QuantConfig("int8"), 3)
    assert d.num_layers == 3 and d.scale_for(0) == (1.0 / 127, 1.0 / 127)


# -------------------------------------------------- serving trajectory

def test_engine_prepare_wires_quant_model():
    cfg, model, params = _lm()
    eng = ServeEngine(model, cfg, max_len=16, batch=2,
                      sparsity=lstm_policy(0.5, 0.5,
                                           quant=QuantConfig("int8")))
    calib = jax.random.randint(jax.random.key(2), (2, 8), 0, cfg.vocab_size)
    packed, report = eng.prepare(params, calib=calib)
    assert eng.model is not model
    assert isinstance(eng.model.quant, QuantPlan)
    assert isinstance(packed["layers"][0]["w_x"], RowBalancedSparseQ8)
    assert report["packed_bytes"] < report["dense_bytes"]


def test_quant_theta0_decode_matches_quantized_reference_exactly():
    """quant=int8 + Θ=0 delta: the Pallas q8 decode reproduces the
    pure-jnp quantized reference trajectory step for step (and the
    non-delta q8 path agrees across backends too)."""
    cfg, model, params = _lm(num_layers=2)
    B, P, G = 2, 8, 16
    prompt = jax.random.randint(jax.random.key(3), (B, P), 0,
                                cfg.vocab_size)
    for delta in (None, DeltaGateConfig()):
        outs = {}
        for backend in ("pallas", "ref"):
            with use_backend(backend):
                eng = ServeEngine(model, cfg, max_len=P + G, batch=B,
                                  sparsity=lstm_policy(
                                      0.875, 0.75, delta=delta,
                                      quant=QuantConfig("int8")))
                packed, _ = eng.prepare(params, calib=prompt)
                outs[backend] = np.asarray(
                    eng.generate(packed, prompt, G))
        np.testing.assert_array_equal(outs["pallas"], outs["ref"])


def test_quant_decode_tracks_f32_trajectory():
    """Calibrated int8 decode stays close to the f32 packed decode: the
    prefill logits agree to quant tolerance (greedy tokens may diverge
    late, so the assertion is on logits, not ids)."""
    cfg, model, params = _lm()
    B, P = 2, 10
    prompt = jax.random.randint(jax.random.key(4), (B, P), 0,
                                cfg.vocab_size)
    with use_backend("ref"):
        feng = ServeEngine(model, cfg, max_len=P + 4, batch=B,
                           sparsity=lstm_policy(0.75, 0.5))
        fpacked, _ = feng.prepare(params)
        flog, _ = feng._prefill(fpacked, prompt, max_len=P + 4)
        qeng = ServeEngine(model, cfg, max_len=P + 4, batch=B,
                           sparsity=lstm_policy(0.75, 0.5,
                                                quant=QuantConfig("int8")))
        qpacked, _ = qeng.prepare(params, calib=prompt)
        qlog, _ = qeng._prefill(qpacked, prompt, max_len=P + 4)
    mae = float(jnp.mean(jnp.abs(qlog - flog)))
    ref = float(jnp.mean(jnp.abs(flog)))
    assert mae / ref < 0.05


def test_model_pack_quant_and_sparse_step(rng):
    """LSTMModel.pack(quant=...) emits Q8 entries and sparse_step runs
    them (identical across backends)."""
    cfg, model, params = _lm()
    pruned, masks = model.prune(params, 0.75, 0.5)
    packed = model.pack(pruned, masks, quant="int8")
    assert isinstance(packed[0]["sx"], RowBalancedSparseQ8)
    x = _rand(rng, (2, cfg.input_size))
    st = model.init_state(2)
    outs = {}
    for backend in ("pallas", "ref"):
        h, st2 = model.sparse_step(packed, x, st, backend=backend)
        outs[backend] = np.asarray(h)
    np.testing.assert_array_equal(outs["pallas"], outs["ref"])


def test_quantize_packed_warns_on_int32_accumulator_risk(rng):
    """A wide-K, high-qmax fixed-point packing whose worst-case row dot
    can wrap the int32 accumulator warns at quantize time (the ref twin
    accumulates in int32 too, so parity tests can't catch wraparound)."""
    big = jnp.full((8, 256), 15.9, jnp.float32)       # saturates q4.11
    s = pack_from_dense(big, 0.5)
    with pytest.warns(UserWarning, match="int32 kernel accumulator"):
        quantize_packed(s, "q4.11")
    # int8 can never reach 2^31 — no warning
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error")
        quantize_packed(s, "int8")


def test_delta_q8_doubles_calibrated_act_scales(rng, monkeypatch):
    """The delta path quantizes DELTAS, which span twice the calibrated
    absolute-activation range — the model must double the scaled-scheme
    act scales before the q8 delta kernel (clipped deltas would bake
    their error into the partial-sum memory permanently)."""
    cfg, model, params = _lm()
    qplan = QuantPlan(parse_scheme("int8"), ((0.01, 0.02),))
    dm = model.with_quant(qplan).with_delta(DeltaGateConfig())
    plan = lstm_policy(0.5, 0.5).compile(params)
    pruned, masks = plan.prune(params)
    packed, _ = lstm_policy(0.5, 0.5, quant=QuantConfig("int8")) \
        .compile(params).pack(pruned, masks)
    seen = {}

    def spying(orig):
        def spy(*a, **kw):
            seen["ax"], seen["ah"] = kw["act_scale_x"], kw["act_scale_h"]
            return orig(*a, **kw)
        return spy

    # the model dispatches the fused single-launch op by default and the
    # chained one under with_fused(False)/mesh — the doubling must reach
    # whichever runs
    monkeypatch.setattr(K, "brds_delta_lstm_step_q8",
                        spying(K.brds_delta_lstm_step_q8))
    monkeypatch.setattr(K, "fused_brds_delta_lstm_step_q8",
                        spying(K.fused_brds_delta_lstm_step_q8))
    cache = dm.init_cache(2, 8)
    tokens = jax.random.randint(jax.random.key(5), (2, 1), 0,
                                cfg.vocab_size)
    with use_backend("ref"):
        dm.decode_step(packed, cache, tokens, 0)
    assert seen["ax"] == pytest.approx(0.02)      # 2 × 0.01
    assert seen["ah"] == pytest.approx(0.04)      # 2 × 0.02


def test_with_quant_preserved_by_with_delta():
    cfg, model, _ = _lm()
    qplan = default_plan(QuantConfig("int8"), cfg.num_layers)
    m2 = model.with_quant(qplan).with_delta(DeltaGateConfig(theta_x=0.1))
    assert m2.quant is qplan and m2.delta.theta_x == 0.1
    m3 = m2.with_quant(None)
    assert m3.quant is None and m3.delta.theta_x == 0.1
