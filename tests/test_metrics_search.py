"""Unit tests for core/metrics.py (previously untested) and for the
Fig.-5 search in sparse/search.py on stub score functions: the search
must return the argmax of its own history, walk the documented phases,
and track a monotone preference for higher scores."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.metrics import (binary_accuracy, cross_entropy, perplexity,
                                token_accuracy)
from repro.sparse.search import (brds_search, execution_time_model,
                                 plane_search)

# ------------------------------------------------------------------ metrics


def test_cross_entropy_matches_log_softmax():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(3, 5, 7)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 7, size=(3, 5)))
    ref = -jax.nn.log_softmax(logits, axis=-1)
    ref = np.asarray(ref)[np.arange(3)[:, None], np.arange(5)[None, :],
                          np.asarray(labels)]
    np.testing.assert_allclose(float(cross_entropy(logits, labels)),
                               ref.mean(), rtol=1e-6)


def test_cross_entropy_mask_excludes_positions():
    rng = np.random.default_rng(1)
    logits = jnp.asarray(rng.normal(size=(2, 4, 6)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 6, size=(2, 4)))
    mask = jnp.asarray([[1, 1, 0, 0], [1, 0, 0, 0]], jnp.float32)
    full = cross_entropy(logits[:, :2], labels[:, :2],
                         mask=jnp.asarray([[1, 1], [1, 0]], jnp.float32))
    masked = cross_entropy(logits, labels, mask=mask)
    np.testing.assert_allclose(float(masked), float(full), rtol=1e-6)
    # an all-zero mask must not divide by zero
    zero = cross_entropy(logits, labels, mask=jnp.zeros((2, 4)))
    assert np.isfinite(float(zero))


def test_cross_entropy_uniform_logits():
    """Uniform logits → NLL = log V exactly, so ppl = V."""
    logits = jnp.zeros((2, 3, 8))
    labels = jnp.asarray(np.random.default_rng(2).integers(0, 8, (2, 3)))
    nll = float(cross_entropy(logits, labels))
    np.testing.assert_allclose(nll, np.log(8.0), rtol=1e-6)
    np.testing.assert_allclose(perplexity(nll), 8.0, rtol=1e-5)


def test_perplexity_is_exp():
    np.testing.assert_allclose(perplexity(0.0), 1.0)
    np.testing.assert_allclose(perplexity(1.0), np.e, rtol=1e-12)


def test_token_accuracy():
    logits = jnp.asarray([[[0.1, 0.9], [0.8, 0.2]],
                          [[0.3, 0.7], [0.6, 0.4]]])
    labels = jnp.asarray([[1, 0], [0, 0]])
    np.testing.assert_allclose(token_accuracy(logits, labels), 0.75)
    mask = jnp.asarray([[1.0, 1.0], [0.0, 1.0]])  # drop the one miss
    np.testing.assert_allclose(token_accuracy(logits, labels, mask), 1.0)


def test_binary_accuracy():
    logits = jnp.asarray([[2.0], [-1.0], [0.5], [-0.2]])
    labels = jnp.asarray([1, 0, 0, 0])
    np.testing.assert_allclose(binary_accuracy(logits, labels), 0.75)


# ------------------------------------------------------------------- search


def _stub_search(score_fn, overall=0.5, **kw):
    """plane_search over a fake 'params' that just records the current
    tuple; score_fn maps (spar_x, spar_h) -> accuracy."""
    def visit(p, sx, sh):
        return {"sx": sx, "sh": sh}, None

    def eval_fn(p):
        return score_fn(p["sx"], p["sh"])

    return plane_search({"sx": 0.0, "sh": 0.0}, overall_sparsity=overall,
                        visit=visit, eval_fn=eval_fn, **kw)


def test_plane_search_returns_history_argmax():
    """Whatever the score landscape, best_* must be the argmax of the
    visited history — the search never returns a tuple it didn't score
    or a score that beats its own best."""
    def score(sx, sh):     # asymmetric, nonmonotone landscape
        return -((sx - 0.7) ** 2) - 2.0 * (sh - 0.4) ** 2
    res = _stub_search(score)
    accs = [h["accuracy"] for h in res.history]
    assert res.best_accuracy == max(accs)
    top = res.history[int(np.argmax(accs))]
    assert (res.best_spar_x, res.best_spar_h) == (top["spar_x"],
                                                  top["spar_h"])


def test_plane_search_phases_and_init_tuple():
    res = _stub_search(lambda sx, sh: 0.0)
    phases = [h["phase"] for h in res.history]
    assert phases[0] == "init"
    assert set(phases) == {"init", "x_up", "h_up"}
    # phase 1 ramps both ratios to overall_sparsity
    init = res.history[0]
    assert init["spar_x"] == init["spar_h"] == 0.5
    # x_up walks Spar_x up / Spar_h down; h_up the reverse
    for h in res.history[1:]:
        if h["phase"] == "x_up":
            assert h["spar_x"] > 0.5 and h["spar_h"] < 0.5
        else:
            assert h["spar_x"] < 0.5 and h["spar_h"] > 0.5


def test_plane_search_monotone_preference_for_spar_x():
    """On a landscape that strictly rewards more Spar_x (the paper's
    claim that W_x tolerates harsher pruning), the search must end at the
    x_up extreme it visited — and symmetrically for Spar_h."""
    res_x = _stub_search(lambda sx, sh: sx - 0.1 * sh)
    xs = [h["spar_x"] for h in res_x.history if h["phase"] == "x_up"]
    assert res_x.best_spar_x == max(xs)
    res_h = _stub_search(lambda sx, sh: sh - 0.1 * sx)
    hs = [h["spar_h"] for h in res_h.history if h["phase"] == "h_up"]
    assert res_h.best_spar_h == max(hs)


def test_brds_search_wires_policy_and_retrain():
    """brds_search visits tuples through real policies: retrain_fn sees
    (pruned, plan, masks) per visit and the winning tuple's policy is
    returned. Uses a tiny real param tree."""
    from repro.models import LSTMConfig, LSTMModel
    from repro.sparse import lstm_policy
    cfg = LSTMConfig("srch", input_size=8, hidden=8, num_layers=1,
                     vocab_size=11)
    params = LSTMModel(cfg).init(jax.random.key(0))
    seen = []

    def retrain_fn(pruned, plan, masks):
        seen.append(set(masks))
        return pruned

    res = brds_search(
        params, overall_sparsity=0.5,
        policy_at=lambda sx, sh: lstm_policy(sx, sh),
        retrain_fn=retrain_fn,
        eval_fn=lambda p: 1.0)
    assert res.best_policy is not None
    assert all(s == {"layers/0/w_x", "layers/0/w_h"} for s in seen)
    # phase 1 ramps through intermediate tuples that get no history entry
    # (only the arrival point is scored), so visits >= scored points
    assert len(seen) >= len(res.history)


def test_execution_time_model_totals():
    out = execution_time_model(0.5, 0.25, 0.05, 0.05, ept=2.0, n_re=3)
    np.testing.assert_allclose(out["total"],
                               out["ex1"] + out["ex2"] + out["ex3"])
    assert out["ex1"] == (0.5 / 0.25) * 2.0 * 3
    # more retrain epochs cost proportionally more
    out2 = execution_time_model(0.5, 0.25, 0.05, 0.05, ept=2.0, n_re=6)
    np.testing.assert_allclose(out2["total"], 2 * out["total"])
