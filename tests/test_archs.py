"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + no NaNs; decode path consistency vs full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config, ARCH_NAMES, get_arch, SHAPES, runnable
from repro.models import build_model
from repro.training import OptConfig, init_state, make_train_step


def _batch_for(cfg, B, S, rng):
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    extra = None
    if cfg.encdec:
        extra = jax.random.normal(rng, (B, 16, cfg.d_model), dtype=jnp.float32)
        batch["frames"] = extra
    elif cfg.num_patches:
        extra = jax.random.normal(rng, (B, cfg.num_patches, cfg.d_model),
                                  dtype=jnp.float32)
        batch["patch_embeds"] = extra
    return batch, extra


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_forward_and_train_step(name):
    cfg = smoke_config(name)
    model = build_model(cfg)
    rng = jax.random.key(0)
    params = model.init(rng)
    B, S = 2, 32
    batch, extra = _batch_for(cfg, B, S, rng)
    if cfg.encdec:
        logits, aux = model.forward(params, batch["tokens"], batch["frames"])
    elif cfg.num_patches:
        logits, aux = model.forward(params, batch["tokens"],
                                    batch["patch_embeds"])
    else:
        logits, aux = model.forward(params, batch["tokens"])
    assert logits.shape == (B, S, model.vocab_padded)
    assert bool(jnp.isfinite(logits).all()), "NaN/inf in logits"
    oc = OptConfig(lr=1e-3, total_steps=10, warmup_steps=1)
    st = init_state(oc, params)
    step = jax.jit(make_train_step(model, cfg, oc))
    params, st, metrics = step(params, st, batch, jnp.int32(0))
    assert bool(jnp.isfinite(metrics["loss"])), "NaN loss"
    assert float(metrics["grad_norm"]) > 0


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_decode_matches_forward(name):
    cfg = smoke_config(name)
    if cfg.moe:
        cfg = cfg.with_(capacity_factor=8.0)   # no-drop → paths identical
    model = build_model(cfg)
    rng = jax.random.key(1)
    params = model.init(rng)
    B, S = 2, 24
    batch, extra = _batch_for(cfg, B, S, rng)
    tokens = batch["tokens"]
    if cfg.encdec:
        full, _ = model.forward(params, tokens, batch["frames"])
        lp, cache = model.prefill(params, tokens[:, :S - 3], S,
                                  extra=batch["frames"])
    elif cfg.num_patches:
        full, _ = model.forward(params, tokens, batch["patch_embeds"])
        lp, cache = model.prefill(params, tokens[:, :S - 3], S,
                                  extra=batch["patch_embeds"])
    else:
        full, _ = model.forward(params, tokens)
        lp, cache = model.prefill(params, tokens[:, :S - 3], S)
    errs = [float(jnp.abs(lp[:, -1] - full[:, S - 4]).max())]
    for i in range(3):
        lg, cache = model.decode_step(params, cache,
                                      tokens[:, S - 3 + i:S - 2 + i], S - 3 + i)
        errs.append(float(jnp.abs(lg[:, 0] - full[:, S - 3 + i]).max()))
    assert max(errs) < 5e-4, f"decode diverges from forward: {errs}"


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_full_config_matches_assignment(name):
    """The registered full configs carry the exact assigned hyperparams."""
    spec = {
        "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "nemotron-4-340b": (96, 18432, 96, 8, 73728, 256000),
        "qwen3-0.6b": (28, 1024, 16, 8, 3072, 151936),
        "minitron-8b": (32, 4096, 32, 8, 16384, 256000),
        "llama3.2-3b": (28, 3072, 24, 8, 8192, 128256),
        "rwkv6-7b": (32, 4096, 64, 64, 14336, 65536),
    }
    cfg = get_arch(name)
    L, d, H, kv, ff, V = spec[name]
    assert (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
            cfg.d_ff, cfg.vocab_size) == (L, d, H, kv, ff, V)


def test_long_500k_applicability():
    """Only sub-quadratic archs run long_500k; 8 N/A cells documented."""
    na = [n for n in ARCH_NAMES
          if not runnable(get_arch(n), SHAPES["long_500k"])[0]]
    assert len(na) == 8
    assert "rwkv6-7b" not in na and "recurrentgemma-9b" not in na


def test_brds_masked_training_on_transformer():
    """BRDS dual-ratio masks freeze pruned transformer weights."""
    from repro.training import brds_masks, sparsity_report
    from repro.training.masked import apply_masks, _path_str
    cfg = smoke_config("minitron-8b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    masks = brds_masks(params, 0.875, 0.5)
    rep = sparsity_report(params, masks)
    assert 0.5 < rep["sparsity"] < 0.875
    params = apply_masks(params, masks)
    oc = OptConfig(lr=1e-2, total_steps=10, warmup_steps=1)
    st = init_state(oc, params)
    step = jax.jit(make_train_step(model, cfg, oc, masks=masks))
    rng = jax.random.key(2)
    batch, _ = _batch_for(cfg, 2, 16, rng)
    for i in range(2):
        params, st, _ = step(params, st, batch, jnp.int32(i))
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    for path, leaf in flat:
        ps = _path_str(path)
        if ps in masks:
            assert bool(jnp.all(jnp.where(masks[ps], True, leaf == 0))), \
                f"pruned weights drifted in {ps}"


@pytest.mark.parametrize("name", ["llama3.2-3b", "recurrentgemma-9b"])
def test_int8_kv_cache_close_to_bf16(name):
    """Beyond-paper: int8 KV cache (BRDS quantization axis) stays within
    quantization tolerance of the bf16 decode path."""
    cfg = smoke_config(name)
    model = build_model(cfg)
    modelq = build_model(cfg.with_(kv_quant=True))
    params = model.init(jax.random.key(0))
    B, S = 2, 24
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    lp, c = model.prefill(params, toks[:, :S - 1], S)
    lpq, cq = modelq.prefill(params, toks[:, :S - 1], S)
    lg, _ = model.decode_step(params, c, toks[:, S - 1:], S - 1)
    lgq, _ = modelq.decode_step(params, cq, toks[:, S - 1:], S - 1)
    rel = float(jnp.abs(lg - lgq).max() / (jnp.abs(lg).max() + 1e-9))
    assert rel < 0.08, rel
