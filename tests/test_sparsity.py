"""Property-based tests (hypothesis) for the paper's core invariants:
row-balanced masks, dual-ratio pruning, packed format roundtrips."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:     # container ships no hypothesis
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import (row_balanced_mask, unstructured_mask, block_mask,
                        bank_balanced_mask, apply_mask, keep_count,
                        pack, unpack, pack_from_dense, sparsity_of)

dims = st.integers(min_value=2, max_value=48)
spars = st.floats(min_value=0.0, max_value=0.95)


@settings(max_examples=25, deadline=None)
@given(rows=dims, cols=dims, spar=spars, seed=st.integers(0, 2**31))
def test_row_balanced_exact_k_per_row(rows, cols, spar, seed):
    """THE paper invariant: every row keeps exactly K non-zeros."""
    w = jnp.asarray(np.random.default_rng(seed).normal(size=(rows, cols)),
                    jnp.float32)
    m = row_balanced_mask(w, spar)
    k = keep_count(cols, spar)
    counts = np.asarray(m.sum(axis=1))
    assert (counts == k).all()


@settings(max_examples=25, deadline=None)
@given(rows=dims, cols=dims, spar=spars, seed=st.integers(0, 2**31))
def test_row_balanced_keeps_largest(rows, cols, spar, seed):
    """Kept entries in each row are ≥ every pruned entry (by |.|)."""
    w = jnp.asarray(np.random.default_rng(seed).normal(size=(rows, cols)),
                    jnp.float32)
    m = np.asarray(row_balanced_mask(w, spar))
    aw = np.abs(np.asarray(w))
    for r in range(rows):
        if m[r].all() or not m[r].any():
            continue
        assert aw[r][m[r]].min() >= aw[r][~m[r]].max() - 1e-7


@settings(max_examples=20, deadline=None)
@given(rows=dims, cols=dims, spar=spars, seed=st.integers(0, 2**31))
def test_pack_unpack_roundtrip(rows, cols, spar, seed):
    w = jnp.asarray(np.random.default_rng(seed).normal(size=(rows, cols)),
                    jnp.float32)
    m = row_balanced_mask(w, spar)
    s = pack(w, m)
    dense = unpack(s)
    assert jnp.allclose(dense, apply_mask(w, m))
    # columns strictly ascending per row
    cols_idx = np.asarray(s.col_indices())
    assert (np.diff(cols_idx, axis=1) > 0).all()
    assert cols_idx.min() >= 0 and cols_idx.max() < cols


@settings(max_examples=20, deadline=None)
@given(cols=st.integers(2, 200), spar=spars)
def test_keep_count_bounds(cols, spar):
    k = keep_count(cols, spar)
    assert 1 <= k <= cols


def test_delta_dtype_narrows():
    w = jnp.asarray(np.random.default_rng(0).normal(size=(8, 100)), jnp.float32)
    s = pack_from_dense(w, 0.5)
    assert s.deltas.dtype == jnp.int8
    w2 = jnp.asarray(np.random.default_rng(0).normal(size=(4, 1000)),
                     jnp.float32)
    s2 = pack_from_dense(w2, 0.5)
    assert s2.deltas.dtype == jnp.int16


def test_memory_accounting():
    w = jnp.asarray(np.random.default_rng(0).normal(size=(64, 128)),
                    jnp.float32)
    s = pack_from_dense(w, 0.75)
    mem = s.memory_bytes()
    assert mem["values"] == 64 * 32 * 4
    assert mem["indices"] == 64 * 32 * 1          # int8 deltas
    assert mem["ratio"] < 0.32


@pytest.mark.parametrize("fn,kw", [
    (unstructured_mask, {}),
    (block_mask, {"block": (2, 2)}),
    (bank_balanced_mask, {"num_banks": 4}),
])
def test_baseline_masks_hit_target_sparsity(fn, kw):
    w = jnp.asarray(np.random.default_rng(1).normal(size=(32, 64)),
                    jnp.float32)
    for spar in (0.25, 0.5, 0.75):
        m = fn(w, spar, **kw)
        assert abs(sparsity_of(m) - spar) < 0.05


def test_bank_balanced_per_bank_counts():
    w = jnp.asarray(np.random.default_rng(2).normal(size=(8, 64)), jnp.float32)
    m = np.asarray(bank_balanced_mask(w, 0.5, num_banks=4))
    banked = m.reshape(8, 4, 16)
    assert (banked.sum(-1) == 8).all()
