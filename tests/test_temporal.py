"""Temporal delta sparsity (repro.sparse.temporal + delta_rb_spmv).

Covers the ISSUE-3 acceptance criteria: delta_rb_spmv pallas↔ref parity,
Θ=0 reproducing the dense/packed decode trajectory, and serving parity
under the continuous-batching scheduler with delta enabled.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pack_from_dense
from repro.kernels import (delta_rb_spmv, delta_rb_dual_spmv, ref)
from repro.kernels import ops as K
from repro.models import LSTMModel, LSTMConfig
from repro.serving import (ServeEngine, ContinuousBatchingEngine,
                           SamplingConfig)
from repro.sparse import (DeltaGateConfig, SparsityPolicy, cap_count,
                          delta_threshold, lstm_policy, occupancy_report,
                          use_backend)


def _rand(rng, shape, dtype=jnp.float32):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32), dtype)


# ------------------------------------------------------- kernel parity

@pytest.mark.parametrize("rows,cols,spar,B", [
    (128, 64, 0.5, 1), (256, 96, 0.75, 4), (96, 33, 0.3, 3),
])
def test_delta_rb_spmv_matches_ref(rng, rows, cols, spar, B):
    s = pack_from_dense(_rand(rng, (rows, cols)), spar)
    d = _rand(rng, (B, cols))
    fired = jnp.asarray(rng.random((B, cols)) > 0.5)
    got = delta_rb_spmv(s, d, fired, block_rows=64)
    want = ref.delta_rb_spmv_ref(s, d, fired.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("H,X,sx,sh", [(64, 48, 0.875, 0.5)])
def test_delta_rb_dual_spmv_matches_ref(rng, H, X, sx, sh):
    """The fused partial-sum update m' = m + Sx@(fx·dx) + Sh@(fh·dh)."""
    sx_p = pack_from_dense(_rand(rng, (4 * H, X)), sx)
    sh_p = pack_from_dense(_rand(rng, (4 * H, H)), sh)
    dx, dh = _rand(rng, (2, X)), _rand(rng, (2, H))
    fx = jnp.asarray(rng.random((2, X)) > 0.3)
    fh = jnp.asarray(rng.random((2, H)) > 0.3)
    m = _rand(rng, (2, 4 * H))
    got = delta_rb_dual_spmv(sx_p, dx, fx, sh_p, dh, fh, m, block_rows=64)
    want = ref.delta_rb_dual_spmv_ref(sx_p, dx, fx.astype(jnp.float32),
                                      sh_p, dh, fh.astype(jnp.float32), m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_delta_spmv_unfired_columns_contribute_nothing(rng):
    """delta_rb_spmv over a fired mask equals rb_spmv over the masked
    delta — the unfired columns' products never land."""
    s = pack_from_dense(_rand(rng, (128, 64)), 0.75)
    d = _rand(rng, (2, 64))
    fired = jnp.asarray(rng.random((2, 64)) > 0.7)
    got = K.delta_rb_spmv(s, d, fired, backend="ref")
    want = K.rb_spmv(s, jnp.where(fired, d, 0.0), backend="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


# ------------------------------------------------- thresholding semantics

def test_delta_threshold_theta0_tracks_exactly(rng):
    v = _rand(rng, (3, 32))
    ref_state = v.at[:, ::2].add(1.0)       # half the columns changed
    d, fired, new_ref = delta_threshold(v, ref_state, 0.0)
    assert bool(jnp.all(fired[:, ::2])) and not bool(jnp.any(fired[:, 1::2]))
    np.testing.assert_array_equal(np.asarray(new_ref), np.asarray(v))


def test_delta_threshold_cap_is_exact_budget(rng):
    v = _rand(rng, (4, 64))
    d, fired, new_ref = delta_threshold(v, jnp.zeros_like(v), 0.0, cap=0.25)
    counts = np.asarray(jnp.sum(fired, axis=1))
    assert (counts == cap_count(0.25, 64)).all()
    # the survivors are the largest |delta| columns
    top = np.argsort(-np.abs(np.asarray(d)), axis=1)[:, :16]
    fired_np = np.asarray(fired)
    for b in range(4):
        assert fired_np[b, top[b]].all()
    # unfired columns keep the old reference
    np.testing.assert_array_equal(np.asarray(new_ref)[~fired_np],
                                  np.zeros_like(v)[~fired_np])


def test_delta_gate_config_validation():
    with pytest.raises(ValueError):
        DeltaGateConfig(theta_x=-0.1)
    with pytest.raises(ValueError):
        DeltaGateConfig(cap_x=0.0)
    assert cap_count(None, 100) is None
    assert cap_count(1.0, 100) is None


# --------------------------------------------------- policy plumbing

def test_policy_carries_activation_rule():
    cfg = DeltaGateConfig(theta_x=0.05, theta_h=0.02)
    pol = lstm_policy(0.875, 0.75, delta=cfg)
    assert pol.activation == cfg
    model = LSTMModel(LSTMConfig("t", input_size=16, hidden=32,
                                 vocab_size=64))
    plan = pol.compile(model.init(jax.random.key(0)))
    assert plan.activation == cfg
    assert pol.with_activation(None).activation is None
    # SparsityPolicy.of also accepts it
    assert SparsityPolicy.of({r"w_x$": 0.5}, activation=cfg).activation is cfg


def test_engine_prepare_wires_delta_model():
    cfg = LSTMConfig("t", input_size=16, hidden=32, vocab_size=64)
    model = LSTMModel(cfg)
    params = model.init(jax.random.key(0))
    dcfg = DeltaGateConfig(theta_x=0.1)
    eng = ServeEngine(model, cfg, max_len=16, batch=2,
                      sparsity=lstm_policy(0.5, 0.5, delta=dcfg))
    eng.prepare(params)
    assert eng.model is not model and eng.model.delta == dcfg
    defs = eng.model.cache_defs(2, 16)["layers"][0]
    assert {"x_ref", "h_ref", "m", "nx", "nh"} <= set(defs)


# --------------------------------------------- decode trajectory parity

def _lm(num_layers=2):
    cfg = LSTMConfig("t", input_size=48, hidden=64, num_layers=num_layers,
                     vocab_size=128)
    model = LSTMModel(cfg)
    return cfg, model, model.init(jax.random.key(0))


def test_theta0_matches_packed_decode_trajectory():
    """Θ=0 fires every changed column → greedy decode reproduces the
    packed (non-delta) trajectory token for token."""
    cfg, model, params = _lm()
    B, P, G = 3, 10, 24
    prompt = jax.random.randint(jax.random.key(1), (B, P), 0, cfg.vocab_size)
    with use_backend("ref"):
        eng0 = ServeEngine(model, cfg, max_len=P + G, batch=B,
                           sparsity=lstm_policy(0.875, 0.75))
        packed0, _ = eng0.prepare(params)
        base = eng0.generate(packed0, prompt, G)

        eng = ServeEngine(model, cfg, max_len=P + G, batch=B,
                          sparsity=lstm_policy(0.875, 0.75,
                                               delta=DeltaGateConfig()))
        packed, _ = eng.prepare(params)
        toks, state = eng.generate(packed, prompt, G, return_state=True)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(toks))
    occ = occupancy_report(state["cache"], steps=P + G, packed=packed)
    assert 0.0 < occ["occupancy"] <= 1.0 and occ["ops_reduction"] >= 1.0


def test_theta0_matches_dense_decode_states():
    """Dense params + Θ=0: the delta path's hidden state tracks the plain
    dense step to accumulation tolerance."""
    cfg, model, params = _lm(num_layers=1)
    dmodel = model.with_delta(DeltaGateConfig())
    B, T = 2, 12
    x = jax.random.randint(jax.random.key(2), (B, T), 0, cfg.vocab_size)
    _, cache_d = model.prefill(params, x, max_len=T)
    _, cache_delta = dmodel.prefill(params, x, max_len=T)
    np.testing.assert_allclose(
        np.asarray(cache_delta["layers"][0]["h"]),
        np.asarray(cache_d["layers"][0]["h"]), atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(
        np.asarray(cache_delta["layers"][0]["c"]),
        np.asarray(cache_d["layers"][0]["c"]), atol=2e-5, rtol=2e-5)


def test_delta_pallas_matches_ref_backend_decode():
    """The packed delta decode agrees between the Pallas kernels and the
    jnp reference formulations."""
    cfg, model, params = _lm(num_layers=1)
    B, P, G = 2, 6, 8
    prompt = jax.random.randint(jax.random.key(3), (B, P), 0, cfg.vocab_size)
    pol = lstm_policy(0.75, 0.5, delta=DeltaGateConfig(theta_x=0.05,
                                                       theta_h=0.05))
    outs = {}
    for backend in ("pallas", "ref"):
        with use_backend(backend):
            eng = ServeEngine(model, cfg, max_len=P + G, batch=B,
                              sparsity=pol)
            packed, _ = eng.prepare(params)
            outs[backend] = np.asarray(eng.generate(packed, prompt, G))
    np.testing.assert_array_equal(outs["pallas"], outs["ref"])


def test_high_theta_reduces_occupancy():
    cfg, model, params = _lm(num_layers=1)
    B, P, G = 2, 8, 16
    prompt = jax.random.randint(jax.random.key(4), (B, P), 0, cfg.vocab_size)
    with use_backend("ref"):
        eng = ServeEngine(model, cfg, max_len=P + G, batch=B,
                          sparsity=lstm_policy(
                              0.875, 0.75,
                              delta=DeltaGateConfig(theta_x=0.3,
                                                    theta_h=0.3)))
        packed, _ = eng.prepare(params)
        _, state = eng.generate(packed, prompt, G, return_state=True)
    occ = occupancy_report(state["cache"], steps=P + G, packed=packed)
    assert occ["occupancy"] < 0.9
    assert occ["ops_reduction"] > 1.1
    assert occ["effective_macs"] < occ["packed_macs"]


# ------------------------------------------------- scheduler (continuous)

def test_scheduler_parity_with_delta_enabled():
    """Θ=0 delta decode under the continuous-batching scheduler returns
    the same tokens as the packed non-delta scheduler run."""
    cfg, model, params = _lm(num_layers=1)
    plan = lstm_policy(0.875, 0.75).compile(params)
    pruned, masks = plan.prune(params)
    packed, _ = plan.pack(pruned, masks)
    reqs = [(4, 10), (9, 6), (6, 12)]

    def run(m):
        sched = ContinuousBatchingEngine(m, packed, slots=2, max_len=32,
                                         sampling=SamplingConfig(), chunk=4)
        for i, (plen, gen) in enumerate(reqs):
            pr = jax.random.randint(jax.random.key(10 + i), (1, plen), 0,
                                    cfg.vocab_size)
            sched.submit(pr, gen)
        return sched.run()

    with use_backend("ref"):
        base = run(model)
        delta = run(model.with_delta(DeltaGateConfig()))
    assert base.keys() == delta.keys()
    for uid in base:
        np.testing.assert_array_equal(base[uid], delta[uid])
