"""Mixture-of-Experts: top-k routing with per-sequence capacity dispatch.

GShard-style grouping: tokens are routed *within their own sequence* (group =
sequence), so the dispatch buffers stay (B, E, C, d) with C = S·k/E·cf and
shard as batch→data, experts→model. Position-in-expert is computed with a
cumulative-sum rank (no sort), overflow tokens are dropped (capacity factor
controls drop rate), and the combine is a slot-aligned weighted sum — no
scatter-add. XLA SPMD turns the token↔expert resharding into all-to-alls.

The expert FFN weights (E, d, ff) / (E, ff, d) are the BRDS "family A"
(pruned harder); the router stays dense (tiny, accuracy-critical).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import PSpec, _act
from ..sharding import constrain


def moe_defs(d_model: int, d_ff: int, num_experts: int, activation: str,
             dtype) -> dict:
    d = {
        "router": PSpec((d_model, num_experts), ("embed", "experts"),
                        dtype=jnp.float32),
    }
    if activation.endswith("_glu"):
        d["w_gate"] = PSpec((num_experts, d_model, d_ff),
                            ("experts", "embed", "mlp"), dtype=dtype)
    d["w_up"] = PSpec((num_experts, d_model, d_ff),
                      ("experts", "embed", "mlp"), dtype=dtype)
    d["w_down"] = PSpec((num_experts, d_ff, d_model),
                        ("experts", "mlp", "embed"), dtype=dtype)
    return d


def capacity(seq_len: int, top_k: int, num_experts: int, cf: float) -> int:
    c = int(math.ceil(seq_len * top_k / num_experts * cf))
    return max(4, c)


def _topk_iterative(probs, K: int):
    """Top-k by K argmax passes. lax.top_k lowers to a TopK custom-call that
    XLA SPMD cannot partition — it all-gathered the full router probs
    (134 MB × 48/step on the granite dry-run). argmax/max are plain
    reductions over the (unsharded) expert dim and partition cleanly."""
    vals, ids = [], []
    p = probs
    for _ in range(K):
        i = jnp.argmax(p, axis=-1)
        vals.append(jnp.max(p, axis=-1))
        ids.append(i)
        p = p - jax.nn.one_hot(i, p.shape[-1], dtype=p.dtype) * 1e9
    return jnp.stack(vals, -1), jnp.stack(ids, -1).astype(jnp.int32)


def moe_apply(p: dict, x, *, num_experts: int, top_k: int,
              capacity_factor: float, activation: str,
              group_size: int = 1024):
    """x: (B, S, d) → (out (B, S, d), aux_loss scalar).

    Tokens are routed within GROUPS of ≤group_size tokens (GShard): dispatch
    cost scales with the per-group capacity C = G·k/E·cf, so smaller groups
    cut the one-hot einsum FLOPs linearly (at slightly higher drop variance).
    """
    B0, S0, d = x.shape
    G = min(group_size, S0)
    while S0 % G:
        G -= 1
    x = x.reshape(B0 * (S0 // G), G, d)
    # sharding propagation can drop the batch sharding across this reshape
    # (measured: replicated router probs → 134 MB top_k all-gathers); pin it
    x = constrain(x, "batch", "seq", "embed")
    B, S = x.shape[:2]
    E, K = num_experts, top_k
    C = capacity(S, K, E, capacity_factor)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                    # (B, S, E)
    gate_vals, expert_ids = _topk_iterative(probs, K)          # (B, S, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    # ---- load-balancing auxiliary loss (Switch-style, per sequence)
    me = jnp.mean(probs, axis=1)                               # (B, E)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_ids, E, dtype=jnp.float32), axis=2),
        axis=1)                                                # (B, E)
    aux = jnp.mean(jnp.sum(me * ce, axis=-1)) * E

    # ---- dispatch: rank each (token, slot) within its expert, drop overflow.
    # GShard-style one-hot EINSUM dispatch: scatter/gather with computed
    # indices does not partition under SPMD (the partitioner replicates the
    # full value tensor — measured as a 34 TB all-reduce on the granite
    # dry-run); einsums partition natively (batch→data, experts→model, the
    # token↔expert movement becomes all-to-all-shaped collectives). The
    # dispatch-mask einsums cost ~25-40% of expert FLOPs — the known GShard
    # overhead; the shard_map all-to-all variant is the §Perf hillclimb.
    flat_ids = expert_ids.reshape(B, S * K)                    # (B, SK)
    onehot = jax.nn.one_hot(flat_ids, E, dtype=jnp.int32)      # (B, SK, E)
    pos = jnp.cumsum(onehot, axis=1) - 1                       # rank within expert
    pos_in_e = jnp.sum(pos * onehot, axis=-1)                  # (B, SK)
    safe_pos = jnp.where(pos_in_e < C, pos_in_e, C)            # C → dropped
    cdt = x.dtype
    # one-hots are functions of INTEGER indices → no gradient flows through
    # them; stop_gradient prunes the (large) structurally-zero backward dots
    D = jax.lax.stop_gradient(
        jax.nn.one_hot(expert_ids, E, dtype=cdt))              # (B, S, K, E)
    P = jax.lax.stop_gradient(
        jax.nn.one_hot(safe_pos.reshape(B, S, K), C, dtype=cdt))
    DP = jax.lax.stop_gradient(
        jnp.einsum("bske,bskc->bsec", D, P))                   # dispatch mask
    buf = jnp.einsum("bsec,bsd->becd", DP, x)                  # (B, E, C, d)
    buf = constrain(buf, "batch", "experts", "expert_cap", "embed")

    # ---- expert FFN (batched over E; E sharded on model axis)
    if activation.endswith("_glu"):
        g = _act(activation, jnp.einsum("becd,edf->becf", buf, p["w_gate"]))
        u = jnp.einsum("becd,edf->becf", buf, p["w_up"])
        h = g * u
    else:
        h = _act(activation, jnp.einsum("becd,edf->becf", buf, p["w_up"]))
    y = jnp.einsum("becf,efd->becd", h, p["w_down"])
    y = constrain(y, "batch", "experts", "expert_cap", "embed")

    # ---- combine: gate-weighted one-hot einsum back to token order
    # (dropped slots hit the zero row of the C-one-hot → contribute 0).
    # Structured as (D·gate) ⊗ P so the gate gradient contracts c locally
    # and only psums a (b,s,k) tensor — the fused 3-operand einsum made XLA
    # all-reduce a (b,s,C,K) fp32 intermediate (2.7 GB/layer) instead.
    Dg = D * gate_vals.astype(cdt)[..., None]                  # (B, S, K, E)
    comb = jnp.einsum("bske,bskc->bsec", Dg, P)                # combine mask
    out = jnp.einsum("becd,bsec->bsd", y, comb)
    return out.astype(x.dtype).reshape(B0, S0, d), aux
