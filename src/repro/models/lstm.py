"""The paper's LSTM (eq. 1–2) with first-class BRDS sparsity.

Gate layout: rows grouped by gate [f; i; g; o], each H rows, so W ∈ R^{4H×X}
and W_h ∈ R^{4H×H} exactly as in the paper (the paper interleaves the four
gates' rows in memory; grouping is an equivalent permutation — noted in
DESIGN.md). Dense masked path for training/retraining; packed row-balanced
path (rb_dual_spmv + lstm_gates Pallas kernels) for inference — the BRDS
accelerator datapath.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from . import layers as L
from ..core import sparsity as S
from ..core.packing import RowBalancedSparse, pad_packed
from ..kernels import ops as K
from ..quant import RowBalancedSparseQ8, quantize_packed, parse_scheme
from ..sparse import get_format, lstm_policy
from ..sparse import mask_grads as _sparse_mask_grads
from ..sparse.temporal import delta_threshold


@dataclasses.dataclass(frozen=True)
class LSTMConfig:
    name: str
    input_size: int            # X
    hidden: int                # H
    num_layers: int = 1
    vocab_size: int = 0        # >0 → language model (embed + head)
    num_classes: int = 0       # >0 → sequence classifier (IMDB) / framewise (TIMIT)
    framewise: bool = False    # per-step classification (TIMIT-style)
    dtype: Any = jnp.float32
    pwl_activations: bool = False   # paper's piecewise-linear σ/tanh


class LSTMModel:
    """The paper's LSTM behind every surface of the stack.

    ``delta`` (a ``repro.sparse.DeltaGateConfig`` or None) switches the
    serving path to Spartus-style temporal sparsity: the DecodeStep cache
    grows per-layer reference states (x_ref, h_ref), a partial-sum memory
    m, and fired-column counters (nx, nh), and prefill/decode step through
    ``_delta_step`` — only columns whose activation delta crossed Θ
    contribute matvec products (``kernels.ops.delta_rb_spmv`` on packed
    params, masked einsum on dense ones).

    ``quant`` (a ``repro.quant.QuantPlan`` or None) carries the calibrated
    per-layer activation scales for quantized packed params
    (RowBalancedSparseQ8 leaves): every step dispatches the q8 kernels
    (integer products, int32 accumulate, per-row dequant). Quantized
    params without a plan still serve — the kernels fall back to dynamic
    max-abs activation scales.

    ``mesh`` (a jax Mesh with a ``model`` axis, or None) switches packed
    decode to the sharded path (``repro.dist``): params must be
    ``partition_lstm_params``' gate-aligned row-sharded layout, the cache
    keeps c (and the delta partial sums m) sharded with h replicated, and
    each step's only collective is the all-gather of h. Composes with
    ``delta`` and ``quant``.

    ``fused`` (None/True/False) controls single-launch decode: the
    default (None) dispatches every packed step through the fused
    ``kernels.fused_step`` kernels — dual-ratio SpMV + bias + gates +
    cell in ONE ``pallas_call``, bitwise-identical to the chained path —
    wherever shapes allow; sharded (``mesh``) decode always falls back to
    the chained per-kernel path (the all-gather between Gate and Function
    needs the kernel boundary). ``fused=False`` forces the chained path
    (two to three launches per token)."""

    def __init__(self, cfg: LSTMConfig, delta=None, quant=None, mesh=None,
                 fused=None):
        self.cfg = cfg
        self.delta = delta
        self.quant = quant
        self.mesh = mesh
        self.fused = fused

    def with_delta(self, delta) -> "LSTMModel":
        """Copy of this model serving through the temporal-delta path
        (``delta``: a DeltaGateConfig, or None to disable)."""
        return LSTMModel(self.cfg, delta=delta, quant=self.quant,
                         mesh=self.mesh, fused=self.fused)

    def with_quant(self, quant) -> "LSTMModel":
        """Copy of this model carrying a quantization plan
        (``quant``: a repro.quant.QuantPlan, or None to disable)."""
        return LSTMModel(self.cfg, delta=self.delta, quant=quant,
                         mesh=self.mesh, fused=self.fused)

    def with_mesh(self, mesh) -> "LSTMModel":
        """Copy of this model decoding through the sharded packed path
        (``mesh``: a Mesh with a ``model`` axis — serve it
        ``repro.dist.partition_lstm_params``' layout — or None)."""
        return LSTMModel(self.cfg, delta=self.delta, quant=self.quant,
                         mesh=mesh, fused=self.fused)

    def with_fused(self, fused) -> "LSTMModel":
        """Copy of this model with single-launch fused decode forced on
        (True), forced off (False), or automatic (None — on wherever
        shapes allow)."""
        return LSTMModel(self.cfg, delta=self.delta, quant=self.quant,
                         mesh=self.mesh, fused=fused)

    @property
    def _use_fused(self) -> bool:
        """Fused single-launch kernels on this step? Default-on; sharded
        decode needs the chained kernel boundary for its collective."""
        return (self.fused is None or bool(self.fused)) \
            and self.mesh is None

    # ------------------------------------------------------------- params
    def param_defs(self) -> dict:
        cfg = self.cfg
        dt = cfg.dtype
        defs: dict[str, Any] = {"layers": []}
        for i in range(cfg.num_layers):
            x_in = cfg.input_size if i == 0 else cfg.hidden
            defs["layers"].append({
                "w_x": L.PSpec((4 * cfg.hidden, x_in),
                               ("lstm_gates", "embed"), dtype=dt),
                "w_h": L.PSpec((4 * cfg.hidden, cfg.hidden),
                               ("lstm_gates", "lstm_hidden"), dtype=dt),
                "b": L.PSpec((4 * cfg.hidden,), ("lstm_gates",),
                             init="zeros", dtype=dt),
            })
        if cfg.vocab_size:
            defs["embed"] = {"table": L.PSpec((cfg.vocab_size, cfg.input_size),
                                              ("vocab", "embed"), scale=1.0,
                                              dtype=dt)}
            defs["head"] = {"w": L.PSpec((cfg.hidden, cfg.vocab_size),
                                         ("embed", "vocab"), dtype=dt)}
        if cfg.num_classes:
            defs["head"] = {"w": L.PSpec((cfg.hidden, cfg.num_classes),
                                         ("embed", None), dtype=dt)}
        return defs

    def init(self, rng):
        return L.init_params(self.param_defs(), rng)

    def abstract_params(self):
        return L.abstract_params(self.param_defs())

    def param_axes(self):
        return L.param_axes(self.param_defs())

    def param_count(self) -> int:
        return L.count_params(self.param_defs())

    # ------------------------------------------------------------- core
    @staticmethod
    def _cell(z, c_prev, *, pwl=False):
        """z (B, 4H) grouped [f; i; g; o] → (c, h)."""
        H4 = z.shape[-1]
        H = H4 // 4
        zf, zi, zg, zo = (z[..., :H], z[..., H:2 * H], z[..., 2 * H:3 * H],
                          z[..., 3 * H:])
        from ..kernels.ref import lstm_cell_ref
        return lstm_cell_ref(zf, zi, zg, zo, c_prev, pwl=pwl)

    def _scan_layer(self, lp, xs, c0, h0):
        """xs (B, T, X_in) → hs (B, T, H)."""
        def step(carry, x_t):
            c, h = carry
            z = (x_t @ lp["w_x"].T + h @ lp["w_h"].T +
                 lp["b"][None, :]).astype(jnp.float32)
            c, h = self._cell(z, c, pwl=self.cfg.pwl_activations)
            return (c, h), h
        (c, h), hs = jax.lax.scan(step, (c0, h0), xs.transpose(1, 0, 2))
        return hs.transpose(1, 0, 2), (c, h)

    def features(self, params, inputs):
        """inputs: tokens (B, T) int if LM else features (B, T, X).
        Returns per-step hidden states of the last layer (B, T, H)."""
        cfg = self.cfg
        if cfg.vocab_size:
            x = L.embed_apply(params["embed"], inputs)
        else:
            x = inputs.astype(cfg.dtype)
        B = x.shape[0]
        for lp in params["layers"]:
            c0 = jnp.zeros((B, cfg.hidden), cfg.dtype)
            h0 = jnp.zeros((B, cfg.hidden), cfg.dtype)
            x, _ = self._scan_layer(lp, x, c0, h0)
        return x

    def forward(self, params, inputs):
        cfg = self.cfg
        hs = self.features(params, inputs)
        if cfg.vocab_size:
            return jnp.einsum("bth,hv->btv", hs,
                              params["head"]["w"]).astype(jnp.float32)
        logits = jnp.einsum("bth,hc->btc", hs,
                            params["head"]["w"]).astype(jnp.float32)
        return logits if cfg.framewise else logits[:, -1]

    def loss(self, params, batch):
        from ..core.metrics import cross_entropy
        cfg = self.cfg
        logits = self.forward(params, batch["inputs"])
        if cfg.vocab_size:
            return cross_entropy(logits[:, :-1], batch["labels"][:, 1:])
        if cfg.framewise:
            return cross_entropy(logits, batch["labels"])
        lab = batch["labels"]
        onehot = jax.nn.one_hot(lab, logits.shape[-1])
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.sum(onehot * logp, axis=-1))

    # ------------------------------------------------------------- BRDS
    # The sparsity surface is repro.sparse: these methods are conveniences
    # over lstm_policy → SparsityPlan so existing callers keep working.
    def sparsity_policy(self, spar_x: float, spar_h: float, *,
                        backend: str = "auto"):
        """The paper's dual-ratio policy for this model's param tree."""
        return lstm_policy(spar_x, spar_h, backend=backend)

    def prune(self, params, spar_x: float, spar_h: float):
        """Row-balanced dual-ratio prune of every layer. Returns
        (pruned_params, masks) — masks: {path: bool_mask} (repro.sparse
        layout, accepted by mask_grads)."""
        plan = self.sparsity_policy(spar_x, spar_h).compile(params)
        return plan.prune(params)

    def mask_grads(self, grads, masks):
        """Freeze pruned weights: zero their gradients. Accepts the plan's
        {path: mask} dict or the legacy per-layer list of dicts."""
        if isinstance(masks, dict):
            return _sparse_mask_grads(grads, masks)
        new_layers = []
        for g, m in zip(grads["layers"], masks):
            new_layers.append({**g,
                               "w_x": S.apply_mask(g["w_x"], m["w_x"]),
                               "w_h": S.apply_mask(g["w_h"], m["w_h"])})
        return {**grads, "layers": new_layers}

    def pack(self, params, masks: dict | None = None, quant=None):
        """Pack pruned layers into RowBalancedSparse pairs for serving.

        ``masks`` is the {path: mask} dict from ``prune`` — packing from
        the plan's masks keeps surviving weights that happen to be exactly
        zero and preserves the row-balance accounting. With masks=None the
        survivors are re-selected per row by magnitude at the maximum
        per-row non-zero count (ties resolve to zeros, so rows stay
        balanced even if some survivors vanished during retraining).
        ``quant`` (a scheme name like ``"int8"``/``"q1.11"``, a
        QuantScheme, or a QuantConfig) additionally quantizes each packed
        matrix to RowBalancedSparseQ8 (integer codes + per-row scales)."""
        fmt = get_format("row_balanced")
        scheme = None
        if quant is not None:
            scheme = parse_scheme(getattr(quant, "scheme", quant))
        packed = []
        for i, lp in enumerate(params["layers"]):
            entry = {"b": lp["b"]}
            for key, out in (("w_x", "sx"), ("w_h", "sh")):
                m = (masks or {}).get(f"layers/{i}/{key}")
                if m is None:
                    m = _survivor_mask(lp[key])
                s = fmt.pack(lp[key], m)
                s = quantize_packed(s, scheme) if scheme else s
                # pad the row axis to the kernel block multiple ONCE here
                # instead of inside every jitted step (sharded decode
                # re-partitions rows, so it packs unpadded)
                entry[out] = s if self.mesh is not None else pad_packed(s)
            packed.append(entry)
        return packed

    @staticmethod
    def pad_packed_params(packed, block_rows: int = 256):
        """Pre-pad every packed matrix's rows to the kernel-block multiple
        (``core.packing.pad_packed``) so the per-step wrappers consume the
        arrays as-is — no per-token re-pad copy of the weight stream on
        the decode hot path. Accepts ``pack``'s per-layer list or a
        SparsityPlan.pack'd param tree; no-op on already-padded or dense
        leaves."""
        def _pad(s):
            return (pad_packed(s, block_rows)
                    if isinstance(s, (RowBalancedSparse, RowBalancedSparseQ8))
                    else s)
        if isinstance(packed, dict) and "layers" in packed:
            return {**packed, "layers": [
                {**lp, "w_x": _pad(lp["w_x"]), "w_h": _pad(lp["w_h"])}
                for lp in packed["layers"]]}
        return [{**lp, "sx": _pad(lp["sx"]), "sh": _pad(lp["sh"])}
                for lp in packed]

    @staticmethod
    def _packed_layers(packed):
        """Normalize to the per-layer [{'sx','sh','b'}] list: accepts that
        list directly or a SparsityPlan.pack'd param tree (whose w_x/w_h
        leaves are RowBalancedSparse)."""
        if isinstance(packed, dict) and "layers" in packed:
            return [{"sx": lp["w_x"], "sh": lp["w_h"], "b": lp["b"]}
                    for lp in packed["layers"]]
        return packed

    def _act_scales(self, i: int):
        """Calibrated (s_x, s_h) activation scales for layer ``i``, or
        (None, None) — the q8 kernels then fall back to dynamic max-abs
        (scaled schemes) / the fixed-point constant."""
        if self.quant is None or i >= self.quant.num_layers:
            return (None, None)
        return self.quant.scale_for(i)

    def sparse_step(self, packed, x_t, state, *, backend: str | None = None):
        """One inference time step on the packed BRDS path.

        x_t (B, X); state: list of (c, h) per layer. The dual-ratio fused
        kernel is the accelerator's Gate module; lstm_gates is Function.
        ``packed`` is model.pack's per-layer list or a SparsityPlan.pack'd
        param tree; quantized packings (RowBalancedSparseQ8) run the q8
        datapath."""
        new_state = []
        inp = x_t
        for i, (lp, (c, h)) in enumerate(zip(self._packed_layers(packed),
                                             state)):
            if isinstance(lp["sx"], RowBalancedSparseQ8):
                ax, ah = self._act_scales(i)
                c, h = K.brds_lstm_step_q8(
                    lp["sx"], inp, lp["sh"], h, lp["b"], c,
                    act_scale_x=ax, act_scale_h=ah,
                    pwl=self.cfg.pwl_activations, backend=backend)
            else:
                c, h = K.brds_lstm_step(lp["sx"], inp, lp["sh"], h, lp["b"],
                                        c, pwl=self.cfg.pwl_activations,
                                        backend=backend)
            new_state.append((c, h))
            inp = h
        return inp, new_state

    def dense_step(self, params, x_t, state):
        """Dense reference step (same contract as sparse_step)."""
        new_state = []
        inp = x_t
        for lp, (c, h) in zip(params["layers"], state):
            z = (inp @ lp["w_x"].T + h @ lp["w_h"].T +
                 lp["b"][None, :]).astype(jnp.float32)
            c, h = self._cell(z, c, pwl=self.cfg.pwl_activations)
            new_state.append((c, h))
            inp = h
        return inp, new_state

    def init_state(self, batch: int):
        cfg = self.cfg
        return [(jnp.zeros((batch, cfg.hidden), cfg.dtype),
                 jnp.zeros((batch, cfg.hidden), cfg.dtype))
                for _ in range(cfg.num_layers)]

    # ------------------------------------------------------------- serving
    # DecodeStep contract (repro.serving.runtime): the recurrent (c, h)
    # pair per layer IS the decode cache. decode_step dispatches on the
    # param leaves: SparsityPlan.pack'd trees (w_x/w_h are
    # RowBalancedSparse) run the packed rb_dual_spmv + lstm_gates
    # accelerator datapath, quantized trees (RowBalancedSparseQ8) the q8
    # int32-accumulate datapath; dense trees run the reference einsum step.
    supports_packed_decode = True

    @staticmethod
    def is_packed(params) -> bool:
        return isinstance(params["layers"][0]["w_x"],
                          (RowBalancedSparse, RowBalancedSparseQ8))

    @staticmethod
    def is_quantized(params) -> bool:
        return isinstance(params["layers"][0]["w_x"], RowBalancedSparseQ8)

    def cache_defs(self, batch: int, max_len: int) -> dict:
        """Decode-cache declaration (a PSpec pytree).

        ``max_len`` is part of the contract but unused — state is O(1).
        With temporal sparsity enabled the cache additionally carries, per
        layer: the reference states ``x_ref`` (B, X_in) / ``h_ref``
        (B, H), the fp32 partial-sum memory ``m`` (B, 4H), and cumulative
        fired-column counters ``nx``/``nh`` (B,) — the effective-ops
        numerators ``repro.sparse.occupancy_report`` reduces.

        With a ``mesh`` the sharded-decode layouts apply: ``c`` carries
        the ``lstm_hidden_shard`` logical axis (model-sharded with the
        gate rows it is updated from) while ``h`` stays replicated — the
        per-step activation broadcast (``m`` already rides the
        model-sharded ``lstm_gates`` axis)."""
        cfg = self.cfg
        c_ax = "lstm_hidden_shard" if self.mesh is not None else "lstm_hidden"
        defs = {"layers": [
            {"c": L.PSpec((batch, cfg.hidden), ("batch", c_ax),
                          init="zeros", dtype=cfg.dtype),
             "h": L.PSpec((batch, cfg.hidden), ("batch", "lstm_hidden"),
                          init="zeros", dtype=cfg.dtype)}
            for _ in range(cfg.num_layers)]}
        if self.delta is not None:
            for i, lp in enumerate(defs["layers"]):
                x_in = cfg.input_size if i == 0 else cfg.hidden
                lp.update({
                    "x_ref": L.PSpec((batch, x_in), ("batch", "embed"),
                                     init="zeros", dtype=cfg.dtype),
                    "h_ref": L.PSpec((batch, cfg.hidden),
                                     ("batch", "lstm_hidden"),
                                     init="zeros", dtype=cfg.dtype),
                    "m": L.PSpec((batch, 4 * cfg.hidden),
                                 ("batch", "lstm_gates"),
                                 init="zeros", dtype=jnp.float32),
                    "nx": L.PSpec((batch,), ("batch",), init="zeros",
                                  dtype=jnp.float32),
                    "nh": L.PSpec((batch,), ("batch",), init="zeros",
                                  dtype=jnp.float32),
                })
        return defs

    def init_cache(self, batch: int, max_len: int):
        return L.init_params(self.cache_defs(batch, max_len),
                             jax.random.key(0))

    def _step(self, params, x_t, state):
        """One time step, packed or dense by param type. state/new_state:
        list of (c, h); returns (h_last, new_state) in cfg.dtype."""
        cfg = self.cfg
        packed = self.is_packed(params)
        quantized = packed and self.is_quantized(params)
        if packed and self.mesh is not None:
            from ..dist import collective_ops as C
            scales = ([self._act_scales(i) for i in range(cfg.num_layers)]
                      if quantized else None)
            return C.dist_lstm_step(self.mesh, params["layers"], x_t, state,
                                    pwl=cfg.pwl_activations, dtype=cfg.dtype,
                                    act_scales=scales)
        fused = self._use_fused
        new_state = []
        inp = x_t
        for i, (lp, (c, h)) in enumerate(zip(params["layers"], state)):
            if quantized:
                ax, ah = self._act_scales(i)
                step_q8 = (K.fused_brds_lstm_step_q8 if fused
                           else K.brds_lstm_step_q8)
                c, h = step_q8(lp["w_x"], inp, lp["w_h"], h,
                               lp["b"], c, act_scale_x=ax,
                               act_scale_h=ah,
                               pwl=cfg.pwl_activations)
            elif packed:
                step = (K.fused_brds_lstm_step if fused
                        else K.brds_lstm_step)
                c, h = step(lp["w_x"], inp, lp["w_h"], h,
                            lp["b"], c,
                            pwl=cfg.pwl_activations)
            else:
                z = (inp @ lp["w_x"].T + h @ lp["w_h"].T +
                     lp["b"][None, :]).astype(jnp.float32)
                c, h = self._cell(z, c, pwl=cfg.pwl_activations)
            c, h = c.astype(cfg.dtype), h.astype(cfg.dtype)
            new_state.append((c, h))
            inp = h
        return inp, new_state

    def _delta_step(self, params, x_t, state):
        """One temporally-sparse time step (Spartus composition).

        ``state``: per-layer dicts {c, h, x_ref, h_ref, m, nx, nh}. Each
        layer thresholds its input/hidden deltas against the reference
        states and advances the partial-sum memory with only the fired
        columns' products: packed params run the fused
        ``brds_delta_lstm_step`` (delta_rb_dual_spmv + lstm_gates), dense
        params the masked-delta einsum. Returns (h_last, new_state)."""
        cfg = self.cfg
        d = self.delta
        packed = self.is_packed(params)
        quantized = packed and self.is_quantized(params)
        if packed and self.mesh is not None:
            from ..dist import collective_ops as C
            scales = None
            if quantized:
                # same delta-range doubling as the loop below: the
                # calibrated scales bound absolute activations, a delta
                # spans twice that range
                scales = [tuple(None if s is None else 2.0 * s
                                for s in self._act_scales(i))
                          for i in range(cfg.num_layers)]
            return C.dist_delta_lstm_step(
                self.mesh, params["layers"], x_t, state, d,
                pwl=cfg.pwl_activations, dtype=cfg.dtype, act_scales=scales)
        fused = self._use_fused
        new_state = []
        inp = x_t
        for i, (lp, st) in enumerate(zip(params["layers"], state)):
            dx, fx, x_ref = delta_threshold(inp, st["x_ref"], d.theta_x,
                                            d.cap_x)
            dh, fh, h_ref = delta_threshold(st["h"], st["h_ref"], d.theta_h,
                                            d.cap_h)
            if quantized:
                ax, ah = self._act_scales(i)
                # the calibrated scales bound ABSOLUTE activations; a
                # delta spans up to twice that range (−amax → +amax), and
                # a clipped delta bakes its error into the partial-sum
                # memory permanently — double the scale on this path
                # (fixed-point schemes ignore it: they saturate by design)
                ax = None if ax is None else 2.0 * ax
                ah = None if ah is None else 2.0 * ah
                step_q8 = (K.fused_brds_delta_lstm_step_q8 if fused
                           else K.brds_delta_lstm_step_q8)
                c, h, m = step_q8(
                    lp["w_x"], dx, fx, lp["w_h"], dh, fh, st["m"], lp["b"],
                    st["c"], act_scale_x=ax, act_scale_h=ah,
                    pwl=cfg.pwl_activations)
            elif packed:
                step_d = (K.fused_brds_delta_lstm_step if fused
                          else K.brds_delta_lstm_step)
                c, h, m = step_d(
                    lp["w_x"], dx, fx, lp["w_h"], dh, fh, st["m"], lp["b"],
                    st["c"], pwl=cfg.pwl_activations)
            else:
                dxm = jnp.where(fx, dx, 0).astype(jnp.float32)
                dhm = jnp.where(fh, dh, 0).astype(jnp.float32)
                m = (st["m"].astype(jnp.float32)
                     + dxm @ lp["w_x"].T.astype(jnp.float32)
                     + dhm @ lp["w_h"].T.astype(jnp.float32))
                z = m + lp["b"].astype(jnp.float32)[None, :]
                c, h = self._cell(z, st["c"], pwl=cfg.pwl_activations)
            new_state.append({
                "c": c.astype(cfg.dtype), "h": h.astype(cfg.dtype),
                "x_ref": x_ref, "h_ref": h_ref,
                "m": m.astype(jnp.float32),
                "nx": st["nx"] + jnp.sum(fx, axis=1, dtype=jnp.float32),
                "nh": st["nh"] + jnp.sum(fh, axis=1, dtype=jnp.float32)})
            inp = new_state[-1]["h"]
        return inp, new_state

    def score(self, params, inputs, labels=None):
        """Teacher-forced mean NLL through the SERVING step path.

        Unlike ``loss`` (the training-time dense scan), ``score`` steps
        every position through ``_step``/``_delta_step`` — the exact
        per-token computation decode runs — so it accepts dense, packed
        (RowBalancedSparse), quantized (RowBalancedSparseQ8), and
        temporal-delta deployments alike and produces the quality number
        *of the deployed model*. ``launch.pipeline`` uses it on both sides
        of its serving-parity gate: the manually packed model and the
        ``ServeEngine.prepare``'d one must score bitwise equal.

        Parameters
        ----------
        params : pytree
            Dense or packed param tree (embed/head stay dense either way).
        inputs : jnp.ndarray
            (B, T) token ids (LM — next-token NLL over positions 1..T-1)
            or (B, T, X) frames (framewise — per-step NLL vs ``labels``).
        labels : jnp.ndarray, optional
            (B, T) int labels; defaults to ``inputs`` (the LM case).

        Returns
        -------
        jnp.ndarray
            Scalar fp32 mean NLL (``core.metrics.perplexity`` exponentiates
            it).
        """
        from ..core.metrics import cross_entropy
        cfg = self.cfg
        if cfg.vocab_size:
            x = L.embed_apply(params["embed"], inputs)
            if labels is None:
                labels = inputs
        else:
            x = inputs.astype(cfg.dtype)
            if labels is None:
                raise ValueError("framewise score needs labels")
        B, T = x.shape[0], x.shape[1]
        if self.delta is not None:
            state0 = tuple(self.init_cache(B, T)["layers"])
            step_fn = lambda st, x_t: self._delta_step(params, x_t, list(st))
        else:
            state0 = tuple(self.init_state(B))
            step_fn = lambda st, x_t: self._step(params, x_t, st)

        def body(st, x_t):
            h, st2 = step_fn(st, x_t)
            return tuple(st2), h

        _, hs = jax.lax.scan(body, state0, x.transpose(1, 0, 2))
        hs = hs.transpose(1, 0, 2)
        logits = jnp.einsum("bth,hv->btv", hs.astype(jnp.float32),
                            params["head"]["w"].astype(jnp.float32))
        if cfg.vocab_size:
            return cross_entropy(logits[:, :-1], labels[:, 1:])
        return cross_entropy(logits, labels)

    def _head_logits(self, params, h):
        """h (B, H) → logits (B, 1, V or C) fp32."""
        return jnp.einsum("bh,hv->bv", h.astype(jnp.float32),
                          params["head"]["w"].astype(jnp.float32))[:, None]

    def _embed_step(self, params, tokens):
        """tokens (B, 1) ids (LM) or (B, 1, X) features → x_t (B, X)."""
        if self.cfg.vocab_size:
            return L.embed_apply(params["embed"], tokens[:, 0])
        return tokens[:, 0].astype(self.cfg.dtype)

    def prefill(self, params, tokens, max_len: int, extra=None,
                length=None):
        """Process a full prompt, build the decode cache.

        Works on dense and SparsityPlan.pack'd params. With temporal
        sparsity enabled the prompt is scanned through ``_delta_step`` so
        the reference states, partial sums, and occupancy counters arrive
        at decode already warm (the Spartus steady state).

        Parameters
        ----------
        params : pytree
            Dense or packed param tree.
        tokens : jnp.ndarray
            (B, S) int token ids (LM) or (B, S, X) feature frames.
        max_len : int
            Cache capacity (contractual; the LSTM cache is O(1)).
        extra : Any, optional
            Unused by the LSTM (family conditioning slot).
        length : int or (B,) int32, optional
            True prompt length(s) when ``tokens`` is right-padded to a
            bucket: steps at t ≥ length compute-and-discard (the carry is
            frozen per sequence), so the returned cache and last-valid
            logits are BITWISE what the unpadded prompt would produce.
            This is the scheduler's bucketed-prefill hook — one compile
            per padded width instead of one per distinct prompt length.

        Returns
        -------
        (logits, cache)
            Logits at the last (valid) position (B, 1, V) and the decode
            cache.
        """
        cfg = self.cfg
        if cfg.vocab_size:
            x = L.embed_apply(params["embed"], tokens)
        else:
            x = tokens.astype(cfg.dtype)
        B = x.shape[0]
        delta = self.delta is not None
        if delta:
            state0 = tuple(self.init_cache(B, max_len)["layers"])
            step_fn = lambda st, x_t: self._delta_step(params, x_t, list(st))
        else:
            state0 = tuple(self.init_state(B))
            step_fn = lambda st, x_t: self._step(params, x_t, st)

        # Exact (length=None) and bucketed prefill share ONE scan body:
        # the select that freezes padded-out state changes XLA's fusion
        # decisions inside the loop body at the ulp level, so a separate
        # unmasked fast path would NOT be bitwise against the masked one.
        # Running every prefill through the masked body makes padded+length
        # reproduce the unpadded prefill exactly (same compiled body, the
        # selects are all-keep no-ops below each sequence's length).
        if length is None:
            length = x.shape[1]
        length = jnp.asarray(length, jnp.int32)

        def step(carry, xt):
            st, h_last = carry
            x_t, t = xt
            h, st2 = step_fn(st, x_t)
            keep = jnp.broadcast_to(t < length, (B,))
            sel = lambda n, o: jnp.where(
                keep.reshape((B,) + (1,) * (n.ndim - 1)), n, o)
            st2 = jax.tree.map(sel, tuple(st2), st)
            return (st2, jnp.where(keep[:, None], h, h_last)), None

        h0 = jnp.zeros((B, cfg.hidden), cfg.dtype)
        (state, h_last), _ = jax.lax.scan(
            step, (state0, h0),
            (x.transpose(1, 0, 2), jnp.arange(x.shape[1])))
        logits = self._head_logits(params, h_last)
        if delta:
            return logits, {"layers": list(state)}
        return logits, {"layers": [{"c": c, "h": h} for c, h in state]}

    def decode_step(self, params, cache, tokens, pos):
        """One decode step over the cache.

        ``pos`` is accepted per the DecodeStep contract but unused (the
        recurrent cache has no positional structure). Dispatches packed vs
        dense on the param leaves, and through the temporal-delta path
        when the model carries a ``delta`` config.

        Returns
        -------
        (logits, cache)
            Logits (B, 1, V) and the advanced cache.
        """
        x_t = self._embed_step(params, tokens)
        if self.delta is not None:
            h, new_state = self._delta_step(params, x_t, cache["layers"])
            return self._head_logits(params, h), {"layers": new_state}
        state = [(lp["c"], lp["h"]) for lp in cache["layers"]]
        h, new_state = self._step(params, x_t, state)
        logits = self._head_logits(params, h)
        cache = {"layers": [{"c": c, "h": h} for c, h in new_state]}
        return logits, cache


def _survivor_mask(w) -> jnp.ndarray:
    """Row-balanced keep-mask for an already-pruned dense weight: per-row
    magnitude top-K at the maximum per-row non-zero count (zero-ties keep
    every row at exactly K non-zeros)."""
    import numpy as np
    counts = np.asarray(jnp.sum(w != 0, axis=1))
    k = int(counts.max()) if counts.size else 0
    order = jnp.argsort(-jnp.abs(w), axis=1)[:, :k]
    rows = jnp.broadcast_to(jnp.arange(w.shape[0])[:, None], order.shape)
    return jnp.zeros(w.shape, bool).at[rows, order].set(True)


# Paper benchmark configs (§5.1): TIMIT X=153 H=1024; PTB large 1500/1500;
# IMDB binary classifier.
LSTM_CONFIGS = {
    "lstm_timit": LSTMConfig("lstm_timit", input_size=153, hidden=1024,
                             num_classes=61, framewise=True),
    "lstm_ptb": LSTMConfig("lstm_ptb", input_size=1500, hidden=1500,
                           vocab_size=10000),
    "lstm_imdb": LSTMConfig("lstm_imdb", input_size=128, hidden=512,
                            vocab_size=0, num_classes=2),
}
