"""Attention: GQA/MQA/MHA with qk-norm, RoPE, local windows, KV caches.

Tensor-parallel strategy (model axis = 16 on the production mesh):
- Projection WEIGHTS shard greedily: heads → model if divisible, else
  head_dim → model (within-head Megatron split), else replicated.
- Attention COMPUTE always shards over q heads: `prepare_heads` repeats kv
  to the q-head count (GQA dup) and pads heads up to the next multiple of
  the model-axis size (dummy heads are zero → inert; outputs are sliced
  back). This keeps the online-softmax scan free of collectives; XLA
  inserts one reshard after the projections and one all-reduce after the
  output projection — the standard Megatron pattern, GQA-safe for any
  head count (llava's 56, llama's 24, MQA's 1, ...).

Two execution paths:
- `blocked_attention` — memory-safe online-softmax attention in pure jnp
  (nested lax.scan over q/kv blocks); the dry-run lowers this for
  train/prefill. The Pallas flash kernel is its TPU twin.
- `decode_attention_einsum` — single-token decode against a long cache
  (directly einsum-able; Pallas decode kernel is the TPU twin).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from .layers import PSpec, rope, rmsnorm, pmm
from ..sharding import constrain, _current_mesh


def attn_defs(d_model: int, num_heads: int, num_kv_heads: int, head_dim: int,
              qk_norm: bool, dtype) -> dict:
    d = {
        "wq": PSpec((d_model, num_heads, head_dim),
                    ("embed", "heads", "head_dim"), dtype=dtype),
        "wk": PSpec((d_model, num_kv_heads, head_dim),
                    ("embed", "kv_heads", "head_dim"), dtype=dtype),
        "wv": PSpec((d_model, num_kv_heads, head_dim),
                    ("embed", "kv_heads", "head_dim"), dtype=dtype),
        "wo": PSpec((num_heads, head_dim, d_model),
                    ("heads", "head_dim", "embed"), dtype=dtype),
    }
    if qk_norm:
        d["q_norm"] = PSpec((head_dim,), ("head_dim",), init="zeros",
                            dtype=jnp.float32)
        d["k_norm"] = PSpec((head_dim,), ("head_dim",), init="zeros",
                            dtype=jnp.float32)
    return d


def model_axis_size() -> int:
    mesh = _current_mesh()
    if mesh is None:
        return 1
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)


def qkv_project(p: dict, x, positions, *, qk_norm: bool, rope_theta: float,
                use_rope: bool = True):
    """x (B, S, d) → q (B, S, Hq, Dh), k/v (B, S, Hkv, Dh)."""
    q = pmm(x, p["wq"])
    k = pmm(x, p["wk"])
    v = pmm(x, p["wv"])
    if qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    if use_rope:
        q = rope(q, positions, rope_theta)
        k = rope(k, positions, rope_theta)
    return q, k, v


def prepare_heads(q, k, v, true_heads: int):
    """GQA dup + head padding for clean tensor parallelism.

    q (B,S,H_eff,D) where H_eff ≥ true_heads (param-level TP padding);
    k/v (B,S,Hkv,D). kv heads are repeated per TRUE GQA group
    (G = true_heads // Hkv), then everything is padded to
    Hp = H_eff rounded up to a model-axis multiple. Padded q rows attend to
    zero keys → uniform garbage that is sliced/masked away downstream.
    Returns (q', k', v') all (B,S,Hp,D)."""
    B, S, H_eff, D = q.shape
    Hkv = k.shape[2]
    G = true_heads // Hkv
    ms = model_axis_size()
    Hp = ((H_eff + ms - 1) // ms) * ms
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    if Hp != k.shape[2]:
        pad = ((0, 0), (0, 0), (0, Hp - k.shape[2]), (0, 0))
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
    if Hp != H_eff:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Hp - H_eff), (0, 0)))
    q = constrain(q, "batch", "seq", "heads", None)
    k = constrain(k, "batch", "seq", "heads", None)
    v = constrain(v, "batch", "seq", "heads", None)
    return q, k, v


def out_project(p: dict, o):
    wo = p["wo"]
    return pmm(o.reshape(*o.shape[:2], -1),
               wo.reshape(-1, wo.shape[-1]))


# ------------------------------------------------------- blocked attention

def blocked_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                      q_offset: int = 0, block_q: int = 512,
                      block_kv: int = 1024):
    """Online-softmax attention, lax.scan over q and kv blocks. MHA layout:
    q, k, v (B, S, H, D) with equal head counts (see prepare_heads).
    q_offset: absolute position of q[0] (kv positions are 0..Sk-1)."""
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    bq = min(block_q, Sq)
    bk = min(block_kv, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, Sk, bq, bk)
    nq, nk = Sq // bq, Sk // bk
    scale = jnp.float32(D ** -0.5)

    qb = q.reshape(B, nq, bq, H, D).transpose(1, 0, 2, 3, 4)
    kb = k.reshape(B, nk, bk, H, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nk, bk, H, D).transpose(1, 0, 2, 3, 4)
    NEG = jnp.float32(-1e30)

    def q_step(_, qx):
        iq, qblk = qx                            # qblk (B, bq, H, D)
        qf = qblk.astype(jnp.float32) * scale

        def kv_step(carry, kx):
            m, l, acc = carry
            ik, kblk, vblk = kx
            s = jnp.einsum("bqhd,bkhd->bhqk", qf,
                           kblk.astype(jnp.float32))        # (B,H,bq,bk)
            qpos = q_offset + iq * bq + jnp.arange(bq)
            kpos = ik * bk + jnp.arange(bk)
            mask = jnp.ones((bq, bk), bool)
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            if window is not None:
                mask &= kpos[None, :] > qpos[:, None] - window
            s = jnp.where(mask[None, None], s, NEG)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.where(s > NEG / 2, jnp.exp(s - m_new[..., None]), 0.0)
            alpha = jnp.exp(m - m_new)
            l_new = alpha * l + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, vblk.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, bq), NEG, jnp.float32)
        l0 = jnp.zeros((B, H, bq), jnp.float32)
        a0 = jnp.zeros((B, H, bq, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), kb, vb))
        out = acc / jnp.maximum(l, 1e-30)[..., None]         # (B,H,bq,D)
        return None, out.transpose(0, 2, 1, 3).astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), qb))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, D)


def full_attention(q, k, v, *, causal=True, window=None, q_offset=0):
    """Direct einsum attention (small seq). MHA layout (B, S, H, D)."""
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * D ** -0.5,
                   k.astype(jnp.float32))
    qpos = q_offset + jnp.arange(Sq)
    kpos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def decode_attention_einsum(q, k_cache, v_cache, length, window=None):
    """q: (B, 1, H, D) (post prepare_heads); caches (B, Smax, H, D);
    length: scalar valid length, or (B,) per-sequence lengths (continuous
    batching over ragged sequences). Returns (B, 1, H, D)."""
    B, _, H, D = q.shape
    Smax = k_cache.shape[1]
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * D ** -0.5,
                   k_cache.astype(jnp.float32))
    if getattr(length, "ndim", 0) == 1:
        length = length.reshape(-1, 1, 1, 1)
    kpos = jnp.arange(Smax)[None, None, None, :]
    mask = kpos < length
    if window is not None:
        mask = mask & (kpos > length - 1 - window)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v_cache.astype(jnp.float32))
    return o.astype(q.dtype)


def expand_cache_heads(k_cache, v_cache, true_heads: int, h_eff: int):
    """Repeat+pad cached TRUE kv heads (B,S,Hkv,D) to the padded q-head
    layout for decode compute. Per-chip slices only under SPMD."""
    Hkv = k_cache.shape[2]
    G = true_heads // Hkv
    ms = model_axis_size()
    Hp = ((h_eff + ms - 1) // ms) * ms
    if G > 1:
        k_cache = jnp.repeat(k_cache, G, axis=2)
        v_cache = jnp.repeat(v_cache, G, axis=2)
    if Hp != k_cache.shape[2]:
        pad = ((0, 0), (0, 0), (0, Hp - k_cache.shape[2]), (0, 0))
        k_cache, v_cache = jnp.pad(k_cache, pad), jnp.pad(v_cache, pad)
    # decode keeps the split-KV layout: seq stays model-sharded, heads
    # replicated (head expansion is then a purely local slice)
    k_cache = constrain(k_cache, "batch", "cache_seq", None, None)
    v_cache = constrain(v_cache, "batch", "cache_seq", None, None)
    return k_cache, v_cache, Hp


def pad_q_heads(q):
    """Pad q (B,1,H_eff,D) to the model-axis multiple (decode path).
    Decode q stays head-replicated: the model axis is spent on the cache
    seq dim (split-KV), and single-token attention flops are negligible."""
    B, S, Hq, D = q.shape
    ms = model_axis_size()
    Hp = ((Hq + ms - 1) // ms) * ms
    if Hp != Hq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Hp - Hq), (0, 0)))
    return constrain(q, "batch", "seq", None, None), Hq


# ----------------------------------------------------------------- caches

def kv_cache_defs(batch: int, max_len: int, num_kv_heads: int, head_dim: int,
                  dtype, quant: bool = False) -> dict:
    """KV cache declarations. quant=True stores int8 values + per-(pos,head)
    f32 scales — a beyond-paper extension of BRDS's quantization axis
    (fixed-16 there): decode_32k cells are CACHE-streaming-bound, so int8
    halves their dominant roofline term at ~1.6% scale overhead."""
    shape = (batch, max_len, num_kv_heads, head_dim)
    axes = ("batch", "cache_seq", "kv_heads", "head_dim")
    if quant:
        sshape = (batch, max_len, num_kv_heads, 1)
        return {
            "k": PSpec(shape, axes, init="zeros", dtype=jnp.int8),
            "v": PSpec(shape, axes, init="zeros", dtype=jnp.int8),
            "k_scale": PSpec(sshape, axes, init="zeros", dtype=jnp.float32),
            "v_scale": PSpec(sshape, axes, init="zeros", dtype=jnp.float32),
        }
    return {"k": PSpec(shape, axes, init="zeros", dtype=dtype),
            "v": PSpec(shape, axes, init="zeros", dtype=dtype)}


def _quantize_kv(x):
    """(B,S,H,D) → (int8 values, (B,S,H,1) f32 scales)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                    keepdims=True) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32)
                           / jnp.maximum(scale, 1e-12)), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_cache(cache: dict, dtype):
    """→ plain {'k','v'} view in compute dtype (no-op if unquantized)."""
    if "k_scale" not in cache:
        return cache
    k = (cache["k"].astype(jnp.float32) * cache["k_scale"]).astype(dtype)
    v = (cache["v"].astype(jnp.float32) * cache["v_scale"]).astype(dtype)
    return {"k": k, "v": v}


def kv_cache_update(cache: dict, k_new, v_new, pos):
    """Insert k/v (B, S_new, Hkv, D) at `pos` — a scalar (every sequence at
    the same position) or an (B,) int vector of per-sequence positions
    (continuous batching over ragged sequences; S_new must be 1)."""
    if getattr(pos, "ndim", 0) == 1:
        idx = jnp.arange(k_new.shape[0])

        def ins(buf, new):
            return buf.at[idx, pos].set(new[:, 0].astype(buf.dtype))
    else:
        def ins(buf, new):
            return jax.lax.dynamic_update_slice(
                buf, new.astype(buf.dtype), (0, pos, 0, 0))
    if "k_scale" in cache:
        kq, ks = _quantize_kv(k_new)
        vq, vs = _quantize_kv(v_new)
        return {
            "k": ins(cache["k"], kq),
            "v": ins(cache["v"], vq),
            "k_scale": ins(cache["k_scale"], ks),
            "v_scale": ins(cache["v_scale"], vs),
        }
    return {"k": ins(cache["k"], k_new), "v": ins(cache["v"], v_new)}
