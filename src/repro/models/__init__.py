"""Model zoo: the paper's LSTM + the 10 assigned architectures."""
from .lstm import LSTMModel, LSTMConfig, LSTM_CONFIGS
from .transformer import TransformerLM
from .encdec import EncDecLM


def build_model(cfg):
    """ArchConfig → model instance."""
    if cfg.encdec:
        return EncDecLM(cfg)
    return TransformerLM(cfg)
