"""Decoder-only LM assembly: block patterns, scan-over-periods, caches.

Supports every assigned architecture family:
- dense GQA transformers (llama3.2, qwen3, minitron, nemotron, llava backbone)
- MoE transformers (qwen3-moe, granite-moe)
- hybrid RG-LRU + local-attention (recurrentgemma, pattern ("rec","rec","attn_local"))
- attention-free RWKV6 (pattern ("rwkv",))

Layers are stacked per pattern-position and scanned over periods (MaxText
style) so the HLO stays compact at 96 layers; remainder layers (depth not a
multiple of the pattern period) are applied unscanned.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from . import layers as L
from . import attention as A
from . import moe as M
from . import recurrent as R
from ..sharding import constrain
from ..configs.base import ArchConfig


class TransformerLM:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        P = len(cfg.block_pattern)
        self.n_periods = cfg.num_layers // P
        self.rem_kinds = tuple(cfg.block_pattern[: cfg.num_layers % P])
        self.vocab_padded = L.pad_vocab(cfg.vocab_size)

    # ------------------------------------------------------------- defs
    def _block_defs(self, kind: str) -> dict:
        cfg = self.cfg
        dt = cfg.jdtype
        d = {"norm1": L.norm_defs(cfg.norm, cfg.d_model)}
        if kind in ("attn", "attn_local"):
            h_eff = cfg.pad_heads_to or cfg.num_heads
            d["attn"] = A.attn_defs(cfg.d_model, h_eff,
                                    cfg.num_kv_heads, cfg.head_dim,
                                    cfg.qk_norm, dt)
        elif kind == "rec":
            d["rec"] = R.rglru_defs(cfg.d_model, cfg.rnn_width,
                                    cfg.conv_width, dt)
        elif kind == "rwkv":
            d["rwkv"] = R.rwkv_defs(cfg.d_model, cfg.num_heads, cfg.head_dim,
                                    cfg.d_ff, dt)
        else:
            raise ValueError(kind)
        d["norm2"] = L.norm_defs(cfg.norm, cfg.d_model)
        if kind != "rwkv":  # rwkv carries its own channel-mix FFN
            if cfg.moe:
                d["moe"] = M.moe_defs(cfg.d_model, cfg.d_ff, cfg.num_experts,
                                      cfg.activation, dt)
            else:
                d["mlp"] = L.mlp_defs(cfg.d_model, cfg.d_ff, cfg.activation, dt)
        return d

    def param_defs(self) -> dict:
        cfg = self.cfg
        dt = cfg.jdtype
        defs: dict[str, Any] = {
            "embed": L.embed_defs(self.vocab_padded, cfg.d_model, dt),
            "final_norm": L.norm_defs(cfg.norm, cfg.d_model),
            "head": {"w": L.PSpec((cfg.d_model, self.vocab_padded),
                                  ("embed", "vocab"), dtype=dt)},
        }
        if cfg.num_patches:
            defs["patch_norm"] = L.norm_defs("rmsnorm", cfg.d_model)
        if self.n_periods:
            defs["blocks"] = tuple(
                L.stack_defs(self._block_defs(k), self.n_periods)
                for k in cfg.block_pattern)
        for i, k in enumerate(self.rem_kinds):
            defs[f"rem_{i}"] = self._block_defs(k)
        return defs

    def init(self, rng):
        return L.init_params(self.param_defs(), rng)

    def abstract_params(self):
        return L.abstract_params(self.param_defs())

    def param_axes(self):
        return L.param_axes(self.param_defs())

    def param_count(self) -> int:
        return L.count_params(self.param_defs())

    # ------------------------------------------------------------- blocks
    def _mixer(self, kind, p, x, positions, mode, cache, pos):
        """Sequence mixer. Returns (y, new_cache)."""
        cfg = self.cfg
        if kind in ("attn", "attn_local"):
            window = cfg.window if kind == "attn_local" else None
            h_eff = cfg.pad_heads_to or cfg.num_heads
            q, k, v = A.qkv_project(p["attn"], x, positions,
                                    qk_norm=cfg.qk_norm,
                                    rope_theta=cfg.rope_theta)
            if mode == "decode":
                new_cache = A.kv_cache_update(cache, k, v, pos)  # true kv
                dq = A.dequantize_cache(new_cache, cfg.jdtype)
                kx, vx, _ = A.expand_cache_heads(dq["k"], dq["v"],
                                                 cfg.num_heads, h_eff)
                qp, _ = A.pad_q_heads(q)
                o = A.decode_attention_einsum(qp, kx, vx, pos + 1,
                                              window=window)[:, :, :h_eff]
            else:
                qp, kp, vp = A.prepare_heads(q, k, v, cfg.num_heads)
                if x.shape[1] <= max(cfg.block_q, 1024):
                    o = A.full_attention(qp, kp, vp, causal=True,
                                         window=window)
                else:
                    o = A.blocked_attention(qp, kp, vp, causal=True,
                                            window=window,
                                            block_q=cfg.block_q,
                                            block_kv=cfg.block_kv)
                o = o[:, :, :h_eff]
                new_cache = None
                if mode == "prefill":
                    new_cache = A.kv_cache_update(cache, k, v, 0)
            if h_eff != cfg.num_heads:
                # hard-mask dummy TP-padding heads → mathematically inert
                hm = (jnp.arange(h_eff) < cfg.num_heads).astype(o.dtype)
                o = o * hm[None, None, :, None]
            return A.out_project(p["attn"], o), new_cache
        if kind == "rec":
            if mode == "decode":
                return R.rglru_step(p["rec"], x, cache)
            st = cache if mode == "prefill_chained" else None
            y, new_state = R.rglru_apply(p["rec"], x, state=st)
            if mode == "train":
                new_state = None
            elif mode == "prefill" and cache is not None:
                pass
            return y, new_state
        if kind == "rwkv":
            if mode == "decode":
                return R.rwkv_time_mix_step(p["rwkv"], x, cache)
            st = cache if cache is not None else {
                "S": jnp.zeros((x.shape[0], self.cfg.num_heads,
                                self.cfg.head_dim, self.cfg.head_dim),
                               jnp.float32),
                "x_tm": jnp.zeros((x.shape[0], x.shape[2]), x.dtype)}
            y, new_state = R.rwkv_time_mix(p["rwkv"], x, st,
                                           chunk=self.cfg.rwkv_chunk)
            if mode == "train":
                new_state = None
            return y, new_state
        raise ValueError(kind)

    def _block(self, kind, p, x, positions, mode, cache, pos):
        """Apply one block. Returns (x, new_cache, aux)."""
        cfg = self.cfg
        aux = jnp.float32(0.0)
        h = L.apply_norm(cfg.norm, p["norm1"], x)
        mix_cache = None if cache is None else cache.get("mix")
        y, new_mix_cache = self._mixer(kind, p, h, positions, mode,
                                       mix_cache, pos)
        x = x + y
        new_cache = {}
        if new_mix_cache is not None:
            new_cache["mix"] = new_mix_cache
        if kind == "rwkv":
            h = L.apply_norm(cfg.norm, p["norm2"], x)
            cm_state = (cache or {}).get("x_cm",
                                         jnp.zeros((x.shape[0], x.shape[2]),
                                                   x.dtype))
            y, new_cm = R.rwkv_channel_mix(p["rwkv"], h, cm_state)
            x = x + y
            if cache is not None and mode != "train":
                new_cache["x_cm"] = new_cm
        else:
            h = L.apply_norm(cfg.norm, p["norm2"], x)
            if cfg.moe:
                y, aux = M.moe_apply(
                    p["moe"], h, num_experts=cfg.num_experts,
                    top_k=cfg.experts_per_token,
                    capacity_factor=cfg.capacity_factor,
                    activation=cfg.activation,
                    group_size=cfg.moe_group)
            else:
                y = L.mlp_apply(p["mlp"], h, cfg.activation)
            x = x + y
        x = constrain(x, "batch", "seq", "embed")
        return x, (new_cache if new_cache else None), aux

    # ------------------------------------------------------------- forward
    def _embed_inputs(self, params, tokens, patch_embeds):
        x = L.embed_apply(params["embed"], tokens)
        if self.cfg.num_patches and patch_embeds is not None:
            pe = L.apply_norm("rmsnorm", params["patch_norm"],
                              patch_embeds.astype(x.dtype))
            P = pe.shape[1]
            x = jnp.concatenate([pe, x[:, P:]], axis=1)
        return constrain(x, "batch", "seq", "embed")

    def _run_blocks(self, params, x, positions, mode, cache, pos):
        """Scan over periods + remainder blocks. Returns (x, new_cache, aux)."""
        cfg = self.cfg
        pattern = cfg.block_pattern
        aux_total = jnp.float32(0.0)
        new_cache = {} if cache is not None or mode == "prefill" else None

        if self.n_periods:
            blocks_p = params["blocks"]
            cache_p = None if cache is None else cache["blocks"]

            def period_body(carry, xs):
                xc, auxc = carry
                if cache_p is None:
                    pslices = xs
                    cslices = (None,) * len(pattern)
                else:
                    pslices, cslices = xs
                outs = []
                for i, kind in enumerate(pattern):
                    xc, c_new, a = self._block(kind, pslices[i], xc,
                                               positions, mode, cslices[i],
                                               pos)
                    outs.append(c_new)
                    auxc = auxc + a
                ys = tuple(outs) if any(o is not None for o in outs) else None
                return (xc, auxc), ys

            body = period_body
            if cfg.remat and mode == "train":
                body = jax.checkpoint(period_body,
                                      prevent_cse=False)
            xs = blocks_p if cache_p is None else (blocks_p, cache_p)
            (x, aux_total), ys = jax.lax.scan(body, (x, aux_total), xs)
            if ys is not None and new_cache is not None:
                new_cache["blocks"] = ys

        for i, kind in enumerate(self.rem_kinds):
            c = None if cache is None else cache[f"rem_{i}"]
            x, c_new, a = self._block(kind, params[f"rem_{i}"], x, positions,
                                      mode, c, pos)
            aux_total = aux_total + a
            if c_new is not None and new_cache is not None:
                new_cache[f"rem_{i}"] = c_new
        return x, new_cache, aux_total

    def forward(self, params, tokens, patch_embeds=None):
        """Training forward: tokens (B, S) → logits (B, S, V) fp32."""
        x = self._embed_inputs(params, tokens, patch_embeds)
        positions = jnp.arange(tokens.shape[1])[None, :]
        x, _, aux = self._run_blocks(params, x, positions, "train", None, 0)
        x = L.apply_norm(self.cfg.norm, params["final_norm"], x)
        logits = L.logits_apply(params["head"], x, self.cfg.vocab_size)
        return logits, aux

    def loss(self, params, batch):
        logits, aux = self.forward(params, batch["tokens"],
                                   batch.get("patch_embeds"))
        from ..core.metrics import cross_entropy
        ce = cross_entropy(logits[:, :-1], batch["labels"][:, 1:],
                           batch.get("mask"))
        return ce + self.cfg.aux_loss_coef * aux

    # ------------------------------------------------------------- serving
    def _cache_defs_block(self, kind, batch, max_len) -> dict:
        cfg = self.cfg
        dt = cfg.jdtype
        if kind in ("attn", "attn_local"):
            return {"mix": A.kv_cache_defs(batch, max_len, cfg.num_kv_heads,
                                           cfg.head_dim, dt,
                                           quant=cfg.kv_quant)}
        if kind == "rec":
            return {"mix": R.rglru_state_defs(batch, cfg.rnn_width,
                                              cfg.conv_width, dt)}
        if kind == "rwkv":
            st = R.rwkv_state_defs(batch, cfg.num_heads, cfg.head_dim,
                                   cfg.d_model, dt)
            return {"mix": {"S": st["S"], "x_tm": st["x_tm"]},
                    "x_cm": st["x_cm"]}
        raise ValueError(kind)

    def cache_defs(self, batch: int, max_len: int) -> dict:
        defs: dict[str, Any] = {}
        if self.n_periods:
            defs["blocks"] = tuple(
                L.stack_defs(self._cache_defs_block(k, batch, max_len),
                             self.n_periods)
                for k in self.cfg.block_pattern)
        for i, k in enumerate(self.rem_kinds):
            defs[f"rem_{i}"] = self._cache_defs_block(k, batch, max_len)
        return defs

    def init_cache(self, batch: int, max_len: int):
        return L.init_params(self.cache_defs(batch, max_len), jax.random.key(0))

    def prefill(self, params, tokens, max_len: int, extra=None):
        """Process a full prompt, build the cache. ``extra`` is the VLM
        patch embeds (DecodeStep contract). Returns (logits_last, cache)."""
        B, S = tokens.shape
        cache = self.init_cache(B, max_len)
        x = self._embed_inputs(params, tokens, extra)
        positions = jnp.arange(S)[None, :]
        x, new_cache, _ = self._run_blocks(params, x, positions, "prefill",
                                           cache, 0)
        x = L.apply_norm(self.cfg.norm, params["final_norm"], x)
        logits = L.logits_apply(params["head"], x[:, -1:], self.cfg.vocab_size)
        return logits, new_cache

    def decode_step(self, params, cache, tokens, pos):
        """One decode step. tokens (B, 1); pos: scalar current position or
        (B,) per-sequence positions (continuous batching).
        Returns (logits (B, 1, V), new_cache)."""
        x = self._embed_inputs(params, tokens, None)
        pos = jnp.asarray(pos, jnp.int32)
        positions = (pos.reshape(-1, 1) if pos.ndim == 1
                     else jnp.full((1, 1), pos, jnp.int32))
        x, new_cache, _ = self._run_blocks(params, x, positions, "decode",
                                           cache, pos)
        x = L.apply_norm(self.cfg.norm, params["final_norm"], x)
        logits = L.logits_apply(params["head"], x, self.cfg.vocab_size)
        return logits, new_cache
