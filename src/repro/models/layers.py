"""Parameter definition system + basic NN layers (pure functional JAX).

Params are nested dicts of arrays. Structure is declared once as a pytree of
`PSpec` (shape + logical sharding axes + init); the same declaration yields
concrete params (init), abstract params (dry-run ShapeDtypeStructs), and
NamedShardings (via repro.sharding).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..sharding import Axes, constrain

DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32, "float16": jnp.float16}


@dataclasses.dataclass(frozen=True)
class PSpec:
    """Declaration of one parameter tensor."""
    shape: tuple
    axes: tuple                      # logical axis names, len == ndim
    init: str = "normal"             # normal | zeros | ones
    scale: float | None = None       # stddev override (default 1/sqrt(fan_in))
    dtype: Any = jnp.bfloat16

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_pspec(x) -> bool:
    return isinstance(x, PSpec)


def _default_scale(shape) -> float:
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    return 1.0 / math.sqrt(max(fan_in, 1))


def init_params(defs, rng):
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_pspec)
    keys = jax.random.split(rng, max(len(leaves), 1))
    out = []
    for k, d in zip(keys, leaves):
        if d.init == "zeros":
            a = jnp.zeros(d.shape, d.dtype)
        elif d.init == "ones":
            a = jnp.ones(d.shape, d.dtype)
        else:
            s = d.scale if d.scale is not None else _default_scale(d.shape)
            a = (jax.random.normal(k, d.shape, jnp.float32) * s).astype(d.dtype)
        out.append(a)
    return jax.tree.unflatten(treedef, out)


def abstract_params(defs):
    return jax.tree.map(lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype),
                        defs, is_leaf=is_pspec)


def param_axes(defs):
    return jax.tree.map(lambda d: Axes(*d.axes), defs, is_leaf=is_pspec)


def param_shapes(defs):
    return jax.tree.map(lambda d: d.shape, defs, is_leaf=is_pspec)


def stack_defs(defs, n: int):
    """Prepend a scan-stacked 'layers' dimension to every PSpec."""
    return jax.tree.map(
        lambda d: PSpec((n, *d.shape), ("layers", *d.axes), d.init, d.scale,
                        d.dtype),
        defs, is_leaf=is_pspec)


def count_params(defs) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=is_pspec)
    return sum(int(np.prod(d.shape)) for d in leaves)


# ------------------------------------------------------------------ layers

def rmsnorm(x, w, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * (1.0 + w.astype(jnp.float32))
    return y.astype(x.dtype)


def layernorm(x, w, b, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * (1.0 + w.astype(jnp.float32)) \
        + b.astype(jnp.float32)
    return y.astype(x.dtype)


def norm_defs(kind: str, dim: int) -> dict:
    if kind == "rmsnorm":
        return {"w": PSpec((dim,), ("embed",), init="zeros", dtype=jnp.float32)}
    return {"w": PSpec((dim,), ("embed",), init="zeros", dtype=jnp.float32),
            "b": PSpec((dim,), ("embed",), init="zeros", dtype=jnp.float32)}


def apply_norm(kind: str, p: dict, x):
    if kind == "rmsnorm":
        return rmsnorm(x, p["w"])
    return layernorm(x, p["w"], p["b"])


# ------------------------------------------------------------------ RoPE

def rope(x, positions, theta: float = 10000.0):
    """x: (..., S, H, D) or (..., H, D) w/ scalar positions. positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = (theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs      # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]                                      # (..., S, 1, half)
    sin = sin[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------- TP-aware matmul

@jax.custom_vjp
def pmm(x, w):
    """y = x @ w over the last dim of x; w (K, N) or (K, *N) flattened.

    Same forward as einsum; the custom VJP keeps the ACTIVATION gradient in
    the activation dtype (bf16). jax's default VJP marks backward dots
    preferred_element_type=f32, which makes XLA all-reduce f32 partials when
    the contracted dim is model-sharded — 2× the wire bytes of the tensor-
    parallel backward (nemotron §Perf cell 2). Weight grads stay f32.
    """
    wf = w.reshape(w.shape[0], -1)
    y = x @ wf
    return y.reshape(*x.shape[:-1], *w.shape[1:])


def _pmm_fwd(x, w):
    return pmm(x, w), (x, w)


def _pmm_bwd(res, g):
    x, w = res
    wf = w.reshape(w.shape[0], -1)
    g2 = g.reshape(*x.shape[:-1], wf.shape[1]).astype(x.dtype)
    gx = g2 @ wf.T                                   # bf16-wire activation grad
    gw = jax.lax.dot_general(
        x.reshape(-1, x.shape[-1]), g2.reshape(-1, g2.shape[-1]),
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)          # f32 accumulation
    # cotangent dtype must match the primal's; the f32 accumulation above
    # still protects the local reduction, the DP all-reduce rides in bf16
    return gx.astype(x.dtype), gw.reshape(w.shape).astype(w.dtype)


pmm.defvjp(_pmm_fwd, _pmm_bwd)


# ------------------------------------------------------------------ MLP

def mlp_defs(d_model: int, d_ff: int, activation: str, dtype) -> dict:
    if activation in ("silu_glu", "gelu_glu"):
        return {
            "w_gate": PSpec((d_model, d_ff), ("embed", "mlp"), dtype=dtype),
            "w_up": PSpec((d_model, d_ff), ("embed", "mlp"), dtype=dtype),
            "w_down": PSpec((d_ff, d_model), ("mlp", "embed"), dtype=dtype),
        }
    return {
        "w_up": PSpec((d_model, d_ff), ("embed", "mlp"), dtype=dtype),
        "w_down": PSpec((d_ff, d_model), ("mlp", "embed"), dtype=dtype),
    }


def _act(activation: str, x):
    if activation.startswith("silu"):
        return jax.nn.silu(x)
    if activation.startswith("gelu"):
        return jax.nn.gelu(x)
    if activation == "sq_relu":
        r = jax.nn.relu(x)
        return r * r
    if activation == "relu":
        return jax.nn.relu(x)
    raise ValueError(activation)


def mlp_apply(p: dict, x, activation: str):
    """x: (..., d_model). Weight masks (BRDS) are pre-applied to params."""
    if activation.endswith("_glu"):
        g = _act(activation, pmm(x, p["w_gate"]))
        u = pmm(x, p["w_up"])
        h = g * u
    else:
        h = _act(activation, pmm(x, p["w_up"]))
    h = constrain(h, "batch", "seq", "mlp") if h.ndim == 3 else h
    return pmm(h, p["w_down"])


# ------------------------------------------------------------------ embed

def pad_vocab(v: int, mult: int = 256) -> int:
    return ((v + mult - 1) // mult) * mult


def embed_defs(vocab_padded: int, d_model: int, dtype) -> dict:
    return {"table": PSpec((vocab_padded, d_model), ("vocab", "embed"),
                           scale=1.0, dtype=dtype)}


def embed_apply(p: dict, tokens):
    return jnp.take(p["table"], tokens, axis=0)


def logits_apply(p_head, x, real_vocab: int):
    """x (..., d) @ head (d, Vp) → (..., V) fp32, padding masked to -inf.

    The pad mask is an elementwise iota compare (partition-friendly along a
    model-sharded vocab dim, unlike a slice-update)."""
    logits = jnp.einsum("...d,dv->...v", x, p_head["w"]).astype(jnp.float32)
    vp = p_head["w"].shape[-1]
    if vp != real_vocab:
        vocab_pos = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                             logits.ndim - 1)
        logits = jnp.where(vocab_pos < real_vocab, logits, -1e30)
    return logits
