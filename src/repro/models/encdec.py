"""Encoder-decoder LM (seamless-m4t backbone).

The audio frontend is a STUB per the assignment: `input_specs()` provides
precomputed frame embeddings (B, S_enc, d_model). The transformer backbone
is real: a bidirectional encoder stack + a causal decoder stack with
cross-attention, both scanned over layers.

Shape conventions: train_4k splits seq 2048 enc / 2048 dec; decode shapes
decode the decoder against a fixed-length encoder memory (cfg.enc_len).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from . import layers as L
from . import attention as A
from ..sharding import constrain
from ..configs.base import ArchConfig


class EncDecLM:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.vocab_padded = L.pad_vocab(cfg.vocab_size)
        self.n_enc = cfg.enc_layers or cfg.num_layers
        self.n_dec = cfg.num_layers

    # ------------------------------------------------------------- defs
    def _enc_block_defs(self) -> dict:
        cfg = self.cfg
        dt = cfg.jdtype
        return {
            "norm1": L.norm_defs(cfg.norm, cfg.d_model),
            "attn": A.attn_defs(cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                                cfg.head_dim, cfg.qk_norm, dt),
            "norm2": L.norm_defs(cfg.norm, cfg.d_model),
            "mlp": L.mlp_defs(cfg.d_model, cfg.d_ff, cfg.activation, dt),
        }

    def _dec_block_defs(self) -> dict:
        d = self._enc_block_defs()
        cfg = self.cfg
        d["norm_x"] = L.norm_defs(cfg.norm, cfg.d_model)
        d["xattn"] = A.attn_defs(cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                                 cfg.head_dim, cfg.qk_norm, cfg.jdtype)
        return d

    def param_defs(self) -> dict:
        cfg = self.cfg
        dt = cfg.jdtype
        return {
            "frame_proj": {"w": L.PSpec((cfg.d_model, cfg.d_model),
                                        ("embed", None), dtype=dt)},
            "embed": L.embed_defs(self.vocab_padded, cfg.d_model, dt),
            "enc_blocks": L.stack_defs(self._enc_block_defs(), self.n_enc),
            "enc_norm": L.norm_defs(cfg.norm, cfg.d_model),
            "dec_blocks": L.stack_defs(self._dec_block_defs(), self.n_dec),
            "final_norm": L.norm_defs(cfg.norm, cfg.d_model),
            "head": {"w": L.PSpec((cfg.d_model, self.vocab_padded),
                                  ("embed", "vocab"), dtype=dt)},
        }

    def init(self, rng):
        return L.init_params(self.param_defs(), rng)

    def abstract_params(self):
        return L.abstract_params(self.param_defs())

    def param_axes(self):
        return L.param_axes(self.param_defs())

    def param_count(self) -> int:
        return L.count_params(self.param_defs())

    # ------------------------------------------------------------- encoder
    def _attend(self, p, x, positions, *, causal, kv=None):
        cfg = self.cfg
        q, k, v = A.qkv_project(p, x, positions, qk_norm=cfg.qk_norm,
                                rope_theta=cfg.rope_theta)
        if kv is not None:
            k, v = kv
        H = cfg.num_heads
        qp, kp, vp = A.prepare_heads(q, k, v, H)
        if x.shape[1] <= 4096 and kp.shape[1] <= 4096:
            o = A.full_attention(qp, kp, vp, causal=causal)
        else:
            o = A.blocked_attention(qp, kp, vp, causal=causal,
                                    block_q=cfg.block_q, block_kv=cfg.block_kv)
        return A.out_project(p, o[:, :, :H])

    def _cross_kv(self, p, enc_out, positions):
        """Precompute cross-attention K/V from encoder output."""
        cfg = self.cfg
        k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
        if cfg.qk_norm:
            from .layers import rmsnorm
            k = rmsnorm(k, p["k_norm"])
        return k, v

    def encode(self, params, frames):
        """frames (B, S_enc, d) precomputed embeddings → encoder memory."""
        cfg = self.cfg
        x = jnp.einsum("bsd,de->bse", frames.astype(cfg.jdtype),
                       params["frame_proj"]["w"])
        x = constrain(x, "batch", "seq", "embed")
        positions = jnp.arange(x.shape[1])[None, :]

        def body(xc, pblk):
            h = L.apply_norm(cfg.norm, pblk["norm1"], xc)
            xc = xc + self._attend(pblk["attn"], h, positions, causal=False)
            h = L.apply_norm(cfg.norm, pblk["norm2"], xc)
            xc = xc + L.mlp_apply(pblk["mlp"], h, cfg.activation)
            return constrain(xc, "batch", "seq", "embed"), None

        if cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, _ = jax.lax.scan(body, x, params["enc_blocks"])
        return L.apply_norm(cfg.norm, params["enc_norm"], x)

    # ------------------------------------------------------------- decoder
    def _dec_blocks(self, params, x, positions, enc_out, mode, cache, pos):
        cfg = self.cfg

        def body(carry, xs):
            xc = carry
            if cache is None:
                pblk = xs
                cblk = None
            else:
                pblk, cblk = xs
            h = L.apply_norm(cfg.norm, pblk["norm1"], xc)
            new_cblk = None
            if mode == "decode":
                q, k, v = A.qkv_project(pblk["attn"], h, positions,
                                        qk_norm=cfg.qk_norm,
                                        rope_theta=cfg.rope_theta)
                kv = A.kv_cache_update(cblk["self"], k, v, pos)
                dqs = A.dequantize_cache(kv, cfg.jdtype)
                kx, vx, _ = A.expand_cache_heads(dqs["k"], dqs["v"],
                                                 cfg.num_heads, cfg.num_heads)
                qp, Hq = A.pad_q_heads(q)
                o = A.decode_attention_einsum(qp, kx, vx, pos + 1)[:, :, :Hq]
                xc = xc + A.out_project(pblk["attn"], o)
                h = L.apply_norm(cfg.norm, pblk["norm_x"], xc)
                qx, _, _ = A.qkv_project(pblk["xattn"], h, positions,
                                         qk_norm=cfg.qk_norm,
                                         rope_theta=cfg.rope_theta,
                                         use_rope=False)
                dqc = A.dequantize_cache(cblk["cross"], cfg.jdtype)
                ckx, cvx, _ = A.expand_cache_heads(dqc["k"], dqc["v"],
                                                   cfg.num_heads,
                                                   cfg.num_heads)
                qxp, Hq2 = A.pad_q_heads(qx)
                ox = A.decode_attention_einsum(
                    qxp, ckx, cvx, cblk["cross"]["k"].shape[1])[:, :, :Hq2]
                xc = xc + A.out_project(pblk["xattn"], ox)
                new_cblk = {"self": kv, "cross": cblk["cross"]}
            else:
                q, k, v = A.qkv_project(pblk["attn"], h, positions,
                                        qk_norm=cfg.qk_norm,
                                        rope_theta=cfg.rope_theta)
                if mode == "prefill":
                    kv = A.kv_cache_update(cblk["self"], k, v, 0)
                qp, kp, vp = A.prepare_heads(q, k, v, cfg.num_heads)
                o = (A.full_attention(qp, kp, vp, causal=True)
                     if x.shape[1] <= 4096 else
                     A.blocked_attention(qp, kp, vp, causal=True,
                                         block_q=cfg.block_q,
                                         block_kv=cfg.block_kv))
                xc = xc + A.out_project(pblk["attn"], o[:, :, :cfg.num_heads])
                h = L.apply_norm(cfg.norm, pblk["norm_x"], xc)
                qx, _, _ = A.qkv_project(pblk["xattn"], h, positions,
                                         qk_norm=cfg.qk_norm,
                                         rope_theta=cfg.rope_theta,
                                         use_rope=False)
                ck, cv = self._cross_kv(pblk["xattn"], enc_out, positions)
                qxp, ckp, cvp = A.prepare_heads(qx, ck, cv, cfg.num_heads)
                ox = (A.full_attention(qxp, ckp, cvp, causal=False)
                      if max(x.shape[1], ckp.shape[1]) <= 4096 else
                      A.blocked_attention(qxp, ckp, cvp, causal=False,
                                          block_q=cfg.block_q,
                                          block_kv=cfg.block_kv))
                xc = xc + A.out_project(pblk["xattn"],
                                        ox[:, :, :cfg.num_heads])
                if mode == "prefill":
                    new_cblk = {"self": kv, "cross": {"k": ck, "v": cv}}
            h = L.apply_norm(cfg.norm, pblk["norm2"], xc)
            xc = xc + L.mlp_apply(pblk["mlp"], h, cfg.activation)
            xc = constrain(xc, "batch", "seq", "embed")
            return xc, new_cblk

        fn = body
        if cfg.remat and mode == "train":
            fn = jax.checkpoint(body, prevent_cse=False)
        xs = params["dec_blocks"] if cache is None else (params["dec_blocks"],
                                                         cache["dec"])
        x, new_cache = jax.lax.scan(fn, x, xs)
        return x, new_cache

    # ------------------------------------------------------------- api
    def forward(self, params, tokens, frames):
        """Train forward. tokens (B, S_dec); frames (B, S_enc, d)."""
        enc_out = self.encode(params, frames)
        x = L.embed_apply(params["embed"], tokens)
        positions = jnp.arange(tokens.shape[1])[None, :]
        x, _ = self._dec_blocks(params, x, positions, enc_out, "train",
                                None, 0)
        x = L.apply_norm(self.cfg.norm, params["final_norm"], x)
        return L.logits_apply(params["head"], x, self.cfg.vocab_size), \
            jnp.float32(0.0)

    def loss(self, params, batch):
        logits, _ = self.forward(params, batch["tokens"], batch["frames"])
        from ..core.metrics import cross_entropy
        return cross_entropy(logits[:, :-1], batch["labels"][:, 1:],
                             batch.get("mask"))

    def cache_defs(self, batch: int, max_len: int) -> dict:
        cfg = self.cfg
        dt = cfg.jdtype
        blk = {
            "self": A.kv_cache_defs(batch, max_len, cfg.num_kv_heads,
                                    cfg.head_dim, dt, quant=cfg.kv_quant),
            "cross": A.kv_cache_defs(batch, cfg.enc_len, cfg.num_kv_heads,
                                     cfg.head_dim, dt, quant=cfg.kv_quant),
        }
        return {"dec": L.stack_defs(blk, self.n_dec)}

    def init_cache(self, batch: int, max_len: int):
        return L.init_params(self.cache_defs(batch, max_len),
                             jax.random.key(0))

    def prefill(self, params, tokens, max_len: int, extra=None):
        """``extra`` is the encoder frame embeddings (B, S_enc, d) — the
        DecodeStep contract's family-specific conditioning."""
        if extra is None:
            raise ValueError("EncDecLM.prefill needs encoder frames "
                             "(extra=...)")
        enc_out = self.encode(params, extra)
        cache = self.init_cache(tokens.shape[0], max_len)
        x = L.embed_apply(params["embed"], tokens)
        positions = jnp.arange(tokens.shape[1])[None, :]
        x, new_dec = self._dec_blocks(params, x, positions, enc_out,
                                      "prefill", cache, 0)
        x = L.apply_norm(self.cfg.norm, params["final_norm"], x)
        logits = L.logits_apply(params["head"], x[:, -1:], self.cfg.vocab_size)
        return logits, {"dec": new_dec}

    def decode_step(self, params, cache, tokens, pos):
        x = L.embed_apply(params["embed"], tokens)
        pos = jnp.asarray(pos, jnp.int32)
        positions = (pos.reshape(-1, 1) if pos.ndim == 1
                     else jnp.full((1, 1), pos, jnp.int32))
        x, new_dec = self._dec_blocks(params, x, positions, None, "decode",
                                      cache, pos)
        x = L.apply_norm(self.cfg.norm, params["final_norm"], x)
        logits = L.logits_apply(params["head"], x, self.cfg.vocab_size)
        return logits, {"dec": new_dec}
