"""Recurrent sequence mixers: RG-LRU (RecurrentGemma/Griffin) and RWKV6.

Both are the paper's W_h analogue made modern — data-dependent diagonal /
low-rank recurrences. Training/prefill use parallel forms (associative scan
for RG-LRU; chunked linear attention for RWKV6); decode uses O(1) state
updates. These blocks make the `long_500k` shape runnable (sub-quadratic).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from .layers import PSpec, _act
from ..core.packing import RowBalancedSparse
from ..sharding import constrain


def _proj(x, w):
    """y = x @ W for dense (d_in, *out) weights OR BRDS-packed weights
    (RowBalancedSparse with rows = flattened out dim, cols = d_in).

    Packed path = the paper's accelerator datapath on the decode hot loop:
    only (rows, K) values + narrow delta indices stream from HBM; the
    column gather is the rb_spmv kernel's semantics (kernels/rb_spmv.py is
    the TPU implementation; this is its lowering-friendly ref form).
    Returns (B, S, F) with F = prod(out dims)."""
    B, S, d = x.shape
    if isinstance(w, RowBalancedSparse):
        cols = jnp.cumsum(w.deltas.astype(jnp.int32), axis=1)   # (R, K)
        g = jnp.take(x.reshape(B * S, d), cols, axis=1)         # (BS, R, K)
        y = jnp.einsum("brk,rk->br", g.astype(jnp.float32),
                       w.values.astype(jnp.float32))
        y = constrain(y, None, "mlp")    # rows stay model-sharded
        return y.reshape(B, S, w.rows).astype(x.dtype)
    return jnp.einsum("bsd,df->bsf", x, w.reshape(w.shape[0], -1))

# ================================================================= RG-LRU

RG_C = 8.0  # Griffin's fixed temperature on the recurrence gate


def rglru_defs(d_model: int, d_rnn: int, conv_width: int, dtype) -> dict:
    return {
        "w_in_gelu": PSpec((d_model, d_rnn), ("embed", "mlp"), dtype=dtype),
        "w_in_rec": PSpec((d_model, d_rnn), ("embed", "mlp"), dtype=dtype),
        "conv_w": PSpec((conv_width, d_rnn), ("conv", "mlp"), dtype=dtype,
                        scale=0.3),
        "conv_b": PSpec((d_rnn,), ("mlp",), init="zeros", dtype=dtype),
        "w_gate_a": PSpec((d_rnn, d_rnn), ("mlp", "embed"), dtype=dtype),
        "w_gate_x": PSpec((d_rnn, d_rnn), ("mlp", "embed"), dtype=dtype),
        "lam": PSpec((d_rnn,), ("mlp",), init="ones", dtype=jnp.float32),
        "w_out": PSpec((d_rnn, d_model), ("mlp", "embed"), dtype=dtype),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv. x (B, S, D), w (W, D). state (B, W-1, D) for
    decode. Returns (y, new_state)."""
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)          # (B, S+W-1, D)
    y = sum(xp[:, i:i + x.shape[1]] * w[i][None, None, :] for i in range(W))
    y = y + b[None, None, :]
    new_state = xp[:, -(W - 1):] if W > 1 else None
    return y, new_state


def _rglru_gates(p, xr):
    """Gate computations shared by scan/step. xr (..., d_rnn) → (log_a, gx)."""
    ga = jax.nn.sigmoid(jnp.einsum("...d,de->...e", xr, p["w_gate_a"])
                        .astype(jnp.float32))
    gx = jax.nn.sigmoid(jnp.einsum("...d,de->...e", xr, p["w_gate_x"])
                        .astype(jnp.float32))
    log_a = -RG_C * jax.nn.softplus(p["lam"]) * ga  # (..., d_rnn) ≤ 0
    return log_a, gx


def rglru_apply(p: dict, x, state=None):
    """Full-sequence RG-LRU block. x (B, S, d_model). state: dict with
    'h' (B, d_rnn) and 'conv' (B, W-1, d_rnn) for chained prefill/decode.
    Returns (y (B, S, d_model), new_state)."""
    gelu_branch = jax.nn.gelu(jnp.einsum("bsd,de->bse", x, p["w_in_gelu"]))
    xr = jnp.einsum("bsd,de->bse", x, p["w_in_rec"])
    xr, conv_state = _causal_conv(xr, p["conv_w"], p["conv_b"],
                                  None if state is None else state["conv"])
    log_a, gx = _rglru_gates(p, xr)
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9))
    b = beta * gx * xr.astype(jnp.float32)          # (B, S, d_rnn)

    # h_t = a_t * h_{t-1} + b_t  — associative scan over seq
    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    a_sc, b_sc = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = b_sc
    if state is not None:
        h = h + a_sc * state["h"].astype(jnp.float32)[:, None, :]
    h = h.astype(x.dtype)
    y = jnp.einsum("bse,ed->bsd", gelu_branch * h, p["w_out"])
    new_state = {"h": h[:, -1], "conv": conv_state}
    return y, new_state


def rglru_step(p: dict, x, state):
    """Single-token decode. x (B, 1, d_model) → (y (B, 1, d), new_state)."""
    gelu_branch = jax.nn.gelu(jnp.einsum("bsd,de->bse", x, p["w_in_gelu"]))
    xr = jnp.einsum("bsd,de->bse", x, p["w_in_rec"])
    xr, conv_state = _causal_conv(xr, p["conv_w"], p["conv_b"], state["conv"])
    log_a, gx = _rglru_gates(p, xr)
    a = jnp.exp(log_a)[:, 0]                        # (B, d_rnn)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9))[:, 0]
    b = beta * gx[:, 0] * xr[:, 0].astype(jnp.float32)
    h = a * state["h"].astype(jnp.float32) + b
    h = h.astype(x.dtype)
    y = jnp.einsum("be,ed->bd", gelu_branch[:, 0] * h, p["w_out"])[:, None]
    return y, {"h": h, "conv": conv_state}


def rglru_state_defs(batch: int, d_rnn: int, conv_width: int, dtype) -> dict:
    return {
        "h": PSpec((batch, d_rnn), ("batch", "mlp"), init="zeros", dtype=dtype),
        "conv": PSpec((batch, conv_width - 1, d_rnn), ("batch", "conv", "mlp"),
                      init="zeros", dtype=dtype),
    }


# ================================================================== RWKV6

def rwkv_defs(d_model: int, num_heads: int, head_dim: int, d_ff: int,
              dtype) -> dict:
    H, Dk = num_heads, head_dim
    return {
        # token-shift lerp coefficients (r, k, v, w, g)
        "mu": PSpec((5, d_model), (None, "embed"), init="zeros",
                    dtype=jnp.float32),
        "w_r": PSpec((d_model, H, Dk), ("embed", "heads", "head_dim"),
                     dtype=dtype),
        "w_k": PSpec((d_model, H, Dk), ("embed", "heads", "head_dim"),
                     dtype=dtype),
        "w_v": PSpec((d_model, H, Dk), ("embed", "heads", "head_dim"),
                     dtype=dtype),
        "w_g": PSpec((d_model, H, Dk), ("embed", "heads", "head_dim"),
                     dtype=dtype),
        # data-dependent decay: w_t = exp(-exp(w0 + x @ w_w))
        "w0": PSpec((H, Dk), ("heads", "head_dim"), init="zeros",
                    dtype=jnp.float32),
        "w_w": PSpec((d_model, H, Dk), ("embed", "heads", "head_dim"),
                     scale=0.01, dtype=dtype),
        "u": PSpec((H, Dk), ("heads", "head_dim"), init="zeros",
                   dtype=jnp.float32),
        "gn": PSpec((H, Dk), ("heads", "head_dim"), init="zeros",
                    dtype=jnp.float32),  # per-head group-norm scale
        "w_out": PSpec((H, Dk, d_model), ("heads", "head_dim", "embed"),
                       dtype=dtype),
        # channel-mix
        "mu_cm": PSpec((d_model,), ("embed",), init="zeros", dtype=jnp.float32),
        "w_cm1": PSpec((d_model, d_ff), ("embed", "mlp"), dtype=dtype),
        "w_cm2": PSpec((d_ff, d_model), ("mlp", "embed"), dtype=dtype),
    }


def _token_shift(x, x_prev_last):
    """x (B, S, d); x_prev_last (B, d) = last token of the previous segment.
    Returns x_{t-1} sequence aligned with x."""
    prev = x_prev_last[:, None, :].astype(x.dtype)
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _rwkv_projections(p, x, x_shift):
    mu = p["mu"].astype(jnp.float32)
    xf = x.astype(jnp.float32)
    sf = x_shift.astype(jnp.float32)
    B, S = x.shape[:2]
    H, Dk = p["u"].shape
    mix = lambda i: (xf + mu[i] * (sf - xf)).astype(x.dtype)
    hd = lambda y: y.reshape(B, S, H, Dk)
    r = hd(_proj(mix(0), p["w_r"]))
    k = hd(_proj(mix(1), p["w_k"]))
    v = hd(_proj(mix(2), p["w_v"]))
    wraw = hd(_proj(mix(3), p["w_w"])).astype(jnp.float32)
    g = jax.nn.silu(hd(_proj(mix(4), p["w_g"])))
    # log decay in [-~20, -1e-4]; clamp for numerical sanity
    log_w = -jnp.exp(jnp.clip(p["w0"].astype(jnp.float32) + wraw, -8.0, 4.0))
    return r, k, v, g, log_w


def rwkv_time_mix(p: dict, x, state, *, chunk: int = 128):
    """Chunked-parallel RWKV6 time mix. x (B, S, d); state dict with
    'S' (B, H, Dk, Dk) and 'x_tm' (B, d). Returns (y, new_state)."""
    B, S, d = x.shape
    H, Dk = p["u"].shape
    L = min(chunk, S)
    while S % L:        # largest divisor of S ≤ chunk (shapes are powers of 2)
        L -= 1
    nc = S // L

    x_shift = _token_shift(x, state["x_tm"])
    r, k, v, g, log_w = _rwkv_projections(p, x, x_shift)
    u = p["u"].astype(jnp.float32)

    rc = r.reshape(B, nc, L, H, Dk).transpose(1, 0, 3, 2, 4)  # (nc,B,H,L,Dk)
    kc = k.reshape(B, nc, L, H, Dk).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(B, nc, L, H, Dk).transpose(1, 0, 3, 2, 4)
    wc = log_w.reshape(B, nc, L, H, Dk).transpose(1, 0, 3, 2, 4)

    def chunk_step(S_prev, xs):
        rb, kb, vb, lwb = xs                      # (B, H, L, Dk) each
        rb = rb.astype(jnp.float32)
        kb = kb.astype(jnp.float32)
        vb = vb.astype(jnp.float32)
        logc = jnp.cumsum(lwb, axis=2)            # inclusive per-channel decay
        logc_excl = logc - lwb                    # exclusive (up to t-1)
        # inter-chunk: r_t ⊙ c_{t-1} applied to carried state
        q_in = rb * jnp.exp(logc_excl)
        o_inter = jnp.einsum("bhld,bhde->bhle", q_in, S_prev)
        # intra-chunk, strict lower triangle with pairwise decay
        # decay3[t, s, d] = exp(logc_excl[t] - logc[s]) for s < t
        dt = logc_excl[:, :, :, None, :] - logc[:, :, None, :, :]
        tri = (jnp.arange(L)[:, None] > jnp.arange(L)[None, :])
        decay3 = jnp.where(tri[None, None, :, :, None], jnp.exp(dt), 0.0)
        att = jnp.einsum("bhtd,bhsd,bhtsd->bhts", rb, kb, decay3)
        o_intra = jnp.einsum("bhts,bhse->bhte", att, vb)
        # current-token bonus: (r_t · u ⊙ k_t) v_t
        bonus = jnp.einsum("bhld,bhld->bhl", rb, u[None, :, None, :] * kb)
        o_bonus = bonus[..., None] * vb
        o = o_inter + o_intra + o_bonus           # (B, H, L, Dk)
        # state update: S = exp(logc_L) ⊙ S_prev + Σ_s exp(logc_L - logc_s) k_s v_sᵀ
        c_end = jnp.exp(logc[:, :, -1])           # (B, H, Dk)
        k_sc = kb * jnp.exp(logc[:, :, -1:, :] - logc)
        S_new = c_end[..., None] * S_prev + jnp.einsum("bhld,bhle->bhde",
                                                       k_sc, vb)
        return S_new, o

    S_fin, outs = jax.lax.scan(chunk_step, state["S"].astype(jnp.float32),
                               (rc, kc, vc, wc))
    o = outs.transpose(1, 0, 3, 2, 4).reshape(B, S, H, Dk)
    o = _rwkv_out(p, o, g)
    return o, {"S": S_fin, "x_tm": x[:, -1]}


def _rwkv_out(p, o, g):
    """Per-head RMS group-norm, gate, output projection."""
    of = o.astype(jnp.float32)
    var = jnp.mean(of * of, axis=-1, keepdims=True)
    of = of * jax.lax.rsqrt(var + 1e-6) * (1.0 + p["gn"].astype(jnp.float32))
    of = of * g.astype(jnp.float32)
    B, S = of.shape[:2]
    w = p["w_out"]
    if not isinstance(w, RowBalancedSparse):
        w = w.reshape(-1, w.shape[-1])
    return _proj(of.astype(g.dtype).reshape(B, S, -1), w)


def rwkv_time_mix_step(p: dict, x, state):
    """Single-token decode. x (B, 1, d)."""
    B = x.shape[0]
    H, Dk = p["u"].shape
    x_shift = state["x_tm"][:, None, :].astype(x.dtype)
    r, k, v, g, log_w = _rwkv_projections(p, x, x_shift)
    rb = r[:, 0].astype(jnp.float32)              # (B, H, Dk)
    kb = k[:, 0].astype(jnp.float32)
    vb = v[:, 0].astype(jnp.float32)
    w = jnp.exp(log_w[:, 0])                      # (B, H, Dk)
    u = p["u"].astype(jnp.float32)
    S_prev = state["S"].astype(jnp.float32)       # (B, H, Dk, Dk)
    kv = kb[..., :, None] * vb[..., None, :]      # (B, H, Dk, Dk)
    o = jnp.einsum("bhd,bhde->bhe", rb, S_prev + u[None, :, :, None] * kv)
    S_new = w[..., None] * S_prev + kv
    o = _rwkv_out(p, o[:, None], g)               # (B,1,H,Dk) → (B,1,d)
    return o, {"S": S_new, "x_tm": x[:, -1]}


def rwkv_channel_mix(p: dict, x, state_x):
    """x (B, S, d); state_x (B, d) last token of prev segment."""
    x_shift = _token_shift(x, state_x)
    mu = p["mu_cm"].astype(jnp.float32)
    xf = x.astype(jnp.float32)
    mixed = (xf + mu * (x_shift.astype(jnp.float32) - xf)).astype(x.dtype)
    h = _proj(mixed, p["w_cm1"])
    h = jax.nn.relu(h)
    h = h * h
    y = _proj(h, p["w_cm2"])
    return y, x[:, -1]


def rwkv_state_defs(batch: int, num_heads: int, head_dim: int, d_model: int,
                    dtype) -> dict:
    return {
        "S": PSpec((batch, num_heads, head_dim, head_dim),
                   ("batch", "heads", "head_dim", None), init="zeros",
                   dtype=jnp.float32),
        "x_tm": PSpec((batch, d_model), ("batch", "embed"), init="zeros",
                      dtype=dtype),
        "x_cm": PSpec((batch, d_model), ("batch", "embed"), init="zeros",
                      dtype=dtype),
    }
