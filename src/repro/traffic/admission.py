"""Priority/deadline admission: who decodes next, who is shed under load.

The queue orders waiting requests by (priority desc, deadline asc, arrival
asc) — a deadline-monotonic ordering within each priority band. Overload
degrades gracefully instead of queueing unboundedly: with ``max_queue``
set, pushing into a full queue sheds the WORST waiting request (lowest
priority, latest deadline) — the incoming request itself when it is the
worst — and the shed request surfaces as a ``rejected`` outcome rather
than silently timing out. Requests whose deadline passes while queued are
dropped at admission time (``expired``); the scheduler additionally evicts
past-deadline work already holding a slot.
"""
from __future__ import annotations

import bisect
import dataclasses
import math
from typing import Any

__all__ = ["QueuedRequest", "AdmissionQueue"]


@dataclasses.dataclass
class QueuedRequest:
    uid: int
    prompt: Any                  # (1, S) int32 tokens (or (1, S, X) frames)
    prompt_len: int
    max_new: int
    extra: Any = None
    deadline: float | None = None    # absolute clock time; None = none
    priority: int = 0                # higher = sooner
    arrival: float = 0.0

    def sort_key(self):
        return (-self.priority,
                self.deadline if self.deadline is not None else math.inf,
                self.arrival, self.uid)


class AdmissionQueue:
    """Sorted admission queue with bounded depth and deadline expiry."""

    def __init__(self, max_queue: int | None = None):
        if max_queue is not None and max_queue <= 0:
            raise ValueError(f"max_queue must be positive, got {max_queue}")
        self.max_queue = max_queue
        self._q: list[tuple] = []       # (sort_key, QueuedRequest)

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)

    def push(self, req: QueuedRequest) -> QueuedRequest | None:
        """Enqueue; returns the request shed by overload (possibly ``req``
        itself), or None when everything fits."""
        bisect.insort(self._q, (req.sort_key(), req))
        if self.max_queue is not None and len(self._q) > self.max_queue:
            return self._q.pop()[1]     # worst = last in sorted order
        return None

    def expire(self, now: float) -> list[QueuedRequest]:
        """Drop every queued request whose deadline has already passed —
        admitting it could only produce late tokens."""
        expired = [r for _, r in self._q
                   if r.deadline is not None and now > r.deadline]
        if expired:
            gone = {r.uid for r in expired}
            self._q = [e for e in self._q if e[1].uid not in gone]
        return expired

    def pop(self, k: int) -> list[QueuedRequest]:
        """Dequeue up to ``k`` requests in admission order."""
        take, self._q = self._q[:k], self._q[k:]
        return [r for _, r in take]

    def peek(self) -> QueuedRequest | None:
        return self._q[0][1] if self._q else None
