"""Pooled slot state: free-list admission over a preallocated slot batch.

The recurrent families this repo serves keep O(1) state per sequence (the
LSTM's (c, h) plus the optional delta reference/partial-sum memory), so a
slot costs a few KB — hundreds of slots are cheap where a paged-KV
transformer would page. The device arrays themselves are preallocated once
by the scheduler (`init_cache(slots, ...)`); this module owns the HOST side
of the pool: which slots are free, which request occupies each busy slot,
and the per-occupant accounting (budget left, deadline, admission time)
that admission/eviction decisions read.

The contract with the scheduler:

  alloc()/alloc_many(k)  → slot indices off the free list (LIFO — recently
                           freed slots rejoin first, keeping the active set
                           dense for occupancy reporting)
  seat(slot, info)       → record the occupant (the device-side join runs
                           separately; the pool never touches arrays)
  free(slot)             → evict: the occupant record is dropped and the
                           slot returns to the free list
  info(slot)/owner(slot) → the occupant record / its uid (None when free)
"""
from __future__ import annotations

import dataclasses
from typing import Any

__all__ = ["SlotInfo", "SlotPool"]


@dataclasses.dataclass
class SlotInfo:
    """Host-side record of one admitted request while it occupies a slot."""
    uid: int
    prompt_len: int
    remaining: int              # tokens still owed (budget minus emitted)
    deadline: float | None = None   # absolute clock time; None = none
    priority: int = 0
    admitted_at: float = 0.0
    emitted: int = 0            # tokens harvested so far
    extra: Any = None
    slot: int = -1              # seat() fills this backref in


class SlotPool:
    """Free-list over ``n`` preallocated decode slots."""

    def __init__(self, n: int):
        if n <= 0:
            raise ValueError(f"slot pool needs n > 0, got {n}")
        self.n = n
        self._free: list[int] = list(range(n - 1, -1, -1))
        self._info: list[SlotInfo | None] = [None] * n

    # ------------------------------------------------------------- alloc
    @property
    def free_count(self) -> int:
        return len(self._free)

    def alloc(self) -> int | None:
        """Pop one free slot (None when the pool is exhausted)."""
        return self._free.pop() if self._free else None

    def alloc_many(self, k: int) -> list[int]:
        """Pop up to ``k`` free slots."""
        out = []
        while self._free and len(out) < k:
            out.append(self._free.pop())
        return out

    def seat(self, slot: int, info: SlotInfo) -> None:
        if self._info[slot] is not None:
            raise RuntimeError(f"slot {slot} already seated "
                               f"(uid {self._info[slot].uid})")
        info.slot = slot
        self._info[slot] = info

    def free(self, slot: int) -> SlotInfo:
        """Evict the occupant; the slot rejoins the free list."""
        info = self._info[slot]
        if info is None:
            raise RuntimeError(f"slot {slot} is already free")
        self._info[slot] = None
        self._free.append(slot)
        return info

    def release_unseated(self, slot: int) -> None:
        """Return a slot popped by alloc() but never seated (a prefill
        group came up short)."""
        if self._info[slot] is not None:
            raise RuntimeError(f"slot {slot} is seated — use free()")
        self._free.append(slot)

    # ------------------------------------------------------------ queries
    def info(self, slot: int) -> SlotInfo | None:
        return self._info[slot]

    def owner(self, slot: int) -> int | None:
        info = self._info[slot]
        return None if info is None else info.uid

    def owners(self) -> list[int | None]:
        """Slot → uid (None when free), the dispatch-time snapshot the
        scheduler attaches to every in-flight chunk."""
        return [None if i is None else i.uid for i in self._info]

    def active(self) -> list[int]:
        """Busy slot indices, ascending."""
        return [s for s, i in enumerate(self._info) if i is not None]

    def __len__(self) -> int:
        return self.n - len(self._free)

    def __repr__(self) -> str:
        return f"SlotPool({len(self)}/{self.n} busy)"
