"""Poisson load generator: offered traffic as a deterministic trace.

``poisson_trace`` draws the whole arrival schedule — exponential
inter-arrival gaps at the offered rate, a short/long prompt-length
mixture, ragged output budgets, optional relative deadlines and priority
bands — from one seeded ``numpy`` generator. No wall clock touches the
schedule, so the same config always produces the same trace: traffic runs
are reproducible and their `BENCH_traffic.json` records diff cleanly
across PRs.

``serve_trace`` drives a ContinuousBatchingEngine through a trace and
timestamps every request (submit, first token via the engine's per-token
callback, finish) into ``metrics.RequestRecord``s:

- ``realtime=True`` paces submissions on the host clock — offered load is
  the trace's; the engine queues/sheds as it would in production.
- ``realtime=False`` ignores pacing and feeds arrivals as fast as the
  engine admits them — a closed-loop saturation driver for steady-state
  throughput measurement and for deterministic CI smoke runs.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from .metrics import RequestRecord, summarize

__all__ = ["LoadConfig", "Arrival", "poisson_trace", "make_prompts",
           "serve_trace"]


@dataclasses.dataclass(frozen=True)
class LoadConfig:
    """Offered-load model. Lengths are inclusive [lo, hi] ranges; prompts
    mix a short and a long population (``long_frac`` of requests draw
    from ``prompt_long``) so prefill cost is realistically bimodal."""
    rate: float                                # offered requests/s
    num_requests: int
    prompt_short: tuple = (4, 16)
    prompt_long: tuple = (24, 64)
    long_frac: float = 0.25
    output_lens: tuple = (4, 32)
    deadline: float | None = None              # relative seconds; None = off
    priorities: tuple = (0,)                   # drawn uniformly per request
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class Arrival:
    t: float                     # seconds since trace start
    prompt_len: int
    max_new: int
    deadline: float | None       # relative to submission; None = none
    priority: int


def poisson_trace(cfg: LoadConfig) -> list[Arrival]:
    """The full arrival schedule, deterministic in ``cfg.seed``."""
    if cfg.rate <= 0:
        raise ValueError(f"offered rate must be positive, got {cfg.rate}")
    g = np.random.default_rng(cfg.seed)
    n = cfg.num_requests
    ts = np.cumsum(g.exponential(1.0 / cfg.rate, size=n))
    is_long = g.random(n) < cfg.long_frac
    short = g.integers(cfg.prompt_short[0], cfg.prompt_short[1] + 1, size=n)
    long = g.integers(cfg.prompt_long[0], cfg.prompt_long[1] + 1, size=n)
    plens = np.where(is_long, long, short)
    outs = g.integers(cfg.output_lens[0], cfg.output_lens[1] + 1, size=n)
    prios = g.choice(np.asarray(cfg.priorities), size=n)
    return [Arrival(float(ts[i]), int(plens[i]), int(outs[i]),
                    cfg.deadline, int(prios[i])) for i in range(n)]


def make_prompts(trace, vocab: int, seed: int = 0) -> list[np.ndarray]:
    """One (1, S) int32 prompt per arrival, deterministic in ``seed``."""
    g = np.random.default_rng(seed + 0x5EED)
    return [g.integers(0, vocab, size=(1, a.prompt_len)).astype(np.int32)
            for a in trace]


def serve_trace(sched, trace, prompts, *, realtime: bool = True,
                clock=None, offered_rps: float | None = None):
    """Drive ``sched`` (a ContinuousBatchingEngine) through ``trace``.

    Returns ``(records, summary)`` — per-request ``RequestRecord``s in
    trace order and the ``metrics.summarize`` reduction. TTFT measures
    from the SCHEDULED arrival in realtime mode (queueing counts) and
    from submission in closed-loop mode (no pacing fiction).
    """
    clock = clock or time.perf_counter
    records: dict[int, RequestRecord] = {}
    order: list[int] = []

    def on_token(uid, toks, first):
        if first and uid in records and records[uid].first_token is None:
            records[uid].first_token = clock()

    prev_cb = sched.on_token
    sched.on_token = on_token
    start = clock()
    i = 0
    try:
        while i < len(trace) or sched.busy:
            now = clock()
            # release due arrivals (all of them, in schedule order)
            while i < len(trace) and (not realtime
                                      or start + trace[i].t <= now):
                a = trace[i]
                sched_t = start + a.t if realtime else now
                deadline = None if a.deadline is None else now + a.deadline
                uid = sched.submit(prompts[i], a.max_new,
                                   deadline=deadline, priority=a.priority)
                records[uid] = RequestRecord(
                    uid, scheduled=sched_t, prompt_len=a.prompt_len,
                    max_new=a.max_new, deadline=deadline, submitted=now,
                    reason="pending")
                order.append(uid)
                i += 1
                if not realtime:
                    break       # closed loop: one per iteration, keep
                                # admission interleaved with decode
            if sched.busy:
                for fin in sched.step():
                    r = records.get(fin.uid)
                    if r is None:
                        continue
                    r.finished = clock()
                    r.tokens = len(fin.tokens)
                    r.reason = fin.reason
            elif realtime and i < len(trace):
                # idle until the next arrival is due (bounded nap so a
                # virtual clock driver can still make progress)
                time.sleep(min(max(start + trace[i].t - clock(), 0.0),
                               1e-3))
    finally:
        sched.on_token = prev_cb
    wall = clock() - start
    recs = [records[u] for u in order]
    return recs, summarize(recs, wall, offered_rps=offered_rps)
