"""Dispatch-ahead bookkeeping: the host runs chunks ahead of its syncs.

jax dispatch is asynchronous — a jitted call returns array futures
immediately and the device works through the queue in program order. The
scheduler exploits that the way the paper's accelerator overlaps its
modules: it enqueues decode chunk N+1 (and any slot joins that precede
it) BEFORE forcing chunk N's tokens to the host, so the device never
idles across the host's per-chunk bookkeeping (token collection, EOS
scanning, admission decisions, Python object churn):

    device:  [ chunk N ][ joins ][ chunk N+1 ][ joins ][ chunk N+2 ] …
    host:         │ dispatch N+1 ──┘               │
                  └ harvest N (the one sync) ──────┴ harvest N+1 …

Each dispatched chunk carries a host-side snapshot of slot ownership at
dispatch time (`InFlight.owners`): by the time its tokens are harvested,
a slot may have been evicted and re-seated, and the tokens must be
credited to the request that actually occupied the slot when the chunk
was enqueued. Correctness never depends on the lag: the device-resident
``done``/``budget`` vectors freeze finished slots inside the chunk
itself, and a join fully overwrites a slot's state before reuse, so the
decoded trajectory of every request is bit-identical to the synchronous
(depth-1) schedule.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any

__all__ = ["InFlight", "DispatchQueue"]


@dataclasses.dataclass
class InFlight:
    """One dispatched-but-unharvested decode chunk."""
    tokens: Any                 # (slots, chunk) device array (a future)
    owners: tuple               # slot → uid (None = idle) at dispatch time
    seq: int                    # dispatch sequence number
    counters: Any = None        # obs counter vector snapshot (a future) —
                                # rides the chunk so the host reads it at
                                # the SAME sync that forces the tokens


class DispatchQueue:
    """FIFO of in-flight chunks, at most ``depth`` deep.

    depth=1 is the synchronous baseline (dispatch, then immediately
    harvest); depth=2 is classic double buffering (harvest chunk N with
    chunk N+1 already queued on the device). Deeper pipelines trade
    eviction/admission latency (a freed slot re-seats one chunk later per
    level) for more host/device overlap.
    """

    def __init__(self, depth: int = 2):
        if depth < 1:
            raise ValueError(f"dispatch depth must be >= 1, got {depth}")
        self.depth = depth
        self._q: deque[InFlight] = deque()
        self._seq = 0

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)

    @property
    def want_dispatch(self) -> bool:
        """Whether another chunk should be enqueued before harvesting."""
        return len(self._q) < self.depth

    def push(self, tokens, owners, counters=None) -> InFlight:
        if len(self._q) >= self.depth:
            raise RuntimeError(f"dispatch queue full (depth {self.depth})")
        inf = InFlight(tokens, tuple(owners), self._seq, counters)
        self._seq += 1
        self._q.append(inf)
        return inf

    def harvest(self) -> InFlight | None:
        """Pop the oldest in-flight chunk (the host then syncs its
        tokens). Returns None when nothing is in flight."""
        return self._q.popleft() if self._q else None
