"""repro.traffic — production-traffic serving support.

The pieces the continuous-batching scheduler is built from, plus the
load model that measures it:

- pool      — SlotPool: free-list admission over preallocated slot state
- admission — AdmissionQueue: priority/deadline ordering, overload shedding
- dispatch  — DispatchQueue: dispatch-ahead (double-buffered) chunk queue
- loadgen   — Poisson arrivals with mixed lengths, deterministic traces
- metrics   — per-request TTFT/TPOT records and the p50/p99 reduction

`repro.serving.scheduler.ContinuousBatchingEngine` composes pool +
admission + dispatch; `benchmarks/traffic.py` drives it with loadgen and
emits the measured latency curve into `BENCH_traffic.json`.
"""
from .admission import AdmissionQueue, QueuedRequest
from .dispatch import DispatchQueue, InFlight
from .loadgen import (Arrival, LoadConfig, make_prompts, poisson_trace,
                      serve_trace)
from .metrics import RequestRecord, percentile, summarize
from .pool import SlotInfo, SlotPool

__all__ = ["AdmissionQueue", "QueuedRequest", "DispatchQueue", "InFlight",
           "Arrival", "LoadConfig", "make_prompts", "poisson_trace",
           "serve_trace", "RequestRecord", "percentile", "summarize",
           "SlotInfo", "SlotPool"]
