"""Per-request latency accounting and the serving-curve reduction.

One ``RequestRecord`` per request, timestamped by the driver (the load
generator or a user callback): scheduled arrival, first harvested token
(TTFT measures from the SCHEDULED arrival, so queueing delay counts —
that is what a user of an overloaded service experiences), finish, token
count, and outcome. ``summarize`` reduces a batch of records to the
figures the benchmark record carries: p50/p90/p99 TTFT, per-token latency
(TPOT = (finish − first token)/(n − 1) per request), completion/shed
counts, throughput, and goodput (tokens of requests that completed within
their deadline — the honest numerator under overload).
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["RequestRecord", "percentile", "summarize"]


@dataclasses.dataclass
class RequestRecord:
    uid: int
    scheduled: float                 # arrival per the trace (absolute)
    prompt_len: int = 0
    max_new: int = 0
    deadline: float | None = None    # absolute; None = no deadline
    submitted: float | None = None   # when the driver called submit()
    first_token: float | None = None
    finished: float | None = None
    tokens: int = 0
    reason: str = ""                 # done | expired | rejected

    @property
    def ttft(self) -> float | None:
        if self.first_token is None:
            return None
        return self.first_token - self.scheduled

    @property
    def tpot(self) -> float | None:
        """Mean per-token latency after the first token."""
        if (self.first_token is None or self.finished is None
                or self.tokens < 2):
            return None
        return (self.finished - self.first_token) / (self.tokens - 1)

    @property
    def in_deadline(self) -> bool:
        """Completed, and on time if a deadline was attached."""
        if self.reason != "done" or self.finished is None:
            return False
        return self.deadline is None or self.finished <= self.deadline


def percentile(xs, q: float) -> float:
    """Linear-interpolated percentile; nan on empty input."""
    xs = np.asarray(list(xs), np.float64)
    if xs.size == 0:
        return float("nan")
    return float(np.percentile(xs, q))


def _pct_ms(xs, q: float) -> float | None:
    """Percentile in ms, or None on empty input — summaries land in JSON
    benchmark records, and NaN is not valid JSON (json.dump with
    allow_nan=False rejects it; other parsers read a corrupt file)."""
    p = percentile(xs, q)
    return None if np.isnan(p) else round(p * 1e3, 3)


def summarize(records, wall: float, offered_rps: float | None = None) -> dict:
    """Reduce request records to the serving curve's figures.

    ``wall``: driver wall time (seconds) over which ``records`` were
    served; ``offered_rps``: the trace's offered load, carried through for
    the goodput-vs-offered-load curve. Latencies are reported in ms.
    """
    recs = list(records)
    ttfts = [r.ttft for r in recs if r.ttft is not None]
    tpots = [r.tpot for r in recs if r.tpot is not None]
    done = [r for r in recs if r.reason == "done"]
    total_tokens = sum(r.tokens for r in recs)
    good_tokens = sum(r.tokens for r in recs if r.in_deadline)
    out = {
        "requests": len(recs),
        "completed": len(done),
        "expired": sum(r.reason == "expired" for r in recs),
        "rejected": sum(r.reason == "rejected" for r in recs),
        "tokens": total_tokens,
        "wall_s": round(float(wall), 6),
        "p50_ttft_ms": _pct_ms(ttfts, 50),
        "p90_ttft_ms": _pct_ms(ttfts, 90),
        "p99_ttft_ms": _pct_ms(ttfts, 99),
        "p50_tpot_ms": _pct_ms(tpots, 50),
        "p99_tpot_ms": _pct_ms(tpots, 99),
        "toks_per_s": round(total_tokens / wall, 1) if wall > 0 else 0.0,
        "goodput_tps": round(good_tokens / wall, 1) if wall > 0 else 0.0,
    }
    if offered_rps is not None:
        out["offered_rps"] = round(float(offered_rps), 3)
    return out
