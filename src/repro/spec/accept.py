"""Acceptance rules + accepted-length accounting for speculative decode.

Conventions (one round, batch row dropped): the draft proposed
``d_1..d_k`` with proposal distributions ``q_1..q_k``; the target's verify
produced distributions ``p_0..p_k`` where ``p_{i-1}`` governs the slot
``d_i`` sits in and ``p_k`` is the bonus slot after a full acceptance.
``accept_len`` a ∈ [0, k] is the length of the accepted draft PREFIX; the
round then commits a+1 tokens total (the round-opening committed token
plus the a accepted proposals) and samples the next token from
``residual_dist`` — the standard corrected distribution on a rejection,
the plain bonus distribution ``p_k`` on full acceptance.

Greedy (temperature 0) uses the exact-match rule; with one-hot greedy
distributions the rejection rule reduces to it, so the same residual
machinery serves both and greedy stays deterministic and lossless.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["accept_length", "greedy_accept", "rejection_accept",
           "residual_dist"]


def accept_length(ok):
    """(B, k) per-position accept bools → (B,) accepted-PREFIX length
    (acceptance stops at the first rejection; later accepts don't count)."""
    return jnp.sum(jnp.cumprod(ok.astype(jnp.int32), axis=1), axis=1)


def greedy_accept(draft_tokens, target_logits):
    """Greedy exact-match rule: accept ``d_i`` while it equals the target's
    argmax at its slot. ``draft_tokens`` (B, k); ``target_logits``
    (B, ≥k, V) raw logits or distributions (argmax-invariant)."""
    k = draft_tokens.shape[1]
    tgt = jnp.argmax(target_logits[:, :k].astype(jnp.float32), axis=-1)
    return accept_length(draft_tokens == tgt.astype(draft_tokens.dtype))


def rejection_accept(rng, draft_tokens, p_dists, q_dists):
    """Standard speculative-sampling rule: accept ``d_i`` while
    ``u_i < p_{i-1}(d_i) / q_i(d_i)`` with u_i ~ U[0, 1). Combined with
    ``residual_dist`` resampling this makes the emitted tokens exact
    samples from the target distribution chain. ``p_dists`` (B, k+1, V),
    ``q_dists`` (B, k, V) — both post-sampling-transform probabilities
    (``sampling.sample_dist``)."""
    B, k = draft_tokens.shape
    idx = draft_tokens[..., None].astype(jnp.int32)
    p_tok = jnp.take_along_axis(p_dists[:, :k], idx, axis=-1)[..., 0]
    q_tok = jnp.take_along_axis(q_dists, idx, axis=-1)[..., 0]
    u = jax.random.uniform(rng, (B, k))
    # u * q < p  ⇔  u < p/q without the division-by-zero hazard
    ok = u * jnp.maximum(q_tok, 1e-30) < p_tok
    return accept_length(ok)


def residual_dist(p_dists, q_dists, accept_len):
    """Next-token distribution at the round's stop slot (B, V).

    On a rejection at slot a < k: ``norm(max(p_a − q_{a+1}, 0))`` — the
    corrected distribution that makes rejection sampling exact. On full
    acceptance (a = k): the plain bonus distribution ``p_k``. Degenerate
    all-zero residuals (p ≤ q everywhere mass sits) fall back to ``p_a``.
    """
    B, k1, V = p_dists.shape
    qz = jnp.concatenate(
        [q_dists, jnp.zeros((B, 1, V), q_dists.dtype)], axis=1)
    a = accept_len[:, None, None].astype(jnp.int32)
    p_a = jnp.take_along_axis(p_dists, a, axis=1)[:, 0]
    q_a = jnp.take_along_axis(qz, a, axis=1)[:, 0]
    res = jnp.maximum(p_a - q_a, 0.0)
    z = jnp.sum(res, axis=-1, keepdims=True)
    return jnp.where(z > 0, res / jnp.maximum(z, 1e-30), p_a)
