"""repro.spec — speculative decoding with BRDS-packed recurrent drafts.

The speculate-then-verify composition that turns the sparsity stack into a
speedup for every architecture in the zoo: a tiny packed recurrent model
(the paper's LSTM, or any DecodeStep family with O(1) state) proposes k
tokens per round, the target scores all k+1 positions in one verify
dispatch, an acceptance rule keeps a prefix, and both models roll back —
the target by cache-position rewind (runtime.DecodeStep's rewind
contract), the draft by checkpoint/restore of its recurrent state.

- draft   — DraftModel adapter: proposal chain + state checkpoints
- verify  — k-token target verify + positional/state cache rollback
- accept  — greedy exact-match + rejection-sampling acceptance rules
- loop    — the on-device speculate→verify→accept round loop

Greedy speculative decode is LOSSLESS: bitwise identical to target-only
greedy decode (tests/test_spec.py pins this for every draft variant).
"""
from .accept import (accept_length, greedy_accept, rejection_accept,
                     residual_dist)
from .draft import DraftModel
from .loop import spec_decode_loop
from .verify import cache_leaf_flags, rollback, state_leaves, verify_chain

__all__ = ["DraftModel", "spec_decode_loop", "verify_chain", "rollback",
           "state_leaves", "cache_leaf_flags", "greedy_accept",
           "rejection_accept", "residual_dist", "accept_length"]
