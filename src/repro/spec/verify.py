"""k-token target verify: DecodeStep generalized to (B, k) token blocks.

``verify_chain`` scores a block of k tokens in ONE dispatch by scanning the
model's own ``decode_step`` body over the block — the same ops the
target-only decode loop runs, so the per-position logits are bitwise what
sequential decoding would produce (the losslessness invariant rides on
this), and k=1 degenerates to exactly one decode_step.

Rollback after partial acceptance splits the decode cache by leaf kind,
read off the ``cache_defs`` logical axes:

- *positional* leaves (a ``cache_seq`` axis — KV caches and their quant
  scales) roll back by position rewind alone: the DecodeStep contract
  requires entries at positions ≥ ``pos`` to be dead, so the rejected
  tail can simply be left in the buffers and overwritten next round;
- *state* leaves (everything else — LSTM (c, h) + delta reference state,
  RG-LRU h/conv, RWKV S/x_tm/x_cm) are O(1) per step, so the scan
  checkpoints them per verified token and ``rollback`` restores the
  checkpoint at each row's accepted length.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models import layers as L

__all__ = ["cache_leaf_flags", "state_leaves", "verify_chain", "rollback"]


def cache_leaf_flags(model):
    """Per-cache-leaf (positional?, batch_axis) lists in flatten order.

    Read from ``model.cache_defs``: a leaf is positional iff its logical
    axes include ``cache_seq``; ``batch_axis`` is where the batch dimension
    sits (layer-stacked blocks put ``layers`` ahead of it)."""
    defs = model.cache_defs(2, 4)    # axes don't depend on sizes
    positional = jax.tree.leaves(jax.tree.map(
        lambda d: "cache_seq" in d.axes, defs, is_leaf=L.is_pspec))
    batch_axes = jax.tree.leaves(jax.tree.map(
        lambda d: d.axes.index("batch"), defs, is_leaf=L.is_pspec))
    return positional, batch_axes


def state_leaves(model, cache):
    """The non-positional (recurrent-state) cache leaves, flatten order."""
    positional, _ = cache_leaf_flags(model)
    return tuple(leaf for leaf, p in zip(jax.tree.leaves(cache), positional)
                 if not p)


def verify_chain(model, params, cache, tokens, pos):
    """Score a (B, T) token block in one dispatch.

    Scans ``model.decode_step`` over the block (token j lands at cache
    position ``pos + j``; ``pos`` scalar or (B,)). Returns

    - ``logits`` (B, T, V) fp32 — position j's logits condition on tokens
      ``[:j]`` of the block, i.e. the distribution for the token AFTER
      ``tokens[:, j]``;
    - ``cache`` — the post-block cache (positions pos..pos+T-1 written);
    - ``states`` — per-leaf stacked state checkpoints with leading axis
      T+1: index m is the state after consuming m block tokens (m=0 is
      the pre-block state), ready for ``rollback``.
    """
    tokens = jnp.asarray(tokens, jnp.int32)
    pos = jnp.asarray(pos, jnp.int32)
    T = tokens.shape[1]
    positional, _ = cache_leaf_flags(model)
    pre = state_leaves(model, cache)

    def body(c, xt):
        tok, j = xt
        logits, c2 = model.decode_step(params, c, tok[:, None], pos + j)
        sts = tuple(leaf for leaf, p in
                    zip(jax.tree.leaves(c2), positional) if not p)
        return c2, (logits[:, 0].astype(jnp.float32), sts)

    cache, (logits, stacked) = jax.lax.scan(
        body, cache, (tokens.T, jnp.arange(T, dtype=jnp.int32)))
    states = tuple(jnp.concatenate([p[None].astype(s.dtype), s], axis=0)
                   for p, s in zip(pre, stacked))
    return jnp.moveaxis(logits, 0, 1), cache, states


def rollback(model, cache, states, commit):
    """Roll a post-verify cache back to ``commit`` (B,) accepted tokens.

    Positional leaves keep the scan-final buffers unchanged — the caller
    rewinds ``pos`` to ``pos + commit`` and the rejected tail at positions
    ≥ the rewound pos is dead by the DecodeStep rewind contract. State
    leaves are restored from the ``verify_chain`` checkpoints at each
    row's ``commit`` index (0 = pre-block state)."""
    positional, batch_axes = cache_leaf_flags(model)
    commit = jnp.asarray(commit, jnp.int32)
    rows = jnp.arange(commit.shape[0])
    out, si = [], 0
    for leaf, p, ax in zip(jax.tree.leaves(cache), positional, batch_axes):
        if p:
            out.append(leaf)
        else:
            s = jnp.moveaxis(states[si], ax + 1, 1)     # (T+1, B, ...)
            out.append(jnp.moveaxis(s[commit, rows], 0, ax))
            si += 1
    return jax.tree.unflatten(jax.tree.structure(cache), out)
