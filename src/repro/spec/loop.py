"""The on-device speculate→verify→accept round loop.

``spec_decode_loop`` is ``decode_loop``'s speculative sibling: same carry
discipline (per-sequence done/emitted/pos, EOS/budget/limit stops, pad
emission after done, chunk-resumable state dict) but the unit of work is a
ROUND, not a token — draft proposes k tokens, the target verifies the
whole block in one dispatch, an acceptance rule keeps a prefix, and both
models roll back to the committed point. Each active row commits at least
one token per round (the round-opening target sample), so the
``lax.while_loop`` terminates within ``steps`` rounds.

The carry's distribution slot: where ``decode_loop`` carries the last
logits, this loop carries ``probs`` — the (B, V) sampling DISTRIBUTION for
each row's next token (a ``sampling.sample_dist`` output, or the
rejection-sampling residual). Greedy distributions are one-hot, so the
greedy path commits exactly the target argmax chain: bitwise identical to
target-only greedy decode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..serving.sampling import SamplingConfig, sample_dist, sample_from_dist
from . import verify as V
from .accept import greedy_accept, rejection_accept, residual_dist

__all__ = ["spec_decode_loop"]


def spec_decode_loop(model, draft, params, dparams, cache, dstate, probs,
                     pos, rng, steps: int, k: int,
                     sampling: SamplingConfig, *, done=None, budget=None,
                     limit: int | None = None):
    """Generate up to ``steps`` tokens per row via speculative rounds.

    Parameters (beyond ``decode_loop``'s)
    -------------------------------------
    draft : DraftModel
        The recurrent draft adapter.
    dparams / dstate : pytree
        Draft params and per-row recurrent state (primed on the same
        prompt as ``cache``).
    probs : jnp.ndarray
        (B, V) fp32 sampling distribution for the next token —
        ``sample_dist(prefill_logits[:, -1], sampling)``, or the carried
        distribution of a previous chunk.
    pos : jnp.ndarray
        Scalar or (B,) next cache position. Always vectorized internally:
        per-row commit counts diverge, and vector positions keep
        ``kv_cache_update`` on the scatter path whose out-of-bounds
        writes drop (the scalar path clamps).
    k : int
        Draft tokens proposed per round (static). k=0 degenerates to
        verified-one-token-per-round, i.e. plain autoregressive decode.

    Returns
    -------
    (tokens, state)
        ``tokens`` (B, steps) int32 — emitted tokens, pad-filled after a
        row finishes/pauses. ``state`` carries everything ``decode_loop``'s
        does (with ``dstate``/``probs`` in place of ``logits``) plus
        per-row round accounting: ``rounds``, ``drafted``, ``accepted`` —
        acceptance-rate = accepted / drafted.
    """
    B, Vv = probs.shape
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        pos = jnp.full((B,), pos, jnp.int32)
    if done is None:
        done = jnp.zeros((B,), bool)
    greedy = sampling.temperature <= 0.0
    dcfg = draft.sampling if draft.sampling is not None else sampling
    out0 = jnp.full((B, steps), jnp.int32(sampling.pad_id))
    zeros = jnp.zeros((B,), jnp.int32)

    def cond(carry):
        _, _, _, _, done, _, emitted, out, *_ = carry
        return jnp.any(~done & (emitted < steps))

    def body(carry):
        (cache, dstate, probs, pos, done, rng, emitted, out,
         rounds, drafted, accepted) = carry
        rng, r_nxt, r_draft, r_acc = jax.random.split(rng, 4)
        active = ~done & (emitted < steps)

        # round-opening token: the sample the previous round left pending
        nxt = sample_from_dist(r_nxt, probs, sampling)
        nxt = jnp.where(done, jnp.int32(sampling.pad_id), nxt)

        # draft chain + one-dispatch target verify of [nxt, d_1..d_k].
        # these spans run at jax-trace time (once per compile, not per
        # round) — they chart staging cost, the first-dispatch tax
        from ..obs import trace as obs_trace
        with obs_trace.span("spec.propose", cat="jax-trace", k=k):
            d_toks, q_dists, d_states = draft.propose(
                dparams, dstate, nxt, pos, k, r_draft, dcfg)
        block = jnp.concatenate([nxt[:, None], d_toks], axis=1)
        with obs_trace.span("spec.verify", cat="jax-trace", k=k):
            t_logits, cache, t_states = V.verify_chain(
                model, params, cache, block, pos)
        p_dists = sample_dist(t_logits, sampling)

        if k == 0:
            a = zeros
        elif greedy:
            a = greedy_accept(d_toks, t_logits)
        else:
            a = rejection_accept(r_acc, d_toks, p_dists, q_dists)

        # stepwise emission — decode_loop's exact stop discipline applied
        # to the a+1 committable tokens (EOS itself emitted, budget
        # checked post-increment, limit = next write position, steps caps
        # the chunk WITHOUT setting done so a later chunk resumes)
        rd, em, m = done, emitted, zeros
        rows = jnp.arange(B)
        for j in range(k + 1):
            tok_j = block[:, j]
            can = ~rd & (j <= a) & (em < steps)
            slot = jnp.minimum(em, steps - 1)
            out = out.at[rows, slot].set(
                jnp.where(can, tok_j, out[rows, slot]))
            em = em + can.astype(jnp.int32)
            m = m + can.astype(jnp.int32)
            if sampling.stops:
                rd = rd | (can & (tok_j == sampling.eos_id))
            if budget is not None:
                rd = rd | (can & (em >= budget))
            if limit is not None:
                rd = rd | (can & (pos + m >= limit))

        # roll both models back to the per-row committed point
        pos2 = pos + m
        with obs_trace.span("spec.rollback", cat="jax-trace"):
            cache2 = V.rollback(model, cache, t_states, m)
            dstate2 = draft.select(dstate, d_states, m)

        # next round's pending distribution: the residual at the stop slot
        # when the commit ended exactly at the acceptance boundary, the
        # verify distribution after the last committed token otherwise
        # (early stop via EOS/budget/limit); untouched when nothing moved
        p_stop = residual_dist(p_dists, q_dists, a)
        idx = jnp.maximum(m - 1, 0)
        p_m = jnp.take_along_axis(
            p_dists, idx[:, None, None], axis=1)[:, 0]
        base = jnp.where((idx == a)[:, None], p_stop, p_m)
        probs2 = jnp.where((m == 0)[:, None], probs, base)

        inc = active.astype(jnp.int32)
        return (cache2, dstate2, probs2, pos2, rd, rng, em, out,
                rounds + inc, drafted + k * inc, accepted + a * inc)

    carry = (cache, dstate, probs, pos, done, rng, jnp.zeros((B,), jnp.int32),
             out0, zeros, zeros, zeros)
    (cache, dstate, probs, pos, done, rng, emitted, out,
     rounds, drafted, accepted) = jax.lax.while_loop(cond, body, carry)
    return out, dict(cache=cache, dstate=dstate, probs=probs, pos=pos,
                     rng=rng, done=done, emitted=emitted, rounds=rounds,
                     drafted=drafted, accepted=accepted)
