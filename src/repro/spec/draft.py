"""DraftModel: a BRDS-packed recurrent model adapted as speculative draft.

Wraps any DecodeStep model whose decode cache is pure O(1) recurrent state
(no ``cache_seq`` axis in its ``cache_defs``) — the paper's LSTM in every
serving variant (dense, packed, temporal-delta, calibrated q8, fused) and
the RWKV/RG-LRU ref decode. Positional-cache models are rejected: a draft
must checkpoint/restore its whole state per round, which is only O(1)
cheap for recurrent families.

The adapter provides the three draft-side operations of a speculative
round:

- ``prefill`` primes the state on the committed prompt. Packed fp32 LSTM
  drafts route exact-length prompts through the multi-token
  ``fused_brds_lstm_scan`` kernel — one launch per layer with (c, h)
  resident in VMEM across the whole prompt. Draft state needs no bitwise
  contract with anything (it only shapes proposal quality), so this fast
  path is free to diverge at the ulp level from the masked prefill body.
- ``propose`` runs the k-token proposal chain (k+1 decode steps in one
  scan) and stacks a state checkpoint per consumed token.
- ``select`` is the rollback: restore the checkpoint at each row's
  committed-token count after acceptance.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..serving import runtime
from ..serving.sampling import sample_dist, sample_from_dist
from . import verify

__all__ = ["DraftModel"]


class DraftModel:
    """Speculative-draft adapter around a recurrent DecodeStep model.

    Parameters
    ----------
    model : DecodeStep
        The draft family (LSTMModel/RWKV-style); its cache must be pure
        recurrent state.
    params : pytree
        Dense, packed, delta-wired, or q8 draft params — ``decode_step``
        dispatches on the leaves, so every BRDS serving variant drafts
        through its own kernels. Stored as a convenience handle; the
        engine passes params explicitly at the jit boundary.
    sampling : SamplingConfig, optional
        Proposal distribution config. Default None → the target's own
        sampling config (the standard choice: proposals are drawn from
        the same transform the acceptance rule scores them under).
    scan_prefill : bool, optional
        Force (True) or disable (False) the fused multi-token scan-kernel
        prefill; None (default) auto-enables it for packed fp32 LSTM
        params on exact-length prompts up to 64 tokens.
    """

    def __init__(self, model, params, *, sampling=None, scan_prefill=None):
        if not runtime.conforms(model):
            raise TypeError(
                f"{type(model).__name__} does not implement the DecodeStep "
                "serving contract (cache_defs / prefill / decode_step)")
        positional, _ = verify.cache_leaf_flags(model)
        if any(positional):
            raise TypeError(
                f"{type(model).__name__} keeps a positional (cache_seq) "
                "decode cache — a speculative draft must carry O(1) "
                "recurrent state so each round can checkpoint/restore it "
                "(use the LSTM/RWKV/RG-LRU families)")
        self.model = model
        self.params = params
        self.sampling = sampling
        self.scan_prefill = scan_prefill

    def init_cache(self, batch: int, max_len: int):
        return self.model.init_cache(batch, max_len)

    # ---------------------------------------------------------- prefill
    def prefill(self, params, tokens, max_len: int, extra=None, length=None):
        """Prime the draft state on the prompt → (logits (B, 1, V), state).

        Mirrors ``model.prefill`` (``length`` supported when the model's
        is), with the fused-scan fast path where it applies."""
        if self._can_scan_prefill(params, tokens, length):
            return self._scan_prefill_lstm(params, tokens)
        if length is not None:
            return self.model.prefill(params, tokens, max_len, extra=extra,
                                      length=length)
        return self.model.prefill(params, tokens, max_len, extra=extra)

    def _can_scan_prefill(self, params, tokens, length) -> bool:
        if self.scan_prefill is False or length is not None:
            return False
        m = self.model
        if not (hasattr(m, "is_packed") and hasattr(m, "cfg")):
            return False
        if (getattr(m, "delta", None) is not None
                or getattr(m, "quant", None) is not None
                or getattr(m, "mesh", None) is not None):
            return False
        if not getattr(m.cfg, "vocab_size", 0) or tokens.ndim != 2:
            return False
        try:
            packed = m.is_packed(params) and not m.is_quantized(params)
        except (KeyError, IndexError, TypeError):
            return False
        if not packed:
            return False
        # the ref-backend scan unrolls T python steps — keep compiles small
        return self.scan_prefill is True or tokens.shape[1] <= 64

    def _scan_prefill_lstm(self, params, tokens):
        """Layer-by-layer ``fused_brds_lstm_scan`` over the whole prompt:
        the multi-token kernel consumes the embedded token sequence with
        (c, h) in VMEM scratch, one launch per layer."""
        from ..kernels import ops as K
        from ..models import layers as L
        m, cfg = self.model, self.model.cfg
        B = tokens.shape[0]
        xs = L.embed_apply(params["embed"], tokens).astype(
            cfg.dtype).transpose(1, 0, 2)                  # (T, B, X)
        layers = []
        for lp in params["layers"]:
            h0 = jnp.zeros((B, cfg.hidden), cfg.dtype)
            c0 = jnp.zeros((B, cfg.hidden), cfg.dtype)
            hs, c_t = K.fused_brds_lstm_scan(
                lp["w_x"], xs, lp["w_h"], h0, lp["b"], c0,
                pwl=cfg.pwl_activations)
            xs = hs.astype(cfg.dtype)
            layers.append({"c": c_t.astype(cfg.dtype), "h": xs[-1]})
        return m._head_logits(params, xs[-1]), {"layers": layers}

    # ---------------------------------------------------------- propose
    def propose(self, params, state, nxt, pos, k: int, rng, cfg):
        """The k-token proposal chain with rollback checkpoints.

        Runs k+1 draft steps in one scan: step j consumes token c_j of
        ``[nxt, d_1..d_k]`` (``nxt`` is the round's target-committed
        token) and samples d_{j+1} from the draft's sampling distribution
        under ``cfg``. Returns

        - ``tokens`` (B, k) — the proposals d_1..d_k;
        - ``qdists`` (B, k, V) — their proposal distributions (the
          rejection rule's q_i);
        - ``states`` — stacked cache-leaf checkpoints, leading axis k+2:
          index m is the draft state after consuming m tokens of
          ``[nxt, d_1..d_k]`` (m=0 pre-round) — ``select(states, m)``
          is the re-prime after m tokens commit.
        """
        def body(carry, j):
            st, tok, r = carry
            r, rk = jax.random.split(r)
            logits, st2 = self.model.decode_step(params, st, tok[:, None],
                                                 pos + j)
            q = sample_dist(logits[:, -1], cfg)
            nxt_d = sample_from_dist(rk, q, cfg)
            return (st2, nxt_d, r), (nxt_d, q,
                                     tuple(jax.tree.leaves(st2)))

        (_, _, _), (toks, qs, stacked) = jax.lax.scan(
            body, (state, jnp.asarray(nxt, jnp.int32), rng),
            jnp.arange(k + 1, dtype=jnp.int32))
        pre = tuple(jax.tree.leaves(state))
        states = tuple(jnp.concatenate([p[None].astype(s.dtype), s], axis=0)
                       for p, s in zip(pre, stacked))
        return toks[:k].T, jnp.moveaxis(qs[:k], 0, 1), states

    # ----------------------------------------------------------- rollback
    def select(self, state_template, states, commit):
        """Checkpoint/restore rollback: the draft state after ``commit``
        (B,) tokens of the round's block committed. ``state_template`` is
        any cache with the right tree structure (e.g. the pre-round
        state)."""
        return verify.rollback(self.model, state_template, states, commit)
