"""Target-hardware constants (TPU v5e) used by the roofline analysis.

This container executes on CPU; these numbers describe the TARGET chip that
the dry-run artifacts are analysed against (per the assignment spec).
"""
PEAK_BF16_FLOPS = 197e12       # per chip, bf16
PEAK_INT8_OPS = 394e12         # per chip, int8 MACs (2x the bf16 MXU rate)
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link (~)
VMEM_BYTES = 128 * 1024 * 1024 # ~128 MiB VMEM per chip (v5e ~128MB)
MXU_TILE = 128                 # systolic array dimension
LANE = 128                     # vector lane width
SUBLANE = 8                    # fp32 sublane count (16 for bf16)
HBM_PER_CHIP = 16 * 2**30      # 16 GiB
