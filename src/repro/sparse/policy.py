"""SparsityPolicy → SparsityPlan: one declaration drives prune, retrain
masking, and packing for ANY model's param tree.

A policy is a list of rules, each mapping a param-path regex to a
(format, ratio) pair:

    policy = SparsityPolicy.of({"w_x$": ("row_balanced", 0.875),
                                "w_h$": ("row_balanced", 0.75)},
                               layout="out_in")
    plan = policy.compile(params)
    pruned, masks = plan.prune(params)         # masks: {path: bool mask}
    grads = plan.mask_grads(grads, masks)      # freeze pruned weights
    packed, report = plan.pack(pruned)         # packed-format param tree

Weight layout per rule (how a leaf maps to the accelerator's
(rows=output, cols=fan-in) matrix):

  "out_in"        (out, in...)   — the LSTM's W ∈ R^{4H×X} convention
  "in_out"        (in..., out)   — transformer projections (out = last dim)
  "out_trailing"  (in, out...)   — rwkv mixer weights

The two stock policies — ``lstm_policy`` (the paper's dual-ratio W_x/W_h
split) and ``transformer_policy`` (family A = feed-forward, family B =
mixer, per DESIGN.md §4) — replace the scattered ``LSTMModel.prune``/
``training.brds_masks`` surfaces; those remain as deprecation shims.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Mapping

import jax
import jax.numpy as jnp
import numpy as np

from .formats import SparseFormat, get_format
from . import backend as B

__all__ = ["Rule", "SparsityPolicy", "SparsityPlan", "lstm_policy",
           "transformer_policy", "apply_masks", "mask_grads",
           "sparsity_report"]

_LAYOUTS = ("out_in", "in_out", "out_trailing")


# ----------------------------------------------------------------- paths

def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


# ----------------------------------------------------------------- rules

@dataclasses.dataclass(frozen=True)
class Rule:
    """One policy entry: params whose path matches ``pattern`` (re.search)
    are pruned with ``format`` at ``ratio``."""

    pattern: str
    format: str = "row_balanced"
    ratio: float = 0.0
    layout: str = "in_out"
    options: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.layout not in _LAYOUTS:
            raise ValueError(f"layout must be one of {_LAYOUTS}, "
                             f"got {self.layout!r}")
        if not (0.0 <= self.ratio < 1.0):
            raise ValueError(f"ratio must be in [0, 1), got {self.ratio}")


@dataclasses.dataclass(frozen=True)
class _Site:
    """One matched param leaf, normalized to the (rows=out, cols=in) view."""

    path: str
    rule: Rule
    fmt: SparseFormat
    L: int | None          # stacked leading dim (scanned blocks) or None
    d_in: int
    d_out: int
    shape: tuple
    dtype: Any

    def to_oi(self, leaf) -> jnp.ndarray:
        """leaf → (L1, d_out, d_in) with rows = output units."""
        L1 = self.L or 1
        if self.rule.layout == "out_in":
            return leaf.reshape(L1, self.d_out, self.d_in)
        w3 = leaf.reshape(L1, self.d_in, self.d_out)
        return jnp.swapaxes(w3, -1, -2)

    def from_oi(self, arr3) -> jnp.ndarray:
        if self.rule.layout == "out_in":
            return arr3.reshape(self.shape)
        return jnp.swapaxes(arr3, -1, -2).reshape(self.shape)


def _resolve_dims(layout: str, core: tuple) -> tuple[int, int]:
    """→ (d_in, d_out) for the un-stacked core shape."""
    if layout == "out_in":
        return int(np.prod(core[1:])), core[0]
    if layout == "out_trailing":
        return core[0], int(np.prod(core[1:]))
    return int(np.prod(core[:-1])), core[-1]


def _is_stacked(ps: str, leaf_ndim: int) -> bool:
    return "blocks/" in ps and leaf_ndim >= 3


# ---------------------------------------------------------------- policy

@dataclasses.dataclass(frozen=True)
class SparsityPolicy:
    """One declaration of everything sparse about a deployment.

    Ordered weight rules (first match wins) select a (format, ratio) per
    param-path regex; ``backend`` picks the kernel implementation once for
    everything the policy touches; ``activation`` optionally adds the
    temporal (activation-side) rule — a
    :class:`repro.sparse.temporal.DeltaGateConfig` that serving threads
    into the model's decode cache (Spartus-style delta skipping composed
    with the packed weight formats).

    Parameters
    ----------
    rules : tuple of Rule
        Weight rules, matched in order against each param path.
    backend : {"auto", "pallas", "ref"}
        Kernel backend for every matvec the compiled plan dispatches.
    activation : DeltaGateConfig, optional
        Temporal-delta activation rule; None (default) means dense
        activations.
    quant : QuantConfig, optional
        Fixed-point inference rule (``repro.quant``): row-balanced sites
        pack to quantized codes + per-row scales and serving runs the q8
        kernels; None (default) keeps float packed values.

    Examples
    --------
    >>> p = SparsityPolicy.of({r"w_x$": ("row_balanced", 0.875),
    ...                        r"w_h$": ("row_balanced", 0.75)},
    ...                       layout="out_in")
    >>> p.match("layers/0/w_x").ratio
    0.875
    >>> p.match("layers/0/b") is None
    True
    """

    rules: tuple
    backend: str = "auto"
    activation: Any = None
    quant: Any = None

    def __post_init__(self):
        if self.backend not in B.BACKENDS:
            raise ValueError(f"backend must be one of {B.BACKENDS}, "
                             f"got {self.backend!r}")

    @classmethod
    def of(cls, mapping: Mapping[str, Any], *, backend: str = "auto",
           layout: str = "in_out", activation: Any = None,
           quant: Any = None) -> "SparsityPolicy":
        """Build a policy from a ``{pattern: spec}`` mapping.

        Parameters
        ----------
        mapping : Mapping[str, float | tuple]
            ``{pattern: ratio | (format, ratio) | (format, ratio,
            options)}``; bare floats mean ``row_balanced``.
        backend : {"auto", "pallas", "ref"}
            Kernel backend for the compiled plan.
        layout : {"out_in", "in_out", "out_trailing"}
            Weight layout shared by every rule built here.
        activation : DeltaGateConfig, optional
            Temporal-delta activation rule.
        quant : QuantConfig, optional
            Fixed-point inference rule (quantized packing + q8 kernels).

        Returns
        -------
        SparsityPolicy
        """
        rules = []
        for pat, spec in mapping.items():
            if isinstance(spec, (int, float)):
                rules.append(Rule(pat, "row_balanced", float(spec), layout))
            else:
                fmt, ratio, *rest = spec
                opts = rest[0] if rest else {}
                rules.append(Rule(pat, fmt, float(ratio), layout,
                                  dict(opts)))
        return cls(rules=tuple(rules), backend=backend,
                   activation=activation, quant=quant)

    def with_backend(self, backend: str) -> "SparsityPolicy":
        """Copy of this policy with a different kernel backend."""
        return dataclasses.replace(self, backend=backend)

    def with_activation(self, activation) -> "SparsityPolicy":
        """Copy of this policy with a temporal-delta activation rule
        (a ``DeltaGateConfig``, or None to disable)."""
        return dataclasses.replace(self, activation=activation)

    def with_quant(self, quant) -> "SparsityPolicy":
        """Copy of this policy with a fixed-point inference rule
        (a ``repro.quant.QuantConfig``, or None to disable)."""
        return dataclasses.replace(self, quant=quant)

    def match(self, path_str: str) -> Rule | None:
        """First rule whose pattern ``re.search``-matches ``path_str``."""
        for r in self.rules:
            if re.search(r.pattern, path_str):
                return r
        return None

    def compile(self, params) -> "SparsityPlan":
        """Walk the param tree once, resolving every matched leaf to a
        (format, layout, dims) site. ``params`` may be concrete arrays or
        ShapeDtypeStructs — only shapes/dtypes are read."""
        sites = {}
        for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
            if not hasattr(leaf, "ndim") or leaf.ndim < 2:
                continue
            ps = _path_str(path)
            rule = self.match(ps)
            if rule is None or rule.ratio <= 0.0:
                continue
            stacked = _is_stacked(ps, leaf.ndim)
            core = leaf.shape[1:] if stacked else leaf.shape
            d_in, d_out = _resolve_dims(rule.layout, core)
            sites[ps] = _Site(
                path=ps, rule=rule, fmt=get_format(rule.format),
                L=leaf.shape[0] if stacked else None,
                d_in=d_in, d_out=d_out, shape=tuple(leaf.shape),
                dtype=leaf.dtype)
        return SparsityPlan(policy=self, sites=sites)


# ------------------------------------------------------------------ plan

# formats whose mask() accepts leading batch dims
_BATCHED_MASK_FORMATS = {"row_balanced", "row_balanced_q8"}


class SparsityPlan:
    """A policy compiled against one param tree.

    All methods are pure and jit-compatible on the array side; site
    resolution (shape/layout/format per matched leaf) happened at compile.
    The plan is the deployment handle: ``prune`` → ``mask_grads`` (retrain)
    → ``pack`` (serve), plus ``matvec`` kernel dispatch per site, with the
    policy's backend and activation rule riding along.

    Attributes
    ----------
    policy : SparsityPolicy
        The declaration this plan was compiled from.
    sites : dict
        ``{path: _Site}`` for every matched param leaf.
    """

    def __init__(self, policy: SparsityPolicy, sites: dict):
        self.policy = policy
        self.sites = sites

    @property
    def backend(self) -> str:
        """The policy's kernel backend ("auto" | "pallas" | "ref")."""
        return self.policy.backend

    @property
    def activation(self):
        """The policy's temporal-delta activation rule
        (``DeltaGateConfig`` or None)."""
        return self.policy.activation

    @property
    def quant(self):
        """The policy's fixed-point inference rule
        (``repro.quant.QuantConfig`` or None)."""
        return self.policy.quant

    def __repr__(self):
        return (f"SparsityPlan(backend={self.backend!r}, "
                f"sites={len(self.sites)})")

    # -- masks ----------------------------------------------------------
    def _site_mask(self, site: _Site, leaf) -> jnp.ndarray:
        w_oi = site.to_oi(leaf)                     # (L1, out, in)
        r, opts = site.rule.ratio, site.rule.options
        if site.fmt.name in _BATCHED_MASK_FORMATS:
            m = site.fmt.mask(w_oi, r, **opts)
        else:
            m = jnp.stack([site.fmt.mask(w_oi[i], r, **opts)
                           for i in range(w_oi.shape[0])])
        return site.from_oi(m)

    def masks(self, params) -> dict:
        """{path: bool mask} for every matched leaf (True = keep)."""
        out = {}
        for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
            ps = _path_str(path)
            if ps in self.sites:
                out[ps] = self._site_mask(self.sites[ps], leaf)
        return out

    # -- prune / retrain ------------------------------------------------
    def prune(self, params):
        """→ (pruned_params, masks)."""
        masks = self.masks(params)
        return apply_masks(params, masks), masks

    def apply_masks(self, params, masks):
        return apply_masks(params, masks)

    def mask_grads(self, grads, masks):
        return mask_grads(grads, masks)

    # -- pack -----------------------------------------------------------
    def pack(self, params, masks: dict | None = None,
             abstract: bool = False):
        """Replace every matched leaf with its packed-format rep.

        masks=None recomputes masks from the rule ratios (correct both for
        raw weights and already-pruned ones — magnitude top-k re-selects
        the survivors). Pass the masks from ``prune`` to pack an exact
        pattern. abstract=True builds ShapeDtypeStruct stand-ins (dry-run).
        A policy ``quant`` rule quantizes every row-balanced site on the
        way out (integer codes + per-row scales; the byte accounting
        reflects the narrowed values). Returns (packed_params, report)."""
        qscheme = None
        if self.quant is not None:
            from ..quant import (abstract_quantize_packed, packed_bytes_q,
                                 parse_scheme, quantize_packed)
            from ..core.packing import RowBalancedSparse
            qscheme = parse_scheme(getattr(self.quant, "scheme", self.quant))
        flat, treedef = jax.tree_util.tree_flatten_with_path(params)
        out_leaves = []
        dense_bytes = packed_bytes = 0
        for path, leaf in flat:
            ps = _path_str(path)
            site = self.sites.get(ps)
            if site is None:
                out_leaves.append(leaf)
                if hasattr(leaf, "dtype"):
                    nbytes = leaf.size * leaf.dtype.itemsize
                    dense_bytes += nbytes
                    packed_bytes += nbytes
                continue
            L1 = site.L or 1
            r, opts = site.rule.ratio, site.rule.options
            dense_bytes += leaf.size * leaf.dtype.itemsize
            if qscheme is not None and site.fmt.name == "row_balanced":
                packed_bytes += L1 * packed_bytes_q(site.d_out, site.d_in,
                                                    r, qscheme)
            else:
                packed_bytes += L1 * site.fmt.packed_bytes(
                    site.d_out, site.d_in, r, leaf.dtype, **opts)
            if abstract:
                rep = site.fmt.abstract_pack(site.d_out, site.d_in, r,
                                             leaf.dtype, **opts)
                if qscheme is not None and isinstance(rep, RowBalancedSparse):
                    rep = abstract_quantize_packed(rep, qscheme)
                if site.L:
                    rep = site.fmt.abstract_stack(rep, site.L)
            else:
                w_oi = site.to_oi(leaf)
                if masks is not None and ps in masks:
                    m_oi = site.to_oi(masks[ps])
                else:
                    m_oi = site.to_oi(self._site_mask(site, leaf))
                packs = [site.fmt.pack(w_oi[i], m_oi[i], **opts)
                         for i in range(L1)]
                rep = site.fmt.stack(packs) if site.L else packs[0]
                if qscheme is not None and isinstance(rep, RowBalancedSparse):
                    rep = quantize_packed(rep, qscheme)
            out_leaves.append(rep)
        packed = jax.tree_util.tree_unflatten(treedef, out_leaves)
        return packed, dict(dense_bytes=dense_bytes,
                            packed_bytes=packed_bytes,
                            ratio=packed_bytes / max(dense_bytes, 1))

    # -- kernel dispatch -------------------------------------------------
    def matvec(self, path: str, packed, x):
        """Dispatch one packed matvec through the site's format with the
        plan's backend."""
        site = self.sites[path]
        return site.fmt.matvec(packed, x, backend=self.backend)

    def summary(self, masks: dict) -> dict:
        return sparsity_report(masks)


# -------------------------------------------------------- tree utilities

def _map_masked(tree, masks: dict, fn):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        ps = _path_str(path)
        out.append(fn(leaf, masks[ps]) if ps in masks else leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def _zero_pruned(leaf, mask):
    return jnp.where(mask, leaf, jnp.zeros_like(leaf))


def apply_masks(params, masks: dict):
    """Zero pruned weights. masks: {path: bool mask}."""
    return _map_masked(params, masks, _zero_pruned)


def mask_grads(grads, masks: dict):
    """Freeze pruned weights by zeroing their gradients."""
    return _map_masked(grads, masks, _zero_pruned)


def sparsity_report(masks: dict) -> dict:
    total = pruned = 0
    for m in masks.values():
        total += m.size
        pruned += int(m.size - jnp.sum(m))
    return {"prunable_params": total, "pruned": pruned,
            "sparsity": pruned / max(total, 1)}


# --------------------------------------------------------- stock policies

def lstm_policy(spar_x: float, spar_h: float, *, backend: str = "auto",
                fmt: str = "row_balanced", delta=None,
                quant=None) -> SparsityPolicy:
    """The paper's dual-ratio split: input weights W_x at ``spar_x``,
    recurrent weights W_h at ``spar_h`` (both row-balanced by default).

    Parameters
    ----------
    spar_x, spar_h : float
        Sparsity ratios for the input / recurrent weight families.
    backend : {"auto", "pallas", "ref"}
        Kernel backend configured on the policy.
    fmt : str
        Registered format name for both families.
    delta : DeltaGateConfig, optional
        Temporal-delta activation rule (Spartus-style skipping) to carry
        alongside the weight rules — serving wires it into the LSTM's
        decode cache (see ``repro.sparse.temporal``).
    quant : QuantConfig, optional
        Fixed-point inference rule (``repro.quant``): pack emits
        quantized codes + per-row scales and decode runs the q8 kernels
        — composes multiplicatively with both weight and delta sparsity.
    """
    return SparsityPolicy.of(
        {r"w_x$": (fmt, spar_x), r"w_h$": (fmt, spar_h)},
        backend=backend, layout="out_in", activation=delta, quant=quant)


# (pattern, family, layout) — family A pruned at spar_a, B at spar_b.
_TRANSFORMER_FAMILIES = (
    (r"(mlp|moe)/w_(gate|up|down)$", "a", "in_out"),
    (r"rwkv/w_cm[12]$", "a", "in_out"),
    (r"(attn|xattn)/w[qkvo]$", "b", "in_out"),
    (r"rec/(w_in_gelu|w_in_rec|w_gate_a|w_gate_x|w_out)$", "b", "in_out"),
    (r"rwkv/w_[rkvgw]$", "b", "out_trailing"),
    (r"rwkv/w_out$", "b", "in_out"),
)


def transformer_policy(spar_a: float, spar_b: float, *,
                       backend: str = "auto",
                       fmt: str = "row_balanced") -> SparsityPolicy:
    """Dual-ratio families for the transformer zoo (DESIGN.md §4):
    family A (feed-forward, pruned harder) at ``spar_a``; family B
    (attention / recurrence mixers) at ``spar_b``."""
    rules = tuple(
        Rule(pat, fmt, spar_a if fam == "a" else spar_b, layout)
        for pat, fam, layout in _TRANSFORMER_FAMILIES)
    return SparsityPolicy(rules=rules, backend=backend)


def classify(path_str: str) -> str | None:
    """Family of a transformer param path ('a' | 'b' | None)."""
    for pat, fam, _ in _TRANSFORMER_FAMILIES:
        if re.search(pat, path_str):
            return fam
    return None
