"""SparseFormat registry — every sparsity pattern the system knows.

A format owns the full lifecycle of its pattern:

  mask(w, ratio)        pruning-mask generation (True = keep)
  pack(w, mask)         packed representation (a pytree → jit/pjit/scan-safe)
  unpack(packed)        dense reconstruction (zeros where pruned)
  matvec / dual_matvec  kernel dispatch (backend: "pallas" | "ref" | "auto")
  memory_bytes          storage accounting for the Table-1 analogue

Matrix convention (the accelerator's): logical shape (rows, ncols) with
rows = OUTPUT units and ncols = fan-in, so ``matvec(packed, x)`` maps
x (B, ncols) → y (B, rows) and every row accumulates exactly its own
non-zeros — the balanced-PE invariant.

Registered formats: ``row_balanced`` (the paper's pattern, packed values +
relative-address deltas, Pallas rb_spmv/rb_dual_spmv kernels),
``bank_balanced`` (BBS [9]), ``block``, and ``unstructured`` (the Fig.-2
baselines, stored as masked-dense with analytic packed-size accounting).
New patterns (e.g. Spartus-style delta sparsity, ESE packed CSC) plug in by
subclassing SparseFormat and calling ``register``.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..core import sparsity as S
from ..core import packing as P

__all__ = ["SparseFormat", "MaskedDense", "register", "get_format",
           "available_formats", "dual_matvec"]


# ------------------------------------------------------------- generic rep

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class MaskedDense:
    """Masked-dense packed form for formats without a dedicated kernel:
    ``values`` is the dense (rows, ncols) matrix with pruned entries zeroed,
    ``mask`` the boolean keep-pattern. The matvec is a dense dot (XLA), so
    these formats ride the whole prune→pack→serve pipeline; only the
    storage accounting reflects their structure."""

    values: jnp.ndarray
    mask: jnp.ndarray

    @property
    def rows(self) -> int:
        return self.values.shape[-2]

    @property
    def ncols(self) -> int:
        return self.values.shape[-1]


# ------------------------------------------------------------- base class

class SparseFormat:
    """One sparsity pattern's full lifecycle.

    Subclasses override the pattern-specific pieces and register an
    instance (``register(MyFormat())``); the registry name is then valid
    in any :class:`~repro.sparse.policy.SparsityPolicy` rule.

    Attributes
    ----------
    name : str
        Registry key (e.g. ``"row_balanced"``). Must be non-empty.

    Notes
    -----
    Matrix convention (the accelerator's): logical shape (rows, ncols)
    with rows = OUTPUT units and ncols = fan-in, so ``matvec(packed, x)``
    maps x (B, ncols) → y (B, rows) and every row accumulates exactly its
    own non-zeros — the balanced-PE invariant.
    """

    name: str = ""

    # -- mask generation -----------------------------------------------
    def mask(self, w: jnp.ndarray, ratio: float, **opts) -> jnp.ndarray:
        """Pruning mask for one weight matrix.

        Parameters
        ----------
        w : jnp.ndarray
            Dense (rows, ncols) weight (or batched, where supported).
        ratio : float
            Fraction to prune, in [0, 1).
        **opts
            Pattern options from the rule (e.g. ``num_banks``, ``block``).

        Returns
        -------
        jnp.ndarray
            Bool keep-mask of ``w``'s shape (True = keep).
        """
        raise NotImplementedError

    # -- packed representation -----------------------------------------
    def pack(self, w: jnp.ndarray, mask: jnp.ndarray, **opts) -> Any:
        """Packed representation of ``w`` under ``mask``.

        ``**opts`` are the rule's pattern options (quantized formats read
        their scheme here; mask-only options are ignored). Returns a
        pytree (jit/pjit/scan-safe). The base implementation is
        :class:`MaskedDense` — formats with dedicated kernels override.
        """
        return MaskedDense(values=S.apply_mask(w, mask), mask=mask)

    def unpack(self, packed: Any) -> jnp.ndarray:
        """Dense (rows, ncols) reconstruction (zeros where pruned)."""
        return packed.values

    def abstract_pack(self, rows: int, ncols: int, ratio: float,
                      dtype, **opts) -> Any:
        """ShapeDtypeStruct stand-in of ``pack`` output (for dry-runs)."""
        return MaskedDense(
            values=jax.ShapeDtypeStruct((rows, ncols), dtype),
            mask=jax.ShapeDtypeStruct((rows, ncols), jnp.bool_))

    def stack(self, reps: list) -> Any:
        """Combine per-layer packed reps into one stacked rep (leading L)."""
        return jax.tree.map(lambda *xs: jnp.stack(xs), *reps)

    def abstract_stack(self, rep: Any, L: int) -> Any:
        """Stacked ShapeDtypeStruct rep from a single abstract rep."""
        return jax.tree.map(
            lambda x: jax.ShapeDtypeStruct((L,) + x.shape, x.dtype), rep)

    # -- kernels --------------------------------------------------------
    def matvec(self, packed: Any, x: jnp.ndarray, *,
               backend: str | None = None) -> jnp.ndarray:
        """Sparse matrix × dense batch-of-vectors.

        Parameters
        ----------
        packed : Any
            This format's packed representation.
        x : jnp.ndarray
            Activations, (B, ncols).
        backend : {"pallas", "ref", "auto", None}, optional
            Kernel backend; None defers to the configured default.

        Returns
        -------
        jnp.ndarray
            (B, rows) in ``x.dtype``. Masked-dense default: a dense dot.
        """
        del backend  # no dedicated kernel; XLA's dot is the only path
        return (x.astype(jnp.float32)
                @ packed.values.astype(jnp.float32).T).astype(x.dtype)

    def dual_matvec(self, pa: Any, x: jnp.ndarray, pb: Any, h: jnp.ndarray,
                    bias: jnp.ndarray | None = None, *,
                    backend: str | None = None) -> jnp.ndarray:
        """z = A@x + B@h (+ bias) — the LSTM gate preactivation shape.

        Same-format pairs may fuse (row_balanced → the Pallas dual-ratio
        kernel); the default is two matvecs accumulated in fp32."""
        z = (self.matvec(pa, x, backend=backend).astype(jnp.float32)
             + self.matvec(pb, h, backend=backend).astype(jnp.float32))
        if bias is not None:
            z = z + bias.astype(jnp.float32)[None, :]
        return z.astype(x.dtype)

    # -- storage accounting --------------------------------------------
    def packed_bytes(self, rows: int, ncols: int, ratio: float,
                     dtype, **opts) -> int:
        """Analytic packed storage in bytes (values + index metadata).

        Parameters
        ----------
        rows, ncols : int
            Logical matrix shape.
        ratio : float
            Sparsity ratio the matrix would be pruned at.
        dtype : dtype-like
            Value storage dtype.

        Returns
        -------
        int
            Packed byte count for one matrix.
        """
        raise NotImplementedError

    def memory_bytes(self, packed: Any, **opts) -> dict:
        """Accounting for a concrete packed rep (Table-1 analogue).

        Returns
        -------
        dict
            ``values``/``indices``/``total`` byte counts, the
            ``dense_equiv`` bytes, and their ``ratio``.
        """
        raise NotImplementedError

    def _mem_dict(self, values_b: int, index_b: int, rows: int, ncols: int,
                  itemsize: int) -> dict:
        dense = rows * ncols * itemsize
        return dict(values=values_b, indices=index_b,
                    total=values_b + index_b, dense_equiv=dense,
                    ratio=(values_b + index_b) / max(dense, 1))


# ------------------------------------------------------------- registry

_REGISTRY: dict[str, SparseFormat] = {}


def register(fmt: SparseFormat) -> SparseFormat:
    if not fmt.name:
        raise ValueError("format needs a non-empty .name")
    _REGISTRY[fmt.name] = fmt
    return fmt


def get_format(name: str) -> SparseFormat:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown sparse format {name!r}; registered: "
                       f"{sorted(_REGISTRY)}") from None


def available_formats() -> list[str]:
    return sorted(_REGISTRY)


# --------------------------------------------------------- row_balanced

class RowBalancedFormat(SparseFormat):
    """The paper's pattern: every row keeps exactly K non-zeros; packed as
    (rows, K) values + delta-encoded column indices; served by the Pallas
    rb_spmv / rb_dual_spmv kernels (fused dual-ratio gate preactivation)."""

    name = "row_balanced"

    def mask(self, w, ratio, **opts):
        return S.row_balanced_mask(w, ratio)

    def pack(self, w, mask, **opts):
        return P.pack(w, mask)

    def unpack(self, packed):
        return P.unpack(packed)

    def abstract_pack(self, rows, ncols, ratio, dtype, **opts):
        k = S.keep_count(ncols, ratio)
        dd = P._delta_dtype(ncols, k)
        return P.RowBalancedSparse(
            values=jax.ShapeDtypeStruct((rows, k), dtype),
            deltas=jax.ShapeDtypeStruct((rows, k), jnp.dtype(dd)),
            ncols=ncols)

    def matvec(self, packed, x, *, backend=None):
        from ..kernels import ops as K
        return K.rb_spmv(packed, x, backend=backend)

    def dual_matvec(self, pa, x, pb, h, bias=None, *, backend=None):
        from ..kernels import ops as K
        if bias is None:
            bias = jnp.zeros((pa.rows,), jnp.float32)
        return K.rb_dual_spmv(pa, x, pb, h, bias, backend=backend)

    def packed_bytes(self, rows, ncols, ratio, dtype, **opts):
        k = S.keep_count(ncols, ratio)
        dd = P._delta_dtype(ncols, k)
        return rows * k * (np.dtype(dtype).itemsize + dd.itemsize)

    def memory_bytes(self, packed, **opts):
        return packed.memory_bytes()


# --------------------------------------------------------- bank_balanced

class BankBalancedFormat(SparseFormat):
    """BBS [9]: fine-grained pruning inside equal row banks. Stored
    masked-dense; accounting models per-bank packed values + in-bank
    positions (one narrow index per non-zero)."""

    name = "bank_balanced"

    def mask(self, w, ratio, *, num_banks: int = 4, **opts):
        return S.bank_balanced_mask(w, ratio, num_banks=num_banks)

    @staticmethod
    def _index_bytes(bank: int) -> int:
        """Narrowest int holding an in-bank position."""
        return 1 if bank - 1 <= 255 else 2

    def packed_bytes(self, rows, ncols, ratio, dtype, *, num_banks: int = 4,
                     **opts):
        bank = ncols // num_banks
        k = S.keep_count(bank, ratio)
        return rows * num_banks * k * (np.dtype(dtype).itemsize
                                       + self._index_bytes(bank))

    def memory_bytes(self, packed, *, num_banks: int = 4, **opts):
        nnz = int(np.asarray(jnp.sum(packed.mask)))
        it = packed.values.dtype.itemsize
        idx_b = self._index_bytes(packed.ncols // num_banks)
        return self._mem_dict(nnz * it, nnz * idx_b, packed.rows,
                              packed.ncols, it)


# ----------------------------------------------------------------- block

class BlockFormat(SparseFormat):
    """Block sparsity (Fig. 2c): values of surviving blocks + a one-bit
    per-block occupancy map."""

    name = "block"

    def mask(self, w, ratio, *, block: tuple[int, int] = (4, 4), **opts):
        return S.block_mask(w, ratio, block=block)

    def packed_bytes(self, rows, ncols, ratio, dtype, *,
                     block: tuple[int, int] = (4, 4), **opts):
        br, bc = block
        nbr, nbc = -(-rows // br), -(-ncols // bc)
        nblocks = nbr * nbc
        kept = max(1, nblocks - int(round(ratio * nblocks)))
        return (kept * br * bc * np.dtype(dtype).itemsize
                + (nblocks + 7) // 8)

    def memory_bytes(self, packed, **opts):
        nnz = int(np.asarray(jnp.sum(packed.mask)))
        it = packed.values.dtype.itemsize
        bitmap = (packed.mask.size + 7) // 8
        return self._mem_dict(nnz * it, bitmap, packed.rows, packed.ncols,
                              it)


# ---------------------------------------------------------- unstructured

class UnstructuredFormat(SparseFormat):
    """Fine-grained global magnitude pruning; accounting models CSR
    (values + int32 column index per non-zero + row pointers)."""

    name = "unstructured"

    def mask(self, w, ratio, **opts):
        return S.unstructured_mask(w, ratio)

    def packed_bytes(self, rows, ncols, ratio, dtype, **opts):
        n = rows * ncols
        nnz = max(1, n - int(round(ratio * n)))
        return nnz * (np.dtype(dtype).itemsize + 4) + (rows + 1) * 4

    def memory_bytes(self, packed, **opts):
        nnz = int(np.asarray(jnp.sum(packed.mask)))
        it = packed.values.dtype.itemsize
        return self._mem_dict(nnz * it, nnz * 4 + (packed.rows + 1) * 4,
                              packed.rows, packed.ncols, it)


register(RowBalancedFormat())
register(BankBalancedFormat())
register(BlockFormat())
register(UnstructuredFormat())


# ------------------------------------------------- mixed-format dispatch

def dual_matvec(fmt_a: SparseFormat, pa, x, fmt_b: SparseFormat, pb, h,
                bias=None, *, backend: str | None = None):
    """z = A@x + B@h (+ bias) across possibly different formats. Same-format
    pairs use the format's fused path (row_balanced → the Pallas dual-ratio
    kernel); mixed pairs fall back to two matvecs."""
    if fmt_a is fmt_b:
        return fmt_a.dual_matvec(pa, x, pb, h, bias, backend=backend)
    z = (fmt_a.matvec(pa, x, backend=backend).astype(jnp.float32)
         + fmt_b.matvec(pb, h, backend=backend).astype(jnp.float32))
    if bias is not None:
        z = z + bias.astype(jnp.float32)[None, :]
    return z.astype(x.dtype)
