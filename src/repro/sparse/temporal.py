"""Temporal delta sparsity — Spartus-style activation skipping.

BRDS prunes the *weights* (row-balanced, dual ratio); Spartus [Gao et al.,
2021] shows the other half of the win is on the *activation* side: across
decode steps, most components of the LSTM input x_t and hidden state
h_{t-1} barely change, so their matvec columns contribute (numerically)
the same products as last step. A delta accelerator keeps a *reference
state* per activation vector and a *partial-sum memory* m per gate
preactivation, and each step computes only the columns whose delta
crossed a threshold Θ:

    d        = v_t - ref                    (raw delta)
    fired    = |d| > Θ                      (optionally capped, see below)
    ref'     = fired ? v_t : ref            (reference tracks fired columns)
    m'       = m + W @ (fired · d)          (only fired columns' products)
    z_t      = m' + bias                    (the gate preactivation)

With Θ = 0 every changed column fires, the reference tracks the input
exactly, and the trajectory reproduces the dense/packed decode (up to
float re-association of the accumulation, which greedy decoding does not
see). With Θ > 0 the *occupancy* (fired fraction) drops and the effective
MAC count shrinks proportionally — multiplying with the weight-sparsity
reduction, since the matvec runs over the packed row-balanced weights
(``kernels.ops.delta_rb_spmv``).

The optional *occupancy cap* bounds the fired-column count per step at a
fixed fraction of the vector (largest-|delta| columns win), giving the
hardware a worst-case bound per step — the activation-side analogue of
the row-balanced guarantee on the weight side.

``DeltaGateConfig`` is the declaration serving carries: per-family
thresholds (Θ_x for the input path, Θ_h for the recurrent path) and caps.
``SparsityPolicy`` accepts it as its activation rule
(``lstm_policy(..., delta=cfg)``), ``SparsityPlan`` exposes it, and
``ServeEngine.prepare`` wires it into the model's DecodeStep cache.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["DeltaGateConfig", "cap_count", "delta_threshold",
           "occupancy_report"]


def cap_count(cap: float | None, n: int) -> int | None:
    """Static fired-column budget for an occupancy cap over ``n`` columns.

    Parameters
    ----------
    cap : float or None
        Occupancy cap in (0, 1], or None for uncapped.
    n : int
        Activation vector width.

    Returns
    -------
    int or None
        Maximum fired columns per step (at least 1), or None if uncapped
        (``cap`` is None or already admits every column).

    Examples
    --------
    >>> cap_count(0.25, 128)
    32
    >>> cap_count(0.001, 128)
    1
    >>> cap_count(None, 128) is None
    True
    >>> cap_count(1.0, 128) is None
    True
    """
    if cap is None:
        return None
    k = max(1, int(round(cap * n)))
    return None if k >= n else k


@dataclasses.dataclass(frozen=True)
class DeltaGateConfig:
    """Declaration of a temporal-delta gate (the activation-side rule).

    Parameters
    ----------
    theta_x : float
        Delta threshold Θ for the input activation path (columns of W_x).
        0.0 means every changed component fires (exact decode).
    theta_h : float
        Threshold for the recurrent path (columns of W_h). The recurrent
        state usually tolerates a smaller Θ than the input (Spartus's
        per-path split, mirroring BRDS's dual weight ratios).
    cap_x, cap_h : float or None
        Optional occupancy caps in (0, 1]: at most ``cap * width`` columns
        fire per step (largest |delta| win), bounding worst-case work —
        the activation-side analogue of row balance.

    Examples
    --------
    >>> cfg = DeltaGateConfig(theta_x=0.05, theta_h=0.02, cap_x=0.5)
    >>> cfg.theta_h
    0.02
    >>> DeltaGateConfig()            # doctest: +ELLIPSIS
    DeltaGateConfig(theta_x=0.0, theta_h=0.0, cap_x=None, cap_h=None)
    """

    theta_x: float = 0.0
    theta_h: float = 0.0
    cap_x: float | None = None
    cap_h: float | None = None

    def __post_init__(self):
        for name in ("theta_x", "theta_h"):
            if getattr(self, name) < 0.0:
                raise ValueError(f"{name} must be >= 0, "
                                 f"got {getattr(self, name)}")
        for name in ("cap_x", "cap_h"):
            v = getattr(self, name)
            if v is not None and not (0.0 < v <= 1.0):
                raise ValueError(f"{name} must be in (0, 1], got {v}")


def delta_threshold(v: jnp.ndarray, ref: jnp.ndarray, theta: float,
                    cap: float | None = None):
    """Threshold one activation vector's delta against its reference state.

    Parameters
    ----------
    v : jnp.ndarray
        Current activation, shape (B, N).
    ref : jnp.ndarray
        Reference state (the last fired values), shape (B, N).
    theta : float
        Fire when ``|v - ref| > theta``. Θ=0 fires exactly the changed
        components, so the new reference equals ``v`` bit-for-bit.
    cap : float or None
        Occupancy cap: keep at most ``cap_count(cap, N)`` fired columns
        per batch row, largest |delta| first (exact budget — ties are
        broken by column order via ``jax.lax.top_k``).

    Returns
    -------
    d : jnp.ndarray
        Raw delta ``v - ref``, (B, N) — the kernel masks it with ``fired``.
    fired : jnp.ndarray
        Bool fired mask, (B, N).
    new_ref : jnp.ndarray
        Updated reference: ``v`` where fired, ``ref`` elsewhere.
    """
    d = (v - ref).astype(v.dtype)
    fired = jnp.abs(d) > theta
    k = cap_count(cap, v.shape[-1])
    if k is not None:
        score = jnp.where(fired, jnp.abs(d).astype(jnp.float32), -jnp.inf)
        topv, topi = jax.lax.top_k(score, k)
        rows = jnp.broadcast_to(jnp.arange(v.shape[0])[:, None], topi.shape)
        fired = jnp.zeros_like(fired).at[rows, topi].set(topv > -jnp.inf)
    new_ref = jnp.where(fired, v, ref)
    return d, fired, new_ref


def occupancy_report(cache, *, steps: int, packed=None) -> dict:
    """Summarize fired-column occupancy from a delta decode cache.

    The LSTM's delta cache accumulates per-sequence fired-column counts
    (``nx``/``nh`` per layer). Given the number of processed steps, this
    reduces them to the occupancy and — when the packed params are
    supplied — the effective-ops reduction vs. always-on packed decode
    (the Spartus × BRDS composition: MACs ≈ occupancy × packed MACs).

    Parameters
    ----------
    cache : dict
        A delta decode cache (``{"layers": [{"nx", "nh", ...}, ...]}``).
    steps : int or array-like
        Decode steps the counters accumulated over (prefill + generated):
        a scalar for a lockstep batch, or a (B,) per-sequence vector (the
        continuous-batching scheduler's ``slot_steps``, where each slot's
        cache restarts at its occupant's join).
    packed : pytree, optional
        The SparsityPlan.pack'd params; enables the MAC-weighted
        reduction (columns weighted by their family's per-row K).

    Returns
    -------
    dict
        ``occupancy_x``/``occupancy_h`` mean fired fractions,
        ``occupancy`` the combined fraction, and — with ``packed`` —
        ``effective_macs``, ``packed_macs`` and ``ops_reduction``
        (packed/effective, ≥ 1; multiply by the weight-side gain for the
        end-to-end figure).
    """
    import numpy as np
    layers = cache["layers"]
    # scalar steps → per-sequence vector, so lockstep and continuous
    # (per-slot slot_steps) share one accounting path
    B = layers[0]["x_ref"].shape[0]
    steps_b = np.broadcast_to(np.asarray(steps, np.float64), (B,))
    step_sum = float(steps_b.sum())
    fx = fh = tx = th = 0.0
    eff = total = 0.0
    for i, lp in enumerate(layers):
        nx = float(np.asarray(jnp.sum(lp["nx"])))
        nh = float(np.asarray(jnp.sum(lp["nh"])))
        X = lp["x_ref"].shape[1]
        H = lp["h_ref"].shape[1]
        fx += nx
        fh += nh
        tx += step_sum * X
        th += step_sum * H
        if packed is not None:
            sx = packed["layers"][i]["w_x"]
            sh = packed["layers"][i]["w_h"]
            # MACs per fired column ≈ the family's nnz-per-column R*K/N
            eff += nx * sx.rows * sx.K / X + nh * sh.rows * sh.K / H
            total += step_sum * (sx.rows * sx.K + sh.rows * sh.K)
    out = dict(occupancy_x=fx / max(tx, 1), occupancy_h=fh / max(th, 1),
               occupancy=(fx + fh) / max(tx + th, 1))
    if packed is not None:
        out.update(effective_macs=eff, packed_macs=total,
                   ops_reduction=total / max(eff, 1e-9))
    return out
