"""repro.sparse — the one public API for sparsity.

Four layers, one seam:

  formats  — SparseFormat registry (row_balanced, bank_balanced, block,
             unstructured): mask generation, packed representation,
             matvec/dual_matvec kernel dispatch, memory accounting.
  policy   — SparsityPolicy (per-weight-family pattern + ratio) compiles
             against any model's param tree into a SparsityPlan with
             prune / mask_grads / pack.
  temporal — DeltaGateConfig: Spartus-style activation-delta skipping
             (threshold Θ, reference-state tracking, occupancy caps)
             carried as the policy's activation rule and composed with
             the packed weight formats at decode time.
  backend  — "pallas" | "ref" | "auto", configured once on the policy or
             process-wide, replacing per-call use_kernel= flags.

The BRDS Fig.-5 search walks SparsityPolicy objects (``brds_search``).
Old surfaces (``LSTMModel.prune``-style methods, ``training.brds_masks``,
``core.brds.brds_search``) remain as thin deprecation shims over this
package.
"""
from .backend import (BACKENDS, get_default_backend, set_default_backend,
                      use_backend)
from .formats import (SparseFormat, MaskedDense, register, get_format,
                      available_formats, dual_matvec)
from .policy import (Rule, SparsityPolicy, SparsityPlan, lstm_policy,
                     transformer_policy, apply_masks, mask_grads,
                     sparsity_report)
from .search import BRDSResult, brds_search, plane_search, \
    execution_time_model
from .temporal import (DeltaGateConfig, cap_count, delta_threshold,
                       occupancy_report)

# Importing repro.quant.formats registers the "row_balanced_q8" format
# (quant depends on this package's registry, so it cannot register itself
# first). Policies reference quantization via the `quant=` rule or the
# registered format name — either path needs the side effect here.
from ..quant import formats as _quant_formats  # noqa: E402,F401
from ..quant import QuantConfig  # noqa: E402  (re-export: the policy rule)

__all__ = [
    "BACKENDS", "get_default_backend", "set_default_backend", "use_backend",
    "SparseFormat", "MaskedDense", "register", "get_format",
    "available_formats", "dual_matvec",
    "Rule", "SparsityPolicy", "SparsityPlan", "lstm_policy",
    "transformer_policy", "apply_masks", "mask_grads", "sparsity_report",
    "BRDSResult", "brds_search", "plane_search", "execution_time_model",
    "DeltaGateConfig", "cap_count", "delta_threshold", "occupancy_report",
    "QuantConfig",
]
