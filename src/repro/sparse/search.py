"""The BRDS dual-ratio search (paper Fig. 5), walking SparsityPolicy
objects over the (Spar_x, Spar_h) plane.

  phase 1 (lines 1-6):  ramp both ratios 0 → OS in steps of alpha, pruning
                        and retraining at each step → NN_{P,I}.
  phase 2 (lines 7-14): from NN_{P,I}, walk Spar_x up / Spar_h down.
  phase 3 (lines 15-23): reload NN_{P,I}, walk the opposite direction.
  return the tuple with the best model accuracy (line 24).

The search is model-agnostic: ``policy_at(spar_x, spar_h)`` builds the
SparsityPolicy for a tuple (``lstm_policy`` for the paper's LSTM,
``transformer_policy`` for the zoo, or any custom policy factory), and at
every visited tuple the policy is compiled into a plan that prunes the
params; ``retrain_fn(params, plan, masks)`` retrains the survivors and
``eval_fn(params)`` scores the result (higher = better).

``repro.core.brds_search`` keeps the legacy raw-callback signature as a
deprecation shim over the same plane walk.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

__all__ = ["BRDSResult", "brds_search", "execution_time_model",
           "plane_search"]


@dataclasses.dataclass
class BRDSResult:
    best_accuracy: float
    best_spar_x: float
    best_spar_h: float
    best_params: Any
    history: list       # list of dicts: phase, spar_x, spar_h, accuracy
    best_policy: Any = None


def plane_search(
    params: Any,
    *,
    overall_sparsity: float,
    visit: Callable,          # (params, spar_x, spar_h) -> (params, aux)
    eval_fn: Callable,        # (params) -> float, higher = better
    alpha: float = 0.25,
    delta_x: float = 0.05,
    delta_h: float = 0.05,
    max_ratio: float = 0.99,
) -> BRDSResult:
    """The Fig.-5 walk, generic over how a tuple is applied. ``visit``
    prunes+retrains params at one (spar_x, spar_h) tuple and returns the
    new params plus an aux object recorded for the best tuple (the new API
    passes the SparsityPolicy; the legacy shim passes None)."""
    os_ = float(overall_sparsity)
    history: list[dict] = []

    # ---- phase 1: ramp to the initial point NN_{P,I} (lines 1-6)
    spar_x = spar_h = 0.0
    aux = None
    while spar_x < os_ and spar_h < os_:
        spar_x = min(os_, spar_x + alpha)
        spar_h = min(os_, spar_h + alpha)
        params, aux = visit(params, spar_x, spar_h)
    nn_pi = params
    acc = float(eval_fn(params))
    best = BRDSResult(acc, spar_x, spar_h, params, history, aux)
    history.append(dict(phase="init", spar_x=spar_x, spar_h=spar_h,
                        accuracy=acc))

    def _walk(params, sx, sh, dx, dh, phase):
        nonlocal best
        while 0.0 < sx + dx <= max_ratio and 0.0 <= sh - dh < max_ratio:
            sx = min(max_ratio, sx + dx)
            sh = max(0.0, sh - dh)
            params, aux = visit(params, sx, sh)
            acc = float(eval_fn(params))
            history.append(dict(phase=phase, spar_x=sx, spar_h=sh,
                                accuracy=acc))
            if acc > best.best_accuracy:
                best = BRDSResult(acc, sx, sh, params, history, aux)
            if sx >= max_ratio or sh <= 0.0:
                break
        return params

    # ---- phase 2: Spar_x up, Spar_h down (lines 7-14)
    _walk(nn_pi, spar_x, spar_h, +delta_x, +delta_h, phase="x_up")
    # ---- phase 3: reload NN_{P,I}; Spar_x down, Spar_h up (lines 15-23)
    _walk(nn_pi, spar_x, spar_h, -delta_x, -delta_h, phase="h_up")

    best.history = history
    return best


def brds_search(
    params: Any,
    *,
    overall_sparsity: float,
    policy_at: Callable,      # (spar_x, spar_h) -> SparsityPolicy
    retrain_fn: Callable,     # (params, plan, masks) -> params
    eval_fn: Callable,        # (params) -> float, higher = better
    alpha: float = 0.25,
    delta_x: float = 0.05,
    delta_h: float = 0.05,
    max_ratio: float = 0.99,
) -> BRDSResult:
    """Run the Fig.-5 search over SparsityPolicy objects."""

    def visit(p, sx, sh):
        policy = policy_at(sx, sh)
        plan = policy.compile(p)
        pruned, masks = plan.prune(p)
        return retrain_fn(pruned, plan, masks), policy

    return plane_search(params, overall_sparsity=overall_sparsity,
                        visit=visit, eval_fn=eval_fn, alpha=alpha,
                        delta_x=delta_x, delta_h=delta_h,
                        max_ratio=max_ratio)


def execution_time_model(os_: float, alpha: float, delta_x: float,
                         delta_h: float, ept: float, n_re: int) -> dict:
    """The paper's cost model, eqs. (3)-(6). Ratios in percent or fractions
    (consistent units). Returns the per-phase and total times."""
    ex1 = (os_ / alpha) * ept * n_re
    ex2 = min((1.0 - os_) / delta_x, os_ / delta_h) * ept * n_re
    ex3 = min((1.0 - os_) / delta_h, os_ / delta_x) * ept * n_re
    return dict(ex1=ex1, ex2=ex2, ex3=ex3, total=ex1 + ex2 + ex3)
