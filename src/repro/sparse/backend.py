"""Kernel-backend selection for the sparse subsystem.

One switch replaces every ``use_kernel=`` / ``interpret=`` flag that used to
be threaded through call sites:

  "pallas" — the Pallas kernels (interpret mode on CPU, compiled on TPU)
  "ref"    — the pure-jnp reference formulations (XLA fuses them; this is
             what the dry-run lowers)
  "auto"   — resolve to "pallas" (the kernels themselves fall back to
             interpret mode off-TPU, so "auto" is always safe)

The default is configured once — on a ``SparsityPolicy``/``SparsityPlan``,
on a format call, or process-wide with ``set_default_backend`` /
``use_backend`` — instead of at every matvec.
"""
from __future__ import annotations

import contextlib
import warnings

__all__ = ["BACKENDS", "resolve", "set_default_backend",
           "get_default_backend", "use_backend", "from_use_kernel"]

BACKENDS = ("auto", "pallas", "ref")

_default = "auto"


def get_default_backend() -> str:
    return _default


def set_default_backend(backend: str) -> None:
    global _default
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")
    _default = backend


@contextlib.contextmanager
def use_backend(backend: str):
    """Scoped override of the process default backend."""
    prev = get_default_backend()
    set_default_backend(backend)
    try:
        yield
    finally:
        set_default_backend(prev)


def resolve(backend: str | None = None) -> str:
    """Resolve a per-call backend to concrete "pallas" or "ref".

    Parameters
    ----------
    backend : {"pallas", "ref", "auto", None}
        Per-call request. None and "auto" both defer to the configured
        process default, so ``set_default_backend``/``use_backend`` reach
        every policy/plan left at backend="auto". A default of "auto"
        means "let the system pick" → "pallas" (the kernels run
        interpreted on CPU, so this is always safe).

    Returns
    -------
    str
        Concrete ``"pallas"`` or ``"ref"``.

    Examples
    --------
    >>> resolve("ref")
    'ref'
    >>> resolve("pallas")
    'pallas'
    >>> resolve(None) in ("pallas", "ref")
    True
    """
    if backend is not None and backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")
    b = _default if backend in (None, "auto") else backend
    return "pallas" if b == "auto" else b


def from_use_kernel(use_kernel: bool, *, stacklevel: int = 3) -> str:
    """Adapter for the deprecated ``use_kernel=`` boolean."""
    warnings.warn(
        "use_kernel= is deprecated; pass backend='pallas'|'ref'|'auto' "
        "(see repro.sparse.backend)", DeprecationWarning,
        stacklevel=stacklevel)
    return "pallas" if use_kernel else "ref"
