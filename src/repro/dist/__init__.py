"""repro.dist — sharded packed-sparse decode.

Row balance as device load balance: every row of a packed
``RowBalancedSparse`` holds exactly NZ survivors, so sharding the 4H gate
rows across a mesh's ``model`` axis yields perfectly load-balanced shards
by construction (dual-ratio = different NZ per family, each internally
balanced). Two modules:

  partition      — the partitioning contract: gate-aligned row
                   permutation, row-sharded placement of packed
                   values/indices/scales/bias, replicated embed/head,
                   and the sharded (c, h, m) cache layouts.
  collective_ops — shard_map-wrapped kernels and the sharded LSTM decode
                   steps; the only per-step collective is the small
                   all-gather of h (the device analogue of the paper's
                   activation broadcast to PEs).

Serving wires it together: ``ServeEngine(..., mesh=mesh)`` partitions at
``prepare`` time and decodes model-parallel;
``ContinuousBatchingEngine(..., mesh=mesh)`` adds data-parallel slot
batches around the model shards. ``launch.serve --mesh D,M`` drives it
end to end.
"""
from .partition import (check_partitioned, gate_row_permutation,
                        is_partitionable, model_axis_size, data_axis_size,
                        partition_lstm_params, permute_packed_rows,
                        supports_dist)
from .collective_ops import (batch_axis, dist_delta_lstm_step,
                             dist_lstm_step, gather_hidden,
                             sharded_delta_rb_dual_spmv,
                             sharded_rb_dual_spmv, sharded_rb_dual_spmv_q8)

__all__ = [
    "check_partitioned",
    "gate_row_permutation", "is_partitionable", "model_axis_size",
    "data_axis_size", "partition_lstm_params", "permute_packed_rows",
    "supports_dist",
    "batch_axis", "dist_delta_lstm_step", "dist_lstm_step", "gather_hidden",
    "sharded_delta_rb_dual_spmv", "sharded_rb_dual_spmv",
    "sharded_rb_dual_spmv_q8",
]
