"""shard_map-wrapped packed-sparse kernels + the sharded LSTM decode steps.

Every wrapper here follows the same collective inventory, the device
analogue of the paper's PE datapath:

* weights enter **row-sharded** over the mesh's ``model`` axis (the
  gate-aligned layout of :mod:`repro.dist.partition`) — each shard runs
  the ordinary packed kernels (``repro.kernels.ops``) over its own rows,
  and because every row carries exactly NZ survivors, the shards finish
  in lockstep: row balance *is* the device load balance;
* activations (``x``, ``h``) enter **replicated** — the broadcast the
  paper feeds its PEs;
* the **only per-step collective** is the small all-gather of the hidden
  state ``h`` (B × H/n per shard) right after the local cell update,
  feeding the next step's (and next layer's) W_h/W_x columns. ``c``,
  the partial-sum memory ``m``, and the gate preactivations never cross
  shard boundaries.

Θ-thresholding for the delta path runs on the *gathered* (replicated)
reference state, so fired-column sets agree across shards by
construction — no collective needed to reconcile them.

Batch shards over the mesh's ``data`` axis whenever it divides B (the
continuous-batching scheduler's batch=1 prefills fall back to replicated
batch); everything below is batch-elementwise, so data parallelism
composes transparently with the model-axis row sharding.

``check_rep=False`` throughout: the Pallas backend's ``pallas_call`` (and
``jax.lax.top_k`` inside the occupancy cap) defeat shard_map's static
replication checker; replication of the h all-gather output holds by
construction.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:                                    # jax >= 0.5
    from jax.shard_map import shard_map as _shard_map
except ImportError:                     # the 0.4.x line this repo targets
    from jax.experimental.shard_map import shard_map as _shard_map

from ..core.packing import RowBalancedSparse
from ..kernels import ops as K
from ..quant import RowBalancedSparseQ8
from ..sparse.temporal import delta_threshold
from .partition import data_axis_size, model_axis_size

__all__ = ["batch_axis", "gather_hidden", "sharded_rb_dual_spmv",
           "sharded_delta_rb_dual_spmv", "sharded_rb_dual_spmv_q8",
           "dist_lstm_step", "dist_delta_lstm_step"]


def batch_axis(mesh: Mesh, batch: int):
    """``"data"`` when the data axis exists and divides ``batch``, else
    None (replicated batch — e.g. the scheduler's batch=1 prefills)."""
    d = data_axis_size(mesh)
    return "data" if d > 1 and batch % d == 0 else None


def gather_hidden(h_loc, axis: str = "model"):
    """All-gather a (B, H/n) hidden shard into the replicated (B, H)
    broadcast — THE per-step collective of the sharded decode path.
    Shards concatenate in mesh-axis order, restoring the original hidden
    order (call only inside a shard_map region)."""
    return jax.lax.all_gather(h_loc, axis, axis=h_loc.ndim - 1, tiled=True)


def _packed_spec(packed, row_axis: str = "model"):
    """shard_map PartitionSpec tree for one packed matrix (row-sharded)."""
    if isinstance(packed, RowBalancedSparseQ8):
        return dataclasses.replace(packed, values=P(row_axis, None),
                                   deltas=P(row_axis, None),
                                   scales=P(row_axis))
    return dataclasses.replace(packed, values=P(row_axis, None),
                               deltas=P(row_axis, None))


def _check_rows(mesh: Mesh, *packed):
    n = model_axis_size(mesh)
    for s in packed:
        if s.rows % n:
            raise ValueError(f"packed rows={s.rows} not divisible by the "
                             f"model axis ({n})")


# ------------------------------------------------- sharded kernel wrappers
# Row-sharded twins of the kernels.ops entry points: bitwise-identical
# results (each output row is computed by exactly one shard with the same
# per-row arithmetic), with the work split 1/n per device. These take the
# UNPERMUTED row order — output rows reassemble contiguously — and exist
# for kernel-level parity tests and as the building blocks the step
# functions below inline.

def sharded_rb_dual_spmv(mesh: Mesh, sx: RowBalancedSparse, x,
                         sh: RowBalancedSparse, h, bias, *,
                         backend: str | None = None):
    """z = Sx@x + Sh@h + bias with the 4H rows sharded over ``model``.

    x/h replicated (the PE activation broadcast); returns the full
    (B, 4H) preactivation, each shard having computed its own rows."""
    _check_rows(mesh, sx, sh)
    b = batch_axis(mesh, x.shape[0])

    def f(sx_, x_, sh_, h_, b_):
        return K.rb_dual_spmv(sx_, x_, sh_, h_, b_, backend=backend)

    return _shard_map(
        f, mesh=mesh,
        in_specs=(_packed_spec(sx), P(b, None), _packed_spec(sh),
                  P(b, None), P("model")),
        out_specs=P(b, "model"), check_rep=False)(sx, x, sh, h, bias)


def sharded_delta_rb_dual_spmv(mesh: Mesh, sx: RowBalancedSparse, dx, fx,
                               sh: RowBalancedSparse, dh, fh, m, *,
                               backend: str | None = None):
    """m' = m + Sx@(fx·dx) + Sh@(fh·dh) — the fused temporal-delta
    partial-sum update with rows (and ``m``) sharded over ``model``;
    deltas and fired masks replicated."""
    _check_rows(mesh, sx, sh)
    b = batch_axis(mesh, dx.shape[0])

    def f(sx_, dx_, fx_, sh_, dh_, fh_, m_):
        return K.delta_rb_dual_spmv(sx_, dx_, fx_, sh_, dh_, fh_, m_,
                                    backend=backend)

    return _shard_map(
        f, mesh=mesh,
        in_specs=(_packed_spec(sx), P(b, None), P(b, None), _packed_spec(sh),
                  P(b, None), P(b, None), P(b, "model")),
        out_specs=P(b, "model"), check_rep=False)(sx, dx, fx, sh, dh, fh, m)


def sharded_rb_dual_spmv_q8(mesh: Mesh, sx: RowBalancedSparseQ8, x,
                            sh: RowBalancedSparseQ8, h, bias, *,
                            act_scale_x=None, act_scale_h=None,
                            backend: str | None = None):
    """Quantized dual-ratio preactivation, rows + per-row scales sharded.

    Activation quantization happens per shard on the replicated x/h —
    identical codes everywhere (the dynamic max-abs fallback reduces over
    the same replicated tensor on every shard)."""
    _check_rows(mesh, sx, sh)
    b = batch_axis(mesh, x.shape[0])

    def f(sx_, x_, sh_, h_, b_):
        return K.rb_dual_spmv_q8(sx_, x_, sh_, h_, b_,
                                 act_scale_x=act_scale_x,
                                 act_scale_h=act_scale_h, backend=backend)

    return _shard_map(
        f, mesh=mesh,
        in_specs=(_packed_spec(sx), P(b, None), _packed_spec(sh),
                  P(b, None), P("model")),
        out_specs=P(b, "model"), check_rep=False)(sx, x, sh, h, bias)


# ----------------------------------------------------- sharded decode steps
# The multi-layer LSTM step as ONE shard_map region: local dual SpMV over
# the gate-aligned permuted rows, local cell close over the shard's hidden
# slice, then the h all-gather that feeds the next layer / next step.
# Layer params MUST be partition_lstm_params' permuted layout.

def _layer_specs(layers):
    return [{k: (_packed_spec(v) if isinstance(
                    v, (RowBalancedSparse, RowBalancedSparseQ8))
                 else P("model"))
             for k, v in lp.items()} for lp in layers]


def dist_lstm_step(mesh: Mesh, layers, x_t, state, *, pwl: bool = False,
                   dtype=jnp.float32, act_scales=None,
                   backend: str | None = None):
    """One sharded packed LSTM step (the ``LSTMModel._step`` twin).

    ``layers``: partition_lstm_params' per-layer ``{w_x, w_h, b}`` (gate-
    aligned permuted rows); ``state``: per-layer (c, h) with c sharded
    over its hidden slice and h replicated. ``act_scales``: per-layer
    (s_x, s_h) static activation scales for q8 layers (None entries fall
    back to the scheme default). Returns (h_last, new_state) exactly as
    the single-device step — bitwise, since every output row is computed
    by exactly one shard with unchanged per-row arithmetic.
    """
    b = batch_axis(mesh, x_t.shape[0])
    state = [tuple(st) for st in state]     # scan carries tuples
    st_spec = [(P(b, "model"), P(b, None)) for _ in layers]

    def f(layers_, x_, state_):
        inp = x_
        new = []
        for i, (lp, (c, h)) in enumerate(zip(layers_, state_)):
            if isinstance(lp["w_x"], RowBalancedSparseQ8):
                ax, ah = act_scales[i] if act_scales else (None, None)
                c2, h2 = K.brds_lstm_step_q8(
                    lp["w_x"], inp, lp["w_h"], h, lp["b"], c,
                    act_scale_x=ax, act_scale_h=ah, pwl=pwl,
                    backend=backend)
            else:
                c2, h2 = K.brds_lstm_step(lp["w_x"], inp, lp["w_h"], h,
                                          lp["b"], c, pwl=pwl,
                                          backend=backend)
            c2, h2 = c2.astype(dtype), h2.astype(dtype)
            h2 = gather_hidden(h2)         # THE per-step collective
            new.append((c2, h2))
            inp = h2
        return inp, new

    return _shard_map(
        f, mesh=mesh,
        in_specs=(_layer_specs(layers), P(b, None), st_spec),
        out_specs=(P(b, None), st_spec), check_rep=False)(
            layers, x_t, state)


def dist_delta_lstm_step(mesh: Mesh, layers, x_t, state, delta, *,
                         pwl: bool = False, dtype=jnp.float32,
                         act_scales=None, backend: str | None = None):
    """One sharded temporally-sparse step (the ``_delta_step`` twin).

    ``state``: per-layer dicts {c, h, x_ref, h_ref, m, nx, nh} with c and
    the partial-sum memory m sharded (m rides the permuted gate rows),
    everything else replicated. Thresholding runs on the replicated
    (gathered) reference state, so every shard derives the SAME fired
    sets and reference updates — the delta gating never needs a
    collective of its own. ``act_scales`` arrive already delta-doubled
    (the model owns that adjustment).
    """
    b = batch_axis(mesh, x_t.shape[0])
    state = list(state)                     # scan may carry a tuple
    st_spec = [{"c": P(b, "model"), "h": P(b, None), "x_ref": P(b, None),
                "h_ref": P(b, None), "m": P(b, "model"), "nx": P(b),
                "nh": P(b)} for _ in layers]

    def f(layers_, x_, state_):
        inp = x_
        new = []
        for i, (lp, st) in enumerate(zip(layers_, state_)):
            dx, fx, x_ref = delta_threshold(inp, st["x_ref"],
                                            delta.theta_x, delta.cap_x)
            dh, fh, h_ref = delta_threshold(st["h"], st["h_ref"],
                                            delta.theta_h, delta.cap_h)
            if isinstance(lp["w_x"], RowBalancedSparseQ8):
                ax, ah = act_scales[i] if act_scales else (None, None)
                c2, h2, m2 = K.brds_delta_lstm_step_q8(
                    lp["w_x"], dx, fx, lp["w_h"], dh, fh, st["m"], lp["b"],
                    st["c"], act_scale_x=ax, act_scale_h=ah, pwl=pwl,
                    backend=backend)
            else:
                c2, h2, m2 = K.brds_delta_lstm_step(
                    lp["w_x"], dx, fx, lp["w_h"], dh, fh, st["m"], lp["b"],
                    st["c"], pwl=pwl, backend=backend)
            h2 = gather_hidden(h2.astype(dtype))
            new.append({
                "c": c2.astype(dtype), "h": h2,
                "x_ref": x_ref, "h_ref": h_ref,
                "m": m2.astype(jnp.float32),
                "nx": st["nx"] + jnp.sum(fx, axis=1, dtype=jnp.float32),
                "nh": st["nh"] + jnp.sum(fh, axis=1, dtype=jnp.float32)})
            inp = h2
        return inp, new

    return _shard_map(
        f, mesh=mesh,
        in_specs=(_layer_specs(layers), P(b, None), st_spec),
        out_specs=(P(b, None), st_spec), check_rep=False)(
            layers, x_t, state)
