"""Partitioning contract for sharded packed-sparse LSTM decode.

The paper's central hardware claim — row-balanced pruning equalizes work
across PEs so no lane stalls — lifts verbatim to device sharding: every
row of a ``RowBalancedSparse`` holds exactly NZ survivors, so splitting
the 4H gate rows across the mesh's ``model`` axis yields perfectly
load-balanced shards *by construction*. Dual-ratio just means the W_x and
W_h shards carry different NZ, each internally balanced (ESE had to
scatter irregular CSC work across PEs and eat the imbalance; BRDS —
and this module — get balance for free from the format).

The contract (everything in ``repro.dist`` and the LSTM dist decode path
assumes it):

* **Gate-aligned row permutation.** The packed gate rows are laid out
  ``[f; i; g; o]`` (each H rows). A naive contiguous split of 4H rows
  would hand shard 0 nothing but forget-gate rows — the elementwise cell
  update needs aligned (f, i, g, o) quadruples. So partitioning first
  permutes rows to ``[f_0; i_0; g_0; o_0; f_1; i_1; ...]`` where ``x_j``
  is hidden slice ``[j·H/n, (j+1)·H/n)`` of gate ``x``: shard ``j``'s
  contiguous block is a complete ``[f; i; g; o]`` layout over its hidden
  slice, so it closes the LSTM cell for those units *locally*.
* **Values, indices, per-row scales, and bias move together** under that
  permutation (a row permutation never touches the delta-encoded column
  indices *within* a row — relative addressing is per-row state).
* **Cache layouts**: ``c`` shards with the gate rows it is updated from
  (logical axis ``lstm_hidden_shard``); ``h`` stays replicated — it is
  the activation broadcast every shard's W_h columns consume (the device
  analogue of the paper's activation broadcast to PEs). The delta path's
  partial-sum memory ``m`` shards with its rows (``lstm_gates``); the
  reference states ``x_ref``/``h_ref`` and fired counters stay
  replicated so Θ-thresholding agrees across shards.

The logical-axis names used here (``packed_rows``, ``lstm_hidden_shard``)
are registered in :data:`repro.sharding.DEFAULT_RULES`.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core.packing import RowBalancedSparse
from ..quant import RowBalancedSparseQ8
from ..sharding import named_sharding

__all__ = ["model_axis_size", "data_axis_size", "gate_row_permutation",
           "permute_packed_rows", "partition_lstm_params",
           "is_partitionable", "supports_dist", "check_partitioned"]

PACKED_TYPES = (RowBalancedSparse, RowBalancedSparseQ8)


def model_axis_size(mesh: Mesh) -> int:
    """Size of the mesh's ``model`` axis (1 when absent)."""
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)


def data_axis_size(mesh: Mesh) -> int:
    """Size of the mesh's ``data`` axis (1 when absent)."""
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get("data", 1)


def gate_row_permutation(hidden: int, shards: int) -> np.ndarray:
    """new→old row index map over the 4H gate rows, gate-aligned per shard.

    ``perm[new]`` is the old row index; shard ``j``'s contiguous block
    ``[j·4H/n, (j+1)·4H/n)`` holds ``[f_j; i_j; g_j; o_j]`` where each
    gate slice covers hidden units ``[j·H/n, (j+1)·H/n)``.

    Examples
    --------
    >>> gate_row_permutation(2, 2).tolist()   # H=2, [f0 f1 i0 i1 g0 g1 o0 o1]
    [0, 2, 4, 6, 1, 3, 5, 7]
    >>> gate_row_permutation(4, 1).tolist() == list(range(16))
    True
    """
    if hidden % shards:
        raise ValueError(f"hidden={hidden} not divisible by {shards} shards")
    hs = hidden // shards
    return np.concatenate([
        g * hidden + j * hs + np.arange(hs)
        for j in range(shards) for g in range(4)])


def permute_packed_rows(packed, perm: np.ndarray):
    """Row-permute a packed matrix (or a plain row-indexed array).

    Values, delta-encoded indices, and per-row scales move together; the
    within-row deltas are untouched (relative addressing is per-row
    state, so a row permutation never invalidates it).
    """
    if isinstance(packed, RowBalancedSparseQ8):
        return dataclasses.replace(packed, values=packed.values[perm],
                                   deltas=packed.deltas[perm],
                                   scales=packed.scales[perm])
    if isinstance(packed, RowBalancedSparse):
        return dataclasses.replace(packed, values=packed.values[perm],
                                   deltas=packed.deltas[perm])
    return packed[perm]                    # bias / any (4H, ...) array


def _packed_shardings(mesh: Mesh, packed):
    """NamedShardings for one packed matrix's leaves (rule-table driven)."""
    row2 = lambda a: named_sharding(mesh, ("packed_rows", None), a.shape)
    row1 = lambda a: named_sharding(mesh, ("packed_rows",), a.shape)
    if isinstance(packed, RowBalancedSparseQ8):
        return dataclasses.replace(packed, values=row2(packed.values),
                                   deltas=row2(packed.deltas),
                                   scales=row1(packed.scales))
    return dataclasses.replace(packed, values=row2(packed.values),
                               deltas=row2(packed.deltas))


def is_partitionable(params) -> bool:
    """Whether ``params`` is a packed LSTM param tree this module shards."""
    try:
        return isinstance(params["layers"][0]["w_x"], PACKED_TYPES)
    except (TypeError, KeyError, IndexError):
        return False


def supports_dist(model, mesh: Mesh) -> bool:
    """Whether ``model`` can decode through the sharded packed path."""
    return (hasattr(model, "with_mesh")
            and getattr(model, "supports_packed_decode", False)
            and "model" in mesh.axis_names)


def check_partitioned(params, mesh: Mesh) -> None:
    """Raise unless packed LSTM params carry the partitioned layout.

    The gate-aligned permuted layout is invisible in the tree structure —
    serving unpermuted packed params through the sharded step would split
    the ``[f; i; g; o]`` rows wrongly and decode garbage WITHOUT an
    error. The row sharding left by :func:`partition_lstm_params` is the
    observable witness: packed values must be committed with ``model`` on
    their row axis. Dense/unpacked trees pass (nothing to shard)."""
    if model_axis_size(mesh) == 1 or not is_partitionable(params):
        return
    v = params["layers"][0]["w_x"].values
    spec = getattr(getattr(v, "sharding", None), "spec", None)
    ax = spec[0] if spec else None
    if not (ax == "model" or (isinstance(ax, tuple) and "model" in ax)):
        raise ValueError(
            "packed params are not dist-partitioned (packed values are not "
            "row-sharded over the 'model' axis): serve the tree returned by "
            "repro.dist.partition_lstm_params / ServeEngine.prepare(mesh=...)"
            " — unpartitioned packed params would decode garbage silently")


def partition_lstm_params(params, mesh: Mesh):
    """Shard a SparsityPlan.pack'd LSTM param tree across ``mesh``.

    Gate rows of every layer's packed ``w_x``/``w_h`` (and ``b``, and q8
    per-row scales) are permuted gate-aligned (:func:`gate_row_permutation`)
    and placed row-sharded over the ``model`` axis; embed/head params are
    replicated. The result is device-committed — jit calls pick the
    layout up without explicit in_shardings.

    The permuted layout is only meaningful to the sharded step
    (``repro.dist.collective_ops``); serve it through a model carrying
    the same mesh (``model.with_mesh(mesh)`` — ``ServeEngine.prepare``
    wires both sides when the engine holds a mesh).
    """
    if not is_partitionable(params):
        raise ValueError(
            "partition_lstm_params wants a SparsityPlan.pack'd LSTM param "
            "tree (layers[*].w_x/w_h packed RowBalancedSparse[Q8])")
    n = model_axis_size(mesh)
    rows = params["layers"][0]["w_x"].rows
    hidden = rows // 4
    if hidden % n:
        raise ValueError(
            f"hidden={hidden} not divisible by model axis size {n}; pick a "
            "mesh whose model axis divides the LSTM hidden size")
    perm = gate_row_permutation(hidden, n)
    rep = NamedSharding(mesh, P())
    out_layers = []
    for lp in params["layers"]:
        entry = {}
        for key, leaf in lp.items():
            if isinstance(leaf, PACKED_TYPES):
                pm = permute_packed_rows(leaf, perm)
                entry[key] = jax.device_put(pm, _packed_shardings(mesh, pm))
            elif hasattr(leaf, "shape") and leaf.shape[:1] == (rows,):
                entry[key] = jax.device_put(
                    leaf[perm],
                    named_sharding(mesh, ("packed_rows",), leaf.shape))
            else:
                entry[key] = jax.device_put(leaf, rep)
        out_layers.append(entry)
    out = {}
    for k, v in params.items():
        out[k] = out_layers if k == "layers" else jax.tree.map(
            lambda a: jax.device_put(a, rep), v)
    return out
