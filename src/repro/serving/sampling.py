"""On-device token sampling for the decode loop.

A ``SamplingConfig`` is a static (hashable) description of how to turn the
last-position logits into the next token — it closes over no arrays, so it
can key a jit cache and live inside a ``lax.scan`` body. ``sample`` itself
is pure jnp: greedy argmax at temperature 0, otherwise temperature-scaled
categorical, optionally restricted to the top-k logits and/or the top-p
(nucleus) probability mass.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["SamplingConfig", "sample", "sample_dist", "sample_with_dist",
           "sample_from_dist"]

_NEG = -1e30


@dataclasses.dataclass(frozen=True)
class SamplingConfig:
    """Static sampling parameters.

    temperature: 0 → greedy argmax; >0 → categorical over logits/T.
    top_k:       >0 → restrict sampling to the k largest logits.
    top_p:       in (0, 1) → nucleus sampling: restrict to the smallest
                 set of tokens whose probability mass (after temperature
                 and top-k) reaches p; 0 or ≥1 disables. The most likely
                 token is always kept. Composes with top_k (k first).
    eos_id:      ≥0 → sequences stop after emitting this id (the EOS token
                 itself is emitted; later steps emit ``pad_id``).
    pad_id:      filler id emitted by finished sequences.
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 0.0
    eos_id: int = -1
    pad_id: int = 0

    def __post_init__(self):
        if self.top_p < 0.0:
            raise ValueError(f"top_p must be >= 0, got {self.top_p}")

    @property
    def stops(self) -> bool:
        return self.eos_id >= 0


def _filtered(logits, cfg: SamplingConfig):
    """Temperature-scaled, top-k/top-p-masked logits (fp32). The shared
    transform behind ``sample``/``sample_dist`` — only valid for
    temperature > 0 (greedy short-circuits before filtering)."""
    scaled = logits.astype(jnp.float32) / cfg.temperature
    if cfg.top_k > 0:
        kth = jax.lax.top_k(scaled, cfg.top_k)[0][..., -1:]
        scaled = jnp.where(scaled < kth, _NEG, scaled)
    if 0.0 < cfg.top_p < 1.0:
        # nucleus: drop tokens outside the smallest prefix (by descending
        # probability) whose cumulative mass reaches p. A token survives
        # iff the mass strictly BEFORE it is < p, so the argmax always
        # survives and ties at the boundary resolve inclusively.
        sort = jnp.sort(scaled, axis=-1)[..., ::-1]            # descending
        probs = jax.nn.softmax(sort, axis=-1)
        before = jnp.cumsum(probs, axis=-1) - probs
        keep = before < cfg.top_p                              # (B, V) sorted
        # smallest surviving logit per row = the cutoff threshold
        cut = jnp.min(jnp.where(keep, sort, jnp.inf), axis=-1, keepdims=True)
        scaled = jnp.where(scaled < cut, _NEG, scaled)
    return scaled


def sample(rng, logits, cfg: SamplingConfig):
    """logits (B, V) → next-token ids (B,) int32. ``cfg`` is static, so the
    greedy/top-k/top-p branches resolve at trace time."""
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits.astype(jnp.float32), axis=-1).astype(
            jnp.int32)
    return jax.random.categorical(rng, _filtered(logits, cfg)).astype(
        jnp.int32)


def sample_dist(logits, cfg: SamplingConfig):
    """The distribution ``sample`` draws from: logits (..., V) → sampling
    probabilities (..., V) fp32. Greedy is the one-hot of the argmax, so
    distribution-space consumers (the speculative-decode acceptance rule)
    degenerate exactly to the greedy token."""
    logits = logits.astype(jnp.float32)
    if cfg.temperature <= 0.0:
        V = logits.shape[-1]
        return jax.nn.one_hot(jnp.argmax(logits, axis=-1), V,
                              dtype=jnp.float32)
    return jax.nn.softmax(_filtered(logits, cfg), axis=-1)


def sample_with_dist(rng, logits, cfg: SamplingConfig):
    """``(sample(...), sample_dist(...))`` in one call: next-token ids (...,)
    int32 plus the per-token sampling distribution (..., V) they were drawn
    from. The ids are bitwise what ``sample`` returns for the same key."""
    return sample(rng, logits, cfg), sample_dist(logits, cfg)


def sample_from_dist(rng, dist, cfg: SamplingConfig):
    """Draw ids (...,) int32 from an explicit probability vector (..., V)
    (a ``sample_dist`` output or the speculative residual distribution) —
    the filtering already happened, so greedy is a plain argmax and
    temperature a plain categorical over log-probabilities."""
    if cfg.temperature <= 0.0:
        return jnp.argmax(dist, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        rng, jnp.log(jnp.maximum(dist, 1e-30))).astype(jnp.int32)
