"""On-device token sampling for the decode loop.

A ``SamplingConfig`` is a static (hashable) description of how to turn the
last-position logits into the next token — it closes over no arrays, so it
can key a jit cache and live inside a ``lax.scan`` body. ``sample`` itself
is pure jnp: greedy argmax at temperature 0, otherwise temperature-scaled
categorical, optionally restricted to the top-k logits.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["SamplingConfig", "sample"]

_NEG = -1e30


@dataclasses.dataclass(frozen=True)
class SamplingConfig:
    """Static sampling parameters.

    temperature: 0 → greedy argmax; >0 → categorical over logits/T.
    top_k:       >0 → restrict sampling to the k largest logits.
    eos_id:      ≥0 → sequences stop after emitting this id (the EOS token
                 itself is emitted; later steps emit ``pad_id``).
    pad_id:      filler id emitted by finished sequences.
    """

    temperature: float = 0.0
    top_k: int = 0
    eos_id: int = -1
    pad_id: int = 0

    @property
    def stops(self) -> bool:
        return self.eos_id >= 0


def sample(rng, logits, cfg: SamplingConfig):
    """logits (B, V) → next-token ids (B,) int32. ``cfg`` is static, so the
    greedy/top-k branches resolve at trace time."""
    logits = logits.astype(jnp.float32)
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits / cfg.temperature
    if cfg.top_k > 0:
        kth = jax.lax.top_k(scaled, cfg.top_k)[0][..., -1:]
        scaled = jnp.where(scaled < kth, _NEG, scaled)
    return jax.random.categorical(rng, scaled).astype(jnp.int32)
