"""Serving engine: sharded prefill + lockstep batched decode.

serve_step (one new token against a KV/recurrent cache) is the unit the
decode_* dry-run shapes lower. The engine jits prefill and decode with
NamedShardings (cache: batch→data, heads→model) and runs greedy/temperature
generation for the examples.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..training.train_loop import param_shardings
from ..sharding import named_sharding


def cache_shardings(mesh: Mesh, model, batch: int, max_len: int):
    from ..models import layers as L
    defs = model.cache_defs(batch, max_len)
    axes = L.param_axes(defs)
    shapes = L.param_shapes(defs)
    return jax.tree.map(
        lambda lg, sh: named_sharding(mesh, lg, sh),
        axes, shapes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


class ServeEngine:
    def __init__(self, model, cfg, mesh: Mesh | None = None,
                 max_len: int = 2048, batch: int = 8, sparsity=None):
        """``sparsity`` is the repro.sparse seam: a SparsityPolicy (or an
        already-compiled SparsityPlan) applied to params via ``prepare``
        before serving — the BRDS deployment scenario."""
        self.model = model
        self.cfg = cfg
        self.mesh = mesh
        self.max_len = max_len
        self.batch = batch
        self.sparsity = sparsity
        if mesh is not None:
            p_sh = param_shardings(mesh, model)
            c_sh = cache_shardings(mesh, model, batch, max_len)
            b_sh = NamedSharding(mesh, P(("pod", "data") if "pod" in
                                         mesh.axis_names else "data"))
            scalar = NamedSharding(mesh, P())
            self._decode = jax.jit(
                model.decode_step,
                in_shardings=(p_sh, c_sh, b_sh, scalar),
                donate_argnums=(1,))
        else:
            self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(model.prefill,
                                static_argnames=("max_len",))

    def prepare(self, params):
        """Apply the engine's sparsity policy/plan to params (prune to the
        policy's patterns). Returns (params, report) — report is None when
        the engine is dense."""
        if self.sparsity is None:
            return params, None
        plan = (self.sparsity.compile(params)
                if hasattr(self.sparsity, "compile") else self.sparsity)
        pruned, masks = plan.prune(params)
        return pruned, plan.summary(masks)

    def generate(self, params, tokens, steps: int, *, extra=None,
                 temperature: float = 0.0, rng=None):
        """Greedy (or sampled) generation. tokens (B, S) prompt.
        Returns (B, steps) generated ids."""
        if self.cfg.encdec:
            logits, cache = self._prefill(params, tokens, extra,
                                          max_len=self.max_len)
        elif extra is not None:
            logits, cache = self._prefill(params, tokens,
                                          max_len=self.max_len,
                                          patch_embeds=extra)
        else:
            logits, cache = self._prefill(params, tokens,
                                          max_len=self.max_len)
        pos = tokens.shape[1]
        out = []
        for i in range(steps):
            if temperature > 0 and rng is not None:
                rng, k = jax.random.split(rng)
                nxt = jax.random.categorical(k, logits[:, -1] / temperature)
            else:
                nxt = jnp.argmax(logits[:, -1], axis=-1)
            nxt = nxt[:, None].astype(jnp.int32)
            out.append(nxt)
            logits, cache = self._decode(params, cache, nxt, pos + i)
        return jnp.concatenate(out, axis=1)
