"""Serving engine: sharded prefill + on-device lockstep batched decode.

The engine programs against the ``DecodeStep`` contract (runtime.py): any
model with cache_defs / prefill / decode_step — the transformer zoo, the
enc-dec, and the paper's LSTM — serves through the same code path.
Generation is one jitted ``lax.scan`` (runtime.decode_loop) with the cache
donated and sampling on device: one dispatch per generate call, zero
per-token host syncs.

``sparsity=`` is the repro.sparse seam: ``prepare(params)`` prunes to the
policy's patterns and, for models that decode through packed kernels
(``supports_packed_decode``, e.g. the LSTM's rb_dual_spmv + lstm_gates
datapath), packs the surviving weights so serving exercises the BRDS
accelerator path rather than masked-dense matmuls.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..obs import trace as obs_trace
from ..training.train_loop import param_shardings
from ..sharding import named_sharding
from . import runtime
from .sampling import SamplingConfig


def cache_shardings(mesh: Mesh, model, batch: int, max_len: int):
    from ..models import layers as L
    defs = model.cache_defs(batch, max_len)
    axes = L.param_axes(defs)
    shapes = L.param_shapes(defs)
    return jax.tree.map(
        lambda lg, sh: named_sharding(mesh, lg, sh),
        axes, shapes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


class ServeEngine:
    def __init__(self, model, cfg=None, mesh: Mesh | None = None,
                 max_len: int = 2048, batch: int = 8, sparsity=None):
        """``sparsity`` is the repro.sparse seam: a SparsityPolicy (or an
        already-compiled SparsityPlan) applied to params via ``prepare``
        before serving — the BRDS deployment scenario."""
        if not runtime.conforms(model):
            raise TypeError(
                f"{type(model).__name__} does not implement the DecodeStep "
                "serving contract (cache_defs / prefill / decode_step)")
        self.model = model
        self.cfg = cfg
        self.mesh = mesh
        self.max_len = max_len
        self.batch = batch
        self.sparsity = sparsity
        self._loops: dict = {}
        # flipped by prepare() when params get dist-partitioned (sharded
        # packed decode): the loop then jits WITHOUT explicit shardings —
        # partitioned params are device-committed and the model's
        # shard_map step pins the cache layout
        self._dist = False
        if mesh is not None:
            self._p_sh = param_shardings(mesh, model)
            self._c_sh = cache_shardings(mesh, model, batch, max_len)
            self._b_sh = NamedSharding(mesh, P(("pod", "data") if "pod" in
                                               mesh.axis_names else "data"))
            self._scalar = NamedSharding(mesh, P())
        self._prefill = jax.jit(model.prefill,
                                static_argnames=("max_len",))
        self._dprefills: dict = {}   # id(draft) → jitted draft prefill

    @obs_trace.traced("engine.prepare")
    def prepare(self, params, pack: bool | None = None, calib=None):
        """Apply the engine's sparsity policy/plan to params. Prunes to the
        policy's patterns; when the model decodes through packed kernels
        (``pack=None`` → ``model.supports_packed_decode``), the pruned
        weights are additionally packed from the prune masks so decode runs
        the row-balanced SpMV path. A policy carrying an activation rule
        (``DeltaGateConfig``) is wired into the model here: the engine
        swaps in ``model.with_delta(...)`` so the decode cache grows the
        temporal reference state and every step skips unfired columns.
        A policy ``quant`` rule (``QuantConfig``) likewise rewires the
        model: activation scales are calibrated over ``calib`` (a token /
        feature batch run through the DENSE params — ``repro.quant.
        calibrate_lstm``; scale-free fallback when None), the model swaps
        to ``with_quant(plan)``, and packing emits RowBalancedSparseQ8 so
        decode runs the int32-accumulate q8 kernels.
        Returns (params, report) — report is None when the engine is
        dense."""
        if self.sparsity is None:
            return params, None
        plan = (self.sparsity.compile(params)
                if hasattr(self.sparsity, "compile") else self.sparsity)
        act = getattr(plan, "activation", None)
        qcfg = getattr(plan, "quant", None)
        rewired = False
        if act is not None:
            if not hasattr(self.model, "with_delta"):
                raise ValueError(
                    f"sparsity policy carries an activation rule ({act}) "
                    f"but {type(self.model).__name__} has no temporal-"
                    "delta serving path (with_delta)")
            self.model = self.model.with_delta(act)
            rewired = True
        if qcfg is not None:
            if not hasattr(self.model, "with_quant"):
                raise ValueError(
                    f"sparsity policy carries a quant rule ({qcfg}) but "
                    f"{type(self.model).__name__} has no quantized "
                    "serving path (with_quant)")
            from ..quant import calibrate_lstm, default_plan
            if calib is not None:
                qplan = calibrate_lstm(self.model, params, calib, qcfg)
            else:
                qplan = default_plan(qcfg, len(params["layers"]))
            self.model = self.model.with_quant(qplan)
            rewired = True
        if rewired:
            self._prefill = jax.jit(self.model.prefill,
                                    static_argnames=("max_len",))
            self._loops.clear()
            if self.mesh is not None:   # the delta cache has more leaves
                self._c_sh = cache_shardings(self.mesh, self.model,
                                             self.batch, self.max_len)
        pruned, masks = plan.prune(params)
        report = plan.summary(masks)
        if pack is None:
            pack = getattr(self.model, "supports_packed_decode", False)
        if pack:
            packed, pack_report = plan.pack(pruned, masks)
            packed = self._maybe_partition(packed)
            if not self._dist and hasattr(self.model, "pad_packed_params"):
                # hoist the kernel-block row padding out of the per-token
                # hot path (sharded decode re-splits rows — skip there)
                packed = self.model.pad_packed_params(packed)
            return packed, {**report, **pack_report}
        return pruned, report

    def _maybe_partition(self, packed):
        """Shard packed params across the engine's mesh (repro.dist):
        gate-aligned row-sharded weights, model rewired to the sharded
        decode step. No-op without a mesh / a model-axis / packed leaves."""
        from .. import dist
        if (self.mesh is None or not dist.supports_dist(self.model, self.mesh)
                or not dist.is_partitionable(packed)):
            return packed
        packed = dist.partition_lstm_params(packed, self.mesh)
        self.model = self.model.with_mesh(self.mesh)
        self._dist = True
        self._prefill = jax.jit(self.model.prefill,
                                static_argnames=("max_len",))
        self._loops.clear()
        return packed

    # ------------------------------------------------------------ decode
    def _loop(self, steps: int, sampling: SamplingConfig):
        """One jitted scan-decode per (steps, sampling); cache donated."""
        key = (steps, sampling)
        if key not in self._loops:
            def run(params, cache, logits, pos, rng):
                return runtime.decode_loop(
                    self.model, params, cache, logits, pos, rng, steps,
                    sampling, limit=self.max_len)
            if self.mesh is not None and not self._dist:
                fn = jax.jit(run,
                             in_shardings=(self._p_sh, self._c_sh,
                                           self._b_sh, self._scalar,
                                           self._scalar),
                             donate_argnums=(1,))
            else:
                fn = jax.jit(run, donate_argnums=(1,))
            self._loops[key] = fn
        return self._loops[key]

    def _spec_loop(self, steps: int, k: int, sampling: SamplingConfig,
                   draft):
        """One jitted speculative round loop per (steps, k, sampling,
        draft); target cache + draft state donated. Jits plain (no
        explicit shardings) — the spec loop is a CPU/single-device
        serving composition."""
        key = ("spec", steps, k, sampling, draft.sampling, id(draft))
        if key not in self._loops:
            from ..spec import spec_decode_loop

            def run(params, dparams, cache, dstate, probs, pos, rng):
                return spec_decode_loop(
                    self.model, draft, params, dparams, cache, dstate,
                    probs, pos, rng, steps, k, sampling,
                    limit=self.max_len)

            self._loops[key] = jax.jit(run, donate_argnums=(2, 3))
        return self._loops[key]

    def generate(self, params, tokens, steps: int, *, extra=None,
                 temperature: float = 0.0, top_k: int = 0, eos_id: int = -1,
                 rng=None, sampling: SamplingConfig | None = None,
                 return_state: bool = False, lengths=None, draft=None,
                 spec_k: int = 4):
        """Generate ``steps`` tokens for a lockstep batch of prompts.

        tokens (B, S) prompt; ``extra`` is family-specific conditioning
        (encoder frames, patch embeds). Returns (B, steps) int32 ids —
        finished sequences (per-sequence EOS) pad with ``sampling.pad_id``.
        ``return_state=True`` additionally returns the decode_loop's final
        state dict (cache/logits/pos/...), e.g. to read the temporal-delta
        occupancy counters out of the cache after serving.

        ``lengths`` (a (B,) int vector) serves a RAGGED batch in one
        lockstep call: ``tokens`` is right-padded to a common width, the
        model's length-aware prefill masks each sequence's padded tail
        out of its state, and decode runs with per-sequence cache
        positions. Requires a model whose prefill accepts ``length``
        (``runtime.prefill_accepts_length``); each row's output is
        bitwise what its unpadded batch=1 decode would produce (greedy).

        ``draft`` (a ``repro.spec.DraftModel``) switches generation to
        speculative rounds: the draft proposes ``spec_k`` tokens, the
        target verifies the block in one dispatch, and both roll back to
        the accepted prefix. Greedy output is bitwise identical to
        ``draft=None``; ``return_state=True`` then also exposes per-row
        ``rounds``/``drafted``/``accepted`` counters (acceptance-rate =
        accepted / drafted).
        """
        if sampling is None:
            sampling = SamplingConfig(temperature=temperature, top_k=top_k,
                                      eos_id=eos_id)
        if rng is None:
            rng = jax.random.key(0)
        if getattr(self.model, "mesh", None) is not None:
            # packed-but-unpartitioned params would decode garbage silently
            # through the sharded step (the permuted layout is invisible in
            # the tree structure) — O(1) sharding check
            from ..dist import check_partitioned
            check_partitioned(params, self.model.mesh)
        if lengths is not None:
            if not runtime.prefill_accepts_length(self.model):
                raise TypeError(
                    f"{type(self.model).__name__}.prefill has no "
                    "length-masked path — ragged lockstep serving needs "
                    "the `length` prefill parameter")
            lengths = jnp.asarray(lengths, jnp.int32)
            with obs_trace.span("engine.prefill", batch=tokens.shape[0],
                                width=tokens.shape[1], ragged=True):
                logits, cache = self._prefill(params, tokens,
                                              max_len=self.max_len,
                                              extra=extra, length=lengths)
            pos = lengths
        else:
            with obs_trace.span("engine.prefill", batch=tokens.shape[0],
                                width=tokens.shape[1], ragged=False):
                logits, cache = self._prefill(params, tokens,
                                              max_len=self.max_len,
                                              extra=extra)
            pos = jnp.int32(tokens.shape[1])
        if draft is not None:
            from .sampling import sample_dist
            dpf = self._dprefills.setdefault(
                id(draft), jax.jit(draft.prefill,
                                   static_argnames=("max_len",)))
            if lengths is not None:
                if not runtime.prefill_accepts_length(draft.model):
                    raise TypeError(
                        f"{type(draft.model).__name__}.prefill has no "
                        "length-masked path — ragged speculative serving "
                        "needs the `length` prefill parameter")
                _, dstate = dpf(draft.params, tokens, max_len=self.max_len,
                                length=lengths)
                pos_v = lengths
            else:
                _, dstate = dpf(draft.params, tokens, max_len=self.max_len)
                pos_v = jnp.full((tokens.shape[0],), tokens.shape[1],
                                 jnp.int32)
            probs = sample_dist(logits[:, -1], sampling)
            with obs_trace.span("engine.spec_loop", steps=steps, k=spec_k):
                toks, state = self._spec_loop(steps, spec_k, sampling,
                                              draft)(params, draft.params,
                                                     cache, dstate, probs,
                                                     pos_v, rng)
            return (toks, state) if return_state else toks
        # the span covers compile+enqueue — decode itself is async; wall
        # time to tokens is the caller's block_until_ready
        with obs_trace.span("engine.decode_loop", steps=steps):
            toks, state = self._loop(steps, sampling)(params, cache, logits,
                                                      pos, rng)
        return (toks, state) if return_state else toks
