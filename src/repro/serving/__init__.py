"""Serving stack: the DecodeStep contract, on-device decode, engines.

- runtime   — the DecodeStep protocol + the scan-based decode_loop
- sampling  — on-device greedy/temperature/top-k sampling with EOS
- engine    — ServeEngine: sharded prefill + lockstep batched decode
- scheduler — ContinuousBatchingEngine: slot-based request streaming
"""
from .engine import ServeEngine, cache_shardings
from .runtime import DecodeStep, conforms, decode_loop
from .sampling import SamplingConfig, sample
from .scheduler import ContinuousBatchingEngine, Request, Finished

__all__ = ["ServeEngine", "cache_shardings", "DecodeStep", "conforms",
           "decode_loop", "SamplingConfig", "sample",
           "ContinuousBatchingEngine", "Request", "Finished"]
