"""Serving stack: the DecodeStep contract, on-device decode, engines.

- runtime   — the DecodeStep protocol + the scan-based decode_loop
- sampling  — on-device greedy/temperature/top-k sampling with EOS
- engine    — ServeEngine: sharded prefill + lockstep batched decode
- scheduler — ContinuousBatchingEngine: pooled-slot continuous batching
              with dispatch-ahead, bucketed prefill, deadlines, and
              per-token streaming (built on repro.traffic)
"""
from .engine import ServeEngine, cache_shardings
from .runtime import (DecodeStep, conforms, decode_loop,
                      prefill_accepts_length)
from .sampling import (SamplingConfig, sample, sample_dist, sample_from_dist,
                       sample_with_dist)
from .scheduler import (ContinuousBatchingEngine, Request, Finished,
                        TokenEvent)

__all__ = ["ServeEngine", "cache_shardings", "DecodeStep", "conforms",
           "decode_loop", "prefill_accepts_length", "SamplingConfig",
           "sample", "sample_dist", "sample_from_dist", "sample_with_dist",
           "ContinuousBatchingEngine", "Request", "Finished", "TokenEvent"]
