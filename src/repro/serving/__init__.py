from .engine import ServeEngine, cache_shardings
