"""The one decode contract every servable model family implements.

``DecodeStep`` is the protocol the serving stack (ServeEngine, the
continuous-batching scheduler, the dry-run decode shapes) programs against:

  cache_defs(batch, max_len)            → PSpec pytree for the decode cache
                                          (KV cache, recurrent state, LSTM
                                          (c, h) — whatever the family keeps
                                          per sequence)
  init_cache(batch, max_len)            → concrete zeroed cache
  prefill(params, tokens, max_len,
          extra=None)                   → (last logits (B, 1, V), cache);
                                          ``extra`` is family-specific
                                          conditioning (VLM patch embeds,
                                          enc-dec encoder frames)
  decode_step(params, cache, tokens,
              pos)                      → (logits (B, 1, V), cache); ``pos``
                                          is a scalar (lockstep batch) or an
                                          (B,) int32 vector of per-sequence
                                          positions (continuous batching)

``decode_loop`` is the generation engine built on that contract: a single
``lax.scan`` over decode steps with sampling, per-sequence EOS/budget stop,
and cache-position bookkeeping all on device — one dispatch per generate
call, zero per-token host syncs.
"""
from __future__ import annotations

import inspect
from typing import Any, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from .sampling import SamplingConfig, sample

__all__ = ["DecodeStep", "conforms", "decode_loop",
           "prefill_accepts_length"]


@runtime_checkable
class DecodeStep(Protocol):
    """The decode contract every servable model family implements.

    Methods
    -------
    cache_defs(batch, max_len)
        Decode-cache declaration as a PSpec pytree — KV cache, recurrent
        state, the LSTM's (c, h) (+ its temporal-delta reference
        state/partial sums when enabled); whatever the family keeps per
        sequence. The logical axis names drive cache sharding and the
        scheduler's slot joins.
    init_cache(batch, max_len)
        Concrete zeroed cache matching ``cache_defs``.
    prefill(params, tokens, max_len, extra=None)
        Process a full prompt. ``tokens``: (B, S) ids or (B, S, X)
        frames; ``extra`` is family-specific conditioning (VLM patch
        embeds, enc-dec encoder frames). Returns (last logits (B, 1, V),
        cache). A family MAY additionally accept ``length`` (an int or
        (B,) int32 vector of true prompt lengths ≤ S): tokens at
        positions ≥ length are padding and must not perturb the
        returned state — the scheduler then pads ragged prompts to
        power-of-two buckets so prefill compiles once per bucket
        instead of once per distinct length
        (``prefill_accepts_length`` probes for the parameter).
    decode_step(params, cache, tokens, pos)
        Advance one token. ``tokens``: (B, 1); ``pos`` is a scalar next
        cache position (lockstep batch) or a (B,) int32 vector of
        per-sequence positions (continuous batching). Returns (logits
        (B, 1, V), cache).

    Rewind contract
    ---------------
    ``pos`` is the source of truth for sequence length: positional cache
    entries (leaves with a ``cache_seq`` axis — KV caches and their quant
    scales) at positions ≥ ``pos`` must be DEAD — never read by a later
    ``decode_step`` at any position, and freely overwritten. A caller may
    therefore rewind a sequence by re-issuing a smaller ``pos`` (as
    speculative decoding does after a partial acceptance): the stale tail
    left in the buffers is invisible. Families honor this by masking
    attention/lookups to positions < the current length and by writing
    (not accumulating) at ``pos``. Non-positional leaves (recurrent
    state: LSTM (c, h) + delta references, RG-LRU h/conv, RWKV S/x_*)
    are exempt — they fold every consumed token irreversibly, so a
    rewinder must checkpoint and restore them instead
    (``repro.spec.verify.rollback`` splits the two kinds by
    ``cache_defs`` axes).
    """

    def cache_defs(self, batch: int, max_len: int) -> Any: ...

    def init_cache(self, batch: int, max_len: int) -> Any: ...

    def prefill(self, params, tokens, max_len: int, extra=None): ...

    def decode_step(self, params, cache, tokens, pos): ...


def conforms(model) -> bool:
    """Whether ``model`` implements the DecodeStep serving contract."""
    return isinstance(model, DecodeStep)


def prefill_accepts_length(model) -> bool:
    """Whether ``model.prefill`` takes the optional ``length`` argument
    (padding-masked bucketed prefill — see the DecodeStep docstring)."""
    try:
        return "length" in inspect.signature(model.prefill).parameters
    except (TypeError, ValueError):
        return False


def decode_loop(model, params, cache, logits, pos, rng, steps: int,
                sampling: SamplingConfig, *, done=None, budget=None,
                limit: int | None = None):
    """Generate ``steps`` tokens on device with one ``lax.scan``.

    Parameters
    ----------
    model : DecodeStep
        The servable model.
    params : pytree
        Dense, pruned, or SparsityPlan.pack'd params.
    cache : pytree
        Decode cache (donate it at the jit boundary).
    logits : jnp.ndarray
        (B, 1, V) last-position logits from prefill (or a previous loop).
    pos : jnp.ndarray
        Scalar next cache position (lockstep) or (B,) per-sequence
        positions (continuous batching; frozen once a sequence is done).
    rng : jax.random key
        Sampling key (split per step).
    steps : int
        Tokens to generate (static — one compiled scan per value).
    sampling : SamplingConfig
        Greedy/temperature/top-k + EOS/pad configuration.
    done : jnp.ndarray, optional
        (B,) bool — sequences that start finished (inactive slots).
    budget : jnp.ndarray, optional
        (B,) int32 — per-sequence max tokens to emit this call.
    limit : int, optional
        Cache capacity; sequences stop before writing past it.

    Returns
    -------
    (tokens, state)
        ``tokens`` (B, steps) int32; ``state`` dict with the final
        cache/logits/pos/rng/done/emitted carry — everything needed to
        resume the loop (the scheduler chains chunks this way).
    """
    B = logits.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    per_seq_pos = pos.ndim == 1
    if done is None:
        done = jnp.zeros((B,), bool)
    emitted = jnp.zeros((B,), jnp.int32)

    def body(carry, _):
        cache, logits, rng, done, pos, emitted = carry
        rng, k = jax.random.split(rng)
        nxt = sample(k, logits[:, -1], sampling)
        nxt = jnp.where(done, jnp.int32(sampling.pad_id), nxt)
        emitted = emitted + jnp.where(done, 0, 1)
        if sampling.stops:
            done = done | (nxt == sampling.eos_id)
        if budget is not None:
            done = done | (emitted >= budget)
        if limit is not None:
            done = done | (pos + 1 >= limit)
        logits, cache = model.decode_step(params, cache, nxt[:, None], pos)
        # freeze positions of finished sequences (scalar: once all finish)
        frozen = done if per_seq_pos else jnp.all(done)
        pos2 = pos + jnp.where(frozen, 0, 1).astype(jnp.int32)
        return (cache, logits, rng, done, pos2, emitted), nxt

    carry = (cache, logits, rng, done, pos, emitted)
    (cache, logits, rng, done, pos, emitted), toks = jax.lax.scan(
        body, carry, None, length=steps)
    return toks.T, dict(cache=cache, logits=logits, rng=rng, done=done,
                        pos=pos, emitted=emitted)
