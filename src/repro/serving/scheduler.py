"""Slot-based continuous batching over the DecodeStep contract.

ESE/Spartus-style request-level serving: instead of one lockstep batch that
lives and dies together, the scheduler owns a fixed number of decode
*slots* over one shared cache. Requests with ragged prompt lengths and
ragged generation budgets stream through:

  submit → queue → (slot free?) prefill the prompt at batch=1 →
  join: write the prefilled cache/logits into the shared cache at the slot
  → decode: all active slots step together in one on-device scan chunk
  (per-slot cache positions — runtime.decode_loop with ``pos`` as a vector)
  → evict: finished slots (EOS / budget / cache full) release and the next
  queued request is admitted.

The host syncs once per decode *chunk* (default 8 tokens), not per token;
admission/eviction decisions ride on that boundary. Prefill is jitted per
distinct prompt length (bucket prompts upstream if lengths are adversarial).
"""
from __future__ import annotations

import dataclasses
import functools
from collections import deque
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import runtime
from .sampling import SamplingConfig

__all__ = ["Request", "Finished", "ContinuousBatchingEngine"]


@dataclasses.dataclass
class Request:
    uid: int
    prompt: Any                 # (1, S) int32 tokens
    max_new: int
    extra: Any = None           # family-specific conditioning (frames, ...)


@dataclasses.dataclass
class Finished:
    uid: int
    tokens: np.ndarray          # emitted ids, EOS included if hit
    prompt_len: int


class ContinuousBatchingEngine:
    """Continuous batching for any DecodeStep model.

    ``params`` may be dense, pruned, or SparsityPlan.pack'd — the model's
    decode_step dispatches (the BRDS LSTM runs rb_dual_spmv + lstm_gates on
    packed params).

    ``mesh`` turns on sharded serving (repro.dist): the slot batch runs
    data-parallel over the mesh's ``data`` axis (when it divides the slot
    count; batch=1 prefills replicate) with model-parallel row shards
    inside each replica group. ``params`` must then be
    ``repro.dist.partition_lstm_params``' layout — ``ServeEngine.prepare``
    with the same mesh produces it (and a model already carrying the mesh,
    in which case ``mesh=`` here is redundant but harmless).
    """

    def __init__(self, model, params, *, slots: int = 4, max_len: int = 256,
                 sampling: SamplingConfig = SamplingConfig(),
                 chunk: int = 8, seed: int = 0, mesh=None):
        if not runtime.conforms(model):
            raise TypeError(
                f"{type(model).__name__} does not implement the DecodeStep "
                "serving contract (cache_defs / prefill / decode_step)")
        if mesh is not None and getattr(model, "mesh", None) is None:
            if not hasattr(model, "with_mesh"):
                raise TypeError(f"{type(model).__name__} has no sharded "
                                "decode path (with_mesh)")
            model = model.with_mesh(mesh)
        if getattr(model, "mesh", None) is not None:
            # the permuted dist layout is invisible in the tree structure;
            # reject packed-but-unpartitioned params before they decode
            # garbage silently
            from ..dist import check_partitioned
            check_partitioned(params, model.mesh)
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.sampling = sampling
        self.chunk = chunk

        self.cache = model.init_cache(slots, max_len)
        # per-leaf batch axis: cache leaves may be layer-stacked (scanned
        # blocks put 'layers' ahead of 'batch'), so the slot join can't
        # assume axis 0 — the cache defs carry the logical axis names.
        from ..models import layers as L
        self._batch_axes = jax.tree.map(
            lambda d: d.axes.index("batch"),
            model.cache_defs(slots, max_len), is_leaf=L.is_pspec)
        self.pos = jnp.zeros((slots,), jnp.int32)
        self.logits = None                      # (slots, 1, V), lazy init
        self.rng = jax.random.key(seed)

        self._queue: deque[Request] = deque()
        self._slot_uid: list[int | None] = [None] * slots
        self._slot_prompt_len = [0] * slots
        # steps the current occupant's cache has accumulated (prefill +
        # chunk decodes) — the divisor for per-slot occupancy accounting
        self.slot_steps = np.zeros(slots, np.int64)
        self._remaining = np.zeros(slots, np.int64)
        self._collected: dict[int, list[int]] = {}
        self._next_uid = 0
        self.steps_dispatched = 0               # device dispatches (chunks)

        self._prefill = jax.jit(model.prefill, static_argnames=("max_len",))
        self._join = jax.jit(self._join_impl, donate_argnums=(0, 1, 2))
        self._chunk_fn = jax.jit(self._chunk_impl, donate_argnums=(1,))

    # ------------------------------------------------------------- device
    def _join_impl(self, cache, logits, pos, pre_cache, pre_logits, slot,
                   prompt_len):
        """Write a batch=1 prefill result into shared state at ``slot``."""
        def upd(c, p, ax):
            starts = tuple(slot if i == ax else 0 for i in range(c.ndim))
            return jax.lax.dynamic_update_slice(c, p.astype(c.dtype), starts)

        cache = jax.tree.map(upd, cache, pre_cache, self._batch_axes)
        logits = jax.lax.dynamic_update_index_in_dim(
            logits, pre_logits[0].astype(logits.dtype), slot, 0)
        pos = pos.at[slot].set(prompt_len)
        return cache, logits, pos

    def _chunk_impl(self, params, cache, logits, pos, rng, done, budget):
        return runtime.decode_loop(
            self.model, params, cache, logits, pos, rng, self.chunk,
            self.sampling, done=done, budget=budget, limit=self.max_len)

    # -------------------------------------------------------------- admit
    def submit(self, prompt, max_new: int, extra=None) -> int:
        """Queue one request. prompt: (S,) or (1, S) int tokens."""
        prompt = jnp.asarray(prompt, jnp.int32)
        if prompt.ndim == 1:
            prompt = prompt[None, :]
        if prompt.shape[1] >= self.max_len:
            raise ValueError(f"prompt length {prompt.shape[1]} ≥ max_len "
                             f"{self.max_len}")
        uid = self._next_uid
        self._next_uid += 1
        self._queue.append(Request(uid, prompt, max_new, extra))
        self._collected[uid] = []
        return uid

    @property
    def active_slots(self) -> list[int]:
        return [s for s, u in enumerate(self._slot_uid) if u is not None]

    @property
    def pending(self) -> int:
        return len(self._queue)

    def _admit(self):
        for slot in range(self.slots):
            if self._slot_uid[slot] is not None or not self._queue:
                continue
            req = self._queue.popleft()
            plen = req.prompt.shape[1]
            lp, pre_cache = self._prefill(self.params, req.prompt,
                                          max_len=self.max_len,
                                          extra=req.extra)
            if self.logits is None:
                self.logits = jnp.zeros((self.slots,) + lp.shape[1:],
                                        lp.dtype)
            self.cache, self.logits, self.pos = self._join(
                self.cache, self.logits, self.pos, pre_cache, lp,
                jnp.int32(slot), jnp.int32(plen))
            self._slot_uid[slot] = req.uid
            self._slot_prompt_len[slot] = plen
            self.slot_steps[slot] = plen    # join resets the slot's cache
            # cap the budget at the cache capacity left after the prompt
            self._remaining[slot] = min(req.max_new, self.max_len - plen)

    # -------------------------------------------------------------- decode
    def step(self) -> list[Finished]:
        """Admit queued requests, decode one chunk, evict finished slots.
        Returns the requests that completed this step."""
        self._admit()
        active = self.active_slots
        if not active:
            return []
        done0 = jnp.asarray(
            [u is None for u in self._slot_uid], bool)
        budget = jnp.asarray(np.maximum(self._remaining, 0), jnp.int32)
        toks, st = self._chunk_fn(self.params, self.cache, self.logits,
                                  self.pos, self.rng, done0, budget)
        self.cache, self.logits = st["cache"], st["logits"]
        self.pos, self.rng = st["pos"], st["rng"]
        self.steps_dispatched += 1
        # every slot steps through decode_step each chunk (done slots
        # included — lockstep semantics), so all caches advance
        self.slot_steps += self.chunk

        toks_np = np.asarray(toks)              # the one host sync per chunk
        finished: list[Finished] = []
        for slot in active:
            uid = self._slot_uid[slot]
            out = self._collected[uid]
            for t in toks_np[slot]:
                if self._remaining[slot] <= 0:
                    break
                out.append(int(t))
                self._remaining[slot] -= 1
                if self.sampling.stops and int(t) == self.sampling.eos_id:
                    self._remaining[slot] = 0
            if self._remaining[slot] <= 0:
                finished.append(Finished(uid, np.asarray(out, np.int32),
                                         self._slot_prompt_len[slot]))
                self._slot_uid[slot] = None     # evict: slot is reusable
        return finished

    def run(self) -> dict[int, np.ndarray]:
        """Drive until queue and slots drain. Returns {uid: tokens}."""
        results: dict[int, np.ndarray] = {}
        while self._queue or self.active_slots:
            for fin in self.step():
                results[fin.uid] = fin.tokens
        return results
