"""Continuous batching over the DecodeStep contract, built for traffic.

ESE/Spartus-style request-level serving, rebuilt around `repro.traffic`:
the scheduler owns a preallocated pool of decode *slots* over one shared
cache (`traffic.pool.SlotPool` — recurrent O(1) state makes hundreds of
slots cheap), a priority/deadline admission queue with overload shedding
(`traffic.admission.AdmissionQueue`), and a dispatch-ahead chunk pipeline
(`traffic.dispatch.DispatchQueue`):

  submit → admission queue → (slots free?) bucketed/batched prefill →
  join: the prefilled cache rows, last logits, positions, done flags and
  token budgets are scattered into the shared device state at the slots →
  decode: all slots step together in on-device scan chunks; ``done`` and
  ``budget`` live ON DEVICE and chain across chunks, so chunk N+1 can be
  dispatched before chunk N's tokens ever reach the host →
  harvest: the oldest in-flight chunk's tokens sync (the one host round
  trip), stream out through per-token callbacks/events, and finished or
  past-deadline slots are evicted back to the pool.

With ``dispatch_depth`` ≥ 2 (the default) the host enqueues the next
chunk — admissions included — while the device runs the current one
(donated-buffer double buffering), the TPU analogue of the paper's
computation overlapping. Depth 1 reproduces the synchronous
chunk-per-sync baseline; both schedules decode every request
bit-identically under greedy sampling (the device-resident done/budget
vectors freeze finished slots regardless of when the host notices).

Prefill compiles once per power-of-two length bucket, not once per
distinct prompt length: prompts are right-padded to the bucket and the
model's ``length=``-aware prefill masks the padded tail out of the state
(bitwise-exact; models without a ``length`` parameter fall back to
exact-length prefill). Same-bucket requests prefill together in one
batched call (``prefill_batch``).
"""
from __future__ import annotations

import dataclasses
import inspect
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import runtime
from .sampling import SamplingConfig
from ..obs import counters as obs_counters
from ..obs import trace as obs_trace
from ..traffic import (AdmissionQueue, DispatchQueue, QueuedRequest,
                       SlotInfo, SlotPool)

__all__ = ["Request", "Finished", "TokenEvent", "ContinuousBatchingEngine"]


@dataclasses.dataclass
class Request:
    uid: int
    prompt: Any                 # (1, S) int32 tokens
    max_new: int
    extra: Any = None           # family-specific conditioning (frames, ...)
    deadline: float | None = None
    priority: int = 0


@dataclasses.dataclass
class Finished:
    uid: int
    tokens: np.ndarray          # emitted ids, EOS included if hit
    prompt_len: int
    reason: str = "done"        # done | expired | rejected


@dataclasses.dataclass
class TokenEvent:
    """Incremental output: tokens harvested for ``uid`` this chunk."""
    uid: int
    tokens: list
    first: bool                 # True on the request's first emitted tokens


def _bucket(n: int, cap: int) -> int:
    """Next power of two ≥ n, capped at ``cap``."""
    b = 1
    while b < n:
        b <<= 1
    return min(b, cap)


class ContinuousBatchingEngine:
    """Continuous batching for any DecodeStep model.

    ``params`` may be dense, pruned, or SparsityPlan.pack'd — the model's
    decode_step dispatches (the BRDS LSTM runs rb_dual_spmv + lstm_gates
    on packed params).

    ``mesh`` turns on sharded serving (repro.dist): the slot batch runs
    data-parallel over the mesh's ``data`` axis with model-parallel row
    shards inside each replica group; ``params`` must then be
    ``repro.dist.partition_lstm_params``' layout.

    Traffic controls (all keyword-only):

    - ``slots``: pool size. Recurrent models keep O(1) state per slot, so
      hundreds are cheap.
    - ``dispatch_depth``: in-flight decode chunks (1 = synchronous
      baseline, 2 = dispatch-ahead double buffering, the default).
    - ``prefill_batch``: same-bucket admissions prefilled per call.
      Keep 1 when serving uncalibrated q8 params (their dynamic max-abs
      fallback reduces over the prefill batch; calibrated plans — the
      real serving path — are exact at any batch).
    - ``bucket_prompts``: pad prompts to power-of-two buckets when the
      model's prefill is ``length``-aware (one compile per bucket).
    - ``max_queue``: bound the admission queue; overload sheds the worst
      waiting request (reason ``"rejected"``) instead of queueing
      unboundedly.
    - ``clock``: time source for deadlines/admission (default
      ``time.perf_counter``; tests inject virtual clocks).
    - ``on_token``: per-token streaming callback
      ``(uid, tokens: list[int], first: bool)`` invoked at harvest.
    - ``counters``: thread the ``repro.obs`` on-device counter vector
      (decode steps, emitted tokens, spec acceptance, delta fired-column
      gauges) through every chunk dispatch. The vector rides the dispatch
      queue next to each chunk's token future and is read at the chunk's
      EXISTING harvest sync — zero extra device→host transfers, zero new
      sync points. ``counters()`` returns the harvested dict. Off (the
      default) compiles exactly the uninstrumented chunk function.
    - ``draft``: a ``repro.spec.DraftModel`` switches every decode chunk
      to speculative rounds (``spec_k`` proposals per round): each slot
      carries the draft's recurrent state alongside its cache rows, a
      partial acceptance rolls both back, and chunks chain through the
      carried next-token distribution exactly as plain chunks chain
      through logits. Greedy token streams are bitwise identical to
      ``draft=None``.
    """

    def __init__(self, model, params, *, slots: int = 4, max_len: int = 256,
                 sampling: SamplingConfig = SamplingConfig(),
                 chunk: int = 8, seed: int = 0, mesh=None,
                 dispatch_depth: int = 2, prefill_batch: int = 1,
                 bucket_prompts: bool = True, max_queue: int | None = None,
                 clock: Callable[[], float] | None = None,
                 on_token: Callable[[int, list, bool], None] | None = None,
                 draft=None, spec_k: int = 4, counters: bool = False):
        if not runtime.conforms(model):
            raise TypeError(
                f"{type(model).__name__} does not implement the DecodeStep "
                "serving contract (cache_defs / prefill / decode_step)")
        if mesh is not None and getattr(model, "mesh", None) is None:
            if not hasattr(model, "with_mesh"):
                raise TypeError(f"{type(model).__name__} has no sharded "
                                "decode path (with_mesh)")
            model = model.with_mesh(mesh)
        if getattr(model, "mesh", None) is not None:
            # the permuted dist layout is invisible in the tree structure;
            # reject packed-but-unpartitioned params before they decode
            # garbage silently
            from ..dist import check_partitioned
            check_partitioned(params, model.mesh)
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.sampling = sampling
        self.chunk = chunk
        self.prefill_batch = max(1, prefill_batch)
        self.bucket_prompts = bucket_prompts
        self.on_token = on_token
        self._clock = clock or time.perf_counter
        if draft is not None and mesh is not None:
            raise ValueError("speculative decoding does not compose with "
                             "sharded serving (mesh) yet")
        self.draft = draft
        self.spec_k = spec_k
        # bucketed joint prefill needs BOTH models' length-masked paths
        self._length_aware = runtime.prefill_accepts_length(model) and (
            draft is None or runtime.prefill_accepts_length(draft.model))

        # ----- device-resident shared state (chained across dispatches)
        self.cache = model.init_cache(slots, max_len)
        # per-leaf batch axis: cache leaves may be layer-stacked (scanned
        # blocks put 'layers' ahead of 'batch'), so the slot join can't
        # assume axis 0 — the cache defs carry the logical axis names.
        from ..models import layers as L
        self._batch_axes = jax.tree.map(
            lambda d: d.axes.index("batch"),
            model.cache_defs(slots, max_len), is_leaf=L.is_pspec)
        self.pos = jnp.zeros((slots,), jnp.int32)
        self.logits = None                      # (slots, 1, V), lazy init
        self.rng = jax.random.key(seed)
        self.done = jnp.ones((slots,), bool)    # idle slots sit done
        self.budget = jnp.zeros((slots,), jnp.int32)

        # ----- host-side traffic machinery
        self.pool = SlotPool(slots)
        self._aq = AdmissionQueue(max_queue)
        self._dq = DispatchQueue(dispatch_depth)
        self._live: dict[int, SlotInfo] = {}    # uid → seated record
        self._collected: dict[int, list[int]] = {}
        self._drops: list[Finished] = []        # shed at submit time
        self._next_uid = 0
        self.steps_dispatched = 0               # device dispatches (chunks)
        # steps the current occupant's cache has accumulated (prefill +
        # chunk decodes) — the divisor for per-slot occupancy accounting
        self.slot_steps = np.zeros(slots, np.int64)

        # ----- on-device observability counters (repro.obs): a small
        # named vector chained across dispatches exactly like done/budget;
        # disabled (None) keeps the jitted chunk fn byte-identical
        self._counter_names = (obs_counters.counter_names(model)
                               if counters else None)
        self.counters_dev = (obs_counters.zeros(self._counter_names)
                             if counters else None)
        self._counters_host: dict | None = None

        self._prefill = jax.jit(model.prefill, static_argnames=("max_len",))
        self._join = jax.jit(self._join_impl, donate_argnums=(0, 1, 2, 3, 4))
        self._chunk_fn = jax.jit(
            self._chunk_obs_impl if counters else self._chunk_impl,
            donate_argnums=(1,))
        self._evict_fn = jax.jit(
            lambda done, s: done.at[s].set(True), donate_argnums=(0,))

        # ----- speculative-decode state (per-slot draft state + the
        # carried next-token distribution replacing chained logits)
        if draft is not None:
            from ..models import layers as L2
            self.dstate = draft.init_cache(slots, max_len)
            self._d_batch_axes = jax.tree.map(
                lambda d: d.axes.index("batch"),
                draft.model.cache_defs(slots, max_len), is_leaf=L2.is_pspec)
            self.probs = None                   # (slots, V) fp32, lazy init
            self._rounds = jnp.zeros((slots,), jnp.int32)
            self._drafted = jnp.zeros((slots,), jnp.int32)
            self._accepted = jnp.zeros((slots,), jnp.int32)
            self._dprefill = jax.jit(draft.prefill,
                                     static_argnames=("max_len",))
            self._join_spec = jax.jit(self._join_spec_impl,
                                      donate_argnums=(0, 1, 2, 3, 4, 5))
            self._chunk_spec_fn = jax.jit(
                self._chunk_spec_obs_impl if counters
                else self._chunk_spec_impl, donate_argnums=(2, 3))

    # ------------------------------------------------------------- device
    def _join_impl(self, cache, logits, pos, done, budget, pre_cache,
                   pre_logits, slots_v, lengths_v, budgets_v):
        """Scatter a batch of prefill results into the shared state at
        ``slots_v`` and arm those slots (done=False, fresh budget)."""
        def upd(c, p, ax):
            cm = jnp.moveaxis(c, ax, 0)
            pm = jnp.moveaxis(p.astype(c.dtype), ax, 0)
            return jnp.moveaxis(cm.at[slots_v].set(pm), 0, ax)

        cache = jax.tree.map(upd, cache, pre_cache, self._batch_axes)
        logits = logits.at[slots_v].set(pre_logits.astype(logits.dtype))
        pos = pos.at[slots_v].set(lengths_v)
        done = done.at[slots_v].set(False)
        budget = budget.at[slots_v].set(budgets_v)
        return cache, logits, pos, done, budget

    def _chunk_impl(self, params, cache, logits, pos, rng, done, budget):
        toks, st = runtime.decode_loop(
            self.model, params, cache, logits, pos, rng, self.chunk,
            self.sampling, done=done, budget=budget, limit=self.max_len)
        # budget lives on device so the next chunk can dispatch before
        # this one's tokens reach the host
        st["budget"] = jnp.maximum(budget - st["emitted"], 0)
        return toks, st

    def _chunk_obs_impl(self, params, cache, logits, pos, rng, done,
                        budget, counters):
        """The counter-threaded chunk: the plain chunk body plus in-graph
        counter folds (pure extra adds — same dispatch, same sync)."""
        toks, st = self._chunk_impl(params, cache, logits, pos, rng, done,
                                    budget)
        st["counters"] = obs_counters.chunk_update(
            self._counter_names, counters, st, self.chunk)
        return toks, st

    def _join_spec_impl(self, cache, dstate, probs, pos, done, budget,
                        pre_cache, pre_dstate, pre_logits, slots_v,
                        lengths_v, budgets_v):
        """The speculative join: scatter target cache rows AND draft state
        rows at ``slots_v``, and seed the carried distribution from the
        prefill logits (the spec loop's analogue of chained logits)."""
        from .sampling import sample_dist

        def upd(c, p, ax):
            cm = jnp.moveaxis(c, ax, 0)
            pm = jnp.moveaxis(p.astype(c.dtype), ax, 0)
            return jnp.moveaxis(cm.at[slots_v].set(pm), 0, ax)

        cache = jax.tree.map(upd, cache, pre_cache, self._batch_axes)
        dstate = jax.tree.map(upd, dstate, pre_dstate, self._d_batch_axes)
        probs = probs.at[slots_v].set(
            sample_dist(pre_logits[:, -1], self.sampling))
        pos = pos.at[slots_v].set(lengths_v)
        done = done.at[slots_v].set(False)
        budget = budget.at[slots_v].set(budgets_v)
        return cache, dstate, probs, pos, done, budget

    def _chunk_spec_impl(self, params, dparams, cache, dstate, probs, pos,
                         rng, done, budget):
        from ..spec import spec_decode_loop
        toks, st = spec_decode_loop(
            self.model, self.draft, params, dparams, cache, dstate, probs,
            pos, rng, self.chunk, self.spec_k, self.sampling, done=done,
            budget=budget, limit=self.max_len)
        st["budget"] = jnp.maximum(budget - st["emitted"], 0)
        return toks, st

    def _chunk_spec_obs_impl(self, params, dparams, cache, dstate, probs,
                             pos, rng, done, budget, counters):
        toks, st = self._chunk_spec_impl(params, dparams, cache, dstate,
                                         probs, pos, rng, done, budget)
        st["counters"] = obs_counters.chunk_update(
            self._counter_names, counters, st, self.chunk)
        return toks, st

    # -------------------------------------------------------------- admit
    def submit(self, prompt, max_new: int, extra=None, *,
               deadline: float | None = None, priority: int = 0) -> int:
        """Queue one request. prompt: (S,) or (1, S) int tokens.

        ``deadline`` is an absolute clock() time — past-deadline requests
        are shed from the queue and evicted from slots; ``priority``
        orders admission (higher first). Overload (a full ``max_queue``)
        sheds the worst waiting request with reason ``"rejected"``.
        """
        prompt = np.asarray(prompt, np.int32)
        if prompt.ndim == 1:
            prompt = prompt[None, :]
        if prompt.shape[1] >= self.max_len:
            raise ValueError(f"prompt length {prompt.shape[1]} ≥ max_len "
                             f"{self.max_len}")
        uid = self._next_uid
        self._next_uid += 1
        shed = self._aq.push(QueuedRequest(
            uid, prompt, prompt.shape[1], max_new, extra, deadline,
            priority, self._clock()))
        if shed is not None:
            self._drops.append(Finished(shed.uid, np.zeros(0, np.int32),
                                        shed.prompt_len, "rejected"))
        return uid

    @property
    def active_slots(self) -> list[int]:
        return self.pool.active()

    @property
    def _slot_uid(self) -> list[int | None]:
        return self.pool.owners()

    @property
    def pending(self) -> int:
        return len(self._aq)

    @property
    def busy(self) -> bool:
        """Whether step() still has work (queued, decoding, in flight, or
        undelivered shed notices)."""
        return bool(self._aq or self._live or self._dq or self._drops)

    def _admit(self, now: float) -> list[Finished]:
        """Admit queued requests into free slots: expire stale ones, group
        by prefill bucket, prefill (batched where exact), join."""
        events = [Finished(r.uid, np.zeros(0, np.int32), r.prompt_len,
                           "expired") for r in self._aq.expire(now)]
        if not (self.pool.free_count and self._aq):
            return events
        with obs_trace.span("sched.admit", queued=len(self._aq),
                            free=self.pool.free_count):
            while self.pool.free_count and self._aq:
                batch = self._aq.pop(min(self.pool.free_count,
                                         self.prefill_batch))
                for group in self._group(batch):
                    self._prefill_join(group, now)
        return events

    def _group(self, batch: list[QueuedRequest]):
        """Split admitted requests into joint-prefill groups: same padded
        bucket, no extra conditioning. Models without length-aware
        prefill (or with bucketing off) prefill one by one at exact
        length — batching would change their prefill numerics."""
        if not (self._length_aware and self.bucket_prompts):
            return [[r] for r in batch]
        groups: dict[int, list] = {}
        singles: list[list] = []
        for r in batch:
            if r.extra is not None:
                singles.append([r])
            else:
                key = _bucket(r.prompt_len, self.max_len - 1)
                groups.setdefault(key, []).append(r)
        return list(groups.values()) + singles

    def _prefill_join(self, group: list[QueuedRequest], now: float):
        k = len(group)
        lengths = [r.prompt_len for r in group]
        budgets = [min(r.max_new, self.max_len - r.prompt_len)
                   for r in group]
        slots = self.pool.alloc_many(k)
        assert len(slots) == k      # _admit popped at most free_count
        if self._length_aware and self.bucket_prompts:
            width = _bucket(max(lengths), self.max_len - 1)
            padded = np.zeros((k, width), np.int32)
            for i, r in enumerate(group):
                padded[i, :r.prompt_len] = r.prompt[0]
            lp, pre_cache = self._prefill(
                self.params, jnp.asarray(padded), max_len=self.max_len,
                extra=group[0].extra,
                length=jnp.asarray(lengths, jnp.int32))
        else:
            lp, pre_cache = self._prefill(
                self.params, jnp.asarray(group[0].prompt),
                max_len=self.max_len, extra=group[0].extra)
        slots_v = jnp.asarray(slots, jnp.int32)
        lengths_v = jnp.asarray(lengths, jnp.int32)
        budgets_v = jnp.asarray(budgets, jnp.int32)
        if self.draft is not None:
            if self._length_aware and self.bucket_prompts:
                _, pre_d = self._dprefill(
                    self.draft.params, jnp.asarray(padded),
                    max_len=self.max_len, length=lengths_v)
            else:
                _, pre_d = self._dprefill(
                    self.draft.params, jnp.asarray(group[0].prompt),
                    max_len=self.max_len)
            if self.probs is None:
                self.probs = jnp.zeros((self.slots, lp.shape[-1]),
                                       jnp.float32)
            (self.cache, self.dstate, self.probs, self.pos, self.done,
             self.budget) = self._join_spec(
                self.cache, self.dstate, self.probs, self.pos, self.done,
                self.budget, pre_cache, pre_d, lp, slots_v, lengths_v,
                budgets_v)
        else:
            if self.logits is None:
                self.logits = jnp.zeros((self.slots,) + lp.shape[1:],
                                        lp.dtype)
            self.cache, self.logits, self.pos, self.done, self.budget = \
                self._join(self.cache, self.logits, self.pos, self.done,
                           self.budget, pre_cache, lp, slots_v, lengths_v,
                           budgets_v)
        for r, slot, budget in zip(group, slots, budgets):
            info = SlotInfo(r.uid, r.prompt_len, budget, r.deadline,
                            r.priority, admitted_at=now, extra=r.extra)
            self.pool.seat(slot, info)
            self._live[r.uid] = info
            self._collected[r.uid] = []
            self.slot_steps[slot] = r.prompt_len    # join reset the cache

    # ------------------------------------------------------------- decode
    def _dispatch(self):
        """Enqueue one decode chunk on the chained device state. Returns
        immediately — tokens are a future harvested later."""
        owners = self.pool.owners()
        obs = self._counter_names is not None
        with obs_trace.span("sched.dispatch", seq=self.steps_dispatched,
                            active=len(self._live)):
            if self.draft is not None:
                args = (self.params, self.draft.params, self.cache,
                        self.dstate, self.probs, self.pos, self.rng,
                        self.done, self.budget)
                toks, st = self._chunk_spec_fn(
                    *(args + (self.counters_dev,) if obs else args))
                self.cache, self.dstate = st["cache"], st["dstate"]
                self.probs = st["probs"]
                self._rounds = self._rounds + st["rounds"]
                self._drafted = self._drafted + st["drafted"]
                self._accepted = self._accepted + st["accepted"]
            else:
                args = (self.params, self.cache, self.logits, self.pos,
                        self.rng, self.done, self.budget)
                toks, st = self._chunk_fn(
                    *(args + (self.counters_dev,) if obs else args))
                self.cache, self.logits = st["cache"], st["logits"]
            if obs:
                self.counters_dev = st["counters"]
            self.pos, self.rng = st["pos"], st["rng"]
            self.done, self.budget = st["done"], st["budget"]
            self.steps_dispatched += 1
            # every slot steps through decode_step each chunk (done slots
            # included — lockstep semantics), so all caches advance
            self.slot_steps += self.chunk
            self._dq.push(toks, owners,
                          counters=self.counters_dev if obs else None)

    def _harvest(self, now: float) -> list:
        """Sync the oldest in-flight chunk's tokens and account them to
        the requests that owned each slot at ITS dispatch time."""
        inflight = self._dq.harvest()
        if inflight is None:
            return []
        with obs_trace.span("sched.harvest", seq=inflight.seq):
            toks_np = np.asarray(inflight.tokens)   # the one host sync
            if inflight.counters is not None:
                # the chunk is host-materialized by the sync above; its
                # counter snapshot reads out with no extra sync point
                self._counters_host = obs_counters.harvest(
                    self._counter_names, inflight.counters)
        events: list = []
        evictions: list[int] = []
        for slot, uid in enumerate(inflight.owners):
            info = self._live.get(uid) if uid is not None else None
            if info is None:        # idle, or finished before this sync
                continue
            fresh: list[int] = []
            for t in toks_np[slot]:
                if info.remaining <= 0:
                    break
                t = int(t)
                fresh.append(t)
                info.remaining -= 1
                info.emitted += 1
                if self.sampling.stops and t == self.sampling.eos_id:
                    info.remaining = 0
            if fresh:
                out = self._collected[uid]
                first = not out
                out.extend(fresh)
                if self.on_token is not None:
                    self.on_token(uid, fresh, first)
                events.append(TokenEvent(uid, fresh, first))
            if info.remaining <= 0:
                events.append(self._finish(uid, "done"))
            elif info.deadline is not None and now > info.deadline:
                # past-deadline occupant: free the slot, freeze it on
                # device so chunks dispatched from here on skip it
                evictions.append(info.slot)
                events.append(self._finish(uid, "expired"))
        if evictions:
            with obs_trace.span("sched.evict", slots=len(evictions)):
                self.done = self._evict_fn(
                    self.done, jnp.asarray(evictions, jnp.int32))
        return events

    def _finish(self, uid: int, reason: str) -> Finished:
        info = self._live.pop(uid)
        self.pool.free(info.slot)
        toks = np.asarray(self._collected.pop(uid), np.int32)
        return Finished(uid, toks, info.prompt_len, reason)

    # -------------------------------------------------------------- drive
    def _step_events(self) -> list:
        """One scheduler iteration: deliver shed notices, admit, keep the
        dispatch pipeline full, harvest the oldest chunk. Returns the
        step's TokenEvent/Finished stream."""
        events: list = self._drops
        self._drops = []
        events += self._admit(self._clock())
        if self._live:
            while self._dq.want_dispatch:
                self._dispatch()
        if self._dq:
            events += self._harvest(self._clock())
        return events

    def step(self) -> list[Finished]:
        """Admit, decode one chunk, harvest, evict. Returns the requests
        that completed (or were shed/expired) this step; per-token output
        flows through ``on_token`` / ``events()``."""
        return [e for e in self._step_events() if isinstance(e, Finished)]

    def events(self):
        """Incremental-results iterator: yields ``TokenEvent``s as chunks
        are harvested and ``Finished`` as requests complete, until the
        engine drains."""
        while self.busy:
            yield from self._step_events()

    def run(self) -> dict[int, np.ndarray]:
        """Drive until queue, slots, and the dispatch pipeline drain.
        Returns {uid: tokens} (shed/expired requests included, with
        whatever prefix they produced)."""
        results: dict[int, np.ndarray] = {}
        for ev in self.events():
            if isinstance(ev, Finished):
                results[ev.uid] = ev.tokens
        return results

    def spec_stats(self) -> dict | None:
        """Cumulative speculative-round accounting (one host sync):
        ``rounds``/``drafted``/``accepted`` totals plus the aggregate
        ``acceptance_rate`` = accepted / drafted. None without a draft."""
        if self.draft is None:
            return None
        rounds = int(np.sum(np.asarray(self._rounds)))
        drafted = int(np.sum(np.asarray(self._drafted)))
        accepted = int(np.sum(np.asarray(self._accepted)))
        return dict(rounds=rounds, drafted=drafted, accepted=accepted,
                    acceptance_rate=accepted / max(drafted, 1))

    def counters(self) -> dict | None:
        """The harvested on-device counter dict (None when the engine was
        built without ``counters=True``).

        While chunks are in flight this returns the snapshot read at the
        last harvest (no sync). Once the pipeline drains — the normal
        read point, after ``run()`` — the chained vector's final value is
        identical to the last harvested snapshot, and reading it forces
        nothing new (every feeding dispatch already synced)."""
        if self._counter_names is None:
            return None
        if self._dq and self._counters_host is not None:
            return dict(self._counters_host)
        return obs_counters.harvest(self._counter_names, self.counters_dev)
