"""llama3.2-3b [dense] — 28L d3072 24H (GQA kv=8) ff8192 v128256.
[hf:meta-llama/Llama-3.2-1B; unverified]
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama3.2-3b",
    family="dense",
    num_layers=28,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=128256,
    norm="rmsnorm",
    activation="silu_glu",
    rope_theta=500000.0,
))
