"""recurrentgemma-9b [hybrid] — 38L d4096 16H (MQA kv=1) ff12288 v256000.

RG-LRU + local attention in a 1:2 ratio: block pattern
(rec, rec, attn_local) × 12 periods + 2 remainder rec blocks = 38 layers,
window 2048. Sub-quadratic → runs long_500k. [arXiv:2402.19427; unverified]
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    norm="rmsnorm",
    activation="gelu_glu",
    rope_theta=10000.0,
    block_pattern=("rec", "rec", "attn_local"),
    window=2048,
    d_rnn=4096,
    conv_width=4,
    subquadratic=True,
    grad_accum=2,
))
