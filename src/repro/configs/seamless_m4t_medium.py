"""seamless-m4t-medium [audio] — enc-dec, 12L each side, d1024 16H (kv=16)
ff4096 v256206. The audio frontend is a STUB: input_specs provides
precomputed frame embeddings (B, S_enc, d). train_4k splits the 4096-token
budget 2048 enc / 2048 dec; decode shapes use a 3072-frame encoder memory.
[arXiv:2308.11596; hf]
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="seamless-m4t-medium",
    family="audio",
    num_layers=12,              # decoder layers
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    norm="layernorm",
    activation="gelu",
    rope_theta=10000.0,
    encdec=True,
    enc_layers=12,
    enc_len=3072,
    layout="dp",   # ≤1.3B params: DP beats TP16 (EXPERIMENTS.md §Perf cell 1)
))
