"""rwkv6-7b [ssm] — Finch: 32L d4096 attention-free, ff14336 v65536,
data-dependent decay linear attention (64 heads × 64 dims). Sub-quadratic →
runs long_500k. [arXiv:2404.05892; hf]
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=64,
    num_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab_size=65536,
    norm="layernorm",
    activation="sq_relu",       # rwkv channel-mix uses relu²
    block_pattern=("rwkv",),
    rwkv_chunk=128,
    subquadratic=True,
    grad_accum=2,
))
