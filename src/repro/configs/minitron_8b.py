"""minitron-8b [dense] — 32L d4096 32H (GQA kv=8) ff16384 v256000,
pruned nemotron (squared-ReLU). [arXiv:2407.14679; hf]
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="minitron-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=256000,
    norm="layernorm",
    activation="sq_relu",
    rope_theta=10000.0,
    grad_accum=2,
))
