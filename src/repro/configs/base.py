"""Architecture + shape configuration dataclasses and the shape grid."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class BRDSConfig:
    """Row-balanced dual-ratio sparsity settings for a model.

    family A = feed-forward-ish weights (paper's W_x, pruned harder);
    family B = recurrent/attention-ish weights (paper's W_h, pruned softer).
    """
    enabled: bool = False
    overall_sparsity: float = 0.875       # paper's hardware evaluation point
    spar_a: float = 0.875                 # W_x-analogue ratio
    spar_b: float = 0.875                 # W_h-analogue ratio


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    activation: str = "silu_glu"      # silu_glu | gelu_glu | gelu | sq_relu
    qk_norm: bool = False
    rope_theta: float = 500000.0
    # block pattern, repeated over depth: attn | attn_local | rec | rwkv
    block_pattern: tuple = ("attn",)
    window: int | None = None         # local attention window
    # MoE
    moe: bool = False
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    aux_loss_coef: float = 0.01
    moe_group: int = 1024             # GShard routing group size (tokens)
    # encoder-decoder (audio family)
    encdec: bool = False
    enc_layers: int = 0
    enc_len: int = 3072               # encoder memory length for decode shapes
    # VLM
    num_patches: int = 0              # patch-embed slots prepended to text
    # tensor-parallel head padding: store q/o projections with this many
    # heads (dummy heads are hard-masked → mathematically inert); needed
    # when num_heads doesn't divide the model axis AND the attention params
    # are too large to replicate (llava: 56 → 64).
    pad_heads_to: int = 0
    # RWKV / RG-LRU
    d_rnn: int = 0                    # defaults to d_model
    conv_width: int = 4
    rwkv_chunk: int = 128
    # capabilities
    subquadratic: bool = False        # can run long_500k
    # parallelism layout: 'tp' (model axis = tensor/expert parallel) or
    # 'dp' (model axis folded into data parallelism; small models)
    layout: str = "tp"
    kv_quant: bool = False            # int8 KV cache (+per-pos/head scales)
    # numerics / training system
    dtype: str = "bfloat16"
    remat: bool = True
    grad_accum: int = 1
    zero1: bool = True                # shard optimizer state over data axis
    grad_compression: bool = False    # int8 DP gradient compression
    brds: BRDSConfig = BRDSConfig()
    # attention blocking (dry-run-lowered online-softmax path)
    block_q: int = 512
    block_kv: int = 1024

    def with_(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    @property
    def jdtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]

    @property
    def rnn_width(self) -> int:
        return self.d_rnn or self.d_model


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                          # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def runnable(arch: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether an (arch × shape) cell is runnable, with a reason if not."""
    if shape.name == "long_500k" and not arch.subquadratic:
        return False, ("pure full-attention arch: 512k dense causal attention "
                       "is quadratic — skipped per DESIGN.md §4")
    return True, ""


_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    from . import ALL  # noqa: F401  — populate registry
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list[str]:
    from . import ALL  # noqa: F401
    return sorted(_REGISTRY)
