"""nemotron-4-340b [dense] — 96L d18432 96H (GQA kv=8) ff73728 v256000,
squared-ReLU MLP, layernorm. [arXiv:2402.16819; unverified]
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="nemotron-4-340b",
    family="dense",
    num_layers=96,
    d_model=18432,
    num_heads=96,
    num_kv_heads=8,
    head_dim=192,
    d_ff=73728,
    vocab_size=256000,
    norm="layernorm",
    activation="sq_relu",
    rope_theta=10000.0,
    grad_accum=8,
))
