"""qwen3-0.6b [dense] — 28L d1024 16H (GQA kv=8, head_dim 128 projected up)
ff3072 v151936, qk_norm. [hf:Qwen/Qwen3-8B; hf]
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen3-0.6b",
    family="dense",
    num_layers=28,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    vocab_size=151936,
    norm="rmsnorm",
    activation="silu_glu",
    qk_norm=True,
    rope_theta=1000000.0,
    layout="dp",   # ≤1.3B params: DP beats TP16 (EXPERIMENTS.md §Perf cell 1)
))
