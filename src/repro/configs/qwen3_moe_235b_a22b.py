"""qwen3-moe-235b-a22b [moe] — 94L d4096 64H (GQA kv=4) expert-ff1536
v151936, MoE 128 experts top-8, qk_norm. [hf:Qwen/Qwen3-30B-A3B; hf]
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,                  # per-expert intermediate size
    vocab_size=151936,
    norm="rmsnorm",
    activation="silu_glu",
    qk_norm=True,
    rope_theta=1000000.0,
    moe=True,
    num_experts=128,
    experts_per_token=8,
    capacity_factor=1.25,
    grad_accum=4,
))
