"""Architecture registry: one module per assigned arch + the paper's LSTMs."""
from .base import (ArchConfig, BRDSConfig, ShapeConfig, SHAPES, runnable,
                   get_arch, list_archs, register)
from . import (
    llava_next_34b,
    qwen3_moe_235b_a22b,
    granite_moe_1b_a400m,
    seamless_m4t_medium,
    recurrentgemma_9b,
    nemotron_4_340b,
    qwen3_0_6b,
    minitron_8b,
    llama3_2_3b,
    rwkv6_7b,
)

ALL = [
    llava_next_34b.CONFIG,
    qwen3_moe_235b_a22b.CONFIG,
    granite_moe_1b_a400m.CONFIG,
    seamless_m4t_medium.CONFIG,
    recurrentgemma_9b.CONFIG,
    nemotron_4_340b.CONFIG,
    qwen3_0_6b.CONFIG,
    minitron_8b.CONFIG,
    llama3_2_3b.CONFIG,
    rwkv6_7b.CONFIG,
]

ARCH_NAMES = [c.name for c in ALL]


def smoke_config(name: str) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests: few layers, small
    width/vocab/experts, short window — structure preserved."""
    full = get_arch(name)
    pat_len = len(full.block_pattern)
    return full.with_(
        num_layers=max(2 * pat_len, pat_len + 1),  # ≥1 period + remainder
        d_model=128,
        num_heads=4,
        num_kv_heads=min(full.num_kv_heads, 2) if full.num_kv_heads > 1 else 1,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        num_experts=min(full.num_experts, 8) if full.moe else 0,
        experts_per_token=min(full.experts_per_token, 2) if full.moe else 0,
        enc_layers=2 if full.encdec else 0,
        enc_len=64,
        num_patches=16 if full.num_patches else 0,
        window=32 if full.window else None,
        d_rnn=128 if full.d_rnn else 0,
        rwkv_chunk=16,
        grad_accum=1,
        block_q=64,
        block_kv=64,
        dtype="float32",
    )
