"""llava-next-34b [vlm] — 60L d7168 56H (GQA kv=8) ff20480 v64000.

anyres tiling: the vision frontend is a STUB; input_specs provides
precomputed patch embeddings for 5 anyres tiles × 576 patches = 2880 slots
prepended to the text sequence. [hf:llava-hf/llava-v1.6-mistral-7b-hf;
unverified]
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llava-next-34b",
    family="vlm",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    norm="rmsnorm",
    activation="silu_glu",
    rope_theta=500000.0,
    num_patches=2880,          # 5 anyres tiles × 576 patches
    pad_heads_to=64,           # TP padding: 56 heads ∤ model=16 (see base.py)
    grad_accum=4,
))
