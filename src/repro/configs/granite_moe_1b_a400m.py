"""granite-moe-1b-a400m [moe] — 24L d1024 16H (GQA kv=8) expert-ff512
v49155, MoE 32 experts top-8. [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,                   # per-expert intermediate size
    vocab_size=49155,
    norm="rmsnorm",
    activation="silu_glu",
    rope_theta=10000.0,
    moe=True,
    num_experts=32,
    experts_per_token=8,
    capacity_factor=1.25,
    layout="dp",   # ≤1.3B params: DP beats TP16 (EXPERIMENTS.md §Perf cell 1)
))
