"""Packed storage for row-balanced sparse matrices.

The accelerator stores only the non-zeros: each row of a row-balanced sparse
matrix has exactly K non-zeros, so values pack densely into a (rows, K)
array. Column positions are stored with the paper's *relative addressing*
(EIE-style [22]): the delta between consecutive non-zero column indices in a
row, which fits a narrow integer type. The kernel reconstructs absolute
columns with a cumulative sum in VMEM — index HBM traffic shrinks 2–4×
vs int32 absolute indices.

This is a pytree, so it flows through jit/pjit/scan and can be sharded.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .sparsity import row_balanced_mask, keep_count

__all__ = ["RowBalancedSparse", "pack", "unpack", "pack_from_dense",
           "pad_packed"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class RowBalancedSparse:
    """Packed row-balanced sparse matrix of logical shape (rows, ncols).

    values:  (rows, K)  non-zero values, row-major by ascending column
    deltas:  (rows, K)  delta-encoded column indices (delta_dtype);
                        col[r, 0] = deltas[r, 0]; col[r, j] = col[r, j-1] + deltas[r, j]
    ncols:   static logical column count
    pad:     static count of zero rows appended by ``pad_packed`` so the
             row axis is a kernel-block multiple; ``rows`` stays logical
    block_rows: static block size the padding targeted (None = unpadded)
    """

    values: jnp.ndarray
    deltas: jnp.ndarray
    ncols: int = dataclasses.field(metadata=dict(static=True))
    pad: int = dataclasses.field(default=0, metadata=dict(static=True))
    block_rows: int | None = dataclasses.field(
        default=None, metadata=dict(static=True))

    @property
    def rows(self) -> int:
        return self.values.shape[0] - self.pad

    def logical(self) -> "RowBalancedSparse":
        """Padding-free view (slices off ``pad_packed``'s zero rows)."""
        if not self.pad:
            return self
        r = self.rows
        return dataclasses.replace(self, values=self.values[:r],
                                   deltas=self.deltas[:r], pad=0,
                                   block_rows=None)

    @property
    def K(self) -> int:
        return self.values.shape[1]

    @property
    def sparsity(self) -> float:
        return 1.0 - self.K / self.ncols

    def col_indices(self) -> jnp.ndarray:
        """Absolute column indices (rows, K), int32."""
        return jnp.cumsum(self.deltas.astype(jnp.int32), axis=1)

    def memory_bytes(self) -> dict:
        """Storage accounting for the Table-1 analogue benchmark (logical
        rows only — ``pad_packed``'s zero rows are a layout artifact)."""
        n = self.rows * self.K
        v = n * self.values.dtype.itemsize
        i = n * self.deltas.dtype.itemsize
        dense = self.rows * self.ncols * self.values.dtype.itemsize
        return dict(values=v, indices=i, total=v + i, dense_equiv=dense,
                    ratio=(v + i) / dense)


def _delta_dtype(ncols: int, k: int) -> np.dtype:
    """Narrowest signed int that can hold the worst-case column delta.

    The first delta is an absolute column (up to ncols-1); subsequent deltas
    are gaps (≥1). Worst case is ncols-1 in both conventions.
    """
    if ncols - 1 <= 127:
        return np.dtype(np.int8)
    if ncols - 1 <= 32767:
        return np.dtype(np.int16)
    return np.dtype(np.int32)


def pack(w: jnp.ndarray, mask: jnp.ndarray) -> RowBalancedSparse:
    """Pack a dense matrix + row-balanced mask into packed form.

    Every row of ``mask`` must have the same popcount K (row-balanced
    invariant); this is asserted on concrete inputs.
    """
    rows, ncols = w.shape
    counts = np.asarray(jnp.sum(mask, axis=1))
    k = int(counts[0])
    if not (counts == k).all():
        raise ValueError("mask is not row-balanced: per-row nnz " f"{np.unique(counts)}")
    # Per row: the column indices where mask is True, ascending. Masked-out
    # positions sort to the end (key = ncols), and exactly K survive.
    colgrid = jnp.broadcast_to(jnp.arange(ncols), (rows, ncols))
    key = jnp.where(mask, colgrid, ncols)
    order = jnp.argsort(key, axis=1)[:, :k]            # (rows, K) ascending cols
    vals = jnp.take_along_axis(w, order, axis=1)
    cols = order.astype(jnp.int32)
    deltas = jnp.diff(cols, axis=1, prepend=jnp.zeros((rows, 1), jnp.int32))
    dd = _delta_dtype(ncols, k)
    return RowBalancedSparse(values=vals, deltas=deltas.astype(dd), ncols=ncols)


def pack_from_dense(w: jnp.ndarray, sparsity: float) -> RowBalancedSparse:
    """Row-balanced prune + pack in one step."""
    return pack(w, row_balanced_mask(w, sparsity))


def unpack(s: RowBalancedSparse) -> jnp.ndarray:
    """Reconstruct the dense (rows, ncols) matrix (zeros where pruned)."""
    s = s.logical()
    cols = s.col_indices()
    rows = s.rows
    out = jnp.zeros((rows, s.ncols), s.values.dtype)
    rowgrid = jnp.broadcast_to(jnp.arange(rows)[:, None], cols.shape)
    return out.at[rowgrid, cols].set(s.values)


def pad_packed(s, block_rows: int = 256):
    """Pre-pad a packed struct's row axis to a kernel-block multiple.

    The kernel wrappers (``kernels.ops``) need the row count to be a
    multiple of their grid block; historically they re-padded
    values/deltas inside every jitted step call — a per-token copy of the
    whole weight stream on the decode hot path. Padding once at
    pack/prepare time (zero rows appended, ``pad``/``block_rows`` recorded
    on the struct) lets the wrappers consume the arrays as-is.

    Accepts :class:`RowBalancedSparse` and its quantized twin
    (``repro.quant.RowBalancedSparseQ8`` — its per-row ``scales`` pad
    along too). Padded rows are all-zero: their cumsum'd columns gather
    x[:, 0] against zero values/scales, contributing exact zeros that the
    wrappers slice away. No-op when the rows already divide ``block_rows``
    or the struct is already padded for it.
    """
    r = s.rows
    eff = min(block_rows, r) if r else block_rows
    pad = (-r) % eff
    if s.pad == pad and (s.block_rows in (None, eff) if pad == 0
                         else s.block_rows == eff):
        return dataclasses.replace(s, block_rows=eff)
    s = s.logical()
    widths = ((0, pad), (0, 0))
    kw = dict(values=jnp.pad(s.values, widths),
              deltas=jnp.pad(s.deltas, widths),
              pad=pad, block_rows=eff)
    if hasattr(s, "scales"):
        kw["scales"] = jnp.pad(s.scales, (0, pad))
    return dataclasses.replace(s, **kw)
