"""Pruning mask generators — the paper's §3 plus the three baselines it
compares against (Fig. 2): unstructured (fine-grained global), block sparse,
bank-balanced (BBS [9]), and the proposed row-balanced pattern.

All functions are pure jnp, jit-compatible, and return boolean masks with
True = keep. Row-balanced masks keep EXACTLY the same number of elements in
every row (the paper's invariant that makes the hardware work balanced).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "keep_count",
    "row_balanced_mask",
    "unstructured_mask",
    "block_mask",
    "bank_balanced_mask",
    "apply_mask",
    "sparsity_of",
]


def keep_count(ncols: int, sparsity: float) -> int:
    """Number of elements kept per row at a given sparsity ratio.

    Matches the paper: prune the smallest ``Spar%`` of each row → keep
    ``ncols - round(Spar * ncols)``. Always keeps at least 1.
    """
    k = ncols - int(round(float(sparsity) * ncols))
    return max(1, min(ncols, k))


def _topk_mask_lastdim(scores: jnp.ndarray, k: int) -> jnp.ndarray:
    """Boolean mask keeping the k largest entries along the last dim.

    Uses double-argsort ranking so ties are broken deterministically by
    position and EXACTLY k entries are kept per row.
    """
    order = jnp.argsort(-scores, axis=-1, stable=True)
    ranks = jnp.argsort(order, axis=-1, stable=True)
    return ranks < k


def row_balanced_mask(w: jnp.ndarray, sparsity: float) -> jnp.ndarray:
    """The paper's row-balanced pattern (Fig. 2e / Fig. 3 pseudo-code).

    Prunes the smallest ``sparsity`` fraction of |w| along the LAST dim of
    every row independently → every row keeps exactly
    ``keep_count(ncols, sparsity)`` non-zeros. Leading dims are batched.
    """
    if w.ndim < 2:
        raise ValueError(f"row_balanced_mask expects ≥2-D weight, got {w.shape}")
    k = keep_count(w.shape[-1], sparsity)
    return _topk_mask_lastdim(jnp.abs(w), k)


def unstructured_mask(w: jnp.ndarray, sparsity: float) -> jnp.ndarray:
    """Fine-grained global magnitude pruning (Fig. 2b)."""
    n = w.size
    k = max(1, n - int(round(float(sparsity) * n)))
    flat = jnp.abs(w).reshape(-1)
    return _topk_mask_lastdim(flat, k).reshape(w.shape)


def block_mask(w: jnp.ndarray, sparsity: float, block: tuple[int, int] = (4, 4)) -> jnp.ndarray:
    """Block sparsity (Fig. 2c): score each b×b block by its mean |w| and
    prune the lowest-scoring blocks globally. Pads rows/cols to a multiple of
    the block size (padding never wins the keep contest: -inf score).
    """
    br, bc = block
    r, c = w.shape
    rp, cp = (-r) % br, (-c) % bc
    wp = jnp.pad(jnp.abs(w), ((0, rp), (0, cp)))
    nbr, nbc = (r + rp) // br, (c + cp) // bc
    blocks = wp.reshape(nbr, br, nbc, bc).transpose(0, 2, 1, 3)
    score = blocks.mean(axis=(-1, -2))
    # padding-only blocks get -inf so they are pruned first
    valid = jnp.ones((r, c), bool)
    validp = jnp.pad(valid, ((0, rp), (0, cp)))
    frac_valid = validp.reshape(nbr, br, nbc, bc).transpose(0, 2, 1, 3).mean(axis=(-1, -2))
    score = jnp.where(frac_valid > 0, score, -jnp.inf)
    nblocks = nbr * nbc
    kblocks = max(1, nblocks - int(round(float(sparsity) * nblocks)))
    bm = _topk_mask_lastdim(score.reshape(-1), kblocks).reshape(nbr, nbc)
    full = jnp.repeat(jnp.repeat(bm, br, axis=0), bc, axis=1)
    return full[:r, :c]


def bank_balanced_mask(w: jnp.ndarray, sparsity: float, num_banks: int = 4) -> jnp.ndarray:
    """Bank-balanced sparsity (BBS [9], Fig. 2d): split each row into
    ``num_banks`` equal banks, fine-grained prune inside each bank.
    """
    r, c = w.shape
    if c % num_banks != 0:
        raise ValueError(f"ncols {c} not divisible by num_banks {num_banks}")
    bank = c // num_banks
    k = keep_count(bank, sparsity)
    banked = jnp.abs(w).reshape(r, num_banks, bank)
    m = _topk_mask_lastdim(banked, k)
    return m.reshape(r, c)


def apply_mask(w: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    return jnp.where(mask, w, jnp.zeros_like(w))


def sparsity_of(mask: jnp.ndarray) -> float:
    return float(1.0 - np.asarray(jnp.mean(mask.astype(jnp.float32))))
