"""Accuracy metrics used by the paper's evaluations."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["perplexity", "token_accuracy", "binary_accuracy", "cross_entropy"]


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Mean token cross-entropy. logits (..., V), labels (...) int.

    Sharding-friendly formulation: the label logit is extracted with a
    one-hot einsum (not take_along_axis — gathers along a model-sharded
    vocab dim replicate the full logits under SPMD), and logsumexp uses
    plain reductions which partition into small psums.
    """
    logits = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1))
    logz = m + jnp.log(jnp.sum(jnp.exp(logits - m[..., None]), axis=-1))
    lab1h = jax.lax.stop_gradient(
        jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32))
    ll = jnp.einsum("...v,...v->...", logits, lab1h)
    nll = logz - ll
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)
    return jnp.mean(nll)


def perplexity(mean_nll: float) -> float:
    """PTB metric: exp of the mean per-token negative log likelihood."""
    return float(np.exp(mean_nll))


def token_accuracy(logits, labels, mask=None) -> float:
    pred = jnp.argmax(logits, axis=-1)
    hit = (pred == labels).astype(jnp.float32)
    if mask is not None:
        return float(jnp.sum(hit * mask) / jnp.maximum(jnp.sum(mask), 1))
    return float(jnp.mean(hit))


def binary_accuracy(logits, labels) -> float:
    """IMDB-style binary sentiment classification accuracy."""
    pred = (logits[..., 0] > 0).astype(labels.dtype)
    return float(jnp.mean((pred == labels).astype(jnp.float32)))
