"""The BRDS dual-ratio search algorithm (paper Fig. 5).

Searches the (Spar_x, Spar_h) plane subject to a designer-given overall
sparsity target OS:

  phase 1 (lines 1-6):  ramp both ratios 0 → OS in steps of alpha, pruning and
                        retraining at each step; the result is NN_{P,I}.
  phase 2 (lines 7-14): from NN_{P,I}, walk Spar_x up / Spar_h down in steps
                        (delta_x, delta_h), prune+retrain+eval each tuple.
  phase 3 (lines 15-23): reload NN_{P,I}, walk the opposite direction.
  return the tuple with the best model accuracy (lines 24).

The algorithm is model-agnostic: it drives three callbacks —

  prune_fn(params, spar_x, spar_h)   -> (params, masks)   row-balanced prune
                                        of the two weight families
  retrain_fn(params, masks)          -> params             masked retraining
  eval_fn(params)                    -> float              higher = better

so it applies unchanged to the paper's LSTM and to any of the assigned
transformer architectures (families per DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Any

__all__ = ["BRDSResult", "brds_search", "execution_time_model"]


@dataclasses.dataclass
class BRDSResult:
    best_accuracy: float
    best_spar_x: float
    best_spar_h: float
    best_params: Any
    history: list  # list of dicts: phase, spar_x, spar_h, accuracy


def brds_search(
    params: Any,
    *,
    overall_sparsity: float,
    prune_fn: Callable,
    retrain_fn: Callable,
    eval_fn: Callable,
    alpha: float = 0.25,
    delta_x: float = 0.05,
    delta_h: float = 0.05,
    max_ratio: float = 0.99,
) -> BRDSResult:
    """Run the Fig.-5 search. Ratios are fractions in [0, 1]."""
    os_ = float(overall_sparsity)
    history: list[dict] = []

    # ---- phase 1: ramp to the initial point NN_{P,I} (lines 1-6)
    spar_x = spar_h = 0.0
    while spar_x < os_ and spar_h < os_:
        spar_x = min(os_, spar_x + alpha)
        spar_h = min(os_, spar_h + alpha)
        params, masks = prune_fn(params, spar_x, spar_h)
        params = retrain_fn(params, masks)
    nn_pi = params
    acc = float(eval_fn(params))
    best = BRDSResult(acc, spar_x, spar_h, params, history)
    history.append(dict(phase="init", spar_x=spar_x, spar_h=spar_h, accuracy=acc))

    def _walk(params, sx, sh, dx, dh, phase):
        nonlocal best
        while 0.0 < sx + dx <= max_ratio and 0.0 <= sh - dh < max_ratio:
            sx = min(max_ratio, sx + dx)
            sh = max(0.0, sh - dh)
            params, masks = prune_fn(params, sx, sh)
            params = retrain_fn(params, masks)
            acc = float(eval_fn(params))
            history.append(dict(phase=phase, spar_x=sx, spar_h=sh, accuracy=acc))
            if acc > best.best_accuracy:
                best = BRDSResult(acc, sx, sh, params, history)
            if sx >= max_ratio or sh <= 0.0:
                break
        return params

    # ---- phase 2: Spar_x up, Spar_h down (lines 7-14)
    _walk(nn_pi, spar_x, spar_h, +delta_x, +delta_h, phase="x_up")
    # ---- phase 3: reload NN_{P,I}; Spar_x down, Spar_h up (lines 15-23)
    _walk(nn_pi, spar_x, spar_h, -delta_x, -delta_h, phase="h_up")

    best.history = history
    return best


def execution_time_model(os_: float, alpha: float, delta_x: float,
                         delta_h: float, ept: float, n_re: int) -> dict:
    """The paper's cost model, eqs. (3)-(6). Ratios in percent or fractions
    (consistent units). Returns the per-phase and total times."""
    ex1 = (os_ / alpha) * ept * n_re
    ex2 = min((1.0 - os_) / delta_x, os_ / delta_h) * ept * n_re
    ex3 = min((1.0 - os_) / delta_h, os_ / delta_x) * ept * n_re
    return dict(ex1=ex1, ex2=ex2, ex3=ex3, total=ex1 + ex2 + ex3)
