"""DEPRECATED shim — the BRDS Fig.-5 search now lives in ``repro.sparse``.

``repro.sparse.brds_search`` walks SparsityPolicy objects
(``policy_at(spar_x, spar_h)`` + ``retrain_fn(params, plan, masks)``).
This module keeps the legacy raw-callback signature
(``prune_fn(params, spar_x, spar_h)`` / ``retrain_fn(params, masks)``)
for out-of-tree callers, implemented over the same plane walk.
"""
from __future__ import annotations

import warnings
from typing import Any, Callable

from ..sparse.search import (BRDSResult, execution_time_model, plane_search)

__all__ = ["BRDSResult", "brds_search", "execution_time_model"]


def brds_search(
    params: Any,
    *,
    overall_sparsity: float,
    prune_fn: Callable,
    retrain_fn: Callable,
    eval_fn: Callable,
    alpha: float = 0.25,
    delta_x: float = 0.05,
    delta_h: float = 0.05,
    max_ratio: float = 0.99,
) -> BRDSResult:
    """Legacy callback-based search. Prefer ``repro.sparse.brds_search``."""
    warnings.warn(
        "repro.core.brds_search is deprecated; use repro.sparse.brds_search "
        "with a SparsityPolicy factory (policy_at=) instead",
        DeprecationWarning, stacklevel=2)

    def visit(p, sx, sh):
        p, masks = prune_fn(p, sx, sh)
        return retrain_fn(p, masks), None

    return plane_search(params, overall_sparsity=overall_sparsity,
                        visit=visit, eval_fn=eval_fn, alpha=alpha,
                        delta_x=delta_x, delta_h=delta_h,
                        max_ratio=max_ratio)
