"""repro.core — the paper's contribution: row-balanced dual-ratio sparsity."""
from .sparsity import (
    row_balanced_mask,
    unstructured_mask,
    block_mask,
    bank_balanced_mask,
    apply_mask,
    sparsity_of,
    keep_count,
)
from .packing import RowBalancedSparse, pack, unpack, pack_from_dense
from .brds import brds_search, BRDSResult, execution_time_model
from . import metrics
