"""Roofline analysis from compiled HLO artifacts.

`compiled.cost_analysis()` counts each while-loop body ONCE (trip counts are
not statically multiplied), so a scanned-layers model under-reports by ~L×.
This module parses the post-optimization HLO text instead and:

  1. splits it into computations,
  2. recovers while-loop trip counts from loop-condition constants
     (scan lowers to `compare(counter, constant(N)), direction=LT`),
  3. builds a call graph (while body/cond, call, fusion, conditional) with
     multiplicative loop multiplicity,
  4. sums dot/convolution FLOPs and collective bytes × multiplicity.

Collective byte → wire-time conversion uses ring formulas:
  all-reduce      2·size·(n-1)/n
  all-gather      size·(n-1)/n      (size = full gathered output)
  reduce-scatter  size·(n-1)/n      (size = full input)
  all-to-all      size·(n-1)/n
  collective-permute  size
All divided by n_links·link_bw when converted to seconds (per-chip view).

The three roofline terms (per step, per chip):
  compute    = FLOPs_total   / (chips × peak_flops)
  memory     = HBM bytes     / (chips × hbm_bw)     [analytic traffic model]
  collective = Σ wire bytes  / (chips × ici_bw)
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Any

import numpy as np

from . import hw

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def shape_bytes(s: str) -> int:
    """Bytes of one HLO shape string (sums tuple elements)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(s):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_elems(s: str) -> int:
    m = _SHAPE_RE.search(s)
    if not m:
        return 0
    dims = m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _shape_dims(s: str) -> list[int]:
    m = _SHAPE_RE.search(s)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class Computation:
    name: str
    lines: list
    # op name -> full shape string (output)
    shapes: dict


_COMP_HEAD = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_OP_DEF = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)(.*)$")


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        if line.endswith("{") and ("->" in line or line.startswith("ENTRY")):
            m = _COMP_HEAD.match(line.strip())
            if m:
                cur = Computation(m.group(1), [], {})
                comps[cur.name] = cur
                continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        cur.lines.append(line)
        m = _OP_DEF.match(line)
        if m:
            cur.shapes[m.group(1)] = m.group(2)
    return comps


_CALLED = re.compile(r"(?:body|condition|to_apply|calls|branch_computations)="
                     r"\{?%?([\w\.\-]+(?:,\s*%?[\w\.\-]+)*)\}?")
_WHILE = re.compile(r"=\s*\S+\s+while\(.*body=%?([\w\.\-]+).*")
_CONST = re.compile(r"%?([\w\.\-]+)\s*=\s*s32\[\]\s+constant\((\d+)\)")
_COMPARE = re.compile(r"compare\(([^)]*)\),?.*direction=(\w+)")
_KNOWN_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def while_trip_count(cond: Computation) -> int:
    """Recover scan trip count from the loop condition computation."""
    consts = dict(_CONST.findall("\n".join(cond.lines)))
    for line in cond.lines:
        m = _COMPARE.search(line)
        if not m:
            continue
        ops = [o.strip().lstrip("%") for o in m.group(1).split(",")]
        for o in ops:
            if o in consts:
                return int(consts[o])
    # fall back: any s32 constant in the condition
    if consts:
        return max(int(v) for v in consts.values())
    return 1


def multiplicities(comps: dict[str, Computation],
                   entry: str) -> dict[str, float]:
    """Execution count per computation, loop-aware."""
    mult: dict[str, float] = {c: 0.0 for c in comps}
    if entry not in comps:
        return mult
    mult[entry] = 1.0
    # topological-ish fixed point (call graphs are acyclic in HLO)
    for _ in range(64):
        changed = False
        for name, comp in comps.items():
            base = mult.get(name, 0.0)
            if base == 0.0:
                continue
            for line in comp.lines:
                wm = re.search(r"while\(", line)
                body = re.search(r"body=%?([\w\.\-]+)", line)
                cond = re.search(r"condition=%?([\w\.\-]+)", line)
                if wm and body and cond:
                    ktc = _KNOWN_TRIP.search(line)
                    if ktc:
                        trips = int(ktc.group(1))
                    elif cond.group(1) in comps:
                        trips = while_trip_count(comps[cond.group(1)])
                    else:
                        trips = 1
                    for tgt, k in ((body.group(1), trips),
                                   (cond.group(1), trips + 1)):
                        if tgt in comps:
                            newv = base * k
                            if mult[tgt] < newv:
                                mult[tgt] = newv
                                changed = True
                    continue
                for m in _CALLED.finditer(line):
                    for tgt in re.split(r",\s*", m.group(1)):
                        tgt = tgt.lstrip("%")
                        if tgt in comps and mult[tgt] < base:
                            mult[tgt] = base
                            changed = True
        if not changed:
            break
    return mult


# ------------------------------------------------------------- collectives

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_RG_SETS = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_RG_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _group_size(line: str, default: int) -> int:
    m = _RG_SETS.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _RG_IOTA.search(line)
    if m:
        return int(m.group(2))
    return default


def collective_stats(comps, mult, n_devices: int) -> dict:
    """Sum payload and ring-wire bytes per collective kind (whole program,
    loop-aware). Wire bytes follow the ring formulas in the module doc."""
    out = {k: {"payload": 0.0, "wire": 0.0, "count": 0.0}
           for k in _COLL_KINDS}
    for name, comp in comps.items():
        m = mult.get(name, 0.0)
        if m <= 0:
            continue
        for line in comp.lines:
            mo = _OP_DEF.match(line)
            if not mo:
                continue
            kind = mo.group(3)
            if kind.endswith("-start"):
                kind = kind[:-6]
            if kind not in _COLL_KINDS:
                continue
            size = shape_bytes(mo.group(2))
            # XLA's CPU float-normalization pass promotes bf16 reductions
            # to f32 ("...clone_promoted"); a TPU build reduces native bf16.
            # Count promoted reduces at their true (half) wire size.
            if "promoted" in line:
                size //= 2
            n = _group_size(line, n_devices)
            if kind == "all-reduce":
                wire = 2 * size * (n - 1) / max(n, 1)
            elif kind == "collective-permute":
                wire = size
            else:
                wire = size * (n - 1) / max(n, 1)
            out[kind]["payload"] += m * size
            out[kind]["wire"] += m * wire
            out[kind]["count"] += m
    return out


# ------------------------------------------------------------------ flops

_DOT_OPERANDS = re.compile(r"dot\(([^)]*)\)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

# Quantized dots must not be costed at the bf16 peak. Two signals mark a
# dot as integer arithmetic: narrow-int operands (pre-optimization HLO /
# TPU builds keep s8 operands into the MXU) or an integer OUTPUT dtype —
# XLA's CPU backend normalizes s8-operand dots to convert→s32-dot, which
# erases the operand signal but keeps the s32 accumulator type; float
# models never emit integer-output dots, so the union is a sound
# classifier either way.
_INT8_DTYPES = {"s8", "u8", "s4", "u4"}
_INT_DOT_OUT = {"s8", "u8", "s16", "u16", "s32", "u32"}


def _lhs_shape_str(line, comp) -> str:
    """The lhs operand's shape string of a dot line ('' if unknown)."""
    ops = _DOT_OPERANDS.search(line)
    if not ops:
        return ""
    # Operands separate on ", " — shape dim commas ("f32[8,16]")
    # have no space, so a plain str.split(",") truncates the lhs
    # shape and drops contraction dims.
    lhs = ops.group(1).split(", ")[0].strip()
    # Post-opt HLO writes operands as "<shape> %name"; read the
    # inline shape, falling back to the defining op for bare
    # "%name" operands.
    if _SHAPE_RE.search(lhs):
        return lhs
    lhs_name = lhs.split()[-1].lstrip("%")
    return comp.shapes.get(lhs_name, "")


def _is_int_dot(line, out_shape: str, comp) -> bool:
    """Integer-arithmetic (quantized) dot: narrow-int lhs operand or an
    integer output/accumulator dtype."""
    sm = _SHAPE_RE.search(out_shape)
    if sm and sm.group(1) in _INT_DOT_OUT:
        return True
    lm = _SHAPE_RE.search(_lhs_shape_str(line, comp))
    return bool(lm and lm.group(1) in _INT8_DTYPES)


def dot_flops(comps, mult, int_only: bool = False) -> float:
    """Loop-aware dot FLOPs. ``int_only`` restricts to integer-arithmetic
    (quantized) dots — see ``_is_int_dot``; False counts every dot."""
    total = 0.0
    for name, comp in comps.items():
        m = mult.get(name, 0.0)
        if m <= 0:
            continue
        for line in comp.lines:
            mo = _OP_DEF.match(line)
            if not mo or mo.group(3) != "dot":
                continue
            if int_only and not _is_int_dot(line, mo.group(2), comp):
                continue
            lhs = _lhs_shape_str(line, comp)
            out_elems = shape_elems(mo.group(2))
            cm = _CONTRACT.search(line)
            contract = 1
            if cm and cm.group(1):
                dims = _shape_dims(lhs)
                for idx in cm.group(1).split(","):
                    i = int(idx)
                    if i < len(dims):
                        contract *= dims[i]
            total += m * 2.0 * out_elems * contract
    return total


def int8_dot_flops(comps, mult) -> float:
    """The integer-dot subset of ``dot_flops``, costed at
    ``hw.PEAK_INT8_OPS`` by the roofline terms. (int16 fixed-point dots
    are approximated at the same rate — the quantized path's dominant
    deployment is int8.)"""
    return dot_flops(comps, mult, int_only=True)


# ------------------------------------------------------------- top level

@dataclasses.dataclass
class RooflineReport:
    flops_hlo: float            # loop-aware parsed dot flops (whole program)
    flops_cost_analysis: float  # XLA cost_analysis (body-once undercount)
    collectives: dict           # per-kind payload/wire bytes
    collective_wire_bytes: float
    n_devices: int
    flops_int8: float = 0.0     # int8-operand subset of flops_hlo

    def terms(self, hbm_bytes_per_chip: float, chips: int) -> dict:
        # post-SPMD HLO shapes are PER-DEVICE, so parsed flops / wire bytes
        # are already per-chip quantities. int8 dots run at the int8 MXU
        # peak (2x bf16) — costing a quantized model at the bf16 rate would
        # overstate its compute time.
        compute_s = ((self.flops_hlo - self.flops_int8)
                     / hw.PEAK_BF16_FLOPS
                     + self.flops_int8 / hw.PEAK_INT8_OPS)
        memory_s = hbm_bytes_per_chip / hw.HBM_BW
        coll_s = self.collective_wire_bytes / hw.ICI_BW
        dom = max(compute_s, memory_s, coll_s)
        which = ("compute" if dom == compute_s else
                 "memory" if dom == memory_s else "collective")
        return dict(compute_s=compute_s, memory_s=memory_s,
                    collective_s=coll_s, bound=which,
                    step_s=dom)


def analyze_hlo(text: str, n_devices: int,
                cost_analysis: dict | None = None) -> RooflineReport:
    comps = parse_hlo(text)
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HEAD.match(line.strip())
            if m:
                entry = m.group(1)
                break
    if entry is None:
        entry = next(iter(comps), "")
    mult = multiplicities(comps, entry)
    colls = collective_stats(comps, mult, n_devices)
    wire = sum(v["wire"] for v in colls.values())
    return RooflineReport(
        flops_hlo=dot_flops(comps, mult),
        flops_cost_analysis=(cost_analysis or {}).get("flops", 0.0),
        collectives=colls,
        collective_wire_bytes=wire,
        n_devices=n_devices,
        flops_int8=int8_dot_flops(comps, mult),
    )


# ---------------------------------------------------- analytic flops model

def model_flops(arch, shape) -> dict:
    """MODEL_FLOPS: 6·N·D for training (2·N·D inference) + attention terms.
    N = active params (MoE: routed active only), D = tokens processed."""
    from .configs.base import ArchConfig, ShapeConfig
    from .models import build_model
    m = build_model(arch)
    n_total = m.param_count()
    # active params: replace expert count by experts_per_token
    if arch.moe:
        act = arch.with_(num_experts=arch.experts_per_token)
        n_active = build_model(act).param_count()
    else:
        n_active = n_total
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        tokens = B * S
        factor = 6.0
    elif shape.kind == "prefill":
        tokens = B * S
        factor = 2.0
    else:  # decode: one token per sequence
        tokens = B * 1
        factor = 2.0
    core = factor * n_active * tokens
    # attention score/value flops (not in 6ND): 2·2·B·S·ctx·H·Dh per layer
    attn_layers = sum(1 for k in arch.block_pattern if k.startswith("attn"))
    n_attn = (arch.num_layers * attn_layers / max(len(arch.block_pattern), 1)
              if not arch.encdec else arch.num_layers + (arch.enc_layers or 0))
    Dh, Hq = arch.head_dim, arch.num_heads
    if shape.kind == "decode":
        ctx = S
        attn = 2 * 2 * B * 1 * ctx * Hq * Dh * n_attn * (factor / 2.0)
    else:
        ctx = S / 2  # causal average
        attn = 2 * 2 * B * S * ctx * Hq * Dh * n_attn * (factor / 2.0)
    if arch.window:
        attn = min(attn, 2 * 2 * B * (S if shape.kind != "decode" else 1)
                   * arch.window * Hq * Dh * n_attn * (factor / 2.0))
    return dict(total=core + attn, core=core, attention=attn,
                n_params=n_total, n_active=n_active)


def analytic_hbm_bytes(arch, shape, chips: int, opt: bool = True) -> dict:
    """Per-chip HBM traffic per step (documented formula, DESIGN.md §6).

    train: weights read 2× (fwd+bwd) + grads written + Adam m,v read+write
           (fp32) + remat block-input activations written+read.
    prefill: weights 1× + kv cache write + activations stream.
    decode: weights 1× + KV cache read at current length + state r/w.
    kv_quant: int8 cache + per-(pos,head) f32 scale (1 + 4/head_dim B/elem).
    """
    from .models import build_model
    m = build_model(arch)
    n = m.param_count()
    B, S = shape.global_batch, shape.seq_len
    bytes_w = 2  # bf16 weights
    kv_bytes = (1.0 + 4.0 / arch.head_dim) if arch.kv_quant else bytes_w
    d = arch.d_model
    L = arch.num_layers + (arch.enc_layers if arch.encdec else 0)
    if shape.kind == "train":
        weights = n * bytes_w * 2                  # fwd + bwd read
        grads = n * 4
        optim = n * 4 * 4 if opt else 0            # m,v read+write fp32
        acts = L * B * S * d * bytes_w * 2          # remat block inputs w+r
        total = weights + grads + optim + acts
    elif shape.kind == "prefill":
        weights = n * bytes_w
        kv = (L * B * S * arch.num_kv_heads * arch.head_dim * 2 * kv_bytes
              if not _attn_free(arch) else 0)
        acts = L * B * S * d * bytes_w
        total = weights + kv + acts
    else:
        weights = n * bytes_w
        kv = (L * B * S * arch.num_kv_heads * arch.head_dim * 2 * kv_bytes
              if not _attn_free(arch) else
              B * arch.num_heads * arch.head_dim ** 2 * 4 * 2)
        if arch.window and not _attn_free(arch):
            kv = min(kv, L * B * arch.window * arch.num_kv_heads
                     * arch.head_dim * 2 * kv_bytes)
        total = weights + kv
    return dict(total_per_chip=total / chips, weights=weights / chips,
                global_total=total)


def _attn_free(arch) -> bool:
    return all(not k.startswith("attn") for k in arch.block_pattern) \
        and not arch.encdec
