"""repro.obs — observability for the serving stack.

Four layers, one goal: make the paper's efficiency claims measurable
*per serve run*, not just per offline benchmark:

- ``trace``: host-side span tracer (Chrome-trace/Perfetto export) with a
  near-zero-cost disabled path, instrumented across ServeEngine, the
  continuous-batching scheduler, repro.spec, and launch.pipeline.
- ``counters``: on-device counter vector (delta fired columns, spec
  acceptance, decode steps, emitted tokens) threaded through the
  scheduler's chained chunk dispatches and harvested at its EXISTING
  host syncs — no extra device→host transfers.
- ``metrics``: counter/gauge/histogram registry with Prometheus-text and
  JSON dumps, absorbing traffic records, spec stats, and device counters.
- ``scorecard``: achieved vs. roofline-bound effective GOPS and
  bytes/token, joining harvested counters with ``repro.roofline``.
- ``collectives``: per-step collective inventory for repro.dist meshes
  (the one-all-gather-per-layer-per-step claim, measured).
"""
import importlib

__all__ = ["collectives", "counters", "metrics", "scorecard", "trace",
           "MetricsRegistry", "enable_tracing", "span", "traced"]

_LAZY = {"MetricsRegistry": ("metrics", "MetricsRegistry"),
         "enable_tracing": ("trace", "enable"),
         "span": ("trace", "span"),
         "traced": ("trace", "traced")}
_SUBMODULES = ("collectives", "counters", "metrics", "scorecard", "trace")


def __getattr__(name):
    # lazy: the scheduler imports this package on every serve, and
    # ``python -m repro.obs.trace`` must not double-import its own module
    if name in _SUBMODULES:
        return importlib.import_module("." + name, __name__)
    if name in _LAZY:
        mod, attr = _LAZY[name]
        return getattr(importlib.import_module("." + mod, __name__), attr)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
