"""Effective-GOPS scorecard: harvested counters × roofline bounds.

The paper's headline figure is *effective* throughput — dense-equivalent
ops per second, which sparsity multiplies without touching the clock
(Table 2: effective-throughput gain ≈ 1/(1−sparsity)). This module turns
one serve run's harvested counters (``obs.counters``) plus its packed
params into that figure and places it against the decode roofline:

- ``effective_gops``  = 2 · dense-equivalent recurrent-cell MACs/token ·
  achieved tok/s — the paper's effective-GOPS axis;
- ``achieved_gops``   = 2 · MACs actually executed / wall — packed MACs,
  further scaled by delta occupancy when fired-column counters are
  present (exactly ``occupancy_report``'s MAC weighting: a fired column
  of family F costs rows_F · K_F / N_F MACs);
- ``bound_toks_per_s`` = B · HBM_BW / weight-stream bytes — the
  memory-roofline decode bound (`benchmarks/decode_throughput` idiom:
  every decode step streams the packed recurrent weights once);
- ``bound_effective_gops`` / ``roofline_gap`` place the run against that
  bound on the same effective axis;
- ``bytes_per_token`` = weight-stream bytes (per lockstep row-step the
  whole packed cell streams once, amortized over the B slots decoding).

Accounting scope matches ``occupancy_report`` and the pack report:
recurrent-cell weights (W_x, W_h) only — embedding row gathers and the
LM head are excluded from both the MAC and the byte ledger on every
line, so ratios stay apples-to-apples.
"""
from __future__ import annotations

from .. import hw
from . import counters as _counters

__all__ = ["layer_geometry", "weight_stream_bytes", "build", "render"]


def _is_packed(leaf) -> bool:
    return hasattr(leaf, "K") and hasattr(leaf, "ncols")


def layer_geometry(params) -> list[dict]:
    """Per-layer MAC/shape ledger from an LSTM param tree (dense, packed,
    or q8-packed leaves): rows/ncols/K for W_x and W_h, plus the dense
    and packed MACs per token they imply (K = ncols when dense)."""
    out = []
    for lp in params["layers"]:
        entry = {}
        for fam, key in (("x", "w_x"), ("h", "w_h")):
            w = lp[key]
            if _is_packed(w):
                rows, ncols, k = w.rows, w.ncols, w.K
            else:
                rows, ncols = w.shape
                k = ncols
            entry[f"rows_{fam}"] = rows
            entry[f"ncols_{fam}"] = ncols
            entry[f"k_{fam}"] = k
        entry["dense_macs"] = (entry["rows_x"] * entry["ncols_x"]
                               + entry["rows_h"] * entry["ncols_h"])
        entry["packed_macs"] = (entry["rows_x"] * entry["k_x"]
                                + entry["rows_h"] * entry["k_h"])
        out.append(entry)
    return out


def weight_stream_bytes(params) -> int:
    """Bytes of recurrent-cell weights one decode step streams from HBM:
    packed leaves count values+indices (+scales), dense leaves their full
    array — the ``pack_report["packed_bytes"]`` figure, recomputed from
    the params actually being served."""
    total = 0
    for lp in params["layers"]:
        for key in ("w_x", "w_h"):
            w = lp[key]
            if hasattr(w, "memory_bytes"):
                total += int(w.memory_bytes()["total"])
            else:
                total += int(w.nbytes)
    return total


def build(params, counters: dict, wall_s: float, *, batch: int = 1,
          bytes_per_step: int | None = None,
          step_sum: float | None = None) -> dict:
    """One serve run's scorecard.

    Parameters
    ----------
    params : pytree
        The params the run served (dense or packed — geometry and byte
        accounting adapt).
    counters : dict
        Harvested counter dict (``obs.counters.harvest``/``from_state``):
        ``tokens`` drives throughput; ``fired_*`` gauges, when present,
        scale executed MACs by the measured delta occupancy.
    wall_s : float
        Driver wall time over which ``counters`` accumulated.
    batch : int
        Lockstep width (slots) — scales the roofline bound: one weight
        stream serves all B rows' steps.
    bytes_per_step : int, optional
        Override the weight-stream byte estimate (e.g. a
        ``pack_report["packed_bytes"]`` that saw pre-padding shapes).
    step_sum : float, optional
        Total per-row steps the fired-column gauges accumulated over
        (``occupancy_report``'s basis: Σ over rows of prefill + decode
        steps — ``sched.slot_steps.sum()`` for the scheduler,
        B·(prompt+generated) for a lockstep run). Enables the occupancy
        lines; without it they are omitted rather than guessed.
    """
    geo = layer_geometry(params)
    dense_macs = sum(g["dense_macs"] for g in geo)
    packed_macs = sum(g["packed_macs"] for g in geo)
    tokens = float(counters.get("tokens", 0.0))
    steps = float(counters.get("decode_steps", 0.0))
    wall_s = max(float(wall_s), 1e-12)
    toks_per_s = tokens / wall_s

    fx, fh = _counters.fired_totals(counters)
    if fx:
        # delta-gated: MACs executed = Σ fired columns × that family's
        # per-column packed cost (occupancy_report's exact weighting)
        executed_macs = sum(
            fxl * g["rows_x"] * g["k_x"] / g["ncols_x"]
            + fhl * g["rows_h"] * g["k_h"] / g["ncols_h"]
            for fxl, fhl, g in zip(fx, fh, geo))
    else:
        executed_macs = tokens * packed_macs

    nbytes = int(bytes_per_step if bytes_per_step is not None
                 else weight_stream_bytes(params))
    bound_toks = batch * hw.HBM_BW / max(nbytes, 1)
    out = {
        "tokens": int(tokens),
        "decode_steps": int(steps),
        "wall_s": round(wall_s, 6),
        "toks_per_s": round(toks_per_s, 3),
        "dense_macs_per_token": int(dense_macs),
        "packed_macs_per_token": int(packed_macs),
        "executed_macs": round(executed_macs, 1),
        "achieved_gops": round(2.0 * executed_macs / wall_s / 1e9, 6),
        "effective_gops": round(2.0 * dense_macs * tokens / wall_s / 1e9, 6),
        "bytes_per_token": nbytes,
        "bound_toks_per_s": round(bound_toks, 1),
        "bound_effective_gops": round(2.0 * dense_macs * bound_toks / 1e9,
                                      3),
        "roofline_gap": round(bound_toks / max(toks_per_s, 1e-12), 2),
        "bound": "memory",
    }
    if counters.get("spec_drafted"):
        out["spec_acceptance_rate"] = round(
            counters["spec_accepted"] / counters["spec_drafted"], 4)
    if fx and step_sum:
        denom_x = sum(step_sum * g["ncols_x"] for g in geo)
        denom_h = sum(step_sum * g["ncols_h"] for g in geo)
        out["occupancy_x"] = round(sum(fx) / max(denom_x, 1), 4)
        out["occupancy_h"] = round(sum(fh) / max(denom_h, 1), 4)
    return out


def render(card: dict) -> str:
    """Human-readable scorecard block for launch.serve --scorecard."""
    lines = [
        "scorecard:",
        f"  tokens {card['tokens']} in {card['wall_s']:.3f}s "
        f"-> {card['toks_per_s']:.1f} tok/s",
        f"  effective GOPS {card['effective_gops']:.3f} "
        f"(dense-equiv {card['dense_macs_per_token']} MACs/token)",
        f"  achieved GOPS {card['achieved_gops']:.3f} "
        f"(executed {card['executed_macs']:.3e} MACs)",
        f"  roofline bound {card['bound_toks_per_s']:.0f} tok/s "
        f"= {card['bound_effective_gops']:.1f} effective GOPS "
        f"({card['bound']}-bound, {card['bytes_per_token']} B/token) "
        f"-> gap {card['roofline_gap']:.1f}x",
    ]
    if "occupancy_x" in card:
        lines.append(f"  delta occupancy x={card['occupancy_x']:.1%} "
                     f"h={card['occupancy_h']:.1%}")
    if "spec_acceptance_rate" in card:
        lines.append(f"  spec acceptance "
                     f"{card['spec_acceptance_rate']:.1%}")
    return "\n".join(lines)
