"""Metrics registry: counters/gauges/histograms with Prometheus + JSON dump.

The serving stack's scattered accounting — ``traffic.metrics`` request
records, the scheduler's ``spec_stats()``, the harvested on-device
counter vector (``obs.counters``) — lands in one registry that exports
either Prometheus text exposition (scrape-ready) or a JSON object
(``BENCH``-style machine-readable). Absorb helpers keep the producers
decoupled: they only ever hand over plain records/dicts.

>>> reg = MetricsRegistry()
>>> reg.counter("requests_total", "requests served").inc(3)
>>> reg.gauge("slots_active").set(2)
>>> h = reg.histogram("ttft_ms", buckets=(1, 10, 100))
>>> h.observe(5.0)
>>> "requests_total 3" in reg.to_prometheus()
True
>>> reg.to_json()["ttft_ms"]["count"]
1
"""
from __future__ import annotations

import json
import math

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "DEFAULT_LATENCY_BUCKETS_MS"]

# powers-of-~3 ms ladder: sub-ms kernels through multi-second queueing
DEFAULT_LATENCY_BUCKETS_MS = (1, 2, 5, 10, 20, 50, 100, 200, 500,
                              1000, 2000, 5000)


class Counter:
    """Monotonically increasing count."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self.value = 0.0

    def inc(self, amount: float = 1.0):
        if amount < 0:
            raise ValueError(f"counter {self.name}: negative inc {amount}")
        self.value += amount

    def to_json(self):
        return {"type": "counter", "value": self.value}

    def expose(self) -> list[str]:
        return [f"{self.name} {_fmt(self.value)}"]


class Gauge:
    """Point-in-time value (may go up or down)."""

    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self.value = 0.0

    def set(self, value: float):
        self.value = float(value)

    def to_json(self):
        return {"type": "gauge", "value": self.value}

    def expose(self) -> list[str]:
        return [f"{self.name} {_fmt(self.value)}"]


class Histogram:
    """Cumulative-bucket histogram (Prometheus semantics: each bucket
    counts observations ≤ its upper bound; +Inf is implicit)."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets=DEFAULT_LATENCY_BUCKETS_MS):
        self.name, self.help = name, help
        self.buckets = tuple(sorted(float(b) for b in buckets))
        self.counts = [0] * (len(self.buckets) + 1)      # +Inf last
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float):
        value = float(value)
        if math.isnan(value):
            return                   # NaN observations are dropped, not
        self.sum += value            # propagated into the exposition
        self.count += 1
        for i, ub in enumerate(self.buckets):
            if value <= ub:
                self.counts[i] += 1
                return
        self.counts[-1] += 1

    def to_json(self):
        cum = []
        running = 0
        for c in self.counts:
            running += c
            cum.append(running)
        return {"type": "histogram", "sum": self.sum, "count": self.count,
                "buckets": [{"le": ub, "count": n}
                            for ub, n in zip(self.buckets, cum[:-1])]
                + [{"le": "+Inf", "count": cum[-1]}]}

    def expose(self) -> list[str]:
        lines = []
        running = 0
        for ub, c in zip(self.buckets, self.counts):
            running += c
            lines.append(f'{self.name}_bucket{{le="{_fmt(ub)}"}} {running}')
        running += self.counts[-1]
        lines.append(f'{self.name}_bucket{{le="+Inf"}} {running}')
        lines.append(f"{self.name}_sum {_fmt(self.sum)}")
        lines.append(f"{self.name}_count {self.count}")
        return lines


def _fmt(v: float) -> str:
    return str(int(v)) if float(v).is_integer() else repr(float(v))


class MetricsRegistry:
    """Named metric store; get-or-create accessors, two export formats."""

    def __init__(self):
        self._metrics: dict[str, object] = {}

    def _get(self, cls, name, *args, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = cls(name, *args, **kw)
            self._metrics[name] = m
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{type(m).__name__}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets=DEFAULT_LATENCY_BUCKETS_MS) -> Histogram:
        return self._get(Histogram, name, help, buckets)

    def __contains__(self, name):
        return name in self._metrics

    def __getitem__(self, name):
        return self._metrics[name]

    # -------------------------------------------------------- absorbers
    def absorb_traffic(self, records, summary: dict | None = None):
        """Fold ``traffic.metrics.RequestRecord``s (and optionally their
        ``summarize`` output) into request counters + latency histograms.
        Records with no TTFT/TPOT (rejected, 0/1-token completions)
        contribute to outcome counts only — never NaN observations."""
        outcomes = self.counter("serve_requests_total",
                                "requests with a final outcome")
        tok = self.counter("serve_tokens_total", "tokens emitted")
        ttft = self.histogram("serve_ttft_ms", "time to first token")
        tpot = self.histogram("serve_tpot_ms", "per-token latency")
        for r in records:
            outcomes.inc()
            self.counter(f"serve_requests_{r.reason or 'unknown'}").inc()
            tok.inc(r.tokens)
            if r.ttft is not None:
                ttft.observe(r.ttft * 1e3)
            if r.tpot is not None:
                tpot.observe(r.tpot * 1e3)
        if summary:
            for key in ("toks_per_s", "goodput_tps", "wall_s"):
                if summary.get(key) is not None:
                    self.gauge(f"serve_{key}").set(summary[key])

    def absorb_spec(self, stats: dict | None):
        """Fold a scheduler ``spec_stats()`` dict (no-op on None)."""
        if not stats:
            return
        for key in ("rounds", "drafted", "accepted"):
            self.counter(f"spec_{key}_total").inc(stats[key])
        self.gauge("spec_acceptance_rate").set(stats["acceptance_rate"])

    def absorb_counters(self, counters: dict | None, prefix: str = "dev_"):
        """Fold a harvested on-device counter dict (``obs.counters``)."""
        if not counters:
            return
        for name, value in counters.items():
            self.gauge(prefix + name).set(value)

    # ---------------------------------------------------------- exports
    def to_prometheus(self) -> str:
        lines = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            lines.extend(m.expose())
        return "\n".join(lines) + "\n"

    def to_json(self) -> dict:
        return {name: self._metrics[name].to_json()
                for name in sorted(self._metrics)}

    def dump(self, path: str):
        """Write by extension: ``.json`` → JSON object, anything else →
        Prometheus text exposition. Never emits NaN (json strict)."""
        if path.endswith(".json"):
            with open(path, "w") as f:
                json.dump(self.to_json(), f, indent=2, allow_nan=False)
                f.write("\n")
        else:
            with open(path, "w") as f:
                f.write(self.to_prometheus())
