"""Span tracer for the serving stack — Chrome-trace/Perfetto export.

One global host-side tracer instruments the whole serving path
(`ServeEngine` prepare/prefill/generate, the continuous-batching
scheduler's admit/dispatch/harvest/evict, `repro.spec`'s
propose/verify/rollback, and the `launch.pipeline` phases). The
contract:

- **Disabled is the default and costs (near) nothing.** ``span()`` on a
  disabled tracer returns one shared no-op context manager — a single
  attribute check and no allocation — so instrumented hot paths are
  unchanged when nobody is looking. All numerics live on device behind
  jit; a host-side span can never perturb a decoded trajectory, enabled
  or not (the golden-trajectory tests pin this).
- **Spans are host-wall-clock.** Device work is asynchronous; a span
  around a dispatch measures the host's enqueue cost, a span around a
  harvest measures the true sync wait. Spans placed inside jit-traced
  code (e.g. the spec propose/verify/rollback bodies) fire once per
  COMPILE, not per step — they show up in the trace as ``jax-trace``
  category events and record tracing cost, which is itself a real
  serving cost on first dispatch.
- **Export is standard Chrome trace JSON** (``chrome://tracing`` /
  Perfetto): complete ``"X"`` events with microsecond ``ts``/``dur``,
  sorted by ``ts``, one pid per process and the Python thread id as
  ``tid``. ``validate()`` checks well-formedness (the CI trace-smoke
  gate): sorted timestamps, matched B/E nesting, non-negative X
  durations.

Usage::

    from repro.obs import trace
    trace.enable()
    with trace.span("serve.generate", steps=32):
        ...
    trace.save("trace.json")

or as a decorator::

    @trace.traced("engine.prepare")
    def prepare(...): ...
"""
from __future__ import annotations

import functools
import json
import os
import sys
import threading
import time

__all__ = ["Tracer", "get_tracer", "enable", "disable", "span", "instant",
           "traced", "save", "validate", "validate_file"]


class _NullSpan:
    """Shared no-op context manager — the disabled-tracer fast path."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL = _NullSpan()


class _Span:
    __slots__ = ("_tracer", "name", "cat", "args", "_t0")

    def __init__(self, tracer, name, cat, args):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args

    def __enter__(self):
        self._t0 = self._tracer._now_us()
        return self

    def __exit__(self, *exc):
        t1 = self._tracer._now_us()
        ev = {"name": self.name, "cat": self.cat, "ph": "X",
              "ts": self._t0, "dur": t1 - self._t0,
              "pid": self._tracer.pid,
              "tid": threading.get_ident() & 0xFFFF}
        if self.args:
            ev["args"] = self.args
        self._tracer.events.append(ev)
        return False


class Tracer:
    """Span recorder with a near-zero-cost disabled path.

    ``span(name, **args)`` returns a context manager; on exit it appends
    one complete ("X") Chrome-trace event. Timestamps are microseconds
    since the tracer's epoch (``perf_counter`` based, monotonic).
    """

    def __init__(self, enabled: bool = False):
        self.enabled = enabled
        self.events: list[dict] = []
        self.pid = os.getpid()
        self._epoch = time.perf_counter()

    def _now_us(self) -> float:
        return (time.perf_counter() - self._epoch) * 1e6

    def span(self, name: str, cat: str = "obs", **args):
        if not self.enabled:
            return _NULL
        return _Span(self, name, cat, args)

    def instant(self, name: str, cat: str = "obs", **args):
        """Record a zero-duration instant event."""
        if not self.enabled:
            return
        ev = {"name": name, "cat": cat, "ph": "i", "s": "t",
              "ts": self._now_us(), "pid": self.pid,
              "tid": threading.get_ident() & 0xFFFF}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def clear(self):
        self.events = []
        self._epoch = time.perf_counter()

    def export(self) -> dict:
        """Chrome trace JSON object (events sorted by ts)."""
        return {"traceEvents": sorted(self.events, key=lambda e: e["ts"]),
                "displayTimeUnit": "ms"}

    def save(self, path: str):
        with open(path, "w") as f:
            json.dump(self.export(), f, indent=1)
            f.write("\n")


# ------------------------------------------------------------ global API
_TRACER = Tracer(enabled=False)


def get_tracer() -> Tracer:
    return _TRACER


def enable(clear: bool = True):
    """Turn the global tracer on (optionally dropping prior events)."""
    if clear:
        _TRACER.clear()
    _TRACER.enabled = True
    return _TRACER


def disable():
    _TRACER.enabled = False


def span(name: str, cat: str = "obs", **args):
    """Span on the global tracer (no-op singleton when disabled)."""
    if not _TRACER.enabled:        # inlined fast path: one check, no alloc
        return _NULL
    return _Span(_TRACER, name, cat, args)


def instant(name: str, cat: str = "obs", **args):
    if _TRACER.enabled:
        _TRACER.instant(name, cat, **args)


def traced(name: str | None = None, cat: str = "obs"):
    """Decorator form: ``@traced("engine.prepare")``."""
    def deco(fn):
        label = name or fn.__qualname__

        @functools.wraps(fn)
        def wrapper(*a, **kw):
            if not _TRACER.enabled:
                return fn(*a, **kw)
            with _TRACER.span(label, cat):
                return fn(*a, **kw)
        return wrapper
    return deco


def save(path: str):
    _TRACER.save(path)


# -------------------------------------------------------------- validate
def validate(payload) -> list[str]:
    """Well-formedness problems of a Chrome-trace JSON object (or event
    list). Empty list = valid. Checked: the event-array shape, known
    phases, per-event required keys, globally sorted ``ts``, non-negative
    ``dur`` on complete events, and matched B/E nesting per (pid, tid).
    """
    problems: list[str] = []
    events = payload.get("traceEvents") if isinstance(payload, dict) \
        else payload
    if not isinstance(events, list):
        return ["traceEvents is not a list"]
    last_ts = None
    stacks: dict[tuple, list[str]] = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            problems.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        if ph == "M":               # metadata events carry no timestamp
            continue
        if ph not in ("X", "B", "E", "i", "I", "C"):
            problems.append(f"event {i}: unknown phase {ph!r}")
            continue
        for key in ("name", "ts", "pid", "tid"):
            if key not in ev:
                problems.append(f"event {i}: missing {key!r}")
        ts = ev.get("ts")
        if isinstance(ts, (int, float)):
            if last_ts is not None and ts < last_ts:
                problems.append(f"event {i}: ts not sorted "
                                f"({ts} after {last_ts})")
            last_ts = ts
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"event {i}: X event needs dur >= 0, "
                                f"got {dur!r}")
        elif ph == "B":
            stacks.setdefault((ev.get("pid"), ev.get("tid")),
                              []).append(ev.get("name"))
        elif ph == "E":
            stack = stacks.setdefault((ev.get("pid"), ev.get("tid")), [])
            if not stack:
                problems.append(f"event {i}: E without matching B")
            else:
                stack.pop()
    for key, stack in stacks.items():
        if stack:
            problems.append(f"unclosed B events on {key}: {stack}")
    return problems


def validate_file(path: str) -> list[str]:
    try:
        with open(path) as f:
            payload = json.load(f)
    except (OSError, ValueError) as e:
        return [f"{path}: unreadable trace JSON ({e})"]
    return validate(payload)


def main(argv=None) -> int:
    """CLI gate: ``python -m repro.obs.trace FILE [FILE...]`` exits
    non-zero (listing problems) unless every file is a well-formed
    Chrome trace with at least one event."""
    argv = sys.argv[1:] if argv is None else argv
    if not argv:
        print("usage: python -m repro.obs.trace FILE [FILE...]",
              file=sys.stderr)
        return 2
    bad = 0
    for path in argv:
        problems = validate_file(path)
        try:
            with open(path) as f:
                n = len(json.load(f).get("traceEvents", []))
        except (OSError, ValueError):
            n = 0
        if not problems and n == 0:
            problems = ["no trace events recorded"]
        if problems:
            bad += 1
            for p in problems:
                print(f"{path}: {p}", file=sys.stderr)
        else:
            print(f"{path}: OK ({n} events)")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
