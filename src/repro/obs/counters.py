"""On-device serving counters — accumulated in-graph, harvested at the
scheduler's existing host syncs.

The serving stack already keeps every per-token quantity the paper's
efficiency claims need ON DEVICE: the temporal-delta cache accumulates
fired-column counts (``nx``/``nh`` per layer), the speculative loop
returns per-row ``rounds``/``drafted``/``accepted``, and ``decode_loop``
counts emitted tokens. This module folds them into ONE small
device-resident vector (a named slot layout, ``counter_names``) that the
scheduler threads through its chained chunk dispatches exactly like
``done``/``budget``:

- accumulation happens inside the already-jitted chunk function (pure
  extra adds — no new dispatches);
- the vector rides the ``DispatchQueue`` next to each chunk's token
  future and is read on the host at the chunk's EXISTING harvest sync,
  so instrumentation adds **no extra device→host transfers** and no new
  sync points.

Slot semantics (all float32 — exact integers up to 2^24, plenty for
bench/serve runs; the delta cache's own ``nx``/``nh`` are float32
already):

- ``decode_steps``, ``tokens``, ``spec_rounds``, ``spec_drafted``,
  ``spec_accepted`` are per-chunk deltas summed over the run (counters);
- ``fired_x_l{i}`` / ``fired_h_l{i}`` are GAUGES: the current cache's
  cumulative fired-column sums, re-read at each chunk exit. At drain
  they equal exactly what ``occupancy_report`` recomputes offline from
  the same cache (the parity invariant ``tests/test_obs.py`` pins).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = ["BASE_COUNTERS", "counter_names", "zeros", "chunk_update",
           "harvest", "from_state", "fired_totals"]

BASE_COUNTERS = ("decode_steps", "tokens", "spec_rounds", "spec_drafted",
                 "spec_accepted")


def _num_delta_layers(model) -> int:
    if getattr(model, "delta", None) is None:
        return 0
    return getattr(getattr(model, "cfg", None), "num_layers", 0)


def counter_names(model) -> tuple:
    """Slot layout for ``model``: the base counters plus one
    ``fired_x_l{i}``/``fired_h_l{i}`` gauge pair per delta-gated layer."""
    names = list(BASE_COUNTERS)
    for i in range(_num_delta_layers(model)):
        names += [f"fired_x_l{i}", f"fired_h_l{i}"]
    return tuple(names)


def zeros(names):
    return jnp.zeros((len(names),), jnp.float32)


def chunk_update(names, counters, st, steps: int):
    """Fold one decode chunk's returned state into the counter vector
    (runs inside the scheduler's jitted chunk fn — device-only).

    ``st`` is the decode/spec loop state: ``emitted`` (B,) always;
    ``rounds``/``drafted``/``accepted`` (B,) on spec chunks; ``cache``
    carrying per-layer ``nx``/``nh`` when the model is delta-gated.
    """
    idx = {n: i for i, n in enumerate(names)}
    c = counters
    c = c.at[idx["decode_steps"]].add(jnp.float32(steps))
    c = c.at[idx["tokens"]].add(
        jnp.sum(st["emitted"]).astype(jnp.float32))
    for key, slot in (("rounds", "spec_rounds"), ("drafted", "spec_drafted"),
                      ("accepted", "spec_accepted")):
        if key in st:
            c = c.at[idx[slot]].add(jnp.sum(st[key]).astype(jnp.float32))
    if "fired_x_l0" in idx:
        for i, lp in enumerate(st["cache"]["layers"]):
            c = c.at[idx[f"fired_x_l{i}"]].set(
                jnp.sum(lp["nx"]).astype(jnp.float32))
            c = c.at[idx[f"fired_h_l{i}"]].set(
                jnp.sum(lp["nh"]).astype(jnp.float32))
    return c


def harvest(names, values) -> dict:
    """Counter vector → {name: float} on the host.

    The caller controls WHEN this runs: the scheduler calls it on the
    vector snapshot riding an already-harvested chunk (the value is by
    then host-materialized alongside the chunk's tokens — no extra
    sync point).
    """
    vals = np.asarray(values, np.float64)
    return {n: float(v) for n, v in zip(names, vals)}


def from_state(model, state, *, steps: int) -> dict:
    """Counters for a LOCKSTEP ``ServeEngine.generate`` run, read from the
    decode loop's final state (``return_state=True``) — one host read of
    quantities the run already produced, no in-loop instrumentation.
    """
    names = counter_names(model)
    out = dict.fromkeys(names, 0.0)
    out["decode_steps"] = float(steps)
    out["tokens"] = float(np.sum(np.asarray(state["emitted"])))
    for key, slot in (("rounds", "spec_rounds"), ("drafted", "spec_drafted"),
                      ("accepted", "spec_accepted")):
        if key in state:
            out[slot] = float(np.sum(np.asarray(state[key])))
    if _num_delta_layers(model):
        for i, lp in enumerate(state["cache"]["layers"]):
            out[f"fired_x_l{i}"] = float(np.asarray(jnp.sum(lp["nx"])))
            out[f"fired_h_l{i}"] = float(np.asarray(jnp.sum(lp["nh"])))
    return out


def fired_totals(counters: dict) -> tuple[list, list]:
    """Per-layer ([fired_x...], [fired_h...]) lists from a harvested
    counter dict (empty lists when the run was not delta-gated)."""
    fx, fh = [], []
    i = 0
    while f"fired_x_l{i}" in counters:
        fx.append(counters[f"fired_x_l{i}"])
        fh.append(counters[f"fired_h_l{i}"])
        i += 1
    return fx, fh
