"""Per-step collective inventory for sharded serving (repro.dist meshes).

``repro.dist`` claims exactly one collective per decode step and layer —
the all-gather of the sharded hidden state h over the ``model`` axis
(docs/architecture.md, "one all-gather per layer per step"). This module
makes that claim *measurable*: compile any jitted step and read the
collectives actually present in its HLO, loop-multiplicity-weighted, via
``roofline.py``'s HLO parser. ``tests/test_obs.py`` pins the claim on 8
forced host devices; ``launch.pipeline``-scale dry-run cells keep the
original top-contributor report (``top``; ``scripts/top_collectives.py``
stays as a thin CLI shim).
"""
from __future__ import annotations

import re

from .. import roofline

__all__ = ["inventory_from_text", "inventory", "decode_step_inventory",
           "summarize_inventory", "top"]


def _entry_name(text: str) -> str:
    entry = [l for l in text.splitlines() if l.startswith("ENTRY")]
    if not entry:
        raise ValueError("no ENTRY computation in HLO text")
    return re.match(r"ENTRY\s+%?([\w\.\-]+)", entry[0]).group(1)


def inventory_from_text(text: str) -> list[dict]:
    """Collectives in compiled HLO text, one record per op site:
    ``kind`` (start-suffix folded), ``mult`` (loop multiplicity from the
    entry computation), ``bytes`` (result payload), ``wire_bytes``
    (bytes × mult), ``where`` (op_name metadata when present).
    Multiplicity-0 sites (dead computations) are dropped."""
    comps = roofline.parse_hlo(text)
    mult = roofline.multiplicities(comps, _entry_name(text))
    items = []
    for name, comp in comps.items():
        m = mult.get(name, 0)
        if m <= 0:
            continue
        for line in comp.lines:
            mo = roofline._OP_DEF.match(line)
            if not mo:
                continue
            kind = mo.group(3)
            if kind.endswith("-start"):
                kind = kind[:-6]
            if kind not in roofline._COLL_KINDS:
                continue
            size = roofline.shape_bytes(mo.group(2))
            meta = re.search(r'op_name="([^"]*)"', line)
            items.append({"kind": kind, "mult": m, "bytes": size,
                          "wire_bytes": m * size,
                          "where": meta.group(1) if meta else
                          line.strip()[:120]})
    items.sort(key=lambda it: -it["wire_bytes"])
    return items


def inventory(fn_or_lowered, *args, **kwargs) -> list[dict]:
    """Collective inventory of a step function: pass a callable (jitted
    or not — it is lowered on the given example args) or an
    already-``jax.jit(...).lower(...)``ed object."""
    import jax
    lowered = fn_or_lowered
    if callable(fn_or_lowered):
        lowered = jax.jit(fn_or_lowered).lower(*args, **kwargs)
    return inventory_from_text(lowered.compile().as_text())


def decode_step_inventory(model, params, cache, tokens, pos) -> list[dict]:
    """Inventory of ONE ``model.decode_step`` dispatch — the per-step
    collective bill a sharded decode pays every token."""
    return inventory(lambda p, c, t, x: model.decode_step(p, c, t, x),
                     params, cache, tokens, pos)


def summarize_inventory(items: list[dict]) -> dict:
    """{kind: mult-weighted count} plus ``wire_bytes`` total — the shape
    tests assert on (e.g. exactly ``num_layers`` all-gathers per step)."""
    by_kind: dict[str, int] = {}
    for it in items:
        by_kind[it["kind"]] = by_kind.get(it["kind"], 0) + it["mult"]
    return {"counts": by_kind,
            "wire_bytes": sum(it["wire_bytes"] for it in items)}


def top(arch, shape, multi=False, n=10, overrides=None):
    """Print the top collective contributors (wire bytes × multiplicity)
    for one ``launch.dryrun`` cell; returns the inventory records."""
    from ..launch.dryrun import build_cell
    lowered, n_dev, aux = build_cell(arch, shape, multi, overrides)
    items = inventory_from_text(lowered.compile().as_text())
    total = sum(it["wire_bytes"] for it in items)
    print(f"total payload×mult: {total:.3e} bytes/chip "
          f"(~{total / 50e9 * 1e3:.0f} ms at ICI)")
    for it in items[:n]:
        print(f"{it['wire_bytes']:.2e}  mult={it['mult']:5.0f} "
              f"size={it['bytes']:.2e} {it['kind']:13s} "
              f"{it['where'][-90:]}")
    return items
