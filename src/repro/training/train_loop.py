"""train_step factory: grad accumulation, masked (BRDS) retraining, ZeRO-1
sharded optimizer state, mixed precision, jit with NamedShardings.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import optim
from ..sparse import apply_masks, mask_grads
from ..sharding import resolve_spec, named_sharding
from .. import sharding as shd


# ----------------------------------------------------------- shardings

def param_shardings(mesh: Mesh, model) -> Any:
    axes = model.param_axes()
    shapes = jax.tree.map(lambda d: d.shape, model.param_defs(),
                          is_leaf=lambda x: hasattr(x, "axes"))
    return jax.tree.map(
        lambda lg, sh: named_sharding(mesh, lg, sh),
        axes, shapes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def zero1_shardings(mesh: Mesh, param_sh, params_abstract) -> Any:
    """Optimizer-state shardings: param spec + shard the first replicated,
    divisible dim over 'data' (ZeRO-1)."""
    dsize = dict(zip(mesh.axis_names, mesh.devices.shape)).get("data", 1)

    def zspec(sh: NamedSharding, ab) -> NamedSharding:
        spec = list(sh.spec) + [None] * (len(ab.shape) - len(sh.spec))
        used = set()
        for s in spec:
            for a in (s if isinstance(s, tuple) else (s,)):
                if a:
                    used.add(a)
        if "data" not in used:
            for i, s in enumerate(spec):
                if s is None and ab.shape[i] % dsize == 0 and ab.shape[i] > 0:
                    spec[i] = "data"
                    break
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(zspec, param_sh, params_abstract)


def opt_shardings(mesh: Mesh, opt_cfg: optim.OptConfig, param_sh,
                  params_abstract, zero1: bool = True):
    moment = (zero1_shardings(mesh, param_sh, params_abstract)
              if zero1 else param_sh)
    scalar = NamedSharding(mesh, P())
    if opt_cfg.name == "adamw":
        return {"m": moment, "v": moment, "count": scalar}
    return {"m": moment, "count": scalar}


def batch_shardings(mesh: Mesh, batch_abstract):
    def spec(ab):
        names = ["batch"] + [None] * (len(ab.shape) - 1)
        return named_sharding(mesh, names, ab.shape)
    return jax.tree.map(spec, batch_abstract)


# ----------------------------------------------------------- train step

def make_train_step(model, arch_cfg, opt_cfg: optim.OptConfig, masks=None):
    """Returns train_step(params, opt_state, batch, step) →
    (params, opt_state, metrics). Grad accumulation over
    arch_cfg.grad_accum microbatches via lax.scan."""
    accum = max(1, arch_cfg.grad_accum)

    def loss_fn(params, mb):
        return model.loss(params, mb)

    def train_step(params, opt_state, batch, step):
        if accum == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def slice_mb(x):
                b = x.shape[0] // accum
                return x.reshape(accum, b, *x.shape[1:])
            mbs = jax.tree.map(slice_mb, batch)
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)

            def mb_step(carry, mb):
                g_acc, l_acc = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b_: a + b_.astype(jnp.float32) / accum,
                    g_acc, g)
                return (g_acc, l_acc + l / accum), None

            (grads, loss), _ = jax.lax.scan(mb_step, (g0, jnp.float32(0.0)),
                                            mbs)
        # NOTE: grads keep the param dtype (bf16) here — casting to f32
        # before the optimizer made XLA hoist the convert above the DP
        # all-reduce, doubling its wire bytes (granite §Perf iteration 4).
        # The optimizer promotes to f32 internally.
        if masks is not None:
            grads = mask_grads(grads, masks)
        new_params, new_opt, metrics = optim.apply_update(
            opt_cfg, params, grads, opt_state, step)
        if masks is not None:
            new_params = apply_masks(new_params, masks)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return train_step


def jit_train_step(mesh: Mesh, model, arch_cfg, opt_cfg: optim.OptConfig,
                   batch_abstract, masks=None, donate: bool = True):
    """jit the train step with full input/output shardings under `mesh`."""
    params_abs = model.abstract_params()
    p_sh = param_shardings(mesh, model)
    o_sh = opt_shardings(mesh, opt_cfg, p_sh, params_abs,
                         zero1=getattr(arch_cfg, "zero1", True))
    b_sh = batch_shardings(mesh, batch_abstract)
    scalar = NamedSharding(mesh, P())
    step_fn = make_train_step(model, arch_cfg, opt_cfg, masks)
    m_sh = {"grad_norm": scalar, "lr": scalar, "loss": scalar}
    return jax.jit(
        step_fn,
        in_shardings=(p_sh, o_sh, b_sh, scalar),
        out_shardings=(p_sh, o_sh, m_sh),
        donate_argnums=(0, 1) if donate else (),
    )
