"""DEPRECATED shim — BRDS masked retraining now lives in ``repro.sparse``.

The transformer dual-ratio surface (``brds_masks`` / ``apply_masks`` /
``mask_grads`` / ``brds_pack_params``) is implemented by
``repro.sparse.transformer_policy`` compiled into a SparsityPlan:

    plan = transformer_policy(spar_a, spar_b).compile(params)
    pruned, masks = plan.prune(params)
    grads = plan.mask_grads(grads, masks)
    packed, report = plan.pack(params, abstract=...)

These wrappers keep the old call signatures (and mask dict layout,
{path: bool_mask}) for out-of-tree callers, with a DeprecationWarning.
"""
from __future__ import annotations

import warnings

from ..sparse.policy import (_path_str, apply_masks, mask_grads,
                             transformer_policy, classify)
from ..sparse.policy import sparsity_report as _sparsity_report

__all__ = ["brds_masks", "apply_masks", "mask_grads", "brds_pack_params",
           "sparsity_report", "classify"]


def _warn(old: str, new: str):
    warnings.warn(f"repro.training.masked.{old} is deprecated; use "
                  f"repro.sparse.{new}", DeprecationWarning, stacklevel=3)


def brds_masks(params, spar_a: float, spar_b: float) -> dict:
    """Build masks for every prunable weight. Returns {path: bool_mask}."""
    _warn("brds_masks", "transformer_policy(...).compile(params).masks()")
    plan = transformer_policy(spar_a, spar_b).compile(params)
    return plan.masks(params)


def brds_pack_params(params, spar_a: float, spar_b: float,
                     abstract: bool = False):
    """Replace every prunable weight with its packed RowBalancedSparse form
    (rows = output units, cols = fan-in — the rb_spmv kernel layout).
    Returns (new_params, report)."""
    _warn("brds_pack_params", "transformer_policy(...).compile(params).pack()")
    plan = transformer_policy(spar_a, spar_b).compile(params)
    return plan.pack(params, abstract=abstract)


def sparsity_report(params, masks) -> dict:
    return _sparsity_report(masks)
