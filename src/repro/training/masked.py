"""BRDS masked retraining for the transformer zoo.

The paper freezes pruned weights and retrains the survivors (§3.2). Here:
masks are boolean pytree entries keyed by flattened path; `apply_masks`
zeros pruned weights, `mask_grads` freezes them.

Row orientation: a "row" is one OUTPUT unit; pruning happens along the
fan-in so each output accumulates exactly K products (the accelerator's
balanced-PE invariant). Per-weight layout is declared in _LAYOUTS:
(stack_dims, in_dims, out_dims) as index tuples.

Dual-ratio families (DESIGN.md §4):
  family A (Spar_a, pruned harder) — feed-forward: mlp/*, moe/w_* (not router)
  family B (Spar_b, softer)        — mixer: attn/w*, rec/w_*, rwkv/w_*, xattn/w*
"""
from __future__ import annotations

import re
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..core.sparsity import row_balanced_mask, apply_mask

# path-suffix regex -> family ('a'|'b'); order matters (first match wins)
_FAMILY = [
    (r"(mlp|moe)/w_(gate|up|down)$", "a"),
    (r"rwkv/w_cm[12]$", "a"),
    (r"(attn|xattn)/w[qkvo]$", "b"),
    (r"rec/(w_in_gelu|w_in_rec|w_gate_a|w_gate_x|w_out)$", "b"),
    (r"rwkv/w_[rkvgw]$", "b"),
    (r"rwkv/w_out$", "b"),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def classify(path_str: str) -> str | None:
    for pat, fam in _FAMILY:
        if re.search(pat, path_str):
            return fam
    return None


def _is_stacked(ps: str, leaf) -> bool:
    return "blocks/" in ps and leaf.ndim >= 3


def _structured_mask(w: jnp.ndarray, spar: float, ps: str,
                     stacked: bool) -> jnp.ndarray:
    """Row-balanced mask: rows = OUTPUT units, pruned along the fan-in.
    Uses the same (in,out) layout resolution as the packed serving form
    (_mat2d_info) so masked training and packing keep identical patterns."""
    L, d_in, out = _mat2d_info(ps, w.shape, stacked)
    w3 = w.reshape((L or 1), d_in, out)
    m = row_balanced_mask(jnp.swapaxes(w3, -1, -2), spar)   # (L, out, in)
    return jnp.swapaxes(m, -1, -2).reshape(w.shape)


def brds_masks(params, spar_a: float, spar_b: float) -> dict:
    """Build masks for every prunable weight. Returns {path: bool_mask}."""
    masks = {}
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    for path, leaf in flat:
        ps = _path_str(path)
        fam = classify(ps)
        if fam is None or leaf.ndim < 2:
            continue
        spar = spar_a if fam == "a" else spar_b
        if spar <= 0:
            continue
        masks[ps] = _structured_mask(leaf, spar, ps, _is_stacked(ps, leaf))
    return masks


def _map_masked(params, masks, fn):
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        ps = _path_str(path)
        out.append(fn(leaf, masks[ps]) if ps in masks else leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def apply_masks(params, masks):
    return _map_masked(params, masks, apply_mask)


def mask_grads(grads, masks):
    return _map_masked(grads, masks, apply_mask)


# ------------------------------------------------- packed (serving) form

# suffixes whose OUT dims trail the first (input) dim; everything else is
# (in..., out)-shaped with out = last dim
_OUT_TRAILING = re.compile(r"rwkv/w_[rkvgw]$")


def _mat2d_info(ps: str, shape: tuple, stacked: bool):
    """→ (L or None, in_size, out_size) for a prunable leaf."""
    core = shape[1:] if stacked else shape
    if _OUT_TRAILING.search(ps):
        d_in, out = core[0], int(np.prod(core[1:]))
    else:
        d_in, out = int(np.prod(core[:-1])), core[-1]
    return (shape[0] if stacked else None), d_in, out


def brds_pack_params(params, spar_a: float, spar_b: float,
                     abstract: bool = False):
    """Replace every prunable weight with its packed RowBalancedSparse form
    (rows = output units, cols = fan-in — the rb_spmv kernel layout).

    abstract=True builds ShapeDtypeStruct stand-ins (for the dry-run);
    concrete packing loops per stacked layer. Returns (new_params, report).
    """
    from ..core.packing import (RowBalancedSparse, pack, _delta_dtype)
    from ..core.sparsity import row_balanced_mask, keep_count
    import jax.numpy as jnp

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out_leaves = []
    dense_bytes = packed_bytes = 0
    for path, leaf in flat:
        ps = _path_str(path)
        fam = classify(ps)
        if fam is None or leaf.ndim < 2:
            out_leaves.append(leaf)
            if hasattr(leaf, "dtype"):
                packed_bytes += leaf.size * leaf.dtype.itemsize
                dense_bytes += leaf.size * leaf.dtype.itemsize
            continue
        spar = spar_a if fam == "a" else spar_b
        stacked = "blocks/" in ps and leaf.ndim >= 3
        L, d_in, out = _mat2d_info(ps, leaf.shape, stacked)
        K = keep_count(d_in, spar)
        dd = _delta_dtype(d_in, K)
        vshape = (L, out, K) if L else (out, K)
        dense_bytes += leaf.size * leaf.dtype.itemsize
        packed_bytes += int(np.prod(vshape)) * (leaf.dtype.itemsize
                                                + dd.itemsize)
        if abstract:
            s = RowBalancedSparse(
                values=jax.ShapeDtypeStruct(vshape, leaf.dtype),
                deltas=jax.ShapeDtypeStruct(vshape, jnp.dtype(dd)),
                ncols=d_in)
        else:
            def pack_one(w2):
                w2 = w2.reshape(d_in, out).T if not _OUT_TRAILING.search(ps) \
                    else w2.reshape(d_in, out).T
                return pack(w2, row_balanced_mask(w2, spar))
            if L:
                packs = [pack_one(leaf[i]) for i in range(L)]
                s = RowBalancedSparse(
                    values=jnp.stack([q.values for q in packs]),
                    deltas=jnp.stack([q.deltas for q in packs]),
                    ncols=d_in)
            else:
                s = pack_one(leaf)
        out_leaves.append(s)
    new = jax.tree_util.tree_unflatten(treedef, out_leaves)
    return new, dict(dense_bytes=dense_bytes, packed_bytes=packed_bytes,
                     ratio=packed_bytes / max(dense_bytes, 1))


def sparsity_report(params, masks) -> dict:
    total = pruned = 0
    for ps, m in masks.items():
        total += m.size
        pruned += int(m.size - jnp.sum(m))
    return {"prunable_params": total, "pruned": pruned,
            "sparsity": pruned / max(total, 1)}
