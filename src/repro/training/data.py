"""Data pipeline: synthetic corpora with controlled structure + sharded,
restartable loaders.

Datasets are license-gated/offline in this environment (DESIGN.md §7), so we
generate corpora whose statistics make pruning-accuracy ORDERINGS measurable:

- ZipfInduction: Zipf unigram distribution + planted bigram "induction"
  rules (p% of the time token t is followed by rule[t]) — a model must learn
  both marginal stats and associations; pruning damage shows up as
  measurable loss deltas.
- CharCorpus: a small embedded English-like char corpus (PTB stand-in).
- FrameCorpus: synthetic acoustic-frame classification (TIMIT stand-in):
  framewise labels from a random projection + temporal smoothing, so
  recurrent state genuinely helps.

Loaders are deterministic functions of (seed, step) — a restart at step k
reproduces the exact same batch k (fault-tolerance invariant, tested).
Every corpus also exposes ``eval_batches``: held-out batches drawn from a
step namespace offset by ``EVAL_STEP_BASE`` so no training run of any
realistic length can alias the eval stream (the old ``10_000 + i`` offset
collided with training step 10_000).
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

# Held-out eval batches draw from steps >= this base: far beyond any
# reachable training step count, so train/eval streams never alias.
EVAL_STEP_BASE = 1 << 40


@dataclasses.dataclass
class ZipfInduction:
    vocab_size: int = 512
    alpha: float = 1.2
    rule_frac: float = 0.5      # fraction of steps following a planted rule
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        ranks = np.arange(1, self.vocab_size + 1)
        p = ranks ** (-self.alpha)
        self.probs = p / p.sum()
        self.rules = rng.permutation(self.vocab_size)

    def batch(self, step: int, batch_size: int, seq_len: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        base = rng.choice(self.vocab_size, size=(batch_size, seq_len),
                          p=self.probs)
        use_rule = rng.random((batch_size, seq_len)) < self.rule_frac
        toks = base.copy()
        for t in range(1, seq_len):
            toks[:, t] = np.where(use_rule[:, t],
                                  self.rules[toks[:, t - 1]], base[:, t])
        toks = toks.astype(np.int32)
        return {"tokens": toks, "labels": toks}

    def eval_batches(self, n: int, batch_size: int, seq_len: int):
        return [self.batch(EVAL_STEP_BASE + i, batch_size, seq_len)
                for i in range(n)]


_CHAR_TEXT = (
    "the quick brown fox jumps over the lazy dog . "
    "a journey of a thousand miles begins with a single step . "
    "to be or not to be that is the question . "
    "all that glitters is not gold . actions speak louder than words . "
    "the early bird catches the worm . practice makes perfect . "
    "knowledge is power . time and tide wait for no man . "
    "a picture is worth a thousand words . better late than never . "
) * 50


@dataclasses.dataclass
class CharCorpus:
    seed: int = 0

    def __post_init__(self):
        chars = sorted(set(_CHAR_TEXT))
        self.stoi = {c: i for i, c in enumerate(chars)}
        self.vocab_size = len(chars)
        self.data = np.array([self.stoi[c] for c in _CHAR_TEXT], np.int32)

    def batch(self, step: int, batch_size: int, seq_len: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        starts = rng.integers(0, len(self.data) - seq_len - 1, batch_size)
        toks = np.stack([self.data[s:s + seq_len] for s in starts])
        return {"tokens": toks, "labels": toks}

    def eval_batches(self, n: int, batch_size: int, seq_len: int):
        return [self.batch(EVAL_STEP_BASE + i, batch_size, seq_len)
                for i in range(n)]


@dataclasses.dataclass
class FrameCorpus:
    """Synthetic framewise classification (TIMIT stand-in)."""
    input_size: int = 153
    num_classes: int = 61
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.proj = rng.normal(size=(self.input_size, self.num_classes)) * 0.5

    def batch(self, step: int, batch_size: int, seq_len: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        x = rng.normal(size=(batch_size, seq_len, self.input_size))
        # temporal smoothing → recurrent state helps
        for t in range(1, seq_len):
            x[:, t] = 0.7 * x[:, t - 1] + 0.3 * x[:, t]
        scores = x @ self.proj
        labels = scores.argmax(-1).astype(np.int32)
        return {"inputs": x.astype(np.float32), "labels": labels}

    def eval_batches(self, n: int, batch_size: int, seq_len: int):
        return [self.batch(EVAL_STEP_BASE + i, batch_size, seq_len)
                for i in range(n)]


@dataclasses.dataclass
class ShardedLoader:
    """Deterministic, restartable loader that yields this process's shard of
    the global batch. On a real multi-host deployment each process passes its
    own (shard_idx, num_shards); resharding after an elastic event is just a
    change of those numbers — determinism in (seed, step) keeps every host
    consistent.
    """
    dataset: object
    global_batch: int
    seq_len: int
    shard_idx: int = 0
    num_shards: int = 1

    def batch(self, step: int) -> dict:
        full = self.dataset.batch(step, self.global_batch, self.seq_len)
        per = self.global_batch // self.num_shards
        lo = self.shard_idx * per
        return {k: v[lo:lo + per] for k, v in full.items()}
