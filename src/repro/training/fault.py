"""Fault tolerance: watchdog/retry training loop, straggler detection,
elastic re-meshing.

On a real multi-pod deployment, node failure surfaces as a raised exception
from the collective runtime (or a coordinator heartbeat timeout). The
recovery contract implemented (and tested) here:

  1. `ResilientLoop.run` executes steps; on exception it restores the last
     valid checkpoint (atomic-commit guarantees it is consistent) and
     replays from that step — the deterministic (seed, step) data pipeline
     makes the replay bitwise-identical.
  2. `StragglerMonitor` keeps a per-step-time EMA and flags outliers
     (> k × EMA); deployments hook `on_straggler` to re-slice data or evict
     the slow host. Synchronous SPMD means mitigation = detection + resharding,
     which is what `elastic_restore` provides.
  3. `elastic_restore` re-device_puts a checkpoint onto a NEW mesh (fewer or
     more hosts) — combined with `make_production_mesh(...)` this is the
     elastic-scaling path: the run continues at the same step with the same
     global batch, re-sharded.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax

from .checkpoint import CheckpointManager


@dataclasses.dataclass
class StragglerMonitor:
    threshold: float = 2.5        # flag step times > threshold × EMA
    alpha: float = 0.1
    ema: float | None = None
    flagged: int = 0
    history: list = dataclasses.field(default_factory=list)

    def record(self, step_time: float) -> bool:
        is_straggler = (self.ema is not None
                        and step_time > self.threshold * self.ema)
        if is_straggler:
            self.flagged += 1
        else:
            self.ema = (step_time if self.ema is None
                        else (1 - self.alpha) * self.ema
                        + self.alpha * step_time)
        self.history.append((step_time, is_straggler))
        return is_straggler


class ResilientLoop:
    """Checkpoint/restart wrapper around a step function.

    step_fn(state, step) -> state. Exceptions trigger restore + replay.
    `clock` is injectable for tests.
    """

    def __init__(self, ckpt: CheckpointManager, *, save_every: int = 50,
                 max_failures: int = 3,
                 on_straggler: Callable | None = None,
                 straggler: StragglerMonitor | None = None,
                 clock: Callable[[], float] = time.monotonic):
        self.ckpt = ckpt
        self.save_every = save_every
        self.max_failures = max_failures
        self.straggler = straggler or StragglerMonitor()
        self.on_straggler = on_straggler
        self.clock = clock
        self.failures = 0

    def run(self, state, step_fn, start_step: int, num_steps: int):
        step = start_step
        while step < start_step + num_steps:
            t0 = self.clock()
            try:
                state = step_fn(state, step)
            except Exception:
                self.failures += 1
                if self.failures > self.max_failures:
                    raise
                restored = self.ckpt.latest_step()
                if restored is None:
                    raise
                state, meta = self.ckpt.restore(state)
                step = meta["step"]
                continue
            if self.straggler.record(self.clock() - t0):
                if self.on_straggler is not None:
                    self.on_straggler(step, self.straggler)
            step += 1
            if step % self.save_every == 0:
                self.ckpt.save(step, state, extra={"data_step": step})
        self.ckpt.wait()
        return state, step


def elastic_restore(ckpt: CheckpointManager, template, new_shardings):
    """Restore the latest checkpoint resharded onto a new mesh (elastic
    scale up/down). Returns (state, meta)."""
    return ckpt.restore(template, shardings=new_shardings)
