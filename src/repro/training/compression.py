"""Gradient compression for the data-parallel all-reduce: int8 quantization
with error feedback (EF-SGD style).

On a pod, the DP all-reduce of bf16 gradients is the dominant cross-slice
collective. Quantizing to int8 (per-tensor scale from a cheap max-abs
pre-reduce) halves/quarters the bytes on the wire; the quantization error is
kept in a local residual buffer and re-injected next step, preserving
convergence (error feedback).

`compressed_psum` is the shard_map building block (summing int8 payloads in
int32); `CompressedAllReduce` carries the residual state pytree.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def quantize(g: jnp.ndarray, residual: jnp.ndarray | None = None):
    """→ (q int8, scale f32, new_residual). g is f32."""
    gf = g.astype(jnp.float32)
    if residual is not None:
        gf = gf + residual
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    new_res = gf - q.astype(jnp.float32) * scale
    return q, scale, new_res


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(g, axis_name: str, residual=None):
    """Inside shard_map: all-reduce-mean `g` over `axis_name` in int8.

    Two small collectives: psum of the scalar max (to agree on a shared
    scale) + psum of the int8 payload accumulated in int32.
    Returns (mean_g f32, new_residual).
    """
    gf = g.astype(jnp.float32)
    if residual is not None:
        gf = gf + residual
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    gmax = jax.lax.pmax(jnp.max(jnp.abs(gf)), axis_name)
    scale = jnp.maximum(gmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127)
    new_res = gf - q * scale
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    mean = total.astype(jnp.float32) * scale / n
    return mean, new_res


def init_residuals(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def tree_compressed_psum(grads, axis_name: str, residuals):
    """Apply compressed_psum leaf-wise. Returns (means, new_residuals)."""
    pairs = jax.tree.map(
        lambda g, r: compressed_psum(g, axis_name, r), grads, residuals)
    means = jax.tree.map(lambda p: p[0], pairs,
                         is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda p: p[1], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    return means, res


def wire_bytes(tree, compressed: bool) -> int:
    """Bytes on the DP wire per all-reduce (payload only)."""
    leaves = jax.tree.leaves(tree)
    if compressed:
        return sum(l.size * 1 for l in leaves)         # int8 payload
    return sum(l.size * l.dtype.itemsize for l in leaves)
