"""Fault-tolerant checkpointing: atomic commit, keep-k, async save,
checksum validation, resharding restore.

Layout (single-process container; multi-host writes one file per process):

  <dir>/step_<N>.tmp/        staging (never read)
  <dir>/step_<N>/            committed atomically by os.rename
      arrays_p0.npz          flattened-path → array
      meta.json              {step, checksum, paths, data_state}

Restore picks the newest *committed* step whose checksum validates —
a half-written checkpoint (node died mid-save) is skipped, which is the
restart guarantee. `restore(..., mesh, shardings)` re-device_puts onto any
mesh — this is how elastic rescaling (N→M hosts) reshards state.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        out[key] = np.asarray(leaf)
    return out


def _checksum(arrays: dict[str, np.ndarray]) -> str:
    h = hashlib.sha256()
    for k in sorted(arrays):
        h.update(k.encode())
        h.update(np.ascontiguousarray(arrays[k]).tobytes()[:4096])
        h.update(str(arrays[k].shape).encode())
    return h.hexdigest()


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save
    def save(self, step: int, tree, extra: dict | None = None):
        arrays = _flatten(tree)
        meta = {"step": int(step), "checksum": _checksum(arrays),
                "extra": extra or {}}
        self.wait()
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, arrays, meta), daemon=True)
            self._thread.start()
        else:
            self._write(step, arrays, meta)

    def _write(self, step: int, arrays, meta):
        tmp = os.path.join(self.dir, f"step_{step:08d}.tmp")
        final = os.path.join(self.dir, f"step_{step:08d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays_p0.npz"), **arrays)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)          # atomic commit
        self._prune()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _prune(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------- load
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def _valid(self, step: int) -> bool:
        d = os.path.join(self.dir, f"step_{step:08d}")
        try:
            with open(os.path.join(d, "meta.json")) as f:
                meta = json.load(f)
            with np.load(os.path.join(d, "arrays_p0.npz")) as z:
                arrays = {k: z[k] for k in z.files}
            return meta["checksum"] == _checksum(arrays)
        except Exception:
            return False

    def latest_step(self) -> int | None:
        for s in reversed(self.all_steps()):
            if self._valid(s):
                return s
        return None

    def restore(self, template, step: int | None = None,
                shardings=None) -> tuple[Any, dict]:
        """Restore into the structure of `template`. If `shardings` (a
        matching pytree of jax.sharding.Sharding) is given, arrays are
        device_put onto it — works for ANY mesh shape (elastic restore)."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no valid checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        with np.load(os.path.join(d, "arrays_p0.npz")) as z:
            arrays = {k: z[k] for k in z.files}
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for path, leaf in flat:
            key = jax.tree_util.keystr(path)
            a = arrays[key]
            if hasattr(leaf, "dtype"):
                a = a.astype(leaf.dtype)
            leaves.append(a)
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        return tree, meta
