"""Training substrate: optimizers, data, checkpointing, fault tolerance,
masked (BRDS) retraining, gradient compression, sharded train steps."""
from .optim import OptConfig, init_state, apply_update, lr_at
from .data import ZipfInduction, CharCorpus, FrameCorpus, ShardedLoader
from .checkpoint import CheckpointManager
from .fault import ResilientLoop, StragglerMonitor, elastic_restore
from .masked import brds_masks, apply_masks, mask_grads, sparsity_report
from .train_loop import (make_train_step, jit_train_step, param_shardings,
                         opt_shardings, batch_shardings)
from . import compression
