"""Hand-rolled optimizers (no optax in this environment): AdamW, SGD-M, Lion.

Optimizer state is a pytree shaped like params; under ZeRO-1 the state
arrays are additionally sharded over the data axis — see
train_loop.opt_shardings. Component updates are computed with separate
tree.maps (params trees contain tuples as structure, so leaves-as-tuples
tricks are unsafe); XLA CSE merges the repeated expressions under jit.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"             # adamw | sgdm | lion
    lr: float = 3e-4
    betas: tuple = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    schedule: str = "cosine"        # cosine | linear | constant


def lr_at(cfg: OptConfig, step):
    """Warmup + cosine/linear decay. `step` may be traced."""
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
            1 + jnp.cos(jnp.pi * prog))
    elif cfg.schedule == "linear":
        decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * (1 - prog)
    else:
        decay = 1.0
    return cfg.lr * warm * decay


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(tree, max_norm):
    n = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), tree), n


def init_state(cfg: OptConfig, params):
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    if cfg.name == "adamw":
        return {"m": jax.tree.map(f32, params),
                "v": jax.tree.map(f32, params),
                "count": jnp.zeros((), jnp.int32)}
    if cfg.name in ("sgdm", "lion"):
        return {"m": jax.tree.map(f32, params),
                "count": jnp.zeros((), jnp.int32)}
    raise ValueError(cfg.name)


def apply_update(cfg: OptConfig, params, grads, state, step=None):
    """Returns (new_params, new_state, metrics). grads cast to fp32."""
    step = state["count"] if step is None else step
    lr = lr_at(cfg, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    b1, b2 = cfg.betas

    if cfg.name == "adamw":
        t = jnp.asarray(step + 1, jnp.float32)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t
        new_m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g,
                             state["m"], grads)
        new_v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g,
                             state["v"], grads)

        def upd(p, m, v):
            stepv = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
            pf = p.astype(jnp.float32)
            return (pf - lr * (stepv + cfg.weight_decay * pf)).astype(p.dtype)

        new_p = jax.tree.map(upd, params, new_m, new_v)
        new_state = {"m": new_m, "v": new_v, "count": state["count"] + 1}
    elif cfg.name == "sgdm":
        new_m = jax.tree.map(lambda m, g: b1 * m + g, state["m"], grads)

        def upd(p, m):
            pf = p.astype(jnp.float32)
            return (pf - lr * (m + cfg.weight_decay * pf)).astype(p.dtype)

        new_p = jax.tree.map(upd, params, new_m)
        new_state = {"m": new_m, "count": state["count"] + 1}
    elif cfg.name == "lion":
        def upd(p, m, g):
            u = jnp.sign(b1 * m + (1 - b1) * g)
            pf = p.astype(jnp.float32)
            return (pf - lr * (u + cfg.weight_decay * pf)).astype(p.dtype)

        new_p = jax.tree.map(upd, params, state["m"], grads)
        new_m = jax.tree.map(lambda m, g: b2 * m + (1 - b2) * g,
                             state["m"], grads)
        new_state = {"m": new_m, "count": state["count"] + 1}
    else:
        raise ValueError(cfg.name)
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}
