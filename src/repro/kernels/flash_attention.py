"""Pallas TPU kernel: blocked online-softmax (flash) attention.

Used by the 32k-prefill and 4k-train paths of the assigned transformer
architectures. Causal + GQA + local-window support. Grid is
(batch, q_heads, q_blocks, kv_blocks) with fp32 running max / sum / acc
scratch carried across the kv_blocks dimension; fully-masked kv blocks are
skipped (causal/window block pruning), which matters at 32k: the causal
triangle halves the streamed bytes and FLOPs.

K/V BlockSpec index maps fold GQA: q head h reads kv head h // group, so
K/V tiles are fetched once per kv head group, not per q head.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale, causal, window, bq, bk, sq, sk, nk):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # --- causal/window block pruning: q rows are right-aligned to the kv end
    off = sk - sq
    q_lo = iq * bq + off          # first absolute q position in this block
    q_hi = q_lo + bq - 1
    k_lo = ik * bk
    k_hi = k_lo + bk - 1
    live = jnp.bool_(True)
    if causal:
        live &= k_lo <= q_hi
    if window is not None:
        live &= k_hi > q_lo - window

    @pl.when(live)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                # (bk, d)
        s = q @ k.T                                        # (bq, bk)
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG)
        m_prev = m_scr[...][:, :1]                         # (bq, 1)
        l_prev = l_scr[...][:, :1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.where(s > NEG / 2, jnp.exp(s - m_new), 0.0)
        l_new = jnp.exp(m_prev - m_new) * l_prev + jnp.sum(p, -1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)                # (bk, d)
        acc_scr[...] = acc_scr[...] * jnp.exp(m_prev - m_new) + p @ v
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ik == nk - 1)
    def _done():
        l = jnp.maximum(l_scr[...][:, :1], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_kv", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    block_q: int = 256, block_kv: int = 256,
                    interpret: bool = True):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D); Hq % Hkv == 0.

    Returns (B, Hq, Sq, D) in q.dtype. Sq/Sk must divide by the block sizes
    (ops wrapper pads)."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    group = Hq // Hkv
    bq, bk = min(block_q, Sq), min(block_kv, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, Sk, bq, bk)
    nq, nk = Sq // bq, Sk // bk
    scale = D ** -0.5
    grid = (B, Hq, nq, nk)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, sq=Sq, sk=Sk, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
