"""Pallas TPU kernels: the WHOLE BRDS-LSTM decode step in one launch.

The paper's accelerator wins by computation overlapping: the Gate module's
MxV output streams through a Buffer straight into the Function module
(σ/tanh/⊙) without ever leaving the chip. Our chained decode path instead
launches 2–3 separate kernels per token (rb_dual_spmv → lstm_gates, plus
the delta partial-sum and q8 dequant variants) with HBM round-trips for
z, c, h and m between them. These kernels are the TPU analogue of the
paper's pipelined datapath — one ``pallas_call`` per layer step:

- the Gate stage runs the SAME per-row-block math as the chained kernels
  (``rb_spmv._rb_dual_kernel`` / ``delta_rb_spmv._delta_rb_dual_kernel`` /
  ``rb_spmv_q8._rb_dual_parts_q8_kernel``), writing each z block into a
  VMEM scratch instead of an HBM output;
- on the last row block the Function stage (``lstm_gates``'s cell math,
  including the PWL LUT mode) closes the cell from the VMEM-resident z —
  c and h never round-trip through HBM between the two stages.

Keeping the Gate stage's block shapes and op order IDENTICAL to the
chained kernels is what makes the fusion bitwise: the per-row K reduction
sees the same (B, block_rows, K) tiles, and the cell is elementwise (shape
changes cannot move a ulp). The ``kernels.ops`` wrappers assert this
parity bar in tests across packed / Θ=0 / Θ>0 delta / calibrated q8.

The multi-token SCAN variants go one step further (Spartus's degree of
fusion): grid (T, row-blocks) iterates T decode steps inside ONE launch,
holding c/h (and x_ref/h_ref/m for the delta path) in VMEM scratch across
steps and re-reading only the packed weight blocks from HBM. At high
sparsity + int8 the packed weights can fit VMEM outright — then even the
weight stream stays on-chip across tokens and decode approaches the
dispatch floor (the crossover `benchmarks/decode_throughput.py` measures).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .lstm_gates import _LUT, _T, _pwl
from .rb_spmv import DEF_BLOCK_ROWS


# ------------------------------------------------------------ shared stages

def _gate_block(x, h, vx_ref, dx_ref, vh_ref, dh_ref):
    """One row block of the dual-family MxV — the exact op order of
    ``rb_spmv._rb_dual_kernel`` (same tiles → bitwise-same reduction)."""
    colsx = jnp.cumsum(dx_ref[...].astype(jnp.int32), axis=1)
    colsh = jnp.cumsum(dh_ref[...].astype(jnp.int32), axis=1)
    gx = jnp.take(x, colsx, axis=1).astype(jnp.float32)    # (B, bR, Kx)
    gh = jnp.take(h, colsh, axis=1).astype(jnp.float32)    # (B, bR, Kh)
    accx = jnp.sum(gx * vx_ref[...].astype(jnp.float32)[None], axis=-1)
    acch = jnp.sum(gh * vh_ref[...].astype(jnp.float32)[None], axis=-1)
    return accx, acch


def _delta_gate_block(dxm, dhm, vx_ref, dx_ref, vh_ref, dh_ref):
    """One row block of the masked-delta dual MxV — the exact op order of
    ``delta_rb_spmv._delta_rb_dual_kernel`` (gathered deltas arrive f32)."""
    colsx = jnp.cumsum(dx_ref[...].astype(jnp.int32), axis=1)
    colsh = jnp.cumsum(dh_ref[...].astype(jnp.int32), axis=1)
    gx = jnp.take(dxm, colsx, axis=1)                      # (B, bR, Kx)
    gh = jnp.take(dhm, colsh, axis=1)                      # (B, bR, Kh)
    accx = jnp.sum(gx * vx_ref[...].astype(jnp.float32)[None], axis=-1)
    acch = jnp.sum(gh * vh_ref[...].astype(jnp.float32)[None], axis=-1)
    return accx, acch


def _q8_gate_block(qx, qh, vx_ref, dx_ref, sx_ref, vh_ref, dh_ref, sh_ref):
    """One row block of the quantized dual MxV — the exact op order of
    ``rb_spmv_q8._rb_dual_parts_q8_kernel`` (int32 accumulate, one dequant
    multiply per family)."""
    colsx = jnp.cumsum(dx_ref[...].astype(jnp.int32), axis=1)
    colsh = jnp.cumsum(dh_ref[...].astype(jnp.int32), axis=1)
    gx = jnp.take(qx.astype(jnp.int32), colsx, axis=1)
    gh = jnp.take(qh.astype(jnp.int32), colsh, axis=1)
    accx = jnp.sum(gx * vx_ref[...].astype(jnp.int32)[None], axis=-1)
    acch = jnp.sum(gh * vh_ref[...].astype(jnp.int32)[None], axis=-1)
    zx = accx.astype(jnp.float32) * sx_ref[...][0][None, :]
    zh = acch.astype(jnp.float32) * sh_ref[...][0][None, :]
    # zx/zh MUST be stored to separate scratch buffers before being added
    # (mirroring rb_spmv_q8.py's two-output no-FMA-contraction contract):
    # any emitted fusion containing dequant-mul → add lets XLA contract
    # them into an FMA and drift a bit off the chained path. A store's
    # value is the bare multiply — exactly rounded — and adds on scratch
    # reads have no multiply operand left to contract.
    return zx, zh


def _function_stage(lut_ref, z, c_prev, p_scr, H, pwl):
    """The Function module on a VMEM-resident z — the exact elementwise
    math of ``lstm_gates._lstm_gates_kernel`` (elementwise ops cannot
    drift across block shapes). z: (B, ≥4H); p_scr: (2, B, H) f32 VMEM
    scratch staging the cell's two products (see below);
    returns (c, h) float32."""
    f32 = jnp.float32
    zf = z[:, :H].astype(f32)
    zi = z[:, H:2 * H].astype(f32)
    zg = z[:, 2 * H:3 * H].astype(f32)
    zo = z[:, 3 * H:4 * H].astype(f32)
    if pwl:
        lut = lut_ref[...]
        lo, hi, n_seg = _T["lo"], _T["hi"], _T["n_seg"]
        sig = lambda v: _pwl(v, lut[0], lut[1], lo, hi, n_seg, 0.0, 1.0)
        th = lambda v: _pwl(v, lut[2], lut[3], lo, hi, n_seg, -1.0, 1.0)
    else:
        sig = jax.nn.sigmoid
        th = jnp.tanh
    f, i, g, o = sig(zf), sig(zi), th(zg), sig(zo)
    # c = f*c_prev + i*g with both products staged through VMEM scratch —
    # a stored product is exactly rounded and multi-use, so the compiler
    # cannot contract it into the add (fmuladd). The chained
    # ``lstm_gates`` kernel stages its cell identically, which is what
    # keeps step, scan and chained trajectories bitwise-identical: an
    # unstaged product's rounding depends on the surrounding kernel body.
    p_scr[0] = f * c_prev.astype(f32)
    p_scr[1] = i * g
    c = p_scr[0] + p_scr[1]
    h = o * th(c)
    return c, h


def _lut():
    return jnp.asarray(_LUT)


def _lut_spec(nargs: int):
    """Constant-index BlockSpec for the PWL LUT, for an ``nargs``-dim grid."""
    return pl.BlockSpec(_LUT.shape, lambda *_: (0,) * 2)


# ------------------------------------------------------------- fused step

def _fused_step_kernel(lut_ref, x_ref, h_ref, c_ref, vx_ref, dx_ref, vh_ref,
                       dh_ref, b_ref, c_out_ref, h_out_ref, z_scr, p_scr, *,
                       block_rows, nblk, H, pwl):
    i = pl.program_id(0)
    accx, acch = _gate_block(x_ref[...], h_ref[...], vx_ref, dx_ref,
                             vh_ref, dh_ref)
    z = accx + acch + b_ref[...].astype(jnp.float32)[None, 0, :]
    # the chained path writes z in x.dtype and re-reads it f32; replicate
    # the round-trip in VMEM so the fused trajectory stays bitwise
    z_scr[:, pl.dslice(i * block_rows, block_rows)] = z.astype(z_scr.dtype)

    @pl.when(i == nblk - 1)
    def _close_cell():
        c, h = _function_stage(lut_ref, z_scr[...], c_ref[...], p_scr, H,
                               pwl)
        c_out_ref[...] = c.astype(c_out_ref.dtype)
        h_out_ref[...] = h.astype(h_out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("pwl", "block_rows", "interpret"))
def fused_brds_lstm_step(vals_x, deltas_x, x, vals_h, deltas_h, h, bias,
                         c_prev, *, pwl: bool = False,
                         block_rows: int = DEF_BLOCK_ROWS,
                         interpret: bool = True):
    """One BRDS-LSTM decode step in ONE launch: dual-ratio SpMV + bias +
    gate nonlinearities + cell update, z/c/h VMEM-resident between the
    Gate and Function stages.

    vals/deltas: (R, K*) packed over the 4H gate rows (R a block_rows
    multiple — the ops wrapper pre-pads); x (B, X), h/c (B, H),
    bias (R,). Returns (c, h) in c_prev.dtype.
    """
    R, Kx = vals_x.shape
    B, X = x.shape
    H = h.shape[1]
    assert vals_h.shape[0] == R and bias.shape == (R,)
    assert R % block_rows == 0, (R, block_rows)
    nblk = R // block_rows
    bspec = pl.BlockSpec((1, block_rows), lambda i: (0, i))
    full = lambda shp: pl.BlockSpec(shp, lambda i: (0, 0))
    rblk = lambda K: pl.BlockSpec((block_rows, K), lambda i: (i, 0))
    c, h_out = pl.pallas_call(
        functools.partial(_fused_step_kernel, block_rows=block_rows,
                          nblk=nblk, H=H, pwl=pwl),
        grid=(nblk,),
        in_specs=[_lut_spec(1), full((B, X)), full((B, H)), full((B, H)),
                  rblk(Kx), rblk(Kx), rblk(vals_h.shape[1]),
                  rblk(vals_h.shape[1]), bspec],
        out_specs=[full((B, H)), full((B, H))],
        out_shape=[jax.ShapeDtypeStruct((B, H), c_prev.dtype)] * 2,
        scratch_shapes=[pltpu.VMEM((B, R), x.dtype),
                        pltpu.VMEM((2, B, H), jnp.float32)],
        interpret=interpret,
    )(_lut(), x, h, c_prev, vals_x, deltas_x, vals_h, deltas_h,
      bias.reshape(1, R))
    return c, h_out


# ------------------------------------------------------- fused delta step

def _fused_delta_step_kernel(lut_ref, dx_ref, fx_ref, dh_ref, fh_ref, c_ref,
                             vx_ref, ix_ref, vh_ref, ih_ref, m_ref, b_ref,
                             c_out_ref, h_out_ref, m_out_ref, z_scr, p_scr,
                             *, block_rows, nblk, H, pwl):
    i = pl.program_id(0)
    dxm = dx_ref[...].astype(jnp.float32) * fx_ref[...]
    dhm = dh_ref[...].astype(jnp.float32) * fh_ref[...]
    accx, acch = _delta_gate_block(dxm, dhm, vx_ref, ix_ref, vh_ref, ih_ref)
    m = m_ref[...].astype(jnp.float32) + accx + acch
    m_out_ref[...] = m.astype(m_out_ref.dtype)
    z_scr[:, pl.dslice(i * block_rows, block_rows)] = m

    @pl.when(i == nblk - 1)
    def _close_cell():
        z = z_scr[...] + b_ref[...].astype(jnp.float32)[0][None, :]
        c, h = _function_stage(lut_ref, z, c_ref[...], p_scr, H, pwl)
        c_out_ref[...] = c.astype(c_out_ref.dtype)
        h_out_ref[...] = h.astype(h_out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("pwl", "block_rows", "interpret"))
def fused_brds_delta_lstm_step(vals_x, deltas_x, dx, fx, vals_h, deltas_h,
                               dh, fh, m, bias, c_prev, *, pwl: bool = False,
                               block_rows: int = DEF_BLOCK_ROWS,
                               interpret: bool = True):
    """One temporally-sparse BRDS-LSTM step in ONE launch: fired-column
    masking + partial-sum memory update + bias + cell, m and z staying in
    VMEM between the Gate and Function stages.

    dx (B, X) / dh (B, H) raw deltas with f32 fired masks fx/fh;
    m (B, R) fp32 partial-sum memory (R block-padded by the wrapper).
    Returns (c, h, m')."""
    R, Kx = vals_x.shape
    B, X = dx.shape
    H = dh.shape[1]
    assert vals_h.shape[0] == R and m.shape == (B, R) and bias.shape == (R,)
    assert R % block_rows == 0, (R, block_rows)
    nblk = R // block_rows
    full = lambda shp: pl.BlockSpec(shp, lambda i: (0, 0))
    rblk = lambda K: pl.BlockSpec((block_rows, K), lambda i: (i, 0))
    mblk = pl.BlockSpec((B, block_rows), lambda i: (0, i))
    c, h, m_out = pl.pallas_call(
        functools.partial(_fused_delta_step_kernel, block_rows=block_rows,
                          nblk=nblk, H=H, pwl=pwl),
        grid=(nblk,),
        in_specs=[_lut_spec(1), full((B, X)), full((B, X)), full((B, H)),
                  full((B, H)), full((B, H)), rblk(Kx), rblk(Kx),
                  rblk(vals_h.shape[1]), rblk(vals_h.shape[1]), mblk,
                  full((1, R))],
        out_specs=[full((B, H)), full((B, H)), mblk],
        out_shape=[jax.ShapeDtypeStruct((B, H), c_prev.dtype),
                   jax.ShapeDtypeStruct((B, H), c_prev.dtype),
                   jax.ShapeDtypeStruct((B, R), m.dtype)],
        scratch_shapes=[pltpu.VMEM((B, R), jnp.float32),
                        pltpu.VMEM((2, B, H), jnp.float32)],
        interpret=interpret,
    )(_lut(), dx, fx, dh, fh, c_prev, vals_x, deltas_x, vals_h, deltas_h,
      m, bias.reshape(1, R))
    return c, h, m_out


# --------------------------------------------------------- fused q8 steps

def _fused_step_q8_kernel(lut_ref, qx_ref, qh_ref, c_ref, vx_ref, ix_ref,
                          sx_ref, vh_ref, ih_ref, sh_ref, b_ref, c_out_ref,
                          h_out_ref, zx_scr, zh_scr, p_scr, *, block_rows,
                          nblk, H, pwl):
    i = pl.program_id(0)
    zx, zh = _q8_gate_block(qx_ref[...], qh_ref[...], vx_ref, ix_ref,
                            sx_ref, vh_ref, ih_ref, sh_ref)
    sl = pl.dslice(i * block_rows, block_rows)
    zx_scr[:, sl] = zx
    zh_scr[:, sl] = zh

    @pl.when(i == nblk - 1)
    def _close_cell():
        z = (zx_scr[...] + zh_scr[...]
             + b_ref[...].astype(jnp.float32)[0][None, :])
        c, h = _function_stage(lut_ref, z, c_ref[...], p_scr, H, pwl)
        c_out_ref[...] = c.astype(c_out_ref.dtype)
        h_out_ref[...] = h.astype(h_out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("pwl", "block_rows", "interpret"))
def fused_brds_lstm_step_q8(vals_x, deltas_x, scales_x, qx, vals_h, deltas_h,
                            scales_h, qh, bias, c_prev, *, pwl: bool = False,
                            block_rows: int = DEF_BLOCK_ROWS,
                            interpret: bool = True):
    """One QUANTIZED BRDS-LSTM step in ONE launch: int32 accumulate +
    per-row dequant feeding the gate nonlinearities in-register.

    vals: (R, K*) int codes; scales: (R,) f32 combined row×act dequant;
    qx (B, X) / qh (B, H) int activation codes (the ops wrapper quantizes,
    so pallas and ref consume the SAME codes). Returns (c, h)."""
    R, Kx = vals_x.shape
    B, X = qx.shape
    H = qh.shape[1]
    assert vals_h.shape[0] == R and bias.shape == (R,)
    assert scales_x.shape == (R,) and scales_h.shape == (R,)
    assert R % block_rows == 0, (R, block_rows)
    nblk = R // block_rows
    full = lambda shp: pl.BlockSpec(shp, lambda i: (0, 0))
    rblk = lambda K: pl.BlockSpec((block_rows, K), lambda i: (i, 0))
    sblk = pl.BlockSpec((1, block_rows), lambda i: (0, i))
    c, h = pl.pallas_call(
        functools.partial(_fused_step_q8_kernel, block_rows=block_rows,
                          nblk=nblk, H=H, pwl=pwl),
        grid=(nblk,),
        in_specs=[_lut_spec(1), full((B, X)), full((B, H)), full((B, H)),
                  rblk(Kx), rblk(Kx), sblk, rblk(vals_h.shape[1]),
                  rblk(vals_h.shape[1]), sblk, full((1, R))],
        out_specs=[full((B, H)), full((B, H))],
        out_shape=[jax.ShapeDtypeStruct((B, H), c_prev.dtype)] * 2,
        scratch_shapes=[pltpu.VMEM((B, R), jnp.float32),
                        pltpu.VMEM((B, R), jnp.float32),
                        pltpu.VMEM((2, B, H), jnp.float32)],
        interpret=interpret,
    )(_lut(), qx, qh, c_prev, vals_x, deltas_x, scales_x.reshape(1, R),
      vals_h, deltas_h, scales_h.reshape(1, R), bias.reshape(1, R))
    return c, h


def _fused_delta_step_q8_kernel(lut_ref, qdx_ref, qdh_ref, c_ref, vx_ref,
                                ix_ref, sx_ref, vh_ref, ih_ref, sh_ref,
                                m_ref, b_ref, c_out_ref, h_out_ref,
                                m_out_ref, zx_scr, zh_scr, p_scr, *,
                                block_rows, nblk, H, pwl):
    i = pl.program_id(0)
    zx, zh = _q8_gate_block(qdx_ref[...], qdh_ref[...], vx_ref, ix_ref,
                            sx_ref, vh_ref, ih_ref, sh_ref)
    sl = pl.dslice(i * block_rows, block_rows)
    zx_scr[:, sl] = zx
    zh_scr[:, sl] = zh

    @pl.when(i == nblk - 1)
    def _close_cell():
        m = m_ref[...].astype(jnp.float32) + zx_scr[...] + zh_scr[...]
        m_out_ref[...] = m.astype(m_out_ref.dtype)
        z = m + b_ref[...].astype(jnp.float32)[0][None, :]
        c, h = _function_stage(lut_ref, z, c_ref[...], p_scr, H, pwl)
        c_out_ref[...] = c.astype(c_out_ref.dtype)
        h_out_ref[...] = h.astype(h_out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("pwl", "block_rows", "interpret"))
def fused_brds_delta_lstm_step_q8(vals_x, deltas_x, scales_x, qdx, vals_h,
                                  deltas_h, scales_h, qdh, m, bias, c_prev,
                                  *, pwl: bool = False,
                                  block_rows: int = DEF_BLOCK_ROWS,
                                  interpret: bool = True):
    """One QUANTIZED temporally-sparse step in ONE launch: masked-delta
    int codes advance the fp32 partial-sum memory, bias applies on top,
    the Function stage closes the cell — all VMEM-resident.

    qdx/qdh are int codes of the MASKED deltas (exact 0 where unfired).
    Returns (c, h, m')."""
    R, Kx = vals_x.shape
    B, X = qdx.shape
    H = qdh.shape[1]
    assert vals_h.shape[0] == R and m.shape == (B, R) and bias.shape == (R,)
    assert R % block_rows == 0, (R, block_rows)
    nblk = R // block_rows
    full = lambda shp: pl.BlockSpec(shp, lambda i: (0, 0))
    rblk = lambda K: pl.BlockSpec((block_rows, K), lambda i: (i, 0))
    sblk = pl.BlockSpec((1, block_rows), lambda i: (0, i))
    c, h, m_out = pl.pallas_call(
        functools.partial(_fused_delta_step_q8_kernel,
                          block_rows=block_rows, nblk=nblk, H=H, pwl=pwl),
        grid=(nblk,),
        in_specs=[_lut_spec(1), full((B, X)), full((B, H)), full((B, H)),
                  rblk(Kx), rblk(Kx), sblk, rblk(vals_h.shape[1]),
                  rblk(vals_h.shape[1]), sblk, full((B, R)), full((1, R))],
        out_specs=[full((B, H)), full((B, H)), full((B, R))],
        out_shape=[jax.ShapeDtypeStruct((B, H), c_prev.dtype),
                   jax.ShapeDtypeStruct((B, H), c_prev.dtype),
                   jax.ShapeDtypeStruct((B, R), m.dtype)],
        scratch_shapes=[pltpu.VMEM((B, R), jnp.float32),
                        pltpu.VMEM((B, R), jnp.float32),
                        pltpu.VMEM((2, B, H), jnp.float32)],
        interpret=interpret,
    )(_lut(), qdx, qdh, c_prev, vals_x, deltas_x, scales_x.reshape(1, R),
      vals_h, deltas_h, scales_h.reshape(1, R), m, bias.reshape(1, R))
    return c, h, m_out


# ---------------------------------------------------- multi-token scan

def _fused_scan_kernel(lut_ref, xs_ref, h0_ref, c0_ref, vx_ref, dx_ref,
                       vh_ref, dh_ref, b_ref, hs_ref, c_out_ref, z_scr,
                       h_scr, c_scr, p_scr, *, block_rows, nblk, H, pwl):
    t, j = pl.program_id(0), pl.program_id(1)

    @pl.when(jnp.logical_and(t == 0, j == 0))
    def _load_state():
        h_scr[...] = h0_ref[...]
        c_scr[...] = c0_ref[...]

    accx, acch = _gate_block(xs_ref[...][0], h_scr[...], vx_ref, dx_ref,
                             vh_ref, dh_ref)
    z = accx + acch + b_ref[...].astype(jnp.float32)[None, 0, :]
    z_scr[:, pl.dslice(j * block_rows, block_rows)] = z.astype(z_scr.dtype)

    @pl.when(j == nblk - 1)
    def _close_cell():
        c, h = _function_stage(lut_ref, z_scr[...], c_scr[...], p_scr, H,
                               pwl)
        c_scr[...] = c.astype(c_scr.dtype)
        h_scr[...] = h.astype(h_scr.dtype)
        hs_ref[...] = h.astype(hs_ref.dtype)[None]
        c_out_ref[...] = c.astype(c_out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("pwl", "block_rows", "interpret"))
def fused_brds_lstm_scan(vals_x, deltas_x, xs, vals_h, deltas_h, h0, bias,
                         c0, *, pwl: bool = False,
                         block_rows: int = DEF_BLOCK_ROWS,
                         interpret: bool = True):
    """T BRDS-LSTM decode steps inside ONE kernel launch.

    Grid (T, row-blocks): c and h live in VMEM scratch across steps, so
    between tokens only the packed weight blocks are re-read from HBM
    (and when they fit VMEM the hardware can keep them resident — the
    paper's computation overlapping taken to its limit). Each step's math
    is the fused single-step kernel's, so the trajectory is bitwise the
    T-times-repeated ``fused_brds_lstm_step``.

    xs: (T, B, X); h0/c0: (B, H). Returns (hs (T, B, H), c_T)."""
    R, Kx = vals_x.shape
    T, B, X = xs.shape
    H = h0.shape[1]
    assert vals_h.shape[0] == R and bias.shape == (R,)
    assert R % block_rows == 0, (R, block_rows)
    nblk = R // block_rows
    full = lambda shp: pl.BlockSpec(shp, lambda t, j: (0, 0))
    rblk = lambda K: pl.BlockSpec((block_rows, K), lambda t, j: (j, 0))
    hs, c = pl.pallas_call(
        functools.partial(_fused_scan_kernel, block_rows=block_rows,
                          nblk=nblk, H=H, pwl=pwl),
        grid=(T, nblk),
        in_specs=[pl.BlockSpec(_LUT.shape, lambda t, j: (0, 0)),
                  pl.BlockSpec((1, B, X), lambda t, j: (t, 0, 0)),
                  full((B, H)), full((B, H)), rblk(Kx), rblk(Kx),
                  rblk(vals_h.shape[1]), rblk(vals_h.shape[1]),
                  pl.BlockSpec((1, block_rows), lambda t, j: (0, j))],
        out_specs=[pl.BlockSpec((1, B, H), lambda t, j: (t, 0, 0)),
                   full((B, H))],
        out_shape=[jax.ShapeDtypeStruct((T, B, H), h0.dtype),
                   jax.ShapeDtypeStruct((B, H), c0.dtype)],
        scratch_shapes=[pltpu.VMEM((B, R), xs.dtype),
                        pltpu.VMEM((B, H), h0.dtype),
                        pltpu.VMEM((B, H), c0.dtype),
                        pltpu.VMEM((2, B, H), jnp.float32)],
        interpret=interpret,
    )(_lut(), xs, h0, c0, vals_x, deltas_x, vals_h, deltas_h,
      bias.reshape(1, R))
    return hs, c


def _fused_delta_scan_kernel(lut_ref, xs_ref, h0_ref, c0_ref, xr0_ref,
                             hr0_ref, m0_ref, vx_ref, ix_ref, vh_ref, ih_ref,
                             b_ref, hs_ref, c_out_ref, xr_out_ref,
                             hr_out_ref, m_out_ref, h_scr, c_scr, xr_scr,
                             hr_scr, dxm_scr, dhm_scr, m_scr, p_scr, *,
                             block_rows, nblk, H, pwl, theta_x, theta_h):
    t, j = pl.program_id(0), pl.program_id(1)
    f32 = jnp.float32

    @pl.when(jnp.logical_and(t == 0, j == 0))
    def _load_state():
        h_scr[...] = h0_ref[...]
        c_scr[...] = c0_ref[...]
        xr_scr[...] = xr0_ref[...]
        hr_scr[...] = hr0_ref[...]
        m_scr[...] = m0_ref[...].astype(f32)

    @pl.when(j == 0)
    def _threshold():
        # in-kernel delta_threshold (repro.sparse.temporal), uncapped:
        # same elementwise ops as the host-side version, on VMEM state
        x = xs_ref[...][0]
        d = (x - xr_scr[...]).astype(x.dtype)
        fired = jnp.abs(d) > theta_x
        xr_scr[...] = jnp.where(fired, x, xr_scr[...])
        dxm_scr[...] = d.astype(f32) * fired.astype(f32)
        hv = h_scr[...]
        dh = (hv - hr_scr[...]).astype(hv.dtype)
        fired_h = jnp.abs(dh) > theta_h
        hr_scr[...] = jnp.where(fired_h, hv, hr_scr[...])
        dhm_scr[...] = dh.astype(f32) * fired_h.astype(f32)

    accx, acch = _delta_gate_block(dxm_scr[...], dhm_scr[...], vx_ref,
                                   ix_ref, vh_ref, ih_ref)
    sl = pl.dslice(j * block_rows, block_rows)
    m_scr[:, sl] = m_scr[:, sl].astype(f32) + accx + acch

    @pl.when(j == nblk - 1)
    def _close_cell():
        z = m_scr[...] + b_ref[...].astype(f32)[0][None, :]
        c, h = _function_stage(lut_ref, z, c_scr[...], p_scr, H, pwl)
        c_scr[...] = c.astype(c_scr.dtype)
        h_scr[...] = h.astype(h_scr.dtype)
        hs_ref[...] = h.astype(hs_ref.dtype)[None]
        c_out_ref[...] = c.astype(c_out_ref.dtype)
        xr_out_ref[...] = xr_scr[...]
        hr_out_ref[...] = hr_scr[...]
        m_out_ref[...] = m_scr[...].astype(m_out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("theta_x", "theta_h", "pwl",
                                    "block_rows", "interpret"))
def fused_brds_delta_lstm_scan(vals_x, deltas_x, xs, vals_h, deltas_h, h0,
                               c0, x_ref0, h_ref0, m0, bias, *,
                               theta_x: float, theta_h: float,
                               pwl: bool = False,
                               block_rows: int = DEF_BLOCK_ROWS,
                               interpret: bool = True):
    """T temporally-sparse decode steps inside ONE kernel launch: the
    delta thresholding, reference-state tracking, partial-sum memory AND
    the cell all advance in VMEM scratch; only packed weight blocks are
    re-read from HBM between tokens. Uncapped thresholds only (the
    occupancy cap's top_k runs host-side — the ops wrapper falls back to
    per-step launches when a cap is set).

    xs (T, B, X); x_ref0 (B, X) / h_ref0 (B, H) reference states;
    m0 (B, R) fp32 partial sums. Returns (hs, c_T, x_ref_T, h_ref_T, m_T).
    """
    R, Kx = vals_x.shape
    T, B, X = xs.shape
    H = h0.shape[1]
    assert vals_h.shape[0] == R and m0.shape == (B, R) and bias.shape == (R,)
    assert R % block_rows == 0, (R, block_rows)
    nblk = R // block_rows
    full = lambda shp: pl.BlockSpec(shp, lambda t, j: (0, 0))
    rblk = lambda K: pl.BlockSpec((block_rows, K), lambda t, j: (j, 0))
    hs, c, xr, hr, m = pl.pallas_call(
        functools.partial(_fused_delta_scan_kernel, block_rows=block_rows,
                          nblk=nblk, H=H, pwl=pwl, theta_x=theta_x,
                          theta_h=theta_h),
        grid=(T, nblk),
        in_specs=[pl.BlockSpec(_LUT.shape, lambda t, j: (0, 0)),
                  pl.BlockSpec((1, B, X), lambda t, j: (t, 0, 0)),
                  full((B, H)), full((B, H)), full((B, X)), full((B, H)),
                  full((B, R)), rblk(Kx), rblk(Kx), rblk(vals_h.shape[1]),
                  rblk(vals_h.shape[1]), full((1, R))],
        out_specs=[pl.BlockSpec((1, B, H), lambda t, j: (t, 0, 0)),
                   full((B, H)), full((B, X)), full((B, H)), full((B, R))],
        out_shape=[jax.ShapeDtypeStruct((T, B, H), h0.dtype),
                   jax.ShapeDtypeStruct((B, H), c0.dtype),
                   jax.ShapeDtypeStruct((B, X), x_ref0.dtype),
                   jax.ShapeDtypeStruct((B, H), h_ref0.dtype),
                   jax.ShapeDtypeStruct((B, R), m0.dtype)],
        scratch_shapes=[pltpu.VMEM((B, H), h0.dtype),
                        pltpu.VMEM((B, H), c0.dtype),
                        pltpu.VMEM((B, X), x_ref0.dtype),
                        pltpu.VMEM((B, H), h_ref0.dtype),
                        pltpu.VMEM((B, X), jnp.float32),
                        pltpu.VMEM((B, H), jnp.float32),
                        pltpu.VMEM((B, R), jnp.float32),
                        pltpu.VMEM((2, B, H), jnp.float32)],
        interpret=interpret,
    )(_lut(), xs, h0, c0, x_ref0, h_ref0, m0, vals_x, deltas_x, vals_h,
      deltas_h, bias.reshape(1, R))
    return hs, c, xr, hr, m
