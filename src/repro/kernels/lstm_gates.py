"""Pallas TPU kernel: fused LSTM cell elementwise update (paper's Function +
Buffer modules).

On the FPGA, the Gate module's MxV output streams through a Buffer into the
Function module (σ/tanh/⊙) so activation traffic never leaves the chip.
The TPU analogue: one kernel consumes the four gate preactivations and
c_{t-1} tile-by-tile from VMEM and emits (c_t, h_t) — no HBM round-trip for
the intermediate gate activations, double-buffered DMAs across grid steps.

Supports the paper's piecewise-linear activation mode (16-segment LUT,
out = a·x + b per segment) as a static option, matching the fixed-point
datapath study. The LUT coefficients ride in as a (4, n_seg) kernel input
(the BRAM LUT analogue).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import pwl_tables

DEF_BLOCK = 512
_T = pwl_tables()
# rows: a_sig, b_sig, a_tanh, b_tanh
_LUT = np.stack([_T["sig"][0], _T["sig"][1], _T["tanh"][0], _T["tanh"][1]])


def _pwl(x, a, b, lo, hi, n_seg, sat_lo, sat_hi):
    xc = jnp.clip(x, lo, hi - 1e-6)
    idx = jnp.clip(jnp.floor((xc - lo) / (hi - lo) * n_seg).astype(jnp.int32),
                   0, n_seg - 1)
    y = a[idx] * xc + b[idx]
    return jnp.where(x < lo, sat_lo, jnp.where(x >= hi, sat_hi, y))


def _lstm_gates_kernel(lut_ref, zf_ref, zi_ref, zg_ref, zo_ref, c_ref,
                       c_out_ref, h_out_ref, p_scr, *, pwl: bool):
    f32 = jnp.float32
    zf, zi = zf_ref[...].astype(f32), zi_ref[...].astype(f32)
    zg, zo = zg_ref[...].astype(f32), zo_ref[...].astype(f32)
    c_prev = c_ref[...].astype(f32)
    if pwl:
        lut = lut_ref[...]
        lo, hi, n_seg = _T["lo"], _T["hi"], _T["n_seg"]
        sig = lambda v: _pwl(v, lut[0], lut[1], lo, hi, n_seg, 0.0, 1.0)
        th = lambda v: _pwl(v, lut[2], lut[3], lo, hi, n_seg, -1.0, 1.0)
    else:
        sig = jax.nn.sigmoid
        th = jnp.tanh
    f, i, g, o = sig(zf), sig(zi), th(zg), sig(zo)
    # c = f*c_prev + i*g, with each product staged through VMEM scratch:
    # a stored product is exactly rounded and multi-use, so the compiler
    # cannot contract it into the add (fmuladd) — the cell rounds the
    # same way in every kernel that inlines this math (the fused
    # single-step and multi-token-scan kernels replicate it bitwise)
    p_scr[0] = f * c_prev
    p_scr[1] = i * g
    c = p_scr[0] + p_scr[1]
    h = o * th(c)
    c_out_ref[...] = c.astype(c_out_ref.dtype)
    h_out_ref[...] = h.astype(h_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("pwl", "block", "interpret"))
def lstm_gates(zf, zi, zg, zo, c_prev, *, pwl: bool = False,
               block: int = DEF_BLOCK, interpret: bool = True):
    """Fused elementwise LSTM cell. All inputs (B, H); returns (c_t, h_t)."""
    B, H = zf.shape
    block = min(block, H)
    assert H % block == 0, (H, block)
    grid = (H // block,)
    spec = pl.BlockSpec((B, block), lambda i: (0, i))
    lut = jnp.asarray(_LUT)
    lut_spec = pl.BlockSpec(lut.shape, lambda i: (0, 0))
    c, h = pl.pallas_call(
        functools.partial(_lstm_gates_kernel, pwl=pwl),
        grid=grid,
        in_specs=[lut_spec] + [spec] * 5,
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((B, H), c_prev.dtype)] * 2,
        scratch_shapes=[pltpu.VMEM((2, B, block), jnp.float32)],
        interpret=interpret,
    )(lut, zf, zi, zg, zo, c_prev)
    return c, h
