"""Pallas TPU kernel: packed row-balanced sparse matrix × dense vector(s).

This is the BRDS accelerator's Gate-module MxV, adapted to TPU:

- every row has exactly K non-zeros → every grid step does identical work
  (the paper's row-balanced PE utilization argument, restated for VMEM
  tiles);
- only (R, K) values + narrow delta indices stream HBM→VMEM (the relative-
  addressing memory saving);
- the dual-ratio variant processes the W_x and W_h packed matrices in the
  SAME grid step so both families advance in lockstep — the Large/Small
  mult-array co-scheduling, with per-step work automatically proportional
  to K_x : K_h exactly like R_L : R_S sizing;
- column indices are rebuilt by an in-register cumulative sum, and the
  dense activation vector is gathered from VMEM (x fits VMEM for every
  assigned arch: d_model ≤ 18432 → 36 KiB bf16).

Used on the memory-bound decode path, where bytes (not FLOPs) dominate:
effective-throughput gain ≈ 1/(1-sparsity), the paper's headline metric.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEF_BLOCK_ROWS = 256


def _rb_spmv_kernel(x_ref, vals_ref, deltas_ref, out_ref):
    """Grid step: one block of rows. x_ref (B, X); vals/deltas (bR, K);
    out_ref (B, bR)."""
    cols = jnp.cumsum(deltas_ref[...].astype(jnp.int32), axis=1)   # (bR, K)
    x = x_ref[...]                                                 # (B, X)
    g = jnp.take(x, cols, axis=1).astype(jnp.float32)              # (B, bR, K)
    v = vals_ref[...].astype(jnp.float32)                          # (bR, K)
    acc = jnp.sum(g * v[None, :, :], axis=-1)                      # (B, bR)
    out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def rb_spmv(values, deltas, x, *, block_rows: int = DEF_BLOCK_ROWS,
            interpret: bool = True):
    """y[b, r] = Σ_k values[r, k] · x[b, cols[r, k]].

    values: (R, K) float; deltas: (R, K) int8/16/32; x: (B, X).
    Returns (B, R) in x.dtype. R must be a multiple of block_rows (the ops
    wrapper pads).
    """
    R, K = values.shape
    B, X = x.shape
    assert R % block_rows == 0, (R, block_rows)
    grid = (R // block_rows,)
    return pl.pallas_call(
        _rb_spmv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((B, X), lambda i: (0, 0)),
            pl.BlockSpec((block_rows, K), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, K), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((B, block_rows), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((B, R), x.dtype),
        interpret=interpret,
    )(x, values, deltas)


def _rb_dual_kernel(x_ref, h_ref, vx_ref, dx_ref, vh_ref, dh_ref, b_ref,
                    out_ref):
    """One row block of z = Sx@x + Sh@h + bias. Both packed families are
    consumed in the same step (Large/Small MA lockstep)."""
    colsx = jnp.cumsum(dx_ref[...].astype(jnp.int32), axis=1)
    colsh = jnp.cumsum(dh_ref[...].astype(jnp.int32), axis=1)
    gx = jnp.take(x_ref[...], colsx, axis=1).astype(jnp.float32)   # (B,bR,Kx)
    gh = jnp.take(h_ref[...], colsh, axis=1).astype(jnp.float32)   # (B,bR,Kh)
    accx = jnp.sum(gx * vx_ref[...].astype(jnp.float32)[None], axis=-1)
    acch = jnp.sum(gh * vh_ref[...].astype(jnp.float32)[None], axis=-1)
    z = accx + acch + b_ref[...].astype(jnp.float32)[None, 0, :]
    out_ref[...] = z.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def rb_dual_spmv(vals_x, deltas_x, x, vals_h, deltas_h, h, bias, *,
                 block_rows: int = DEF_BLOCK_ROWS, interpret: bool = True):
    """z = Sx @ x + Sh @ h + bias for packed row-balanced Sx (R,Kx), Sh (R,Kh).

    x: (B, X), h: (B, H), bias: (R,). Returns (B, R)."""
    R, Kx = vals_x.shape
    _, Kh = vals_h.shape
    B, X = x.shape
    H = h.shape[1]
    assert vals_h.shape[0] == R and bias.shape == (R,)
    assert R % block_rows == 0, (R, block_rows)
    grid = (R // block_rows,)
    bias2 = bias.reshape(1, R)
    return pl.pallas_call(
        _rb_dual_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((B, X), lambda i: (0, 0)),
            pl.BlockSpec((B, H), lambda i: (0, 0)),
            pl.BlockSpec((block_rows, Kx), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, Kx), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, Kh), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, Kh), lambda i: (i, 0)),
            pl.BlockSpec((1, block_rows), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((B, block_rows), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((B, R), x.dtype),
        interpret=interpret,
    )(x, h, vals_x, deltas_x, vals_h, deltas_h, bias2)
