"""jit'd public wrappers around the Pallas kernels.

Backend selection goes through ``repro.sparse.backend``: "pallas" runs the
kernels (interpret mode on CPU, compiled on TPU), "ref" the pure-jnp
reference formulations (the dry-run path lowers these; XLA fuses them),
"auto"/None the configured default. The old per-call ``use_kernel=``
boolean is accepted as a deprecated alias.

Row padding to kernel-block multiples is handled here, with a fast path
for structs pre-padded by ``core.packing.pad_packed`` (the model/serving
layer pads once at pack time so no per-token copy of the weight stream
happens inside the jitted step).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref as _ref
from . import fused_step as _fused
from .rb_spmv import rb_spmv as _rb_spmv_kernel, rb_dual_spmv as _rb_dual_kernel
from .delta_rb_spmv import (delta_rb_spmv as _delta_rb_spmv_kernel,
                            delta_rb_dual_spmv as _delta_rb_dual_kernel)
from .rb_spmv_q8 import (rb_spmv_q8 as _rb_spmv_q8_kernel,
                         rb_dual_parts_q8 as _rb_dual_parts_q8_kernel)
from .lstm_gates import lstm_gates as _lstm_gates_kernel
from .flash_attention import flash_attention as _flash_kernel
from .decode_attention import decode_attention as _decode_kernel
from ..core.packing import RowBalancedSparse
from ..quant.scheme import quantize as _quantize
from ..sparse import backend as _backend


def on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _resolve(backend: str | None, use_kernel: bool | None) -> str:
    """→ concrete "pallas" | "ref" (use_kernel= is the deprecated alias)."""
    if use_kernel is not None:
        return _backend.from_use_kernel(use_kernel, stacklevel=4)
    return _backend.resolve(backend)


def _pad_rows(arr, mult):
    r = arr.shape[0]
    pad = (-r) % mult
    if pad:
        arr = jnp.pad(arr, ((0, pad),) + ((0, 0),) * (arr.ndim - 1))
    return arr, pad


def _prep_rows(s, block_rows):
    """→ (values, deltas, scales | None, eff_block, padded_rows).

    The padded row count is a pure function of (logical rows, block):
    ``Rp = R + (-R) % min(block_rows, R)`` — so the two structs of a dual
    call always agree. Fast path: the struct was pre-padded to exactly
    that count by ``core.packing.pad_packed`` (or needs no padding) and
    its arrays are consumed as-is, no per-call copy. Otherwise fall back
    to slicing to logical rows and padding here.
    """
    R = s.rows
    eff = min(block_rows, R) if R else block_rows
    Rp = R + (-R) % eff
    scales = getattr(s, "scales", None)
    if s.values.shape[0] == Rp:
        return s.values, s.deltas, scales, eff, Rp
    s = s.logical()
    vals, _ = _pad_rows(s.values, eff)
    deltas, _ = _pad_rows(s.deltas, eff)
    scales = getattr(s, "scales", None)
    if scales is not None and Rp > R:
        scales = jnp.pad(scales, (0, Rp - R))
    return vals, deltas, scales, eff, Rp


def _fit(vec, n):
    """Pad (with zeros) or slice ``vec``'s last axis to length ``n`` —
    bias/partial-sum vectors ride whichever padding the struct carries."""
    have = vec.shape[-1]
    if have == n:
        return vec
    if have > n:
        return vec[..., :n]
    widths = ((0, 0),) * (vec.ndim - 1) + ((0, n - have),)
    return jnp.pad(vec, widths)


# ---------------------------------------------------------------- rb_spmv

def rb_spmv(s: RowBalancedSparse, x: jnp.ndarray, *, block_rows: int = 256,
            backend: str | None = None,
            use_kernel: bool | None = None) -> jnp.ndarray:
    """Packed row-balanced SpMV; x (B, ncols) → (B, rows)."""
    if _resolve(backend, use_kernel) == "ref":
        return _ref.rb_spmv_ref(s, x)
    R = s.rows
    vals, deltas, _, eff, Rp = _prep_rows(s, block_rows)
    y = _rb_spmv_kernel(vals, deltas, x, block_rows=eff, interpret=on_cpu())
    return y[:, :R] if Rp > R else y


def rb_dual_spmv(sx: RowBalancedSparse, x, sh: RowBalancedSparse, h, bias,
                 *, block_rows: int = 256, backend: str | None = None,
                 use_kernel: bool | None = None):
    """z = Sx@x + Sh@h + bias — the fused dual-ratio gate preactivation."""
    if _resolve(backend, use_kernel) == "ref":
        return _ref.rb_dual_spmv_ref(sx, x, sh, h, bias)
    R = sx.rows
    vx, dx, _, eff, Rp = _prep_rows(sx, block_rows)
    vh, dh, _, _, _ = _prep_rows(sh, block_rows)
    z = _rb_dual_kernel(vx, dx, x, vh, dh, h, _fit(bias, Rp),
                        block_rows=eff, interpret=on_cpu())
    return z[:, :R] if Rp > R else z


def delta_rb_spmv(s: RowBalancedSparse, d, fired, *, block_rows: int = 256,
                  backend: str | None = None):
    """Temporal-delta SpMV: y[b, r] = Σ_k vals[r, k] · fired[b, c] · d[b, c].

    ``d`` (B, ncols) raw activation deltas, ``fired`` (B, ncols) bool/0-1
    threshold mask. Returns (B, rows)."""
    fired = fired.astype(jnp.float32)
    if _resolve(backend, None) == "ref":
        return _ref.delta_rb_spmv_ref(s, d, fired)
    R = s.rows
    vals, deltas, _, eff, Rp = _prep_rows(s, block_rows)
    y = _delta_rb_spmv_kernel(vals, deltas, d, fired, block_rows=eff,
                              interpret=on_cpu())
    return y[:, :R] if Rp > R else y


def delta_rb_dual_spmv(sx: RowBalancedSparse, dx, fx,
                       sh: RowBalancedSparse, dh, fh, m, *,
                       block_rows: int = 256, backend: str | None = None):
    """m' = m + Sx@(fx·dx) + Sh@(fh·dh) — the fused temporal-delta gate
    accumulation (partial-sum memory update)."""
    fx = fx.astype(jnp.float32)
    fh = fh.astype(jnp.float32)
    if _resolve(backend, None) == "ref":
        return _ref.delta_rb_dual_spmv_ref(sx, dx, fx, sh, dh, fh, m)
    R = sx.rows
    vx, dxi, _, eff, Rp = _prep_rows(sx, block_rows)
    vh, dhi, _, _, _ = _prep_rows(sh, block_rows)
    z = _delta_rb_dual_kernel(vx, dxi, dx, fx, vh, dhi, dh, fh, _fit(m, Rp),
                              block_rows=eff, interpret=on_cpu())
    return z[:, :R] if Rp > R else z


# --------------------------------------------------------------- quantized

def _quant_act(x, packed, act_scale):
    """→ (codes, scale): quantize one activation batch for a q8 matvec.

    ``act_scale`` None → the packing's scheme decides: fixed-point uses
    its constant 2^-N; scaled schemes fall back to a dynamic per-call
    max-abs (the calibrated static scales arrive through the model)."""
    scheme = packed.scheme
    sa = scheme.act_scale(act_scale)
    if sa is None:
        amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
        sa = jnp.maximum(amax / scheme.qmax, 1e-12)
    return _quantize(x, sa, scheme), sa


def rb_spmv_q8(s, x, *, act_scale=None, block_rows: int = 256,
               backend: str | None = None):
    """Quantized packed SpMV: int codes × int activation codes, int32
    accumulate, per-row dequant. ``s``: RowBalancedSparseQ8; x (B, ncols)
    float activations (quantized here, so pallas and ref consume the SAME
    codes). Returns (B, rows) float32."""
    qx, sa = _quant_act(x, s, act_scale)
    if _resolve(backend, None) == "ref":
        return _ref.rb_spmv_q8_ref(s, qx, sa)
    R = s.rows
    vals, deltas, scales, eff, Rp = _prep_rows(s, block_rows)
    comb = (scales * sa).astype(jnp.float32)
    y = _rb_spmv_q8_kernel(vals, deltas, comb, qx, block_rows=eff,
                           interpret=on_cpu())
    return y[:, :R] if Rp > R else y


def _prep_parts_q8(sx, sax, sh, sah, block_rows):
    """Prep both q8 families: padded arrays + combined (row × act) dequant
    scales (padded scales are zero → padded rows dequantize to exact 0)."""
    vx, dxi, scx, eff, Rp = _prep_rows(sx, block_rows)
    vh, dhi, sch, _, _ = _prep_rows(sh, block_rows)
    cx = (scx * sax).astype(jnp.float32)
    ch = (sch * sah).astype(jnp.float32)
    return vx, dxi, cx, vh, dhi, ch, eff, Rp


def _dual_parts_q8(sx, qx, sax, sh, qh, sah, block_rows):
    """Run the two-family q8 kernel → (zx, zh) dequantized partial sums,
    both (B, rows) f32."""
    R = sx.rows
    vx, dxi, cx, vh, dhi, ch, eff, Rp = _prep_parts_q8(sx, sax, sh, sah,
                                                       block_rows)
    zx, zh = _rb_dual_parts_q8_kernel(vx, dxi, cx, qx, vh, dhi, ch, qh,
                                      block_rows=eff, interpret=on_cpu())
    return (zx[:, :R], zh[:, :R]) if Rp > R else (zx, zh)


def rb_dual_spmv_q8(sx, x, sh, h, bias, *, act_scale_x=None,
                    act_scale_h=None, block_rows: int = 256,
                    backend: str | None = None):
    """z = dq(Sx@qx) + dq(Sh@qh) + bias — the quantized dual-ratio gate
    preactivation (each family dequantized by its own row × act scales).
    Returns (B, rows) float32."""
    qx, sax = _quant_act(x, sx, act_scale_x)
    qh, sah = _quant_act(h, sh, act_scale_h)
    if _resolve(backend, None) == "ref":
        return _ref.rb_dual_spmv_q8_ref(sx, qx, sax, sh, qh, sah, bias)
    zx, zh = _dual_parts_q8(sx, qx, sax, sh, qh, sah, block_rows)
    return zx + zh + bias[:zx.shape[-1]].astype(jnp.float32)[None, :]


def delta_rb_dual_spmv_q8(sx, dx, fx, sh, dh, fh, m, *, act_scale_x=None,
                          act_scale_h=None, block_rows: int = 256,
                          backend: str | None = None):
    """m' = m + dq(Sx@q(fx·dx)) + dq(Sh@q(fh·dh)) — the quantized fused
    temporal-delta gate accumulation. Deltas are masked BEFORE quantizing,
    so unfired columns carry exact 0 codes into the int32 accumulation;
    ``m`` stays the fp32 partial-sum memory. Returns (B, rows) float32."""
    dxm = jnp.where(fx.astype(bool), dx, 0).astype(dx.dtype)
    dhm = jnp.where(fh.astype(bool), dh, 0).astype(dh.dtype)
    qdx, sax = _quant_act(dxm, sx, act_scale_x)
    qdh, sah = _quant_act(dhm, sh, act_scale_h)
    if _resolve(backend, None) == "ref":
        return _ref.delta_rb_dual_spmv_q8_ref(sx, qdx, sax, sh, qdh, sah, m)
    zx, zh = _dual_parts_q8(sx, qdx, sax, sh, qdh, sah, block_rows)
    return m.astype(jnp.float32) + zx + zh


def brds_lstm_step_q8(sx, x, sh, h_prev, bias, c_prev, *, act_scale_x=None,
                      act_scale_h=None, pwl: bool = False,
                      block_rows: int = 256, backend: str | None = None):
    """One quantized BRDS-LSTM inference step: the q8 dual-ratio SpMV
    (int32 accumulate + per-row dequant) feeding the Function module.
    Returns (c, h)."""
    z = rb_dual_spmv_q8(sx, x, sh, h_prev, bias, act_scale_x=act_scale_x,
                        act_scale_h=act_scale_h, block_rows=block_rows,
                        backend=backend)
    H = z.shape[-1] // 4
    return lstm_gates(z[:, :H], z[:, H:2 * H], z[:, 2 * H:3 * H],
                      z[:, 3 * H:], c_prev, pwl=pwl, backend=backend)


def brds_delta_lstm_step_q8(sx, dx, fx, sh, dh, fh, m_prev, bias, c_prev,
                            *, act_scale_x=None, act_scale_h=None,
                            pwl: bool = False, block_rows: int = 256,
                            backend: str | None = None):
    """One quantized temporally-sparse BRDS-LSTM step: fired-column
    quantized products advance the fp32 partial-sum memory, bias applies
    on top, the Function module closes the cell. Returns (c, h, m)."""
    m = delta_rb_dual_spmv_q8(sx, dx, fx, sh, dh, fh, m_prev,
                              act_scale_x=act_scale_x,
                              act_scale_h=act_scale_h,
                              block_rows=block_rows, backend=backend)
    z = m + bias[:m.shape[-1]].astype(jnp.float32)[None, :]
    H = z.shape[-1] // 4
    c, h = lstm_gates(z[:, :H], z[:, H:2 * H], z[:, 2 * H:3 * H],
                      z[:, 3 * H:], c_prev, pwl=pwl, backend=backend)
    return c, h, m


def brds_delta_lstm_step(sx: RowBalancedSparse, dx, fx,
                         sh: RowBalancedSparse, dh, fh, m_prev, bias, c_prev,
                         *, pwl: bool = False, block_rows: int = 256,
                         backend: str | None = None):
    """One temporally-sparse BRDS-LSTM inference step.

    The Spartus composition of the accelerator datapath: the fused delta
    dual-SpMV advances the partial-sum memory ``m`` with only the fired
    columns' products, the bias is applied on top, and the Function module
    (lstm_gates) produces the new cell state. Returns (c, h, m)."""
    m = delta_rb_dual_spmv(sx, dx, fx, sh, dh, fh, m_prev,
                           block_rows=block_rows, backend=backend)
    z = m.astype(jnp.float32) + bias[:m.shape[-1]].astype(jnp.float32)[None, :]
    H = z.shape[-1] // 4
    c, h = lstm_gates(z[:, :H], z[:, H:2 * H], z[:, 2 * H:3 * H],
                      z[:, 3 * H:], c_prev, pwl=pwl, backend=backend)
    return c, h, m


def brds_lstm_step(sx: RowBalancedSparse, x, sh: RowBalancedSparse, h_prev,
                   bias, c_prev, *, pwl: bool = False,
                   block_rows: int = 256, backend: str | None = None):
    """One BRDS-LSTM inference step — the accelerator datapath as one op:
    the fused dual-ratio SpMV (the paper's Gate module) feeding the LSTM
    nonlinearities (the Function module). x (B, X), h/c (B, H) with
    sx/sh packed over the 4H gate rows. Returns (c, h).

    This is the decode hot loop: the serving runtime scans it once per
    generated token with the (c, h) cache donated. Chained form — two
    kernel launches (SpMV, gates) with z through HBM between them; see
    ``fused_brds_lstm_step`` for the single-launch fusion."""
    z = rb_dual_spmv(sx, x, sh, h_prev, bias, block_rows=block_rows,
                     backend=backend)
    H = z.shape[-1] // 4
    return lstm_gates(z[:, :H], z[:, H:2 * H], z[:, 2 * H:3 * H],
                      z[:, 3 * H:], c_prev, pwl=pwl, backend=backend)


# ------------------------------------------------------------- fused step

def fused_brds_lstm_step(sx: RowBalancedSparse, x, sh: RowBalancedSparse,
                         h_prev, bias, c_prev, *, pwl: bool = False,
                         block_rows: int = 256, backend: str | None = None):
    """``brds_lstm_step`` in ONE kernel launch: the Gate stage's z blocks
    land in VMEM scratch and the Function stage closes the cell from
    there — no HBM round-trip for z/c/h between the two. Bitwise-identical
    to the chained path (same block shapes → same reductions). Returns
    (c, h)."""
    if _resolve(backend, None) == "ref":
        z = _ref.rb_dual_spmv_ref(sx, x, sh, h_prev, bias)
        H = z.shape[-1] // 4
        return _ref.lstm_cell_ref(z[:, :H], z[:, H:2 * H],
                                  z[:, 2 * H:3 * H], z[:, 3 * H:],
                                  c_prev, pwl=pwl)
    vx, dx, _, eff, Rp = _prep_rows(sx, block_rows)
    vh, dh, _, _, _ = _prep_rows(sh, block_rows)
    return _fused.fused_brds_lstm_step(vx, dx, x, vh, dh, h_prev,
                                       _fit(bias, Rp), c_prev, pwl=pwl,
                                       block_rows=eff, interpret=on_cpu())


def fused_brds_delta_lstm_step(sx: RowBalancedSparse, dx, fx,
                               sh: RowBalancedSparse, dh, fh, m_prev, bias,
                               c_prev, *, pwl: bool = False,
                               block_rows: int = 256,
                               backend: str | None = None):
    """``brds_delta_lstm_step`` in ONE launch: fired-column masking, the
    partial-sum memory update, bias and the cell — m and z VMEM-resident
    between the stages. Returns (c, h, m)."""
    fx = fx.astype(jnp.float32)
    fh = fh.astype(jnp.float32)
    if _resolve(backend, None) == "ref":
        m = _ref.delta_rb_dual_spmv_ref(sx, dx, fx, sh, dh, fh, m_prev)
        z = (m.astype(jnp.float32)
             + bias[:m.shape[-1]].astype(jnp.float32)[None, :])
        H = z.shape[-1] // 4
        c, h = _ref.lstm_cell_ref(z[:, :H], z[:, H:2 * H],
                                  z[:, 2 * H:3 * H], z[:, 3 * H:],
                                  c_prev, pwl=pwl)
        return c, h, m
    R = sx.rows
    vx, dxi, _, eff, Rp = _prep_rows(sx, block_rows)
    vh, dhi, _, _, _ = _prep_rows(sh, block_rows)
    c, h, m = _fused.fused_brds_delta_lstm_step(
        vx, dxi, dx, fx, vh, dhi, dh, fh, _fit(m_prev, Rp), _fit(bias, Rp),
        c_prev, pwl=pwl, block_rows=eff, interpret=on_cpu())
    return c, h, m[:, :R] if Rp > R else m


def fused_brds_lstm_step_q8(sx, x, sh, h_prev, bias, c_prev, *,
                            act_scale_x=None, act_scale_h=None,
                            pwl: bool = False, block_rows: int = 256,
                            backend: str | None = None):
    """``brds_lstm_step_q8`` in ONE launch: int32 accumulate + per-row
    dequant feeding the gate nonlinearities in-register. Returns (c, h)."""
    qx, sax = _quant_act(x, sx, act_scale_x)
    qh, sah = _quant_act(h_prev, sh, act_scale_h)
    if _resolve(backend, None) == "ref":
        z = _ref.rb_dual_spmv_q8_ref(sx, qx, sax, sh, qh, sah, bias)
        H = z.shape[-1] // 4
        return _ref.lstm_cell_ref(z[:, :H], z[:, H:2 * H],
                                  z[:, 2 * H:3 * H], z[:, 3 * H:],
                                  c_prev, pwl=pwl)
    vx, dxi, cx, vh, dhi, ch, eff, Rp = _prep_parts_q8(sx, sax, sh, sah,
                                                       block_rows)
    return _fused.fused_brds_lstm_step_q8(vx, dxi, cx, qx, vh, dhi, ch, qh,
                                          _fit(bias, Rp), c_prev, pwl=pwl,
                                          block_rows=eff,
                                          interpret=on_cpu())


def fused_brds_delta_lstm_step_q8(sx, dx, fx, sh, dh, fh, m_prev, bias,
                                  c_prev, *, act_scale_x=None,
                                  act_scale_h=None, pwl: bool = False,
                                  block_rows: int = 256,
                                  backend: str | None = None):
    """``brds_delta_lstm_step_q8`` in ONE launch: masked-delta int codes
    advance the fp32 partial-sum memory, bias applies on top, the cell
    closes — all VMEM-resident. Returns (c, h, m)."""
    dxm = jnp.where(fx.astype(bool), dx, 0).astype(dx.dtype)
    dhm = jnp.where(fh.astype(bool), dh, 0).astype(dh.dtype)
    qdx, sax = _quant_act(dxm, sx, act_scale_x)
    qdh, sah = _quant_act(dhm, sh, act_scale_h)
    if _resolve(backend, None) == "ref":
        m = _ref.delta_rb_dual_spmv_q8_ref(sx, qdx, sax, sh, qdh, sah,
                                           m_prev)
        z = m + bias[:m.shape[-1]].astype(jnp.float32)[None, :]
        H = z.shape[-1] // 4
        c, h = _ref.lstm_cell_ref(z[:, :H], z[:, H:2 * H],
                                  z[:, 2 * H:3 * H], z[:, 3 * H:],
                                  c_prev, pwl=pwl)
        return c, h, m
    R = sx.rows
    vx, dxi, cx, vh, dhi, ch, eff, Rp = _prep_parts_q8(sx, sax, sh, sah,
                                                       block_rows)
    c, h, m = _fused.fused_brds_delta_lstm_step_q8(
        vx, dxi, cx, qdx, vh, dhi, ch, qdh, _fit(m_prev, Rp),
        _fit(bias, Rp), c_prev, pwl=pwl, block_rows=eff,
        interpret=on_cpu())
    return c, h, m[:, :R] if Rp > R else m


# ------------------------------------------------------- multi-token scan

def fused_brds_lstm_scan(sx: RowBalancedSparse, xs, sh: RowBalancedSparse,
                         h0, bias, c0, *, pwl: bool = False,
                         block_rows: int = 256,
                         backend: str | None = None):
    """T decode steps in ONE kernel launch. c/h stay in VMEM scratch
    across tokens; only the packed weight blocks are re-read from HBM per
    step (and can stay resident when they fit VMEM — see
    ``benchmarks/decode_throughput.py``'s crossover report). Trajectory
    is bitwise the T-times-repeated ``fused_brds_lstm_step``.

    xs (T, B, X); h0/c0 (B, H). Returns (hs (T, B, H), c_T)."""
    if _resolve(backend, None) == "ref":
        # python loop, NOT lax.scan: a traced scan body compiles into one
        # XLA computation whose fused mul+adds can contract (FMA) and
        # drift off the eagerly-dispatched per-step oracle
        c, h, hs = c0, h0, []
        for t in range(xs.shape[0]):
            z = _ref.rb_dual_spmv_ref(sx, xs[t], sh, h, bias)
            H = z.shape[-1] // 4
            c, h = _ref.lstm_cell_ref(z[:, :H], z[:, H:2 * H],
                                      z[:, 2 * H:3 * H], z[:, 3 * H:],
                                      c, pwl=pwl)
            hs.append(h)
        return jnp.stack(hs), c
    vx, dx, _, eff, Rp = _prep_rows(sx, block_rows)
    vh, dh, _, _, _ = _prep_rows(sh, block_rows)
    return _fused.fused_brds_lstm_scan(vx, dx, xs, vh, dh, h0,
                                       _fit(bias, Rp), c0, pwl=pwl,
                                       block_rows=eff, interpret=on_cpu())


def fused_brds_delta_lstm_scan(sx: RowBalancedSparse, xs,
                               sh: RowBalancedSparse, h0, c0, x_ref0,
                               h_ref0, m0, bias, *, theta_x: float,
                               theta_h: float, pwl: bool = False,
                               block_rows: int = 256,
                               backend: str | None = None):
    """T temporally-sparse decode steps in ONE launch: thresholding,
    reference tracking, the partial-sum memory AND the cell all advance
    in VMEM scratch. Uncapped thresholds only (occupancy caps need
    ``top_k`` — callers fall back to per-step launches when one is set).

    xs (T, B, X); x_ref0/h_ref0 reference states; m0 (B, 4H) fp32 partial
    sums. Returns (hs, c_T, x_ref_T, h_ref_T, m_T)."""
    from ..sparse.temporal import delta_threshold
    if _resolve(backend, None) == "ref":
        # python loop, NOT lax.scan — see fused_brds_lstm_scan
        c, h, xr, hr, m = c0, h0, x_ref0, h_ref0, m0
        hs = []
        for t in range(xs.shape[0]):
            d_x, f_x, xr = delta_threshold(xs[t], xr, theta_x)
            d_h, f_h, hr = delta_threshold(h, hr, theta_h)
            m = _ref.delta_rb_dual_spmv_ref(
                sx, d_x, f_x.astype(jnp.float32), sh, d_h,
                f_h.astype(jnp.float32), m)
            z = (m.astype(jnp.float32)
                 + bias[:m.shape[-1]].astype(jnp.float32)[None, :])
            H = z.shape[-1] // 4
            c, h = _ref.lstm_cell_ref(z[:, :H], z[:, H:2 * H],
                                      z[:, 2 * H:3 * H], z[:, 3 * H:],
                                      c, pwl=pwl)
            hs.append(h)
        return jnp.stack(hs), c, xr, hr, m
    R = sx.rows
    vx, dxi, _, eff, Rp = _prep_rows(sx, block_rows)
    vh, dhi, _, _, _ = _prep_rows(sh, block_rows)
    hs, c, xr, hr, m = _fused.fused_brds_delta_lstm_scan(
        vx, dxi, xs, vh, dhi, h0, c0, x_ref0, h_ref0, _fit(m0, Rp),
        _fit(bias, Rp), theta_x=float(theta_x), theta_h=float(theta_h),
        pwl=pwl, block_rows=eff, interpret=on_cpu())
    return hs, c, xr, hr, m[:, :R] if Rp > R else m


# ---------------------------------------------------------------- lstm cell

def lstm_gates(zf, zi, zg, zo, c_prev, *, pwl: bool = False,
               backend: str | None = None, use_kernel: bool | None = None):
    if _resolve(backend, use_kernel) == "ref":
        return _ref.lstm_cell_ref(zf, zi, zg, zo, c_prev, pwl=pwl)
    B, H = zf.shape
    for cand in (512, 256, 128, 64):
        if H % cand == 0:
            block = cand
            break
    else:
        if H > 64:
            # odd hidden sizes: pad to the nearest 64-multiple and slice
            # (the _pad_rows convention) instead of one giant block = H
            Hp = -(-H // 64) * 64
            w = ((0, 0), (0, Hp - H))
            c, h = _lstm_gates_kernel(
                jnp.pad(zf, w), jnp.pad(zi, w), jnp.pad(zg, w),
                jnp.pad(zo, w), jnp.pad(c_prev, w), pwl=pwl, block=64,
                interpret=on_cpu())
            return c[:, :H], h[:, :H]
        block = H
    return _lstm_gates_kernel(zf, zi, zg, zo, c_prev, pwl=pwl, block=block,
                              interpret=on_cpu())


# ---------------------------------------------------------------- attention

def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    block_q: int = 256, block_kv: int = 256,
                    backend: str | None = None,
                    use_kernel: bool | None = None):
    if _resolve(backend, use_kernel) == "ref":
        return _ref.mha_ref(q, k, v, causal=causal, window=window)
    B, Hq, Sq, D = q.shape
    Sk = k.shape[2]
    bq = max(g for g in (block_q, 128, 64, 32, 16, 8, 1) if Sq % g == 0)
    bk = max(g for g in (block_kv, 128, 64, 32, 16, 8, 1) if Sk % g == 0)
    return _flash_kernel(q, k, v, causal=causal, window=window, block_q=bq,
                         block_kv=bk, interpret=on_cpu())


def decode_attention(q, k, v, lengths, *, block_kv: int = 512,
                     backend: str | None = None,
                     use_kernel: bool | None = None):
    if _resolve(backend, use_kernel) == "ref":
        return _ref.decode_attention_ref(q, k, v, lengths)
    S = k.shape[2]
    bk = max(g for g in (block_kv, 256, 128, 64, 32, 16, 8, 1) if S % g == 0)
    return _decode_kernel(q, k, v, lengths, block_kv=bk, interpret=on_cpu())
