"""jit'd public wrappers around the Pallas kernels.

Backend selection goes through ``repro.sparse.backend``: "pallas" runs the
kernels (interpret mode on CPU, compiled on TPU), "ref" the pure-jnp
reference formulations (the dry-run path lowers these; XLA fuses them),
"auto"/None the configured default. The old per-call ``use_kernel=``
boolean is accepted as a deprecated alias. Wrappers handle padding to
block multiples.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref as _ref
from .rb_spmv import rb_spmv as _rb_spmv_kernel, rb_dual_spmv as _rb_dual_kernel
from .delta_rb_spmv import (delta_rb_spmv as _delta_rb_spmv_kernel,
                            delta_rb_dual_spmv as _delta_rb_dual_kernel)
from .rb_spmv_q8 import (rb_spmv_q8 as _rb_spmv_q8_kernel,
                         rb_dual_parts_q8 as _rb_dual_parts_q8_kernel)
from .lstm_gates import lstm_gates as _lstm_gates_kernel
from .flash_attention import flash_attention as _flash_kernel
from .decode_attention import decode_attention as _decode_kernel
from ..core.packing import RowBalancedSparse
from ..quant.scheme import quantize as _quantize
from ..sparse import backend as _backend


def on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _resolve(backend: str | None, use_kernel: bool | None) -> str:
    """→ concrete "pallas" | "ref" (use_kernel= is the deprecated alias)."""
    if use_kernel is not None:
        return _backend.from_use_kernel(use_kernel, stacklevel=4)
    return _backend.resolve(backend)


def _pad_rows(arr, mult):
    r = arr.shape[0]
    pad = (-r) % mult
    if pad:
        arr = jnp.pad(arr, ((0, pad),) + ((0, 0),) * (arr.ndim - 1))
    return arr, pad


# ---------------------------------------------------------------- rb_spmv

def rb_spmv(s: RowBalancedSparse, x: jnp.ndarray, *, block_rows: int = 256,
            backend: str | None = None,
            use_kernel: bool | None = None) -> jnp.ndarray:
    """Packed row-balanced SpMV; x (B, ncols) → (B, rows)."""
    if _resolve(backend, use_kernel) == "ref":
        return _ref.rb_spmv_ref(s, x)
    R = s.rows
    block_rows = min(block_rows, R)
    vals, padded = _pad_rows(s.values, block_rows)
    deltas, _ = _pad_rows(s.deltas, block_rows)
    y = _rb_spmv_kernel(vals, deltas, x, block_rows=block_rows,
                        interpret=on_cpu())
    return y[:, :R] if padded else y


def rb_dual_spmv(sx: RowBalancedSparse, x, sh: RowBalancedSparse, h, bias,
                 *, block_rows: int = 256, backend: str | None = None,
                 use_kernel: bool | None = None):
    """z = Sx@x + Sh@h + bias — the fused dual-ratio gate preactivation."""
    if _resolve(backend, use_kernel) == "ref":
        return _ref.rb_dual_spmv_ref(sx, x, sh, h, bias)
    R = sx.rows
    block_rows = min(block_rows, R)
    vx, padded = _pad_rows(sx.values, block_rows)
    dx, _ = _pad_rows(sx.deltas, block_rows)
    vh, _ = _pad_rows(sh.values, block_rows)
    dh, _ = _pad_rows(sh.deltas, block_rows)
    b = jnp.pad(bias, (0, vx.shape[0] - R)) if padded else bias
    z = _rb_dual_kernel(vx, dx, x, vh, dh, h, b, block_rows=block_rows,
                        interpret=on_cpu())
    return z[:, :R] if padded else z


def delta_rb_spmv(s: RowBalancedSparse, d, fired, *, block_rows: int = 256,
                  backend: str | None = None):
    """Temporal-delta SpMV: y[b, r] = Σ_k vals[r, k] · fired[b, c] · d[b, c].

    ``d`` (B, ncols) raw activation deltas, ``fired`` (B, ncols) bool/0-1
    threshold mask. Returns (B, rows)."""
    fired = fired.astype(jnp.float32)
    if _resolve(backend, None) == "ref":
        return _ref.delta_rb_spmv_ref(s, d, fired)
    R = s.rows
    block_rows = min(block_rows, R)
    vals, padded = _pad_rows(s.values, block_rows)
    deltas, _ = _pad_rows(s.deltas, block_rows)
    y = _delta_rb_spmv_kernel(vals, deltas, d, fired, block_rows=block_rows,
                              interpret=on_cpu())
    return y[:, :R] if padded else y


def delta_rb_dual_spmv(sx: RowBalancedSparse, dx, fx,
                       sh: RowBalancedSparse, dh, fh, m, *,
                       block_rows: int = 256, backend: str | None = None):
    """m' = m + Sx@(fx·dx) + Sh@(fh·dh) — the fused temporal-delta gate
    accumulation (partial-sum memory update)."""
    fx = fx.astype(jnp.float32)
    fh = fh.astype(jnp.float32)
    if _resolve(backend, None) == "ref":
        return _ref.delta_rb_dual_spmv_ref(sx, dx, fx, sh, dh, fh, m)
    R = sx.rows
    block_rows = min(block_rows, R)
    vx, padded = _pad_rows(sx.values, block_rows)
    dxi, _ = _pad_rows(sx.deltas, block_rows)
    vh, _ = _pad_rows(sh.values, block_rows)
    dhi, _ = _pad_rows(sh.deltas, block_rows)
    mp = jnp.pad(m, ((0, 0), (0, vx.shape[0] - R))) if padded else m
    z = _delta_rb_dual_kernel(vx, dxi, dx, fx, vh, dhi, dh, fh, mp,
                              block_rows=block_rows, interpret=on_cpu())
    return z[:, :R] if padded else z


# --------------------------------------------------------------- quantized

def _quant_act(x, packed, act_scale):
    """→ (codes, scale): quantize one activation batch for a q8 matvec.

    ``act_scale`` None → the packing's scheme decides: fixed-point uses
    its constant 2^-N; scaled schemes fall back to a dynamic per-call
    max-abs (the calibrated static scales arrive through the model)."""
    scheme = packed.scheme
    sa = scheme.act_scale(act_scale)
    if sa is None:
        amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
        sa = jnp.maximum(amax / scheme.qmax, 1e-12)
    return _quantize(x, sa, scheme), sa


def rb_spmv_q8(s, x, *, act_scale=None, block_rows: int = 256,
               backend: str | None = None):
    """Quantized packed SpMV: int codes × int activation codes, int32
    accumulate, per-row dequant. ``s``: RowBalancedSparseQ8; x (B, ncols)
    float activations (quantized here, so pallas and ref consume the SAME
    codes). Returns (B, rows) float32."""
    qx, sa = _quant_act(x, s, act_scale)
    if _resolve(backend, None) == "ref":
        return _ref.rb_spmv_q8_ref(s, qx, sa)
    R = s.rows
    block_rows = min(block_rows, R)
    vals, padded = _pad_rows(s.values, block_rows)
    deltas, _ = _pad_rows(s.deltas, block_rows)
    comb = (s.scales * sa).astype(jnp.float32)
    if padded:
        comb = jnp.pad(comb, (0, vals.shape[0] - R))
    y = _rb_spmv_q8_kernel(vals, deltas, comb, qx, block_rows=block_rows,
                           interpret=on_cpu())
    return y[:, :R] if padded else y


def _dual_parts_q8(sx, qx, sax, sh, qh, sah, block_rows):
    """Run the two-family q8 kernel (padding to block multiples) →
    (zx, zh) dequantized partial sums, both (B, rows) f32."""
    R = sx.rows
    block_rows = min(block_rows, R)
    vx, padded = _pad_rows(sx.values, block_rows)
    dxi, _ = _pad_rows(sx.deltas, block_rows)
    vh, _ = _pad_rows(sh.values, block_rows)
    dhi, _ = _pad_rows(sh.deltas, block_rows)
    cx = (sx.scales * sax).astype(jnp.float32)
    ch = (sh.scales * sah).astype(jnp.float32)
    if padded:
        pad = vx.shape[0] - R
        cx, ch = jnp.pad(cx, (0, pad)), jnp.pad(ch, (0, pad))
    zx, zh = _rb_dual_parts_q8_kernel(vx, dxi, cx, qx, vh, dhi, ch, qh,
                                      block_rows=block_rows,
                                      interpret=on_cpu())
    return (zx[:, :R], zh[:, :R]) if padded else (zx, zh)


def rb_dual_spmv_q8(sx, x, sh, h, bias, *, act_scale_x=None,
                    act_scale_h=None, block_rows: int = 256,
                    backend: str | None = None):
    """z = dq(Sx@qx) + dq(Sh@qh) + bias — the quantized dual-ratio gate
    preactivation (each family dequantized by its own row × act scales).
    Returns (B, rows) float32."""
    qx, sax = _quant_act(x, sx, act_scale_x)
    qh, sah = _quant_act(h, sh, act_scale_h)
    if _resolve(backend, None) == "ref":
        return _ref.rb_dual_spmv_q8_ref(sx, qx, sax, sh, qh, sah, bias)
    zx, zh = _dual_parts_q8(sx, qx, sax, sh, qh, sah, block_rows)
    return zx + zh + bias.astype(jnp.float32)[None, :]


def delta_rb_dual_spmv_q8(sx, dx, fx, sh, dh, fh, m, *, act_scale_x=None,
                          act_scale_h=None, block_rows: int = 256,
                          backend: str | None = None):
    """m' = m + dq(Sx@q(fx·dx)) + dq(Sh@q(fh·dh)) — the quantized fused
    temporal-delta gate accumulation. Deltas are masked BEFORE quantizing,
    so unfired columns carry exact 0 codes into the int32 accumulation;
    ``m`` stays the fp32 partial-sum memory. Returns (B, rows) float32."""
    dxm = jnp.where(fx.astype(bool), dx, 0).astype(dx.dtype)
    dhm = jnp.where(fh.astype(bool), dh, 0).astype(dh.dtype)
    qdx, sax = _quant_act(dxm, sx, act_scale_x)
    qdh, sah = _quant_act(dhm, sh, act_scale_h)
    if _resolve(backend, None) == "ref":
        return _ref.delta_rb_dual_spmv_q8_ref(sx, qdx, sax, sh, qdh, sah, m)
    zx, zh = _dual_parts_q8(sx, qdx, sax, sh, qdh, sah, block_rows)
    return m.astype(jnp.float32) + zx + zh


def brds_lstm_step_q8(sx, x, sh, h_prev, bias, c_prev, *, act_scale_x=None,
                      act_scale_h=None, pwl: bool = False,
                      block_rows: int = 256, backend: str | None = None):
    """One quantized BRDS-LSTM inference step: the q8 dual-ratio SpMV
    (int32 accumulate + per-row dequant) feeding the Function module.
    Returns (c, h)."""
    z = rb_dual_spmv_q8(sx, x, sh, h_prev, bias, act_scale_x=act_scale_x,
                        act_scale_h=act_scale_h, block_rows=block_rows,
                        backend=backend)
    H = z.shape[-1] // 4
    return lstm_gates(z[:, :H], z[:, H:2 * H], z[:, 2 * H:3 * H],
                      z[:, 3 * H:], c_prev, pwl=pwl, backend=backend)


def brds_delta_lstm_step_q8(sx, dx, fx, sh, dh, fh, m_prev, bias, c_prev,
                            *, act_scale_x=None, act_scale_h=None,
                            pwl: bool = False, block_rows: int = 256,
                            backend: str | None = None):
    """One quantized temporally-sparse BRDS-LSTM step: fired-column
    quantized products advance the fp32 partial-sum memory, bias applies
    on top, the Function module closes the cell. Returns (c, h, m)."""
    m = delta_rb_dual_spmv_q8(sx, dx, fx, sh, dh, fh, m_prev,
                              act_scale_x=act_scale_x,
                              act_scale_h=act_scale_h,
                              block_rows=block_rows, backend=backend)
    z = m + bias.astype(jnp.float32)[None, :]
    H = z.shape[-1] // 4
    c, h = lstm_gates(z[:, :H], z[:, H:2 * H], z[:, 2 * H:3 * H],
                      z[:, 3 * H:], c_prev, pwl=pwl, backend=backend)
    return c, h, m


def brds_delta_lstm_step(sx: RowBalancedSparse, dx, fx,
                         sh: RowBalancedSparse, dh, fh, m_prev, bias, c_prev,
                         *, pwl: bool = False, block_rows: int = 256,
                         backend: str | None = None):
    """One temporally-sparse BRDS-LSTM inference step.

    The Spartus composition of the accelerator datapath: the fused delta
    dual-SpMV advances the partial-sum memory ``m`` with only the fired
    columns' products, the bias is applied on top, and the Function module
    (lstm_gates) produces the new cell state. Returns (c, h, m)."""
    m = delta_rb_dual_spmv(sx, dx, fx, sh, dh, fh, m_prev,
                           block_rows=block_rows, backend=backend)
    z = m.astype(jnp.float32) + bias.astype(jnp.float32)[None, :]
    H = z.shape[-1] // 4
    c, h = lstm_gates(z[:, :H], z[:, H:2 * H], z[:, 2 * H:3 * H],
                      z[:, 3 * H:], c_prev, pwl=pwl, backend=backend)
    return c, h, m


def brds_lstm_step(sx: RowBalancedSparse, x, sh: RowBalancedSparse, h_prev,
                   bias, c_prev, *, pwl: bool = False,
                   block_rows: int = 256, backend: str | None = None):
    """One BRDS-LSTM inference step — the accelerator datapath as one op:
    the fused dual-ratio SpMV (the paper's Gate module) feeding the LSTM
    nonlinearities (the Function module). x (B, X), h/c (B, H) with
    sx/sh packed over the 4H gate rows. Returns (c, h).

    This is the decode hot loop: the serving runtime scans it once per
    generated token with the (c, h) cache donated."""
    z = rb_dual_spmv(sx, x, sh, h_prev, bias, block_rows=block_rows,
                     backend=backend)
    H = z.shape[-1] // 4
    return lstm_gates(z[:, :H], z[:, H:2 * H], z[:, 2 * H:3 * H],
                      z[:, 3 * H:], c_prev, pwl=pwl, backend=backend)


# ---------------------------------------------------------------- lstm cell

def lstm_gates(zf, zi, zg, zo, c_prev, *, pwl: bool = False,
               backend: str | None = None, use_kernel: bool | None = None):
    if _resolve(backend, use_kernel) == "ref":
        return _ref.lstm_cell_ref(zf, zi, zg, zo, c_prev, pwl=pwl)
    B, H = zf.shape
    block = H
    for cand in (512, 256, 128, 64):
        if H % cand == 0:
            block = cand
            break
    return _lstm_gates_kernel(zf, zi, zg, zo, c_prev, pwl=pwl, block=block,
                              interpret=on_cpu())


# ---------------------------------------------------------------- attention

def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    block_q: int = 256, block_kv: int = 256,
                    backend: str | None = None,
                    use_kernel: bool | None = None):
    if _resolve(backend, use_kernel) == "ref":
        return _ref.mha_ref(q, k, v, causal=causal, window=window)
    B, Hq, Sq, D = q.shape
    Sk = k.shape[2]
    bq = max(g for g in (block_q, 128, 64, 32, 16, 8, 1) if Sq % g == 0)
    bk = max(g for g in (block_kv, 128, 64, 32, 16, 8, 1) if Sk % g == 0)
    return _flash_kernel(q, k, v, causal=causal, window=window, block_q=bq,
                         block_kv=bk, interpret=on_cpu())


def decode_attention(q, k, v, lengths, *, block_kv: int = 512,
                     backend: str | None = None,
                     use_kernel: bool | None = None):
    if _resolve(backend, use_kernel) == "ref":
        return _ref.decode_attention_ref(q, k, v, lengths)
    S = k.shape[2]
    bk = max(g for g in (block_kv, 256, 128, 64, 32, 16, 8, 1) if S % g == 0)
    return _decode_kernel(q, k, v, lengths, block_kv=bk, interpret=on_cpu())
