"""Pallas TPU kernel: temporal-delta SpMV over packed row-balanced weights.

This is the Spartus [Gao et al., 2021] composition on top of the BRDS
Gate-module MxV: the activation vector is a *delta* against a reference
state, thresholded on the host side into a fired-column mask, and the
kernel accumulates only (surviving row, changed column) products into the
partial-sum memory ``m``:

    m'[b, r] = m[b, r] + Σ_k vals[r, k] · fired[b, c] · d[b, c],
               c = cols[r, k]

- the weight side stays the paper's row-balanced packing (exactly K
  non-zeros per row, values + narrow delta-encoded column indices), so
  every grid step still does identical work per row — the balanced-PE
  invariant survives the temporal composition;
- the activation side gathers BOTH the delta vector and its fired mask
  from VMEM; a column that did not cross the threshold Θ contributes an
  exact 0.0 to the accumulation — the product a real delta accelerator
  would never issue.  The occupancy (fired fraction) is the effective-ops
  metric `benchmarks/fig_delta_occupancy.py` sweeps;
- the dual variant processes the W_x and W_h packed families in the SAME
  grid step (the Large/Small mult-array lockstep of rb_dual_spmv) and
  fuses the partial-sum update, so one kernel launch advances the whole
  temporal gate preactivation.

Used on the memory-bound decode path: weight bytes already shrink by
(1 - weight sparsity); firing columns shrink the *compute* by the delta
occupancy — the two ratios multiply into the effective-ops reduction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .rb_spmv import DEF_BLOCK_ROWS


def _delta_rb_spmv_kernel(d_ref, f_ref, vals_ref, deltas_ref, out_ref):
    """Grid step: one block of rows. d/f (B, X); vals/deltas (bR, K);
    out_ref (B, bR)."""
    cols = jnp.cumsum(deltas_ref[...].astype(jnp.int32), axis=1)   # (bR, K)
    dm = d_ref[...].astype(jnp.float32) * f_ref[...]               # (B, X)
    g = jnp.take(dm, cols, axis=1)                                 # (B, bR, K)
    v = vals_ref[...].astype(jnp.float32)                          # (bR, K)
    acc = jnp.sum(g * v[None, :, :], axis=-1)                      # (B, bR)
    out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def delta_rb_spmv(values, deltas, d, fired, *,
                  block_rows: int = DEF_BLOCK_ROWS, interpret: bool = True):
    """y[b, r] = Σ_k values[r, k] · fired[b, c] · d[b, c], c = cols[r, k].

    values: (R, K) float; deltas: (R, K) int8/16/32; d: (B, X) raw
    activation deltas; fired: (B, X) float32 0/1 threshold-crossing mask.
    Returns (B, R) in d.dtype. R must be a multiple of block_rows (the ops
    wrapper pads).
    """
    R, K = values.shape
    B, X = d.shape
    assert fired.shape == (B, X), (fired.shape, d.shape)
    assert R % block_rows == 0, (R, block_rows)
    grid = (R // block_rows,)
    return pl.pallas_call(
        _delta_rb_spmv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((B, X), lambda i: (0, 0)),
            pl.BlockSpec((B, X), lambda i: (0, 0)),
            pl.BlockSpec((block_rows, K), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, K), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((B, block_rows), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((B, R), d.dtype),
        interpret=interpret,
    )(d, fired, values, deltas)


def _delta_rb_dual_kernel(dx_ref, fx_ref, dh_ref, fh_ref, vx_ref, ix_ref,
                          vh_ref, ih_ref, m_ref, out_ref):
    """One row block of m' = m + Sx@(fx·dx) + Sh@(fh·dh). Both packed
    families advance in the same step (Large/Small MA lockstep)."""
    colsx = jnp.cumsum(ix_ref[...].astype(jnp.int32), axis=1)
    colsh = jnp.cumsum(ih_ref[...].astype(jnp.int32), axis=1)
    dx = dx_ref[...].astype(jnp.float32) * fx_ref[...]
    dh = dh_ref[...].astype(jnp.float32) * fh_ref[...]
    gx = jnp.take(dx, colsx, axis=1)                               # (B,bR,Kx)
    gh = jnp.take(dh, colsh, axis=1)                               # (B,bR,Kh)
    accx = jnp.sum(gx * vx_ref[...].astype(jnp.float32)[None], axis=-1)
    acch = jnp.sum(gh * vh_ref[...].astype(jnp.float32)[None], axis=-1)
    m = m_ref[...].astype(jnp.float32) + accx + acch
    out_ref[...] = m.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def delta_rb_dual_spmv(vals_x, deltas_x, dx, fx, vals_h, deltas_h, dh, fh,
                       m, *, block_rows: int = DEF_BLOCK_ROWS,
                       interpret: bool = True):
    """m' = m + Sx @ (fx·dx) + Sh @ (fh·dh) for packed row-balanced
    Sx (R, Kx), Sh (R, Kh).

    dx: (B, X), dh: (B, H) raw deltas; fx/fh their float32 fired masks;
    m: (B, R) partial-sum memory. Returns (B, R) in m.dtype."""
    R, Kx = vals_x.shape
    _, Kh = vals_h.shape
    B, X = dx.shape
    H = dh.shape[1]
    assert vals_h.shape[0] == R and m.shape == (B, R)
    assert R % block_rows == 0, (R, block_rows)
    grid = (R // block_rows,)
    return pl.pallas_call(
        _delta_rb_dual_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((B, X), lambda i: (0, 0)),
            pl.BlockSpec((B, X), lambda i: (0, 0)),
            pl.BlockSpec((B, H), lambda i: (0, 0)),
            pl.BlockSpec((B, H), lambda i: (0, 0)),
            pl.BlockSpec((block_rows, Kx), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, Kx), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, Kh), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, Kh), lambda i: (i, 0)),
            pl.BlockSpec((B, block_rows), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((B, block_rows), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((B, R), m.dtype),
        interpret=interpret,
    )(dx, fx, dh, fh, vals_x, deltas_x, vals_h, deltas_h, m)
