"""Pallas TPU kernel: single-token decode attention over a long KV cache.

The dominant op of the decode_32k cells: one query attends to a 32k cache.
Purely memory-bound (arithmetic intensity ≈ 1 flop/byte), so the kernel's
job is to stream K/V through VMEM exactly once with online softmax, skipping
blocks past the valid cache length. Valid lengths live in SMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, scale, bk, nk):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[pl.program_id(0)]
    live = ik * bk < length

    @pl.when(live)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (G, d) q-head group
        k = k_ref[0, 0].astype(jnp.float32)                  # (bk, d)
        s = q @ k.T                                          # (G, bk)
        kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < length, s, NEG)
        m_prev = m_scr[...][:, :1]
        l_prev = l_scr[...][:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
        p = jnp.where(s > NEG / 2, jnp.exp(s - m_new), 0.0)
        l_new = jnp.exp(m_prev - m_new) * l_prev + jnp.sum(p, -1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)
        acc_scr[...] = acc_scr[...] * jnp.exp(m_prev - m_new) + p @ v
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ik == nk - 1)
    def _done():
        l = jnp.maximum(l_scr[...][:, :1], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_kv", "interpret"))
def decode_attention(q, k, v, lengths, *, block_kv: int = 512,
                     interpret: bool = True):
    """q: (B, Hq, D); k, v: (B, Hkv, S, D); lengths: (B,) int32.

    Returns (B, Hq, D). The q heads of one kv group ride in the same tile
    (G = Hq // Hkv rows), so K/V stream once per kv head."""
    B, Hq, D = q.shape
    _, Hkv, S, _ = k.shape
    G = Hq // Hkv
    bk = min(block_kv, S)
    assert S % bk == 0, (S, bk)
    nk = S // bk
    qg = q.reshape(B, Hkv, G, D)
    grid = (B, Hkv, nk)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=D ** -0.5, bk=bk, nk=nk),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, D), lambda b, h, ik, *_: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, bk, D), lambda b, h, ik, *_: (b, h, ik, 0)),
                pl.BlockSpec((1, 1, bk, D), lambda b, h, ik, *_: (b, h, ik, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, ik, *_: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, 128), jnp.float32),
                pltpu.VMEM((G, 128), jnp.float32),
                pltpu.VMEM((G, D), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, k, v)
    return out.reshape(B, Hq, D)
