"""Pallas TPU kernels for the BRDS framework.

Each kernel ships with a pure-jnp oracle in ref.py; ops.py holds the jit'd
public wrappers (interpret=True on CPU, compiled on TPU).
"""
from .ops import (
    rb_spmv,
    rb_dual_spmv,
    rb_spmv_q8,
    rb_dual_spmv_q8,
    delta_rb_spmv,
    delta_rb_dual_spmv,
    delta_rb_dual_spmv_q8,
    lstm_gates,
    fused_brds_lstm_step,
    fused_brds_delta_lstm_step,
    fused_brds_lstm_step_q8,
    fused_brds_delta_lstm_step_q8,
    fused_brds_lstm_scan,
    fused_brds_delta_lstm_scan,
    flash_attention,
    decode_attention,
    on_cpu,
)
from . import ref
