"""Pallas TPU kernels for the BRDS framework.

Each kernel ships with a pure-jnp oracle in ref.py; ops.py holds the jit'd
public wrappers (interpret=True on CPU, compiled on TPU).
"""
from .ops import (
    rb_spmv,
    rb_dual_spmv,
    rb_spmv_q8,
    rb_dual_spmv_q8,
    delta_rb_spmv,
    delta_rb_dual_spmv,
    delta_rb_dual_spmv_q8,
    lstm_gates,
    flash_attention,
    decode_attention,
    on_cpu,
)
from . import ref
