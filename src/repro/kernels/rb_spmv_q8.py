"""Pallas TPU kernels: QUANTIZED packed row-balanced SpMV.

The arithmetic-fidelity half of the BRDS datapath: the FPGA evaluates its
pruned LSTMs in fixed point (ESE ships 12-bit sparse weights, Spartus a
fixed-point spatio-temporal sparse LSTM), and on the TPU the same move
pays twice —

- the decode hot path is MEMORY bound, so int8 codes stream 4× fewer
  weight bytes HBM→VMEM than f32 (2× for an int16-stored qM.N), on top of
  the 1/(1-sparsity) packing gain;
- int8 × int8 products accumulate in int32 on the MXU at twice the bf16
  rate (``hw.PEAK_INT8_OPS``).

Kernel structure mirrors the float kernels (rb_spmv / delta_rb_spmv) so
every invariant survives quantization: identical per-row work (row
balance), delta-encoded columns rebuilt by an in-register cumsum
(relative addressing — quantization never moves a column), and the dual
variants advancing both weight families in the same grid step (Large/
Small mult-array lockstep). New here is the epilogue: the int32
accumulator is dequantized by ONE multiply per row — the per-row weight
scale pre-combined with the static activation scale — landing in the
existing fp32 partial-sum memory.

The wrappers (kernels.ops) quantize the activations; the kernels consume
integer codes only, so pallas↔ref parity is EXACT (integer accumulation
has no float re-association to disagree about).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .rb_spmv import DEF_BLOCK_ROWS


def _rb_spmv_q8_kernel(qx_ref, vals_ref, deltas_ref, scales_ref, out_ref):
    """Grid step: one block of rows. qx (B, X) int codes; vals/deltas
    (bR, K); scales (1, bR) combined row·act dequant; out (B, bR) f32."""
    cols = jnp.cumsum(deltas_ref[...].astype(jnp.int32), axis=1)   # (bR, K)
    g = jnp.take(qx_ref[...].astype(jnp.int32), cols, axis=1)      # (B, bR, K)
    v = vals_ref[...].astype(jnp.int32)                            # (bR, K)
    acc = jnp.sum(g * v[None, :, :], axis=-1)                      # int32
    out_ref[...] = acc.astype(jnp.float32) * scales_ref[...][0][None, :]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def rb_spmv_q8(values, deltas, scales, qx, *,
               block_rows: int = DEF_BLOCK_ROWS, interpret: bool = True):
    """y[b, r] = scales[r] · Σ_k values[r, k] · qx[b, cols[r, k]].

    values: (R, K) int codes; deltas: (R, K) int8/16/32; scales: (R,)
    f32 combined (per-row weight scale × activation scale); qx: (B, X)
    int activation codes. Products accumulate in int32; the per-row
    dequant is the only float op. Returns (B, R) float32.
    """
    R, K = values.shape
    B, X = qx.shape
    assert scales.shape == (R,), (scales.shape, R)
    assert R % block_rows == 0, (R, block_rows)
    grid = (R // block_rows,)
    return pl.pallas_call(
        _rb_spmv_q8_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((B, X), lambda i: (0, 0)),
            pl.BlockSpec((block_rows, K), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, K), lambda i: (i, 0)),
            pl.BlockSpec((1, block_rows), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((B, block_rows), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((B, R), jnp.float32),
        interpret=interpret,
    )(qx, values, deltas, scales.reshape(1, R))


def _rb_dual_parts_q8_kernel(qx_ref, qh_ref, vx_ref, ix_ref, sx_ref,
                             vh_ref, ih_ref, sh_ref, zx_ref, zh_ref):
    """One row block of the dual-family quantized MxV: both packed
    families advance in the same step (Large/Small MA lockstep), each
    int32 accumulator dequantizes with its own per-row scales.

    The kernel emits the TWO dequantized partial sums (zx, zh) instead of
    their total: the epilogue is then multiply-only, so XLA cannot
    FMA-contract a dequant multiply into an add and drift a last bit away
    from the reference twins — the wrapper performs the (shared, exact-
    order) adds. Integer work stays fully in-kernel."""
    colsx = jnp.cumsum(ix_ref[...].astype(jnp.int32), axis=1)
    colsh = jnp.cumsum(ih_ref[...].astype(jnp.int32), axis=1)
    gx = jnp.take(qx_ref[...].astype(jnp.int32), colsx, axis=1)
    gh = jnp.take(qh_ref[...].astype(jnp.int32), colsh, axis=1)
    accx = jnp.sum(gx * vx_ref[...].astype(jnp.int32)[None], axis=-1)
    acch = jnp.sum(gh * vh_ref[...].astype(jnp.int32)[None], axis=-1)
    zx_ref[...] = accx.astype(jnp.float32) * sx_ref[...][0][None, :]
    zh_ref[...] = acch.astype(jnp.float32) * sh_ref[...][0][None, :]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def rb_dual_parts_q8(vals_x, deltas_x, scales_x, qx, vals_h, deltas_h,
                     scales_h, qh, *, block_rows: int = DEF_BLOCK_ROWS,
                     interpret: bool = True):
    """(zx, zh) = (dq(Sx @ qx), dq(Sh @ qh)) — the quantized dual-ratio
    MxV pair underlying both the gate preactivation
    (``ops.rb_dual_spmv_q8``: zx + zh + bias) and the temporal partial-sum
    update (``ops.delta_rb_dual_spmv_q8``: m + zx + zh).

    scales_*: (R,) f32 combined (row × activation) dequant scales;
    qx (B, X) / qh (B, H) int codes. Returns two (B, R) float32 arrays.
    """
    R, Kx = vals_x.shape
    _, Kh = vals_h.shape
    B, X = qx.shape
    H = qh.shape[1]
    assert vals_h.shape[0] == R
    assert scales_x.shape == (R,) and scales_h.shape == (R,)
    assert R % block_rows == 0, (R, block_rows)
    grid = (R // block_rows,)
    return pl.pallas_call(
        _rb_dual_parts_q8_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((B, X), lambda i: (0, 0)),
            pl.BlockSpec((B, H), lambda i: (0, 0)),
            pl.BlockSpec((block_rows, Kx), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, Kx), lambda i: (i, 0)),
            pl.BlockSpec((1, block_rows), lambda i: (0, i)),
            pl.BlockSpec((block_rows, Kh), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, Kh), lambda i: (i, 0)),
            pl.BlockSpec((1, block_rows), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((B, block_rows), lambda i: (0, i)),
            pl.BlockSpec((B, block_rows), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, R), jnp.float32),
            jax.ShapeDtypeStruct((B, R), jnp.float32),
        ],
        interpret=interpret,
    )(qx, qh, vals_x, deltas_x, scales_x.reshape(1, R), vals_h, deltas_h,
      scales_h.reshape(1, R))
