"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics contracts: tests assert the kernels (interpret=True
on CPU) match these to fp tolerance across shape/dtype sweeps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.packing import RowBalancedSparse


# ---------------------------------------------------------------- rb_spmv

def rb_spmv_ref(s: RowBalancedSparse, x: jnp.ndarray) -> jnp.ndarray:
    """y[b, r] = sum_k vals[r, k] * x[b, cols[r, k]].  x: (B, ncols)."""
    s = s.logical()          # oracles compute logical rows only
    cols = s.col_indices()                                 # (R, K)
    g = jnp.take(x, cols, axis=1)                          # (B, R, K)
    return jnp.einsum("brk,rk->br", g.astype(jnp.float32),
                      s.values.astype(jnp.float32)).astype(x.dtype)


def rb_dual_spmv_ref(sx: RowBalancedSparse, x: jnp.ndarray,
                     sh: RowBalancedSparse, h: jnp.ndarray,
                     bias: jnp.ndarray | None = None) -> jnp.ndarray:
    """The LSTM gate preactivation: z = Sx@x + Sh@h (+ bias).

    Both packed matrices have the same row count (4H in the paper); the
    hardware analogue runs them on the Large/Small mult-arrays in lockstep.
    """
    z = (rb_spmv_ref(sx, x).astype(jnp.float32)
         + rb_spmv_ref(sh, h).astype(jnp.float32))
    if bias is not None:
        z = z + bias[:z.shape[-1]].astype(jnp.float32)[None, :]
    return z.astype(x.dtype)


# ----------------------------------------------------------- delta_rb_spmv

def delta_rb_spmv_ref(s: RowBalancedSparse, d: jnp.ndarray,
                      fired: jnp.ndarray) -> jnp.ndarray:
    """Temporal-delta SpMV: y[b, r] = Σ_k vals[r, k] · fired[b, c] · d[b, c].

    ``d`` (B, ncols) is a raw activation delta, ``fired`` its 0/1
    threshold-crossing mask (Spartus-style temporal sparsity): columns that
    did not fire contribute an exact 0.0 — the products a delta accelerator
    skips. Equivalent to ``rb_spmv_ref(s, fired * d)``.
    """
    return rb_spmv_ref(s, (d.astype(jnp.float32)
                           * fired.astype(jnp.float32)).astype(d.dtype))


def delta_rb_dual_spmv_ref(sx: RowBalancedSparse, dx: jnp.ndarray,
                           fx: jnp.ndarray, sh: RowBalancedSparse,
                           dh: jnp.ndarray, fh: jnp.ndarray,
                           m: jnp.ndarray) -> jnp.ndarray:
    """Fused temporal-delta gate update: m' = m + Sx@(fx·dx) + Sh@(fh·dh).

    ``m`` (B, 4H) is the partial-sum memory carried across decode steps;
    the bias is NOT folded in (the caller adds it once per step on top of
    m', keeping m a pure accumulation of delta contributions).
    """
    z = (m.astype(jnp.float32)
         + delta_rb_spmv_ref(sx, dx, fx).astype(jnp.float32)
         + delta_rb_spmv_ref(sh, dh, fh).astype(jnp.float32))
    return z.astype(m.dtype)


# ------------------------------------------------------------ quantized

def rb_spmv_q8_ref(s, qx: jnp.ndarray, act_scale) -> jnp.ndarray:
    """Quantized packed SpMV oracle: integer products, int32 accumulate,
    one dequant multiply per row.

    ``s``: a :class:`repro.quant.RowBalancedSparseQ8` (int codes + f32
    per-row scales); ``qx`` (B, ncols) int activation codes; ``act_scale``
    the scalar activation scale. Returns (B, rows) float32 =
    ``(Σ_k codes · qx) · (row_scale · act_scale)``. The accumulation is
    exact integer arithmetic, so the Pallas kernel matches bit-for-bit.
    """
    s = s.logical()          # oracles compute logical rows only
    cols = s.col_indices()                                  # (R, K)
    # keep the codes at their storage width into the dot (s8/s16 operands,
    # int32 accumulation via preferred_element_type): exact integer math,
    # and the compiled HLO shows an int8-operand dot so the roofline's
    # int8 bucket (roofline.int8_dot_flops) costs it at the int8 peak
    g = jnp.take(qx, cols, axis=1)                          # (B, R, K)
    acc = jnp.einsum("brk,rk->br", g, s.values,
                     preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * (s.scales * act_scale)[None, :]


def rb_dual_spmv_q8_ref(sx, qx, ax, sh, qh, ah,
                        bias: jnp.ndarray) -> jnp.ndarray:
    """Quantized dual-ratio gate preactivation oracle:
    z = dq(Sx@qx) + dq(Sh@qh) + bias, each family dequantized with its own
    combined (row × activation) scales. Returns (B, rows) float32."""
    z = rb_spmv_q8_ref(sx, qx, ax) + rb_spmv_q8_ref(sh, qh, ah)
    return z + bias[:z.shape[-1]].astype(jnp.float32)[None, :]


def delta_rb_dual_spmv_q8_ref(sx, qdx, ax, sh, qdh, ah,
                              m: jnp.ndarray) -> jnp.ndarray:
    """Quantized temporal partial-sum update oracle:
    m' = m + dq(Sx@qdx) + dq(Sh@qdh). ``qdx``/``qdh`` are int codes of the
    MASKED deltas (exact 0 where unfired — a zero code contributes a zero
    integer product, the skip a delta accelerator never issues). ``m``
    (B, rows) float32; bias NOT folded (the caller adds it per step)."""
    return (m.astype(jnp.float32) + rb_spmv_q8_ref(sx, qdx, ax)
            + rb_spmv_q8_ref(sh, qdh, ah))


# ---------------------------------------------------------------- lstm cell

def pwl_tables(n_seg: int = 16, lo: float = -8.0, hi: float = 8.0):
    """Piecewise-linear coefficient tables (a, b per segment) for sigmoid and
    tanh — the paper's LUT-based activation (§4: out = a*x + b per segment).
    Computed by least-squares-free endpoint interpolation per segment."""
    import numpy as np
    xs = np.linspace(lo, hi, n_seg + 1)
    def mk(f):
        y = f(xs)
        a = (y[1:] - y[:-1]) / (xs[1:] - xs[:-1])
        b = y[:-1] - a * xs[:-1]
        return a.astype(np.float32), b.astype(np.float32)
    sig = lambda v: 1.0 / (1.0 + np.exp(-v))
    tanh = np.tanh
    a_s, b_s = mk(sig)
    a_t, b_t = mk(tanh)
    return dict(lo=lo, hi=hi, n_seg=n_seg, sig=(a_s, b_s), tanh=(a_t, b_t))


def _pwl_apply(x, a, b, lo, hi, n_seg, sat_lo, sat_hi):
    xc = jnp.clip(x, lo, hi - 1e-6)
    idx = jnp.floor((xc - lo) / (hi - lo) * n_seg).astype(jnp.int32)
    idx = jnp.clip(idx, 0, n_seg - 1)
    y = a[idx] * xc + b[idx]
    y = jnp.where(x < lo, sat_lo, y)
    y = jnp.where(x >= hi, sat_hi, y)
    return y


def pwl_sigmoid_ref(x, tables=None):
    t = tables or pwl_tables()
    a, b = map(jnp.asarray, t["sig"])
    return _pwl_apply(x.astype(jnp.float32), a, b, t["lo"], t["hi"], t["n_seg"], 0.0, 1.0)


def pwl_tanh_ref(x, tables=None):
    t = tables or pwl_tables()
    a, b = map(jnp.asarray, t["tanh"])
    return _pwl_apply(x.astype(jnp.float32), a, b, t["lo"], t["hi"], t["n_seg"], -1.0, 1.0)


def lstm_cell_ref(zf, zi, zg, zo, c_prev, *, pwl: bool = False):
    """Paper eq. (1)-(2) elementwise part, from gate preactivations.

    c = sig(zf) * c_prev + sig(zi) * tanh(zg);  h = sig(zo) * tanh(c)
    """
    f32 = jnp.float32
    if pwl:
        sig, th = pwl_sigmoid_ref, pwl_tanh_ref
        f, i, g, o = sig(zf), sig(zi), th(zg), sig(zo)
        c = f * c_prev.astype(f32) + i * g
        h = o * th(c)
    else:
        f = jax.nn.sigmoid(zf.astype(f32))
        i = jax.nn.sigmoid(zi.astype(f32))
        g = jnp.tanh(zg.astype(f32))
        o = jax.nn.sigmoid(zo.astype(f32))
        c = f * c_prev.astype(f32) + i * g
        h = o * jnp.tanh(c)
    return c.astype(c_prev.dtype), h.astype(c_prev.dtype)


# ---------------------------------------------------------------- attention

def mha_ref(q, k, v, *, causal: bool = True, scale: float | None = None,
            window: int | None = None) -> jnp.ndarray:
    """Reference attention. q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D).
    GQA: Hq must be a multiple of Hkv. window: local-attention window
    (keys within [qpos-window+1, qpos])."""
    B, Hq, Sq, D = q.shape
    Hkv = k.shape[1]
    group = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    kf = jnp.repeat(kf, group, axis=1)
    vf = jnp.repeat(vf, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", qf, kf)
    Sk = k.shape[2]
    qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)  # right-aligned
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q.dtype)


def decode_attention_ref(q, k, v, lengths) -> jnp.ndarray:
    """Single-token decode attention. q: (B, Hq, D); k, v: (B, Hkv, S, D);
    lengths: (B,) valid cache lengths. Returns (B, Hq, D)."""
    B, Hq, D = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    group = Hq // Hkv
    kf = jnp.repeat(k.astype(jnp.float32), group, axis=1)
    vf = jnp.repeat(v.astype(jnp.float32), group, axis=1)
    s = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32) * D ** -0.5, kf)
    mask = jnp.arange(S)[None, None, :] < lengths[:, None, None]
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhk,bhkd->bhd", p, vf).astype(q.dtype)
