"""repro — BRDS row-balanced dual-ratio sparsity as a multi-pod JAX framework.

Paper: Ghasemzadeh et al., "BRDS: An FPGA-based LSTM Accelerator with
Row-Balanced Dual-Ratio Sparsification" (2021), adapted to TPU v5e.
See DESIGN.md for the architecture and EXPERIMENTS.md for results.
"""
__version__ = "1.0.0"
