"""Close the paper's accuracy loop: train → prune → retrain → calibrate →
pack → serve, with perplexity as a tested gate.

The paper's headline quality claim — dual-ratio (Spar_x, Spar_h) pruning
with retraining costs ≲1.4% PTB perplexity — is the one result the stack's
individually-verified pieces (masked retraining, ``brds_search``,
``QuantConfig`` calibration, packed serving) never produced end to end.
This driver runs the whole arc on the synthetic corpora in
``training/data.py`` (CharCorpus as the PTB stand-in, FrameCorpus for the
TIMIT claim) and enforces two invariants:

  quality gate      at the primary (Spar_x, Spar_h) tuple, the retrained
                    model's eval perplexity delta vs the dense baseline
                    must stay under ``--gate`` percent (CI's
                    quality-smoke job — the quality analogue of
                    bench-smoke's perf pins).
  serving parity    the ``ServeEngine.prepare``'d model (prune → pack →
                    calibrate → pad → delta/quant rewiring) must score
                    BITWISE equal to the manually composed deployment at
                    every grid point — the serving stack may change speed,
                    never quality.

It emits ``BENCH_pipeline.json`` quality×compression records — perplexity
delta vs dense, packed weight bytes, serving tokens/s — over a small
(Spar_x, Spar_h) × {fp32, quant} × {Θ=0, Θ>0} grid (schema pinned by
``scripts/check_bench_schema.py``), and ``--mesh D,M`` runs BOTH training
phases (dense and masked retrain) through ``jit_train_step`` over a
(data, model) device mesh — sharded training of masked models, the one
layer ``repro.dist`` serving did not exercise.

  PYTHONPATH=src python -m repro.launch.pipeline --smoke
  PYTHONPATH=src python -m repro.launch.pipeline --smoke --gate 5
  PYTHONPATH=src python -m repro.launch.pipeline --corpus frame --smoke
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  PYTHONPATH=src python -m repro.launch.pipeline --smoke --mesh 2,4
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
import types
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["PipelineConfig", "PipelineError", "build_task", "train_lstm",
           "evaluate", "prepare_manual", "run_point", "run_pipeline",
           "write_bench", "main"]


class PipelineError(AssertionError):
    """A pipeline invariant (serving parity, quality gate) failed."""


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    """One end-to-end accuracy-loop run.

    ``spar_grid`` lists the (Spar_x, Spar_h) tuples swept (each gets its
    own masked retrain); the FIRST tuple is the primary point the quality
    gate reads. Every tuple is crossed with {fp32, ``quant``} ×
    {Θ=0, ``theta``}. ``mesh`` (data, model) runs both training phases
    sharded via ``training.train_loop.jit_train_step``."""

    corpus: str = "char"            # char | frame | zipf
    embed: int = 32                 # LM embedding width / frame input dim
    hidden: int = 64
    num_layers: int = 1
    vocab: int = 64                 # zipf corpus only (char derives its own)
    frame_classes: int = 16         # frame corpus only
    train_steps: int = 300
    retrain_steps: int = 200
    batch: int = 16
    seq_len: int = 32
    lr: float = 5e-3
    retrain_lr: float = 2e-3
    spar_grid: tuple = ((0.75, 0.5), (0.875, 0.625))
    quant: str = "int8"
    theta: float = 0.05
    eval_batches: int = 4
    eval_batch: int = 16
    eval_seq: int = 32
    gen_batch: int = 4
    gen_prompt: int = 8
    gen_steps: int = 16
    seed: int = 0
    backend: str = "auto"
    mesh: tuple | None = None       # (data, model) training mesh


# --------------------------------------------------------------- task setup

def build_task(cfg: PipelineConfig):
    """→ (corpus, LSTMConfig). The corpus is the quality claim's dataset
    stand-in; the LSTMConfig is the deployment the claim is made about."""
    from ..models import LSTMConfig
    from ..training.data import CharCorpus, FrameCorpus, ZipfInduction
    name = f"pipeline_{cfg.corpus}"
    if cfg.corpus == "char":
        corpus = CharCorpus(seed=cfg.seed)
        return corpus, LSTMConfig(name, input_size=cfg.embed,
                                  hidden=cfg.hidden,
                                  num_layers=cfg.num_layers,
                                  vocab_size=corpus.vocab_size)
    if cfg.corpus == "zipf":
        corpus = ZipfInduction(vocab_size=cfg.vocab, seed=cfg.seed)
        return corpus, LSTMConfig(name, input_size=cfg.embed,
                                  hidden=cfg.hidden,
                                  num_layers=cfg.num_layers,
                                  vocab_size=cfg.vocab)
    if cfg.corpus == "frame":
        corpus = FrameCorpus(input_size=cfg.embed,
                             num_classes=cfg.frame_classes, seed=cfg.seed)
        return corpus, LSTMConfig(name, input_size=cfg.embed,
                                  hidden=cfg.hidden,
                                  num_layers=cfg.num_layers,
                                  num_classes=cfg.frame_classes,
                                  framewise=True)
    raise ValueError(f"unknown corpus {cfg.corpus!r} "
                     "(expected char | frame | zipf)")


def _as_model_batch(raw: dict) -> dict:
    """Corpus batch → the model.loss contract ({'inputs', 'labels'})."""
    if "inputs" in raw:
        return {"inputs": jnp.asarray(raw["inputs"]),
                "labels": jnp.asarray(raw["labels"])}
    return {"inputs": jnp.asarray(raw["tokens"]),
            "labels": jnp.asarray(raw["labels"])}


# ----------------------------------------------------------------- training

def train_lstm(model, corpus, cfg: PipelineConfig, *, steps: int, lr: float,
               params=None, masks=None, mesh=None, log: Callable = None):
    """Train (or masked-retrain) the LSTM for ``steps`` on ``corpus``.

    ``masks`` switches on BRDS retraining — gradients of pruned weights
    are zeroed and the masks re-applied after every update, exactly the
    paper's retrain phase. ``mesh`` routes the step through
    ``jit_train_step`` (full NamedSharding in/out specs over the
    (data, model) axes) — with ``masks`` set this is sharded training OF a
    masked model, the layer the serving-side ``repro.dist`` never touched.
    Returns (params, final_loss)."""
    from ..training import OptConfig, init_state, make_train_step
    from ..training.data import ShardedLoader
    from ..training.train_loop import jit_train_step
    if params is None:
        params = model.init(jax.random.key(cfg.seed))
    oc = OptConfig(lr=lr, total_steps=steps,
                   warmup_steps=max(1, steps // 20))
    opt_state = init_state(oc, params)
    # the train-step factory only reads grad_accum/zero1 off the arch config
    arch = types.SimpleNamespace(grad_accum=1, zero1=True)
    if mesh is None:
        step_fn = jax.jit(make_train_step(model, arch, oc, masks))
    else:
        sample = _as_model_batch(corpus.batch(0, cfg.batch, cfg.seq_len))
        batch_abs = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), sample)
        with mesh:
            step_fn = jit_train_step(mesh, model, arch, oc, batch_abs,
                                     masks)
    loader = ShardedLoader(corpus, cfg.batch, cfg.seq_len)
    loss = float("nan")
    for step in range(steps):
        batch = _as_model_batch(loader.batch(step))
        params, opt_state, metrics = step_fn(params, opt_state, batch,
                                             jnp.int32(step))
        if log is not None and (step % 100 == 0 or step == steps - 1):
            log(f"  step {step:4d} loss {float(metrics['loss']):.4f}")
    loss = float(metrics["loss"])
    # normalize off the mesh (grid pruning/packing below is host-side)
    return jax.device_get(params), loss


# --------------------------------------------------------------- evaluation

def evaluate(model, params, batches) -> dict:
    """Eval ``params`` over held-out ``batches`` through the SERVING step
    path (``LSTMModel.score``) — the quality of the deployed model, valid
    for dense, packed, quantized, and temporal-delta param/model pairs.
    Returns {'nll', 'ppl'} (+ 'acc' for classifiers)."""
    from ..core.metrics import perplexity, token_accuracy
    score = jax.jit(model.score)
    # classifier accuracy rides the dense forward path — packed trees
    # (RowBalancedSparse/Q8 leaves) are NLL-only (the parity invariant)
    def _packed(v):
        return hasattr(v, "values") and hasattr(v, "ncols")
    dense_tree = not any(_packed(l) for l in
                         jax.tree.leaves(params, is_leaf=_packed))
    nlls = []
    accs = []
    for raw in batches:
        b = _as_model_batch(raw)
        nlls.append(float(score(params, b["inputs"], b["labels"])))
        if not model.cfg.vocab_size and dense_tree:
            logits = model.forward(params, b["inputs"])
            accs.append(token_accuracy(logits, b["labels"]))
    out = {"nll": float(np.mean(nlls)), "ppl": perplexity(np.mean(nlls))}
    if accs:
        out["acc"] = float(np.mean(accs))
    return out


# ------------------------------------------------- deployment (two routes)

def _policy_at(cfg: PipelineConfig, spar_x: float, spar_h: float,
               scheme: str | None, theta: float):
    from ..sparse import DeltaGateConfig, QuantConfig, lstm_policy
    delta = (DeltaGateConfig(theta_x=theta, theta_h=theta)
             if theta > 0 else None)
    quant = QuantConfig(scheme) if scheme else None
    return lstm_policy(spar_x, spar_h, backend=cfg.backend, delta=delta,
                       quant=quant)


def prepare_manual(model, policy, params, calib=None):
    """The deployment composed BY HAND from the public pieces — compile →
    prune → pack (→ quantize) → pad, plus the delta/quant model rewiring.
    ``ServeEngine.prepare`` must reproduce this bitwise; ``run_point``
    asserts it. Returns (model', packed_params, report)."""
    from ..quant import calibrate_lstm
    plan = policy.compile(params)
    if plan.activation is not None:
        model = model.with_delta(plan.activation)
    if plan.quant is not None:
        if calib is None:
            raise ValueError("quantized deployment needs a calib batch")
        model = model.with_quant(
            calibrate_lstm(model, params, calib, plan.quant))
    pruned, masks = plan.prune(params)
    packed, report = plan.pack(pruned, masks)
    packed = model.pad_packed_params(packed)
    return model, packed, report


def _time_call(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds per call (compiles on warmup)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def _serve_throughput(engine, params, model_cfg, cfg: PipelineConfig,
                      eval_batch) -> float:
    """Serving tokens/s for this deployment. LMs run a real greedy
    ``ServeEngine.generate``; framewise classifiers (whose decode feeds
    class ids, not frames) time the jitted serving-path scorer instead —
    frames/s through the same packed kernels."""
    if model_cfg.vocab_size:
        prompt = jnp.asarray(
            eval_batch["tokens"][:cfg.gen_batch, :cfg.gen_prompt])
        dt = _time_call(
            lambda: engine.generate(params, prompt, cfg.gen_steps))
        return cfg.gen_batch * cfg.gen_steps / dt
    b = _as_model_batch(eval_batch)
    score = jax.jit(engine.model.score)
    dt = _time_call(score, params, b["inputs"], b["labels"])
    return b["inputs"].shape[0] * b["inputs"].shape[1] / dt


def run_point(model, lcfg, retrained, cfg: PipelineConfig, spar_x, spar_h,
              scheme, theta, eval_set, calib, gen_batch_raw) -> dict:
    """One grid point: deploy ``retrained`` at (spar_x, spar_h) with the
    given quant scheme and delta threshold through BOTH routes, assert the
    bitwise serving-parity invariant, and measure quality + speed."""
    from ..models import LSTMModel
    from ..serving import ServeEngine
    policy = _policy_at(cfg, spar_x, spar_h, scheme, theta)
    needs_calib = scheme is not None
    # route 1: the serving stack end to end
    engine = ServeEngine(LSTMModel(lcfg), lcfg,
                         max_len=cfg.gen_prompt + cfg.gen_steps,
                         batch=cfg.gen_batch, sparsity=policy)
    served_params, report = engine.prepare(
        retrained, calib=calib if needs_calib else None)
    served = evaluate(engine.model, served_params, eval_set)
    # route 2: the same deployment composed by hand
    manual_model, manual_packed, _ = prepare_manual(
        LSTMModel(lcfg), policy, retrained,
        calib=calib if needs_calib else None)
    manual = evaluate(manual_model, manual_packed, eval_set)
    if served["nll"] != manual["nll"]:
        raise PipelineError(
            f"serving stack changed quality at (Spar_x={spar_x}, "
            f"Spar_h={spar_h}, scheme={scheme}, theta={theta}): "
            f"served nll {served['nll']!r} != manual nll {manual['nll']!r}")
    toks_per_s = _serve_throughput(engine, served_params, lcfg, cfg,
                                   gen_batch_raw)
    return {"metrics": served, "weight_bytes": int(report["packed_bytes"]),
            "dense_bytes": int(report["dense_bytes"]),
            "toks_per_s": toks_per_s}


# -------------------------------------------------------------- the driver

def run_pipeline(cfg: PipelineConfig, *, smoke: bool = False,
                 log: Callable = print) -> dict:
    """The full arc. Returns the BENCH_pipeline payload:
    {'benchmark', 'smoke', 'wall_time_s', 'rows', 'gate'} — rows in the
    ``benchmarks/common.py`` record shape (name + us_per_call + derived
    fields), gate the primary-point quality summary the CLI enforces."""
    from ..models import LSTMModel
    from ..sparse import set_default_backend
    t_all = time.time()
    set_default_backend(cfg.backend)
    mesh = None
    if cfg.mesh is not None:
        from .mesh import make_host_mesh
        d, m = cfg.mesh
        mesh = make_host_mesh(data=d, model=m)
        log(f"mesh: data={d} model={m} over {d * m} devices "
            "(sharded dense train + masked retrain)")
    corpus, lcfg = build_task(cfg)
    model = LSTMModel(lcfg)
    eval_set = corpus.eval_batches(cfg.eval_batches, cfg.eval_batch,
                                   cfg.eval_seq)
    calib = _as_model_batch(
        corpus.batch(1 << 41, cfg.eval_batch, cfg.eval_seq))["inputs"]
    gen_raw = corpus.batch(1 << 42, max(cfg.gen_batch, 1), cfg.eval_seq)

    from ..obs import trace as obs_trace
    log(f"[1/4] train dense: corpus={cfg.corpus} H={cfg.hidden} "
        f"L={cfg.num_layers} steps={cfg.train_steps}")
    with obs_trace.span("pipeline.train_dense", steps=cfg.train_steps):
        dense_params, loss = train_lstm(model, corpus, cfg,
                                        steps=cfg.train_steps, lr=cfg.lr,
                                        mesh=mesh, log=log)
    dense = evaluate(model, dense_params, eval_set)
    log(f"      dense eval: ppl {dense['ppl']:.4f}"
        + (f" acc {dense['acc']:.3f}" if "acc" in dense else ""))
    dense_row = {"name": "pipeline_dense", "us_per_call": 0.0,
                 "ppl": dense["ppl"], "nll": dense["nll"],
                 "train_loss": round(loss, 5)}
    if "acc" in dense:
        dense_row["acc"] = dense["acc"]
    rows = [dense_row]

    gate_info = None
    parity_points = 0
    for gi, (spar_x, spar_h) in enumerate(cfg.spar_grid):
        log(f"[2/4] prune+retrain (Spar_x={spar_x}, Spar_h={spar_h}) "
            f"steps={cfg.retrain_steps}")
        with obs_trace.span("pipeline.prune_retrain", spar_x=spar_x,
                            spar_h=spar_h, steps=cfg.retrain_steps):
            plan = _policy_at(cfg, spar_x, spar_h, None, 0.0).compile(
                dense_params)
            pruned, masks = plan.prune(dense_params)
            retrained, _ = train_lstm(model, corpus, cfg,
                                      steps=cfg.retrain_steps,
                                      lr=cfg.retrain_lr, params=pruned,
                                      masks=masks, mesh=mesh, log=log)
        for scheme in (None, cfg.quant):
            for theta in (0.0, cfg.theta):
                with obs_trace.span("pipeline.run_point", spar_x=spar_x,
                                    spar_h=spar_h, theta=theta,
                                    scheme=scheme or "fp32"):
                    point = run_point(model, lcfg, retrained, cfg, spar_x,
                                      spar_h, scheme, theta, eval_set,
                                      calib, gen_raw)
                parity_points += 1
                met = point["metrics"]
                delta_pct = 100.0 * (met["ppl"] - dense["ppl"]) / dense["ppl"]
                sname = scheme or "fp32"
                name = (f"pipeline_sx{spar_x}_sh{spar_h}_{sname}"
                        f"_t{theta}")
                us = 1e6 / max(point["toks_per_s"], 1e-9)
                log(f"[3/4] {name}: ppl {met['ppl']:.4f} "
                    f"({delta_pct:+.2f}% vs dense), "
                    f"{point['weight_bytes']} weight bytes, "
                    f"{point['toks_per_s']:.0f} tok/s [serving parity "
                    f"bitwise OK]")
                row = {"name": name, "us_per_call": round(us, 3),
                       "ppl": met["ppl"], "ppl_delta_pct": delta_pct,
                       "weight_bytes": point["weight_bytes"],
                       "compression": point["weight_bytes"]
                       / max(point["dense_bytes"], 1),
                       "toks_per_s": point["toks_per_s"],
                       "spar_x": spar_x, "spar_h": spar_h,
                       "theta": theta, "scheme": sname}
                if "acc" in met:
                    row["acc"] = met["acc"]
                rows.append(row)
                if gi == 0 and scheme is None and theta == 0.0:
                    gate_info = {"spar_x": spar_x, "spar_h": spar_h,
                                 "ppl_dense": dense["ppl"],
                                 "ppl_sparse": met["ppl"],
                                 "ppl_delta_pct": delta_pct}
    rows.append({"name": "pipeline_serve_parity", "us_per_call": 0.0,
                 "bitwise": 1, "points": parity_points})
    payload = {"benchmark": "pipeline", "smoke": smoke,
               "wall_time_s": round(time.time() - t_all, 3),
               "rows": rows, "gate": gate_info}
    log(f"[4/4] done in {payload['wall_time_s']:.1f}s — {parity_points} "
        "grid points, serving parity bitwise at every one")
    return payload


def write_bench(payload: dict, out_dir: str | None = None) -> str:
    """Write BENCH_pipeline.json (REPRO_BENCH_DIR honored, like
    ``benchmarks/run.py``). Returns the path."""
    out_dir = out_dir or os.environ.get("REPRO_BENCH_DIR", ".")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "BENCH_pipeline.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return path


# ------------------------------------------------------------------ the CLI

def smoke_config(**overrides) -> PipelineConfig:
    """The CI-sized run (the quality-smoke job's shapes): seconds on a
    laptop CPU, yet the full arc — and the (0.75, 0.5) CharCorpus point
    retrains to within a few percent of dense, the smoke-scale analogue
    of the paper's ≤1.4% PTB claim."""
    return PipelineConfig(**overrides)


def _parse_grid(spec: str) -> tuple:
    out = []
    for part in spec.split(","):
        sx, sh = part.split(":")
        out.append((float(sx), float(sh)))
    return tuple(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="train -> prune -> retrain -> calibrate -> pack -> "
                    "serve, with perplexity as a gate")
    ap.add_argument("--corpus", default="char",
                    choices=("char", "frame", "zipf"))
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized shapes/steps (the quality-smoke job)")
    ap.add_argument("--hidden", type=int, default=None)
    ap.add_argument("--embed", type=int, default=None)
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--retrain-steps", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--lr", type=float, default=None)
    ap.add_argument("--retrain-lr", type=float, default=None)
    ap.add_argument("--grid", default=None, metavar="SX:SH,SX:SH",
                    help="(Spar_x, Spar_h) tuples; the first is the "
                         "gate's primary point (default 0.75:0.5,"
                         "0.875:0.625)")
    ap.add_argument("--quant", default="int8", metavar="SCHEME",
                    help="quant leg of the grid ('int8' or qM.N)")
    ap.add_argument("--theta", type=float, default=0.05,
                    help="delta-gating leg of the grid (Theta > 0)")
    ap.add_argument("--gate", type=float, default=5.0, metavar="PCT",
                    help="max allowed retrained-perplexity delta vs dense "
                         "at the primary tuple, percent (negative "
                         "disables; exit 1 past it)")
    ap.add_argument("--backend", default="auto",
                    choices=("auto", "pallas", "ref"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default=None, metavar="DATA,MODEL",
                    help="shard BOTH training phases over a (data, model) "
                         "mesh (jit_train_step; force host devices with "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N)")
    ap.add_argument("--out", default=None,
                    help="BENCH_pipeline.json directory (default "
                         "$REPRO_BENCH_DIR or cwd)")
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="record a Chrome-trace of the pipeline phases "
                         "(repro.obs spans) to FILE")
    args = ap.parse_args(argv)

    overrides: dict[str, Any] = {"corpus": args.corpus, "seed": args.seed,
                                 "backend": args.backend,
                                 "quant": args.quant, "theta": args.theta}
    if not args.smoke:
        # full-size defaults (still CPU-tractable; smoke keeps the tiny
        # dataclass defaults)
        overrides.update(hidden=128, embed=64, train_steps=800,
                         retrain_steps=400, seq_len=48, eval_seq=48)
    for key, val in (("hidden", args.hidden), ("embed", args.embed),
                     ("num_layers", args.layers),
                     ("train_steps", args.steps),
                     ("retrain_steps", args.retrain_steps),
                     ("batch", args.batch), ("seq_len", args.seq),
                     ("lr", args.lr), ("retrain_lr", args.retrain_lr)):
        if val is not None:
            overrides[key] = val
    if args.grid is not None:
        overrides["spar_grid"] = _parse_grid(args.grid)
    if args.mesh is not None:
        try:
            d, m = (int(v) for v in args.mesh.split(","))
        except ValueError:
            raise SystemExit(f"--mesh wants 'DATA,MODEL' ints, got "
                             f"{args.mesh!r}")
        overrides["mesh"] = (d, m)
    cfg = PipelineConfig(**overrides)

    if args.trace:
        from ..obs import trace as obs_trace
        obs_trace.enable()
    payload = run_pipeline(cfg, smoke=args.smoke)
    if args.trace:
        obs_trace.save(args.trace)
        print(f"trace: {args.trace} "
              f"({len(obs_trace.get_tracer().events)} events)")
    path = write_bench(payload, args.out)
    print(f"wrote {path} ({len(payload['rows'])} rows)")
    gate = payload["gate"]
    if gate is not None and args.gate >= 0:
        if gate["ppl_delta_pct"] > args.gate:
            print(f"QUALITY GATE FAIL: ppl delta "
                  f"{gate['ppl_delta_pct']:+.2f}% > {args.gate:.2f}% at "
                  f"(Spar_x={gate['spar_x']}, Spar_h={gate['spar_h']}) "
                  f"(dense {gate['ppl_dense']:.4f} -> sparse "
                  f"{gate['ppl_sparse']:.4f})")
            return 1
        print(f"quality gate OK: ppl delta {gate['ppl_delta_pct']:+.2f}% "
              f"<= {args.gate:.2f}% at (Spar_x={gate['spar_x']}, "
              f"Spar_h={gate['spar_h']})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
