"""input_specs: ShapeDtypeStruct stand-ins for every model input of every
(arch × shape × phase) cell — weak-type-correct, shardable, no allocation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeConfig
from ..models import build_model


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(arch: ArchConfig, shape: ShapeConfig) -> dict:
    """Abstract inputs for the phase implied by shape.kind.

    train:   {tokens, labels, (patch_embeds | frames)}
    prefill: {tokens, (patch_embeds | frames)}
    decode:  {tokens (B,1), cache, pos}
    """
    B, S = shape.global_batch, shape.seq_len
    d = arch.d_model
    jd = arch.jdtype
    model = build_model(arch)

    if shape.kind == "train":
        if arch.encdec:
            half = S // 2
            return {
                "tokens": _sds((B, half), jnp.int32),
                "labels": _sds((B, half), jnp.int32),
                "frames": _sds((B, half, d), jd),
            }
        out = {"tokens": _sds((B, S), jnp.int32),
               "labels": _sds((B, S), jnp.int32)}
        if arch.num_patches:
            out["patch_embeds"] = _sds((B, arch.num_patches, d), jd)
        return out

    if shape.kind == "prefill":
        if arch.encdec:
            return {"tokens": _sds((B, S), jnp.int32),
                    "frames": _sds((B, arch.enc_len, d), jd)}
        out = {"tokens": _sds((B, S), jnp.int32)}
        if arch.num_patches:
            out["patch_embeds"] = _sds((B, arch.num_patches, d), jd)
        return out

    if shape.kind == "decode":
        from ..models import layers as L
        cache_defs = model.cache_defs(B, S)
        cache = L.abstract_params(cache_defs)
        return {"tokens": _sds((B, 1), jnp.int32), "cache": cache,
                "pos": _sds((), jnp.int32)}

    raise ValueError(shape.kind)
