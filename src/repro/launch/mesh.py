"""Production mesh construction. A FUNCTION, not a module constant — importing
this module never touches jax device state."""
from __future__ import annotations

import jax


def _axis_type_kwargs(n_axes: int) -> dict:
    """jax ≥ 0.5 wants explicit Auto axis types; older jax (this container
    ships 0.4.x) has neither the kwarg nor jax.sharding.AxisType."""
    at = getattr(jax.sharding, "AxisType", None)
    if at is None:
        return {}
    return dict(axis_types=(at.Auto,) * n_axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over host CPU devices for tests (requires
    XLA_FLAGS=--xla_force_host_platform_device_count set before jax init)."""
    n = len(jax.devices())
    if data * model > n:
        raise ValueError(f"need {data * model} devices, have {n}")
    return jax.make_mesh((data, model), ("data", "model"),
                         **_axis_type_kwargs(2))
