"""Production mesh construction. A FUNCTION, not a module constant — importing
this module never touches jax device state.

Every mesh in the repo is built here: ``make_mesh`` is the one place that
carries the jax-0.4.x compat shim (``axis_types=`` / ``jax.sharding.AxisType``
only exist on jax >= 0.5), so callers — ServeEngine, the drivers, the
distributed tests — never construct ``Mesh(...)`` ad hoc.
"""
from __future__ import annotations

import jax


def _axis_type_kwargs(n_axes: int) -> dict:
    """jax ≥ 0.5 wants explicit Auto axis types; older jax (this container
    ships 0.4.x) has neither the kwarg nor jax.sharding.AxisType."""
    at = getattr(jax.sharding, "AxisType", None)
    if at is None:
        return {}
    return dict(axis_types=(at.Auto,) * n_axes)


def make_mesh(shape: tuple, axes: tuple):
    """General mesh over the available devices (the one AxisType-shim site).

    ``shape``/``axes`` as for ``jax.make_mesh`` — e.g.
    ``make_mesh((8,), ("data",))`` or ``make_mesh((2, 4), ("data", "model"))``.
    """
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_axis_type_kwargs(len(axes)))


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small (data, model) mesh over host CPU devices for tests/drivers
    (requires XLA_FLAGS=--xla_force_host_platform_device_count set before
    jax init when forcing more devices than the host has)."""
    n = len(jax.devices())
    if data * model > n:
        raise ValueError(f"need {data * model} devices, have {n}")
    return make_mesh((data, model), ("data", "model"))
