import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes, prove sharding coherence + memory fit, and extract
the roofline terms from the compiled artifacts.

MUST be run as its own process (the two lines above must execute before any
jax import anywhere): PYTHONPATH=src python -m repro.launch.dryrun [...]

Results are cached as JSON per cell under reports/dryrun/ — rerunning skips
completed cells (resumable).
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, ARCH_NAMES, get_arch, runnable
from repro.models import build_model
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs
from repro.training import OptConfig, train_loop
from repro.training import optim as optim_lib
from repro import roofline, hw
from repro.serving.engine import cache_shardings
from repro.sharding import use_rules, rules_for
from jax.sharding import NamedSharding, PartitionSpec as P


def _mem_analysis(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
        out = {}
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                out[k] = int(v)
        return out
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}


def _cost_analysis(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and not k.startswith("utilization")}
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}


def _packed_shardings(mesh, packed_abs, orig_sh):
    """Shardings for a BRDS-packed param tree: original shardings where the
    leaf survived; packed values/deltas shard their row (output) dim over
    the model axis when divisible."""
    import numpy as _np
    orig = {jax.tree_util.keystr(pp): ss for pp, ss in
            jax.tree_util.tree_flatten_with_path(orig_sh)[0]}
    msize = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)

    def build(path, leaf):
        key = jax.tree_util.keystr(path)
        if key in orig:
            return orig[key]
        spec = [None] * len(leaf.shape)
        if len(leaf.shape) >= 2 and leaf.shape[-2] % msize == 0:
            spec[-2] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(build, packed_abs)


def build_cell(arch_name: str, shape_name: str, multi_pod: bool,
               overrides: dict | None = None):
    """Returns (lowered, n_devices, aux_info)."""
    brds = bool(overrides and overrides.pop("brds", False))
    arch = get_arch(arch_name)
    if overrides:
        arch = arch.with_(**overrides)
    shape = SHAPES[shape_name]
    n_total = 512 if multi_pod else 256
    if arch.layout == "dp" and (
            shape.kind == "decode"
            or (shape.kind == "train" and shape.global_batch % n_total)
            or (shape.kind == "prefill" and not arch.moe)):
        # decode keeps TP (the model axis carries the split-KV cache);
        # train keeps DP only when the batch covers every chip; prefill
        # keeps DP only for MoE (whose TP dispatch collectives dominate) —
        # dense small archs measured faster under TP at batch 32 (§Perf).
        arch = arch.with_(layout="tp")
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(np.prod(mesh.devices.shape))
    model = build_model(arch)
    specs = input_specs(arch, shape)
    params_abs = model.abstract_params()
    with use_rules(rules_for(arch)):
        p_sh = train_loop.param_shardings(mesh, model)
    brds_report = None
    if brds:
        from repro.sparse import transformer_policy
        bc = arch.brds
        plan = transformer_policy(bc.spar_a, bc.spar_b).compile(params_abs)
        params_abs, brds_report = plan.pack(params_abs, abstract=True)
        p_sh = _packed_shardings(mesh, params_abs, p_sh)
    scalar = NamedSharding(mesh, P())

    with mesh, use_rules(rules_for(arch)):
        if shape.kind == "train":
            oc = OptConfig()
            opt_abs = jax.eval_shape(lambda p: optim_lib.init_state(oc, p),
                                     params_abs)
            o_sh = train_loop.opt_shardings(mesh, oc, p_sh, params_abs,
                                            zero1=arch.zero1)
            b_sh = train_loop.batch_shardings(mesh, specs)
            step_fn = train_loop.make_train_step(model, arch, oc)
            m_sh = {"grad_norm": scalar, "lr": scalar, "loss": scalar}
            fn = jax.jit(step_fn,
                         in_shardings=(p_sh, o_sh, b_sh, scalar),
                         out_shardings=(p_sh, o_sh, m_sh),
                         donate_argnums=(0, 1))
            lowered = fn.lower(params_abs, opt_abs, specs,
                               jax.ShapeDtypeStruct((), jnp.int32))
        elif shape.kind == "prefill":
            b_sh = train_loop.batch_shardings(mesh, specs)
            c_sh = cache_shardings(mesh, model, shape.global_batch,
                                   shape.seq_len)
            if arch.encdec:
                fn = jax.jit(
                    lambda p, t, f: model.prefill(p, t, shape.seq_len,
                                                  extra=f),
                    in_shardings=(p_sh, b_sh["tokens"], b_sh["frames"]),
                    out_shardings=(NamedSharding(mesh, P("data")), c_sh))
                lowered = fn.lower(params_abs, specs["tokens"],
                                   specs["frames"])
            elif arch.num_patches:
                fn = jax.jit(
                    lambda p, t, pe: model.prefill(p, t, shape.seq_len,
                                                   extra=pe),
                    in_shardings=(p_sh, b_sh["tokens"],
                                  b_sh["patch_embeds"]),
                    out_shardings=(NamedSharding(mesh, P("data")), c_sh))
                lowered = fn.lower(params_abs, specs["tokens"],
                                   specs["patch_embeds"])
            else:
                fn = jax.jit(
                    lambda p, t: model.prefill(p, t, shape.seq_len),
                    in_shardings=(p_sh, b_sh["tokens"]),
                    out_shardings=(NamedSharding(mesh, P("data")), c_sh))
                lowered = fn.lower(params_abs, specs["tokens"])
        else:  # decode
            from repro.sharding import named_sharding
            c_sh = cache_shardings(mesh, model, shape.global_batch,
                                   shape.seq_len)
            tok_sh = named_sharding(mesh, ("batch", None),
                                    (shape.global_batch, 1))
            fn = jax.jit(model.decode_step,
                         in_shardings=(p_sh, c_sh, tok_sh, scalar),
                         out_shardings=(tok_sh, c_sh),
                         donate_argnums=(1,))
            lowered = fn.lower(params_abs, specs["cache"], specs["tokens"],
                               specs["pos"])
    return lowered, n_dev, dict(arch=arch, shape=shape, model=model,
                                brds=brds_report)


def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             out_dir: str, force: bool = False, hlo_dir: str | None = None,
             overrides: dict | None = None, tag: str = ""):
    mesh_tag = ("pod2x16x16" if multi_pod else "pod16x16") + tag
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch_name}__{shape_name}__{mesh_tag}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    arch = get_arch(arch_name)
    shape = SHAPES[shape_name]
    ok, reason = runnable(arch, shape)
    rec = {"arch": arch_name, "shape": shape_name, "mesh": mesh_tag}
    if not ok:
        rec.update(status="n/a", reason=reason)
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        return rec
    t0 = time.time()
    try:
        lowered, n_dev, aux = build_cell(arch_name, shape_name, multi_pod,
                                         overrides)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = _mem_analysis(compiled)
        cost = _cost_analysis(compiled)
        hlo = compiled.as_text()
        if hlo_dir:
            os.makedirs(hlo_dir, exist_ok=True)
            with open(os.path.join(
                    hlo_dir, f"{arch_name}__{shape_name}__{mesh_tag}.hlo"),
                    "w") as f:
                f.write(hlo)
        rep = roofline.analyze_hlo(hlo, n_dev, cost)
        mflops = roofline.model_flops(aux["arch"], aux["shape"])
        hbm = roofline.analytic_hbm_bytes(aux["arch"], aux["shape"], n_dev)
        if aux.get("brds"):
            br = aux["brds"]
            # packed weights replace the dense weight traffic term
            delta = (br["dense_bytes"] - br["packed_bytes"]) / n_dev
            hbm["weights"] = max(hbm["weights"] - delta, 0.0)
            hbm["total_per_chip"] = max(hbm["total_per_chip"] - delta, 0.0)
            hbm["brds_packed_ratio"] = br["ratio"]
            rec["brds"] = br
        terms = rep.terms(hbm["total_per_chip"], n_dev)
        rec.update(
            status="ok",
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            n_devices=n_dev,
            memory_analysis=mem,
            cost_analysis=cost,
            hlo_flops_per_chip=rep.flops_hlo,
            hlo_flops_global=rep.flops_hlo * n_dev,
            model_flops=mflops,
            useful_flops_ratio=(mflops["total"] / (rep.flops_hlo * n_dev)
                                if rep.flops_hlo else None),
            collectives={k: v for k, v in rep.collectives.items()
                         if v["count"]},
            collective_wire_bytes=rep.collective_wire_bytes,
            hbm_bytes=hbm,
            roofline=terms,
        )
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=float)
    return rec


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all",
                    help="arch name or 'all'")
    ap.add_argument("--shape", default="all",
                    help="shape name or 'all'")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="reports/dryrun")
    ap.add_argument("--hlo-dir", default=None,
                    help="optionally dump compiled HLO text here")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--layout", default=None, choices=[None, "tp", "dp"])
    ap.add_argument("--brds", action="store_true",
                    help="lower the BRDS packed-sparse serving variant")
    ap.add_argument("--kv-quant", action="store_true",
                    help="int8 KV cache variant")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    archs = ARCH_NAMES if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    n_ok = n_na = n_err = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                ov = {}
                if args.layout:
                    ov["layout"] = args.layout
                if args.brds:
                    ov["brds"] = True
                if args.kv_quant:
                    ov["kv_quant"] = True
                ov = ov or None
                rec = run_cell(arch, shape, mp, args.out, args.force,
                               args.hlo_dir, overrides=ov, tag=args.tag)
                tag = "pod2x16x16" if mp else "pod16x16"
                status = rec.get("status")
                if status == "ok":
                    n_ok += 1
                    r = rec["roofline"]
                    print(f"[OK ] {arch:26s} {shape:12s} {tag:10s} "
                          f"compile={rec.get('compile_s', 0):7.1f}s "
                          f"bound={r['bound']:10s} "
                          f"step={r['step_s'] * 1e3:9.3f}ms", flush=True)
                elif status == "n/a":
                    n_na += 1
                    print(f"[N/A] {arch:26s} {shape:12s} {tag}: "
                          f"{rec['reason'][:60]}", flush=True)
                else:
                    n_err += 1
                    print(f"[ERR] {arch:26s} {shape:12s} {tag}: "
                          f"{rec.get('error', '')[:120]}", flush=True)
    print(f"done: {n_ok} ok, {n_na} n/a, {n_err} errors", flush=True)


if __name__ == "__main__":
    main()
