"""End-to-end training driver.

CPU-scale example (default: a reduced config on the host device):
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b --smoke \
      --steps 50 --batch 8 --seq 128

Production shape (what a real pod launch runs — identical code path, the
mesh is bigger):
  python -m repro.launch.train --arch llama3.2-3b --steps 1000 --mesh pod

Features exercised: sharded train step (DP×TP), grad accumulation, BRDS
masked sparse training (--brds), checkpoint/restart (auto-resume), fault
injection (--inject-failure-at), straggler monitoring.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=20)
    ap.add_argument("--mesh", default="host", choices=["host", "pod", "multipod"])
    ap.add_argument("--brds", action="store_true",
                    help="apply BRDS dual-ratio masks and retrain")
    ap.add_argument("--spar-a", type=float, default=0.75)
    ap.add_argument("--spar-b", type=float, default=0.5)
    ap.add_argument("--inject-failure-at", type=int, default=-1,
                    help="raise at this step once (tests restart path)")
    args = ap.parse_args()

    from repro.configs import get_arch, smoke_config
    from repro.models import build_model
    from repro.training import (OptConfig, init_state, make_train_step,
                                jit_train_step, ZipfInduction, ShardedLoader,
                                CheckpointManager, StragglerMonitor)
    from repro.sparse import transformer_policy

    cfg = smoke_config(args.arch) if args.smoke else get_arch(args.arch)
    model = build_model(cfg)
    print(f"arch={cfg.name} params={model.param_count()/1e6:.1f}M "
          f"layers={cfg.num_layers}")

    rng = jax.random.key(0)
    params = model.init(rng)
    oc = OptConfig(lr=args.lr, total_steps=args.steps,
                   warmup_steps=max(args.steps // 20, 1))
    opt_state = init_state(oc, params)

    masks = None
    if args.brds:
        plan = transformer_policy(args.spar_a, args.spar_b).compile(params)
        params, masks = plan.prune(params)
        print("BRDS:", plan.summary(masks))

    if args.mesh == "host":
        step_fn = jax.jit(make_train_step(model, cfg, oc, masks))
    else:
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=(args.mesh == "multipod"))
        batch_abs = {
            "tokens": jax.ShapeDtypeStruct((args.batch, args.seq), jnp.int32),
            "labels": jax.ShapeDtypeStruct((args.batch, args.seq), jnp.int32)}
        with mesh:
            step_fn = jit_train_step(mesh, model, cfg, oc, batch_abs, masks)

    ds = ZipfInduction(vocab_size=cfg.vocab_size)
    loader = ShardedLoader(ds, args.batch, args.seq)
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    mon = StragglerMonitor()

    start = 0
    latest = ckpt.latest_step()
    if latest is not None:
        (params, opt_state), meta = ckpt.restore((params, opt_state))
        start = meta["step"]
        print(f"resumed from checkpoint at step {start}")

    injected = [False]
    t_all = time.time()
    for step in range(start, args.steps):
        if step == args.inject_failure_at and not injected[0]:
            injected[0] = True
            print(f"!! injecting failure at step {step}; restarting from "
                  f"checkpoint")
            latest = ckpt.latest_step()
            if latest is not None:
                (params, opt_state), meta = ckpt.restore((params, opt_state))
                step = meta["step"]
        t0 = time.time()
        batch = {k: jnp.asarray(v) for k, v in loader.batch(step).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch,
                                             jnp.int32(step))
        dt = time.time() - t0
        straggler = mon.record(dt)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} {dt*1e3:.0f}ms"
                  + (" [straggler]" if straggler else ""))
        if (step + 1) % args.save_every == 0:
            ckpt.save(step + 1, (params, opt_state))
    ckpt.wait()
    print(f"done in {time.time()-t_all:.1f}s; straggler events: {mon.flagged}")


if __name__ == "__main__":
    main()
