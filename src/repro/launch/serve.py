"""Serving driver: on-device batched decode, dense vs BRDS-sparse weights.

Serves every DecodeStep model — the transformer zoo AND the paper's LSTMs
(whose packed row-balanced kernels are exercised with --brds):

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
      --prompt-len 64 --gen 32 --batch 4
  PYTHONPATH=src python -m repro.launch.serve --arch lstm_ptb --smoke --brds
  PYTHONPATH=src python -m repro.launch.serve --arch lstm_ptb --smoke \
      --brds --quant int8
  PYTHONPATH=src python -m repro.launch.serve --arch lstm_ptb --smoke \
      --brds --continuous --slots 4
  PYTHONPATH=src python -m repro.launch.serve --arch lstm_ptb --smoke \
      --brds --traffic --rate 16 --requests 64 --slots 8 --deadline 2.0
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
      --draft lstm_ptb --draft-brds --spec-k 4
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.serve --arch lstm_ptb --smoke \
      --brds --mesh 2,4
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np


def _build(args):
    """→ (model, cfg, vocab_size, sparsity_policy, extra_fn) where
    ``extra_fn(rng, batch)`` builds the family conditioning (encoder
    frames, patch embeds) for a batch of that size, or None."""
    from repro.models import LSTMModel, LSTM_CONFIGS

    if args.delta is None and (args.delta_h is not None
                               or args.occupancy is not None):
        raise SystemExit("--delta-h/--occupancy require --delta")
    if args.quant is not None and not args.brds:
        raise SystemExit("--quant requires --brds (quantization rides the "
                         "packed row-balanced weights)")
    if args.mesh is not None and args.arch in LSTM_CONFIGS and not args.brds:
        raise SystemExit("--mesh on an LSTM requires --brds (sharded decode "
                         "row-shards the packed gate rows — repro.dist)")
    if args.arch in LSTM_CONFIGS:
        cfg = LSTM_CONFIGS[args.arch]
        if args.smoke:
            cfg = dataclasses.replace(cfg, input_size=min(cfg.input_size, 128),
                                      hidden=min(cfg.hidden, 128))
        if not cfg.vocab_size:
            raise SystemExit(f"{args.arch} is not a language model")
        sparsity = None
        if args.brds or args.delta is not None:
            from repro.sparse import lstm_policy, DeltaGateConfig, QuantConfig
            delta = None
            if args.delta is not None:
                delta = DeltaGateConfig(
                    theta_x=args.delta,
                    theta_h=args.delta_h if args.delta_h is not None
                    else args.delta,
                    cap_x=args.occupancy, cap_h=args.occupancy)
            quant = QuantConfig(args.quant) if args.quant else None
            # ratio 0 compiles to an empty weight plan, so --delta without
            # --brds serves dense weights with temporal skipping only
            sparsity = lstm_policy(args.spar_a if args.brds else 0.0,
                                   args.spar_b if args.brds else 0.0,
                                   delta=delta, quant=quant)
        return (LSTMModel(cfg, fused=args.fused), cfg, cfg.vocab_size,
                sparsity, lambda rng, batch: None)

    if args.scorecard:
        raise SystemExit("--scorecard is LSTM-only (its MAC/byte ledger "
                         "covers the recurrent cell — repro.obs.scorecard)")
    if args.delta is not None:
        raise SystemExit("--delta is LSTM-only (temporal sparsity rides "
                         "the recurrent decode cache)")
    if args.quant is not None:
        raise SystemExit("--quant is LSTM-only (quantization rides the "
                         "packed LSTM decode path)")
    from repro.configs import get_arch, smoke_config
    from repro.models import build_model
    cfg = smoke_config(args.arch) if args.smoke else get_arch(args.arch)
    model = build_model(cfg)
    sparsity = None
    if args.brds:
        from repro.sparse import transformer_policy
        sparsity = transformer_policy(args.spar_a, args.spar_b)

    def extra_fn(rng, batch):
        if cfg.encdec:
            return jax.random.normal(rng, (batch, 32, cfg.d_model),
                                     dtype=cfg.jdtype)
        if cfg.num_patches:
            return jax.random.normal(rng, (batch, cfg.num_patches,
                                           cfg.d_model), dtype=cfg.jdtype)
        return None

    return model, cfg, cfg.vocab_size, sparsity, extra_fn


def _build_draft(args, vocab: int, max_len: int, batch: int):
    """Build the --draft DraftModel: an LSTM LM rebound to the target's
    vocab, prepared (prune/pack/delta/quant) through its own ServeEngine
    so every BRDS serving variant can play draft."""
    from repro.models import LSTMModel, LSTM_CONFIGS
    from repro.serving import ServeEngine
    from repro.spec import DraftModel

    if args.draft not in LSTM_CONFIGS:
        raise SystemExit(f"--draft wants an LSTM arch "
                         f"({', '.join(LSTM_CONFIGS)}), got {args.draft!r}")
    if args.draft_quant and not args.draft_brds:
        raise SystemExit("--draft-quant requires --draft-brds")
    cfg = LSTM_CONFIGS[args.draft]
    if args.smoke:
        cfg = dataclasses.replace(cfg, input_size=min(cfg.input_size, 128),
                                  hidden=min(cfg.hidden, 128))
    cfg = dataclasses.replace(cfg, vocab_size=vocab)
    sparsity = None
    if args.draft_brds or args.draft_delta is not None:
        from repro.sparse import lstm_policy, DeltaGateConfig, QuantConfig
        delta = None
        if args.draft_delta is not None:
            delta = DeltaGateConfig(theta_x=args.draft_delta,
                                    theta_h=args.draft_delta)
        quant = QuantConfig(args.draft_quant) if args.draft_quant else None
        sparsity = lstm_policy(args.spar_a if args.draft_brds else 0.0,
                               args.spar_b if args.draft_brds else 0.0,
                               delta=delta, quant=quant)
    deng = ServeEngine(LSTMModel(cfg), cfg, max_len=max_len, batch=batch,
                       sparsity=sparsity)
    dparams = deng.model.init(jax.random.key(7))
    calib = None
    if args.draft_quant:
        calib = jax.random.randint(jax.random.key(8),
                                   (batch, min(args.prompt_len, 32)),
                                   0, vocab)
    dparams, report = deng.prepare(dparams, calib=calib)
    if report is not None:
        print("draft BRDS:", report)
    return DraftModel(deng.model, dparams)


def _obs_outputs(args, params, counters, wall_s, *, batch, step_sum=None,
                 records=None, summary=None, spec=None, extra_gauges=None):
    """--scorecard / --metrics / --trace outputs, shared by the lockstep,
    --continuous, and --traffic paths (repro.obs)."""
    if args.scorecard and counters is not None:
        from repro.obs import scorecard as obs_scorecard
        card = obs_scorecard.build(params, counters, wall_s, batch=batch,
                                   step_sum=step_sum)
        print(obs_scorecard.render(card))
    if args.metrics:
        from repro.obs import MetricsRegistry
        reg = MetricsRegistry()
        if records is not None:
            reg.absorb_traffic(records, summary)
        reg.absorb_spec(spec)
        reg.absorb_counters(counters)
        for name, val in (extra_gauges or {}).items():
            reg.gauge(name).set(val)
        reg.dump(args.metrics)
        print(f"metrics -> {args.metrics}")
    if args.trace:
        from repro.obs import trace as obs_trace
        obs_trace.save(args.trace)
        print(f"trace -> {args.trace} "
              f"({len(obs_trace.get_tracer().events)} events)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b",
                    help="transformer-zoo arch or lstm_ptb/lstm_timit/...")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--brds", action="store_true",
                    help="row-balanced prune (and, for the LSTM, pack) "
                         "the weights first")
    ap.add_argument("--spar-a", type=float, default=0.75)
    ap.add_argument("--spar-b", type=float, default=0.5)
    ap.add_argument("--delta", type=float, default=None, metavar="THETA",
                    help="LSTM only: serve with Spartus-style temporal "
                         "delta sparsity at threshold THETA (0 = exact; "
                         "composes with --brds packed weights)")
    ap.add_argument("--delta-h", type=float, default=None,
                    help="separate recurrent-path threshold "
                         "(default: same as --delta)")
    ap.add_argument("--occupancy", type=float, default=None, metavar="CAP",
                    help="cap the fired-column fraction per step "
                         "(hardware worst-case bound)")
    ap.add_argument("--quant", default=None, metavar="SCHEME",
                    help="LSTM only, requires --brds: serve fixed-point "
                         "quantized packed weights ('int8' or paper-style "
                         "'qM.N', e.g. 'q1.11'); activation scales are "
                         "calibrated on a prompt-shaped batch")
    ap.add_argument("--backend", default="auto",
                    choices=("auto", "pallas", "ref"),
                    help="sparse-kernel backend for packed decode")
    ap.add_argument("--fused", dest="fused", action="store_true",
                    default=None,
                    help="LSTM: force single-launch fused decode kernels "
                         "(default: on wherever shapes allow; sharded "
                         "--mesh decode always chains)")
    ap.add_argument("--no-fused", dest="fused", action="store_false",
                    help="LSTM: force the chained per-kernel decode path")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=0.0,
                    help="nucleus sampling mass in (0, 1); 0 disables")
    ap.add_argument("--eos-id", type=int, default=-1)
    ap.add_argument("--mesh", default=None, metavar="DATA,MODEL",
                    help="serve through a (data, model) device mesh, e.g. "
                         "'2,4' (repro.dist sharded packed decode; for the "
                         "LSTM requires --brds so the gate rows can be "
                         "row-sharded — force host devices with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N to try "
                         "on CPU)")
    ap.add_argument("--continuous", action="store_true",
                    help="serve a ragged request stream through the "
                         "continuous-batching scheduler instead of one "
                         "lockstep batch")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--traffic", action="store_true",
                    help="drive the scheduler with a seeded Poisson arrival "
                         "trace (repro.traffic.loadgen) and report the "
                         "latency curve: TTFT/TPOT percentiles, goodput, "
                         "drops. Composes with --brds/--delta/--quant/"
                         "--mesh; uses --slots and --dispatch-depth")
    ap.add_argument("--rate", type=float, default=8.0,
                    help="--traffic: offered load, requests/second")
    ap.add_argument("--requests", type=int, default=64,
                    help="--traffic: total requests in the trace")
    ap.add_argument("--deadline", type=float, default=None, metavar="SEC",
                    help="--traffic: per-request TTLT deadline; queued "
                         "requests expire and in-slot requests are evicted "
                         "past it (overload shedding)")
    ap.add_argument("--dispatch-depth", type=int, default=2,
                    help="decode chunks kept in flight ahead of the host "
                         "(1 = synchronous harvest-before-dispatch)")
    ap.add_argument("--load-seed", type=int, default=0,
                    help="--traffic: arrival-trace RNG seed (the schedule "
                         "is fully deterministic given the seed)")
    ap.add_argument("--draft", default=None, metavar="ARCH",
                    help="speculative decoding: propose with this LSTM "
                         "arch (e.g. lstm_ptb) rebound to the target's "
                         "vocab; greedy output is bitwise identical to "
                         "serving without it. Composes with --continuous "
                         "and --traffic")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="--draft: tokens proposed per speculative round")
    ap.add_argument("--draft-brds", action="store_true",
                    help="row-balanced prune + pack the draft's weights "
                         "(--spar-a/--spar-b ratios)")
    ap.add_argument("--draft-delta", type=float, default=None,
                    metavar="THETA",
                    help="draft with temporal delta sparsity at THETA")
    ap.add_argument("--draft-quant", default=None, metavar="SCHEME",
                    help="draft with quantized packed weights ('int8' or "
                         "'qM.N'); requires --draft-brds")
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="record a Chrome-trace (Perfetto-loadable JSON) of "
                         "engine/scheduler spans to FILE (repro.obs.trace)")
    ap.add_argument("--metrics", default=None, metavar="FILE",
                    help="dump a metrics snapshot to FILE — Prometheus "
                         "text, or JSON when FILE ends in .json "
                         "(repro.obs.metrics)")
    ap.add_argument("--scorecard", action="store_true",
                    help="LSTM only: print the effective-GOPS scorecard — "
                         "harvested on-device counters against the decode "
                         "roofline (repro.obs.scorecard)")
    args = ap.parse_args()

    from repro.serving import (ServeEngine, ContinuousBatchingEngine,
                               SamplingConfig)
    from repro.sparse import set_default_backend

    set_default_backend(args.backend)
    if args.trace:
        from repro.obs import trace as obs_trace
        obs_trace.enable()
    # counters ride the decode dispatches only when an obs output wants them
    want_counters = args.scorecard or args.metrics is not None
    mesh = None
    if args.mesh is not None:
        from repro.launch.mesh import make_host_mesh
        try:
            d, m = (int(v) for v in args.mesh.split(","))
        except ValueError:
            raise SystemExit(f"--mesh wants 'DATA,MODEL' ints, got "
                             f"{args.mesh!r}")
        try:
            mesh = make_host_mesh(data=d, model=m)
        except ValueError as e:
            raise SystemExit(
                f"--mesh {args.mesh}: {e} (force host devices with "
                "XLA_FLAGS=--xla_force_host_platform_device_count=N)")
        print(f"mesh: data={d} model={m} over {d * m} devices")
    model, cfg, vocab, sparsity, extra_fn = _build(args)
    params = model.init(jax.random.key(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n/1e6:.1f}M")

    max_len = args.prompt_len + args.gen
    eng = ServeEngine(model, cfg, max_len=max_len, batch=args.batch,
                      sparsity=sparsity, mesh=mesh)
    calib = None
    if args.quant:
        # calibrate activation scales on a prompt-shaped batch through the
        # dense params (prepare prunes/packs afterwards)
        calib = jax.random.randint(jax.random.key(3),
                                   (args.batch, min(args.prompt_len, 32)),
                                   0, vocab)
    params, brds_report = eng.prepare(params, calib=calib)
    if brds_report is not None:
        print("BRDS:", brds_report)
    rng = jax.random.key(1)
    sampling = SamplingConfig(temperature=args.temperature, top_k=args.top_k,
                              top_p=args.top_p, eos_id=args.eos_id)

    draft = None
    if args.draft is not None:
        if args.mesh is not None:
            raise SystemExit("--draft does not compose with --mesh yet")
        draft = _build_draft(args, vocab, max_len, args.batch)
        print(f"draft={args.draft} spec_k={args.spec_k}")

    if args.traffic:
        from repro.traffic import LoadConfig, poisson_trace, make_prompts, \
            serve_trace
        sched = ContinuousBatchingEngine(
            eng.model, params, slots=args.slots, max_len=max_len,
            sampling=sampling, dispatch_depth=args.dispatch_depth,
            mesh=mesh if eng._dist else None, draft=draft,
            spec_k=args.spec_k, counters=want_counters)
        short_hi = max(5, args.prompt_len // 4)
        long_hi = max(short_hi + 1, args.prompt_len)
        lc = LoadConfig(rate=args.rate, num_requests=args.requests,
                        prompt_short=(4, short_hi),
                        prompt_long=(short_hi, long_hi),
                        output_lens=(4, args.gen), deadline=args.deadline,
                        seed=args.load_seed)
        trace = poisson_trace(lc)
        prompts = make_prompts(trace, vocab, seed=args.load_seed)
        print(f"traffic: {args.requests} requests at {args.rate:.1f} req/s, "
              f"slots={args.slots} depth={args.dispatch_depth}"
              + (f" deadline={args.deadline}s" if args.deadline else ""))
        records, summary = serve_trace(sched, trace, prompts,
                                       offered_rps=args.rate)
        print(f"completed={summary['completed']} "
              f"expired={summary['expired']} rejected={summary['rejected']} "
              f"({summary['tokens']} tokens, {summary['wall_s']:.2f}s wall, "
              f"{sched.steps_dispatched} chunk dispatches)")
        ms = lambda v: "n/a" if v is None else f"{v:.2f}"
        print(f"TTFT ms: p50={ms(summary['p50_ttft_ms'])} "
              f"p90={ms(summary['p90_ttft_ms'])} "
              f"p99={ms(summary['p99_ttft_ms'])}")
        print(f"TPOT ms: p50={ms(summary['p50_tpot_ms'])} "
              f"p99={ms(summary['p99_tpot_ms'])}")
        print(f"goodput: {summary['goodput_tps']:.1f} tok/s "
              f"(total {summary['toks_per_s']:.1f} tok/s)")
        if draft is not None:
            st = sched.spec_stats()
            print(f"spec: acceptance={st['acceptance_rate']:.1%} "
                  f"({st['accepted']}/{st['drafted']} drafted over "
                  f"{st['rounds']} rounds)")
        _obs_outputs(
            args, params, sched.counters() if want_counters else None,
            summary["wall_s"], batch=args.slots,
            step_sum=float(np.sum(sched.slot_steps))
            if args.delta is not None else None,
            records=records, summary=summary,
            spec=sched.spec_stats() if draft is not None else None)
        return

    if args.continuous:
        # eng.model carries the delta/quant/mesh wiring applied by prepare;
        # only dist-partitioned serving passes the mesh through (the
        # scheduler has no sharded path for the transformer zoo)
        sched = ContinuousBatchingEngine(eng.model, params, slots=args.slots,
                                         max_len=max_len, sampling=sampling,
                                         mesh=mesh if eng._dist else None,
                                         draft=draft, spec_k=args.spec_k,
                                         counters=want_counters)
        lens = [max(4, args.prompt_len - 3 * i) for i in range(args.batch)]
        for i, plen in enumerate(lens):
            req_rng = jax.random.fold_in(rng, i)
            prompt = jax.random.randint(req_rng, (1, plen), 0, vocab)
            sched.submit(prompt, args.gen, extra=extra_fn(req_rng, 1))
        t0 = time.time()
        results = sched.run()
        dt = time.time() - t0
        total = sum(len(v) for v in results.values())
        print(f"served {len(results)} ragged requests "
              f"({total} tokens) in {dt:.2f}s ({total / dt:.1f} tok/s, "
              f"{sched.steps_dispatched} chunk dispatches)")
        if draft is not None:
            st = sched.spec_stats()
            print(f"spec: acceptance={st['acceptance_rate']:.1%} "
                  f"({st['accepted']}/{st['drafted']} drafted over "
                  f"{st['rounds']} rounds)")
        if args.delta is not None:
            from repro.sparse import occupancy_report
            occ = occupancy_report(
                sched.cache, steps=sched.slot_steps,
                packed=params if args.brds else None)
            line = (f"delta: occupancy x={occ['occupancy_x']:.1%} "
                    f"h={occ['occupancy_h']:.1%}")
            if "ops_reduction" in occ:
                line += (f", effective-ops reduction "
                         f"{occ['ops_reduction']:.2f}x")
            print(line + " (final slot residents)")
        _obs_outputs(
            args, params, sched.counters() if want_counters else None,
            dt, batch=args.slots,
            step_sum=float(np.sum(sched.slot_steps))
            if args.delta is not None else None,
            spec=sched.spec_stats() if draft is not None else None,
            extra_gauges={"serve_toks_per_s": total / dt})
        uid0 = min(results)
        print("sample ids:", results[uid0][:16])
        return

    tokens = jax.random.randint(rng, (args.batch, args.prompt_len), 0, vocab)
    extra = extra_fn(rng, args.batch)
    t0 = time.time()
    out, state = eng.generate(params, tokens, args.gen, extra=extra,
                              sampling=sampling, rng=jax.random.key(2),
                              return_state=True, draft=draft,
                              spec_k=args.spec_k)
    out.block_until_ready()
    dt = time.time() - t0
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s, one decode dispatch)")
    spec = None
    if draft is not None:
        drafted = int(np.sum(np.asarray(state["drafted"])))
        accepted = int(np.sum(np.asarray(state["accepted"])))
        rounds = int(np.sum(np.asarray(state["rounds"])))
        spec = dict(rounds=rounds, drafted=drafted, accepted=accepted,
                    acceptance_rate=accepted / max(drafted, 1))
        print(f"spec: acceptance={accepted / max(drafted, 1):.1%} "
              f"({accepted}/{drafted} drafted over {rounds} rounds)")
    if args.delta is not None:
        from repro.sparse import occupancy_report
        occ = occupancy_report(
            state["cache"], steps=args.prompt_len + args.gen,
            packed=params if args.brds else None)
        line = (f"delta: occupancy x={occ['occupancy_x']:.1%} "
                f"h={occ['occupancy_h']:.1%}")
        if "ops_reduction" in occ:
            line += f", effective-ops reduction {occ['ops_reduction']:.2f}x"
        print(line)
    c = None
    if want_counters:
        from repro.obs import counters as obs_counters
        c = obs_counters.from_state(eng.model, state, steps=args.gen)
    _obs_outputs(
        args, params, c, dt, batch=args.batch,
        step_sum=float(args.batch * (args.prompt_len + args.gen))
        if args.delta is not None else None,
        spec=spec, extra_gauges={"serve_toks_per_s":
                                 args.batch * args.gen / dt})
    print("sample ids:", np.asarray(out[0][:16]))


if __name__ == "__main__":
    main()
