"""Serving driver: prefill + batched decode, dense vs BRDS-sparse weights.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
      --prompt-len 64 --gen 32 --batch 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--brds", action="store_true",
                    help="row-balanced prune the FFN/attention weights first")
    ap.add_argument("--spar-a", type=float, default=0.75)
    ap.add_argument("--spar-b", type=float, default=0.5)
    args = ap.parse_args()

    from repro.configs import get_arch, smoke_config
    from repro.models import build_model
    from repro.serving import ServeEngine

    cfg = smoke_config(args.arch) if args.smoke else get_arch(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    print(f"arch={cfg.name} params={model.param_count()/1e6:.1f}M")

    sparsity = None
    if args.brds:
        from repro.sparse import transformer_policy
        sparsity = transformer_policy(args.spar_a, args.spar_b)

    max_len = args.prompt_len + args.gen
    eng = ServeEngine(model, cfg, max_len=max_len, batch=args.batch,
                      sparsity=sparsity)
    params, brds_report = eng.prepare(params)
    if brds_report is not None:
        print("BRDS:", brds_report)
    rng = jax.random.key(1)
    tokens = jax.random.randint(rng, (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    extra = None
    if cfg.encdec:
        extra = jax.random.normal(rng, (args.batch, 32, cfg.d_model),
                                  dtype=cfg.jdtype)
    elif cfg.num_patches:
        extra = jax.random.normal(rng, (args.batch, cfg.num_patches,
                                        cfg.d_model), dtype=cfg.jdtype)

    t0 = time.time()
    out = eng.generate(params, tokens, args.gen, extra=extra)
    out.block_until_ready()
    dt = time.time() - t0
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print("sample ids:", np.asarray(out[0][:16]))


if __name__ == "__main__":
    main()
