"""Calibration: QuantConfig (the policy rule) → QuantPlan (the deployment).

Weight scales come out of the weights themselves at pack time (per-row
max-abs — see ``quantize_packed``); the ACTIVATION scales need data. The
calibration pass runs the dense model over a calibration batch, collects
per-layer input (x-path) and hidden-state (h-path) magnitude statistics,
and freezes one static float scale per (layer, path) into a ``QuantPlan``
— a hashable declaration the model carries, so the decode loop compiles
the scales in as constants (no per-step max reductions on the hot path).

Fixed-point (qM.N) schemes skip statistics entirely: every scale is the
format's 2^-N, exactly like the FPGA datapath.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from .scheme import QuantScheme, parse_scheme

__all__ = ["QuantConfig", "QuantPlan", "calibrate_lstm", "default_plan"]

_METHODS = ("absmax", "percentile")


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """The policy-side quantization rule (what to do, not yet the scales).

    Parameters
    ----------
    scheme : str
        ``"int8"`` (symmetric, per-row weight scales, calibrated
        activation scales) or ``"qM.N"`` fixed point (e.g. ``"q1.11"``).
    method : {"absmax", "percentile"}
        Activation-scale statistic over the calibration batch. Percentile
        clips outliers (the usual post-training-quantization trick);
        max-abs guarantees no activation clipping on the batch.
    percentile : float
        The percentile of |activation| used when ``method="percentile"``.

    Examples
    --------
    >>> QuantConfig("int8").resolved.qmax
    127
    >>> QuantConfig("q1.11", method="percentile", percentile=99.0).method
    'percentile'
    """

    scheme: str = "int8"
    method: str = "absmax"
    percentile: float = 99.9

    def __post_init__(self):
        parse_scheme(self.scheme)  # validate early
        if self.method not in _METHODS:
            raise ValueError(f"method must be one of {_METHODS}, "
                             f"got {self.method!r}")
        if not (0.0 < self.percentile <= 100.0):
            raise ValueError(f"percentile must be in (0, 100], "
                             f"got {self.percentile}")

    @property
    def resolved(self) -> QuantScheme:
        return parse_scheme(self.scheme)


@dataclasses.dataclass(frozen=True)
class QuantPlan:
    """Calibration output: the scheme plus per-layer activation scales.

    ``act_scales`` is a tuple of ``(s_x, s_h)`` float pairs, one per LSTM
    layer — static (hashable) so the plan can live on the model object
    and key jit caches. ``scale_for(i)`` is what the decode step feeds
    the q8 kernel wrappers."""

    scheme: QuantScheme
    act_scales: tuple

    def scale_for(self, layer: int) -> tuple[float, float]:
        return self.act_scales[layer]

    @property
    def num_layers(self) -> int:
        return len(self.act_scales)


def _act_scale(x, cfg: QuantConfig, scheme: QuantScheme) -> float:
    """One static activation scale from a batch of activations."""
    if scheme.frac_bits is not None:
        return scheme.fixed_scale
    a = np.abs(np.asarray(x, np.float32))
    amax = (float(np.percentile(a, cfg.percentile))
            if cfg.method == "percentile" else float(a.max()))
    return (amax / scheme.qmax) if amax > 0 else 1.0 / scheme.qmax


def calibrate_lstm(model, params, tokens, cfg: QuantConfig) -> QuantPlan:
    """Run the dense LSTM over a calibration batch and freeze act scales.

    Parameters
    ----------
    model : LSTMModel
        The model to calibrate (its dense scan path is used).
    params : pytree
        DENSE params — calibration happens before prune/pack so the
        statistics see the deployment's embedding/hidden distributions.
    tokens : jnp.ndarray
        (B, S) token ids (LM) or (B, S, X) feature frames.
    cfg : QuantConfig
        Scheme + statistic.

    Returns
    -------
    QuantPlan
        Per-layer ``(s_x, s_h)`` activation scales.
    """
    from ..models import layers as L
    scheme = cfg.resolved
    cfgm = model.cfg
    if cfgm.vocab_size:
        x = L.embed_apply(params["embed"], tokens)
    else:
        x = tokens.astype(cfgm.dtype)
    B = x.shape[0]
    scales = []
    for lp in params["layers"]:
        s_x = _act_scale(x, cfg, scheme)
        c0 = jnp.zeros((B, cfgm.hidden), cfgm.dtype)
        h0 = jnp.zeros((B, cfgm.hidden), cfgm.dtype)
        hs, _ = model._scan_layer(lp, x, c0, h0)
        s_h = _act_scale(hs, cfg, scheme)
        scales.append((s_x, s_h))
        x = hs
    return QuantPlan(scheme=scheme, act_scales=tuple(scales))


def default_plan(cfg: QuantConfig, num_layers: int) -> QuantPlan:
    """Calibration-free fallback when no batch is available.

    Fixed-point schemes need none (scales are 2^-N by construction). For
    scaled schemes the assumed |activation| bound is 1.0 — exact for the
    tanh-bounded hidden path, a guess for the input path (prefer a real
    calibration batch when embeddings can exceed unit range)."""
    scheme = cfg.resolved
    s = scheme.fixed_scale if scheme.frac_bits is not None \
        else 1.0 / scheme.qmax
    return QuantPlan(scheme=scheme, act_scales=((s, s),) * num_layers)
