"""repro.quant — fixed-point inference quantization.

The arithmetic-fidelity axis of the reproduction, composed with both
sparsity axes (row-balanced weights × temporal deltas):

  scheme    — QuantScheme number formats (symmetric ``int8``, paper-style
              ``qM.N`` fixed point), quantize/dequantize, per-row scales
  formats   — RowBalancedSparseQ8 packed storage (integer codes + f32
              per-row scales + the UNCHANGED delta-encoded columns) and
              the registered ``row_balanced_q8`` SparseFormat
  calibrate — QuantConfig (the policy's ``quant=`` rule) → QuantPlan
              (static per-layer activation scales) via a max-abs /
              percentile pass over a calibration batch

The packed codes feed the Pallas q8 kernels (``kernels.rb_spmv_q8``:
integer products, int32 accumulation, per-row dequant into the fp32
partial-sum memory); ``SparsityPolicy(..., quant=QuantConfig(...))``
threads the whole thing through prune → pack → serve.
"""
from .calibrate import QuantConfig, QuantPlan, calibrate_lstm, default_plan
from .formats import (RowBalancedQ8Format, RowBalancedSparseQ8,
                      abstract_quantize_packed, dequantize_packed,
                      packed_bytes_q, quantize_packed)
from .scheme import (QuantScheme, dequantize, parse_scheme, quantize,
                     row_scales)

__all__ = [
    "QuantScheme", "parse_scheme", "quantize", "dequantize", "row_scales",
    "RowBalancedSparseQ8", "RowBalancedQ8Format", "quantize_packed",
    "dequantize_packed", "abstract_quantize_packed", "packed_bytes_q",
    "QuantConfig", "QuantPlan", "calibrate_lstm", "default_plan",
]
