"""Quantization arithmetic: schemes, (de)quantization, per-row scales.

The BRDS accelerator evaluates its pruned LSTMs in fixed-point arithmetic
(the paper's Table-1 storage is "fixed-16"), and the baselines it beats
treat bit width as a first-class axis next to sparsity: ESE stores 12-bit
sparse LSTM weights, Spartus serves fixed-point spatio-temporal sparse
LSTMs. This module is the arithmetic core of that axis:

  QuantScheme   the number format — symmetric ``int8`` (per-row max-abs
                scales, the TPU-native path) or paper-style ``qM.N``
                fixed point (sign + M integer + N fraction bits, one
                global scale 2^-N — values saturate, like the FPGA)
  quantize      x → integer codes  q = clip(round(x / scale), ±qmax)
  dequantize    codes → floats     x̂ = q · scale
  row_scales    per-row dequant scales for a (…, rows, K) value array, so
                the scales ride the row-balanced packed layout

Everything here is pure jnp and shared by the packed formats, the Pallas
q8 kernels' wrappers, and the reference twins — both backends see the SAME
codes and scales, which is what makes pallas↔ref parity exact (integer
accumulation has no rounding to disagree about).
"""
from __future__ import annotations

import dataclasses
import re

import jax.numpy as jnp

__all__ = ["QuantScheme", "parse_scheme", "quantize", "dequantize",
           "row_scales"]

_QMN = re.compile(r"^q(\d+)\.(\d+)$")


@dataclasses.dataclass(frozen=True)
class QuantScheme:
    """One number format for quantized inference.

    Parameters
    ----------
    name : str
        Registry-style name (``"int8"`` or ``"qM.N"``).
    qmax : int
        Largest positive integer code; codes live in [-qmax, qmax]
        (symmetric — the asymmetric extra negative code is never used).
    frac_bits : int or None
        ``None`` for scaled schemes (per-row max-abs scales, int8 style);
        ``N`` for qM.N fixed point, where every scale is the constant
        2^-N and out-of-range values saturate.

    Examples
    --------
    >>> parse_scheme("int8").qmax
    127
    >>> s = parse_scheme("q1.11")
    >>> (s.qmax, s.frac_bits, str(s.storage))
    (4095, 11, 'int16')
    >>> parse_scheme("q1.11").fixed_scale
    0.00048828125
    """

    name: str
    qmax: int
    frac_bits: int | None = None

    @property
    def storage(self):
        """Narrowest jnp integer dtype holding the codes."""
        return jnp.dtype(jnp.int8) if self.qmax <= 127 else \
            jnp.dtype(jnp.int16)

    @property
    def fixed_scale(self) -> float | None:
        """The constant scale 2^-N of a fixed-point scheme (None if
        scaled)."""
        return None if self.frac_bits is None else 2.0 ** -self.frac_bits

    @property
    def bits(self) -> int:
        """Code width in bits (sign included)."""
        return 1 + int(self.qmax).bit_length()

    def act_scale(self, scale):
        """Resolve an activation scale: fixed-point schemes always use
        2^-N; scaled schemes use the given ``scale`` (None → caller
        derives one, e.g. dynamic max-abs)."""
        return self.fixed_scale if self.frac_bits is not None else scale


def parse_scheme(spec) -> QuantScheme:
    """``"int8"`` | ``"qM.N"`` | QuantScheme → QuantScheme.

    ``qM.N`` is sign + M integer + N fraction bits (1+M+N total, ≤ 16):
    codes in [-(2^(M+N)-1), 2^(M+N)-1], value = code · 2^-N. The paper's
    12-bit fixed point is ``q0.11``; ``q1.11`` adds one integer bit of
    headroom for the gate preactivation range.
    """
    if isinstance(spec, QuantScheme):
        return spec
    if spec == "int8":
        return QuantScheme("int8", qmax=127, frac_bits=None)
    m = _QMN.match(str(spec))
    if not m:
        raise ValueError(f"unknown quant scheme {spec!r}; expected 'int8' "
                         "or 'qM.N' (e.g. 'q1.11')")
    mi, n = int(m.group(1)), int(m.group(2))
    if n < 1 or mi + n > 15:
        raise ValueError(f"qM.N needs 1 <= N and M+N <= 15, got q{mi}.{n}")
    return QuantScheme(f"q{mi}.{n}", qmax=2 ** (mi + n) - 1, frac_bits=n)


def quantize(x, scale, scheme: QuantScheme):
    """x → integer codes: ``clip(round(x / scale), -qmax, qmax)``.

    ``scale`` broadcasts against ``x`` (scalar activation scale or
    per-row ``scales[..., None]``). Returns ``scheme.storage`` codes.
    """
    q = jnp.round(x.astype(jnp.float32) / scale)
    return jnp.clip(q, -scheme.qmax, scheme.qmax).astype(scheme.storage)


def dequantize(q, scale):
    """Integer codes → float32 values (``q · scale``)."""
    return q.astype(jnp.float32) * scale


def row_scales(values, scheme: QuantScheme):
    """Per-row dequant scales for a (…, rows, K) value array.

    Scaled schemes (int8): max-abs over the row's K packed values / qmax,
    so the row's largest weight maps exactly onto qmax (no clipping and
    a ≤ scale/2 round-off bound). All-zero rows get scale 1.0. Fixed-point
    schemes: the constant 2^-N (values saturate at ±qmax·2^-N).
    Returns float32 of shape ``values.shape[:-1]``.
    """
    shape = values.shape[:-1]
    if scheme.frac_bits is not None:
        return jnp.full(shape, scheme.fixed_scale, jnp.float32)
    amax = jnp.max(jnp.abs(values.astype(jnp.float32)), axis=-1)
    return jnp.where(amax > 0, amax / scheme.qmax, 1.0)
