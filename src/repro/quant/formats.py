"""Quantized packed storage: RowBalancedSparseQ8 + the registered format.

``RowBalancedSparseQ8`` is the quantized twin of
:class:`repro.core.packing.RowBalancedSparse`: the SAME delta-encoded
column indices (the relative-addressing layout is orthogonal to value
precision), integer value codes instead of floats, and one float32 dequant
scale per row — scales ride the row-balanced layout because every row has
exactly K codes, so ``scales[r]`` multiplies a whole (B, K) gather tile in
the kernel's int32→fp32 epilogue.

Weight bytes on the decode hot path (the memory-bound regime the ROADMAP
targets) shrink by itemsize(f32)/itemsize(codes): 4× for int8, 2× for a
qM.N stored in int16 — multiplying with the 1/(1-sparsity) packing gain.

``row_balanced_q8`` is also a registered :class:`repro.sparse.SparseFormat`
so a policy rule can name it directly
(``("row_balanced_q8", 0.875, {"scheme": "q1.11"})``); the usual entry
point, though, is the policy-level ``quant=`` rule which quantizes every
row-balanced site at ``SparsityPlan.pack`` time.
"""
from __future__ import annotations

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from ..core import packing as P
from ..core import sparsity as S
from ..sparse.formats import SparseFormat, register
from .scheme import QuantScheme, parse_scheme, quantize, row_scales

__all__ = ["RowBalancedSparseQ8", "quantize_packed", "dequantize_packed",
           "abstract_quantize_packed", "packed_bytes_q",
           "RowBalancedQ8Format"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class RowBalancedSparseQ8:
    """Quantized packed row-balanced sparse matrix, logical (rows, ncols).

    values:  (rows, K)  integer value codes (int8 / int16)
    deltas:  (rows, K)  delta-encoded column indices — identical to the
                        float packing's (quantization never moves a column)
    scales:  (rows,)    float32 per-row dequant scales
    ncols:   static logical column count
    qmax:    static largest positive code (symmetric range)
    frac_bits: static   fixed-point fraction bits, or None for scaled
    pad:     static count of zero rows appended by ``core.packing.
             pad_packed`` (codes, deltas AND scales); ``rows`` stays logical
    block_rows: static block size the padding targeted (None = unpadded)
    """

    values: jnp.ndarray
    deltas: jnp.ndarray
    scales: jnp.ndarray
    ncols: int = dataclasses.field(metadata=dict(static=True))
    qmax: int = dataclasses.field(metadata=dict(static=True))
    frac_bits: int | None = dataclasses.field(
        default=None, metadata=dict(static=True))
    pad: int = dataclasses.field(default=0, metadata=dict(static=True))
    block_rows: int | None = dataclasses.field(
        default=None, metadata=dict(static=True))

    @property
    def rows(self) -> int:
        return self.values.shape[-2] - self.pad

    def logical(self) -> "RowBalancedSparseQ8":
        """Padding-free view (slices off ``pad_packed``'s zero rows)."""
        if not self.pad:
            return self
        r = self.rows
        return dataclasses.replace(
            self, values=self.values[..., :r, :],
            deltas=self.deltas[..., :r, :], scales=self.scales[..., :r],
            pad=0, block_rows=None)

    @property
    def K(self) -> int:
        return self.values.shape[-1]

    @property
    def sparsity(self) -> float:
        return 1.0 - self.K / self.ncols

    @property
    def scheme(self) -> QuantScheme:
        if self.frac_bits is not None:
            m = int(self.qmax + 1).bit_length() - 1 - self.frac_bits
            name = f"q{m}.{self.frac_bits}"
        else:
            name = "int8" if self.qmax == 127 else f"sym{self.qmax}"
        return QuantScheme(name, qmax=self.qmax, frac_bits=self.frac_bits)

    def col_indices(self) -> jnp.ndarray:
        """Absolute column indices (rows, K), int32."""
        return jnp.cumsum(self.deltas.astype(jnp.int32), axis=-1)

    def memory_bytes(self) -> dict:
        """Storage accounting (values + indices + per-row scales) vs the
        dense float32 equivalent — logical rows only (``pad_packed``'s
        zero rows are a layout artifact)."""
        rows_total = self.values.size // self.values.shape[-1] \
            - self.pad * (self.values.size // np.prod(self.values.shape[-2:]))
        n = rows_total * self.K
        v = n * self.values.dtype.itemsize
        i = n * self.deltas.dtype.itemsize
        sc = rows_total * 4
        dense = rows_total * self.ncols * 4
        return dict(values=v, indices=i, scales=sc, total=v + i + sc,
                    dense_equiv=dense, ratio=(v + i + sc) / dense)


def quantize_packed(s: P.RowBalancedSparse, scheme) -> RowBalancedSparseQ8:
    """Quantize a float packed matrix to codes + per-row scales.

    The deltas pass through untouched — sparsity pattern and value
    precision are orthogonal axes. Works on stacked (L, rows, K) packings
    too (scales come out (L, rows))."""
    scheme = parse_scheme(scheme)
    scales = row_scales(s.values, scheme)
    q = quantize(s.values, scales[..., None], scheme)
    _check_accumulator(q, scheme)
    return RowBalancedSparseQ8(values=q, deltas=s.deltas, scales=scales,
                               ncols=s.ncols, qmax=scheme.qmax,
                               frac_bits=scheme.frac_bits)


def _check_accumulator(codes, scheme: QuantScheme) -> None:
    """Warn when a row's worst-case integer dot can wrap int32.

    The kernels accumulate code products in int32 (the documented
    contract). Per row the accumulation is bounded by
    ``Σ_k |w_code| · qmax`` (activation codes are clipped to ±qmax); int8
    schemes can never reach 2^31, but a wide-K matrix under a high-qmax
    ``qM.N`` scheme can — and since the reference twins accumulate in
    int32 too, parity tests would NOT catch the wraparound. Skipped for
    traced values (packing happens eagerly in practice)."""
    if isinstance(codes, jax.core.Tracer):
        return
    worst = int(np.abs(np.asarray(codes, np.int64)).sum(axis=-1).max())
    worst *= scheme.qmax
    if worst >= 2 ** 31:
        warnings.warn(
            f"quantize_packed: scheme {scheme.name!r} can overflow the "
            f"int32 kernel accumulator (worst-case per-row dot "
            f"{worst:.3g} >= 2^31); use fewer bits (e.g. 'q1.11') or "
            "higher sparsity (smaller K)", stacklevel=3)


def dequantize_packed(q: RowBalancedSparseQ8) -> P.RowBalancedSparse:
    """Reconstruct the float packing (codes · per-row scales). Padding
    from ``pad_packed`` is stripped (re-pad the result if needed)."""
    q = q.logical()
    vals = q.values.astype(jnp.float32) * q.scales[..., None]
    return P.RowBalancedSparse(values=vals, deltas=q.deltas, ncols=q.ncols)


def abstract_quantize_packed(rep: P.RowBalancedSparse,
                             scheme) -> RowBalancedSparseQ8:
    """ShapeDtypeStruct stand-in of ``quantize_packed`` (dry-run packs)."""
    scheme = parse_scheme(scheme)
    return RowBalancedSparseQ8(
        values=jax.ShapeDtypeStruct(rep.values.shape, scheme.storage),
        deltas=rep.deltas,
        scales=jax.ShapeDtypeStruct(rep.values.shape[:-1], jnp.float32),
        ncols=rep.ncols, qmax=scheme.qmax, frac_bits=scheme.frac_bits)


def packed_bytes_q(rows: int, ncols: int, ratio: float, scheme) -> int:
    """Analytic packed storage of one quantized row-balanced matrix:
    codes + delta indices + one f32 scale per row."""
    scheme = parse_scheme(scheme)
    k = S.keep_count(ncols, ratio)
    dd = P._delta_dtype(ncols, k)
    return rows * k * (scheme.storage.itemsize + dd.itemsize) + rows * 4


class RowBalancedQ8Format(SparseFormat):
    """Registered quantized row-balanced format (``row_balanced_q8``).

    Same mask as ``row_balanced`` (the pattern is identical); ``pack``
    additionally quantizes (rule options pick the scheme, default int8);
    matvec dispatches the q8 kernels with a dynamic max-abs activation
    scale (calibrated static scales come in through the model/serving
    path, not the generic format surface)."""

    name = "row_balanced_q8"

    def __init__(self, default_scheme: str = "int8"):
        self.default_scheme = default_scheme

    def mask(self, w, ratio, **opts):
        return S.row_balanced_mask(w, ratio)

    def pack(self, w, mask, scheme: str | None = None, **opts):
        return quantize_packed(P.pack(w, mask),
                               scheme or self.default_scheme)

    def unpack(self, packed):
        return P.unpack(dequantize_packed(packed))

    def abstract_pack(self, rows, ncols, ratio, dtype,
                      scheme: str | None = None, **opts):
        k = S.keep_count(ncols, ratio)
        dd = P._delta_dtype(ncols, k)
        rep = P.RowBalancedSparse(
            values=jax.ShapeDtypeStruct((rows, k), jnp.float32),
            deltas=jax.ShapeDtypeStruct((rows, k), jnp.dtype(dd)),
            ncols=ncols)
        return abstract_quantize_packed(rep, scheme or self.default_scheme)

    def matvec(self, packed, x, *, backend=None):
        from ..kernels import ops as K
        return K.rb_spmv_q8(packed, x, backend=backend).astype(x.dtype)

    def dual_matvec(self, pa, x, pb, h, bias=None, *, backend=None):
        from ..kernels import ops as K
        if bias is None:
            bias = jnp.zeros((pa.rows,), jnp.float32)
        return K.rb_dual_spmv_q8(pa, x, pb, h, bias,
                                 backend=backend).astype(x.dtype)

    def packed_bytes(self, rows, ncols, ratio, dtype,
                     scheme: str | None = None, **opts):
        return packed_bytes_q(rows, ncols, ratio,
                              scheme or self.default_scheme)

    def memory_bytes(self, packed, **opts):
        return packed.memory_bytes()


register(RowBalancedQ8Format())
