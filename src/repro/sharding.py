"""Logical-axis sharding rules (MaxText-style), divisibility-aware.

Every parameter / activation dimension carries a *logical* axis name
('batch', 'embed', 'heads', 'mlp', 'experts', 'vocab', ...). A rule table
maps logical names to candidate physical mesh axes in priority order; the
resolver picks, per tensor dimension, the first candidate whose mesh-axis
product divides the dim size and whose physical axes are not already taken
by another dimension of the same tensor. Non-divisible dims degrade to
replication instead of erroring — e.g. kv_heads=8 on a model=16 axis falls
through to sharding head_dim instead (Megatron-style within-head split).
"""
from __future__ import annotations

import math
from typing import Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical name -> candidate physical axes, priority ordered. Each candidate
# is a tuple of mesh axis names (joint sharding) or None (replicate).
DEFAULT_RULES: dict[str, list] = {
    "batch":     [("pod", "data"), ("data",), None],
    "seq":       [None],
    # KV caches are sequence-sharded over the model axis (split-KV /
    # flash-decode): each chip streams 1/model of the cache and decode
    # attention combines with tiny stat psums.
    "cache_seq": [("model",), None],
    "embed":     [None],
    # head_dim is never sharded: within-head splits force per-layer
    # activation all-gathers that cost more than the redundant compute they
    # save (measured on the granite dry-run — see EXPERIMENTS.md §Perf).
    "heads":     [("model",), None],
    "kv_heads":  [("model",), None],
    "head_dim":  [None],
    "qkv":       [("model",), None],     # flattened q/k/v output dim
    "mlp":       [("model",), None],
    "experts":   [("model",), None],
    "expert_cap": [None],
    "vocab":     [("model",), None],
    "layers":    [None],                  # scan-stacked leading dim
    "lstm_gates": [("model",), None],     # the LSTM 4H gate dim
    "lstm_hidden": [None],
    # repro.dist packed-sparse serving: the row dim of a packed
    # RowBalancedSparse[Q8] (values/deltas/scales/bias move together —
    # every row holds exactly NZ survivors, so a row split is perfectly
    # load-balanced by construction)
    "packed_rows": [("model",), None],
    # the dist decode cache's hidden slice: c shards with the gate rows
    # it is updated from, while h stays replicated ("lstm_hidden") — it
    # is the activation broadcast every shard's W_h columns consume
    "lstm_hidden_shard": [("model",), None],
    "conv":      [None],
    "zero":      [("data",), None],       # ZeRO-1 optimizer-state dim
}


def _mesh_axes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


# Per-arch layout policies. "tp16" is the default rule table above;
# "dp" folds the model axis into data parallelism (small models: TP
# activation all-reduces cost more than replicating the weights).
_ACTIVE_RULES: list = []


def dp_rules() -> dict:
    r = dict(DEFAULT_RULES)
    r["batch"] = [("pod", "data", "model"), ("data", "model"),
                  ("pod", "data"), ("data",), None]
    for name in ("heads", "kv_heads", "mlp", "experts", "vocab",
                 "lstm_gates", "cache_seq"):
        r[name] = [None]
    return r


def rules_for(cfg) -> dict:
    """ArchConfig → rule table (cfg.layout: 'tp' default | 'dp')."""
    if getattr(cfg, "layout", "tp") == "dp":
        return dp_rules()
    return DEFAULT_RULES


class use_rules:
    """Context manager: overrides the rule table seen by constrain()
    during tracing (and by explicit resolve calls that pass rules=None)."""

    def __init__(self, rules: dict | None):
        self.rules = rules

    def __enter__(self):
        _ACTIVE_RULES.append(self.rules)
        return self.rules

    def __exit__(self, *a):
        _ACTIVE_RULES.pop()


def active_rules() -> dict | None:
    return _ACTIVE_RULES[-1] if _ACTIVE_RULES else None


def resolve_spec(mesh: Mesh, logical: Sequence[str | None],
                 shape: Sequence[int],
                 rules: dict | None = None,
                 extra_taken: Sequence[str] = ()) -> P:
    """Resolve a logical axis tuple to a PartitionSpec for `mesh`."""
    rules = rules or active_rules() or DEFAULT_RULES
    sizes = _mesh_axes(mesh)
    taken: set[str] = set(extra_taken)
    out = []
    for name, dim in zip(logical, shape):
        if name is None:
            out.append(None)
            continue
        cands = rules.get(name, [None])
        pick = None
        for cand in cands:
            if cand is None:
                break
            axes = tuple(a for a in cand if a in sizes)
            if not axes:
                continue
            prod = math.prod(sizes[a] for a in axes)
            if dim % prod == 0 and not (set(axes) & taken):
                pick = axes
                taken.update(axes)
                break
        out.append(pick if pick is None else (pick if len(pick) > 1 else pick[0]))
    return P(*out)


def named_sharding(mesh: Mesh, logical: Sequence[str | None],
                   shape: Sequence[int], rules: dict | None = None) -> NamedSharding:
    return NamedSharding(mesh, resolve_spec(mesh, logical, shape, rules))


def spec_tree(mesh: Mesh, logical_tree, shape_tree, rules: dict | None = None):
    """Map resolve_spec over matching pytrees of logical tuples and shapes."""
    return jax.tree.map(
        lambda lg, sh: named_sharding(mesh, lg, sh, rules),
        logical_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )


def constrain(x, *logical, rules: dict | None = None):
    """with_sharding_constraint by logical axes — no-op outside a mesh ctx."""
    mesh = _current_mesh()
    if mesh is None or mesh.empty:
        return x
    spec = resolve_spec(mesh, logical, x.shape, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _current_mesh() -> Mesh | None:
    try:
        from jax._src.mesh import thread_resources
        m = thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:
        return None


class Axes(tuple):
    """A logical-axes annotation: Axes('embed','mlp'). Pytree-leaf tuple."""
    __slots__ = ()

    def __new__(cls, *names):
        return super().__new__(cls, names)
