"""obs: instrumentation cost and counter fidelity of repro.obs.

Observability only earns its place if it is (a) free when off, (b) cheap
when on, and (c) EXACT — the harvested on-device counters must agree
with the offline reductions the repo already trusts. Three row families
pin all three:

  obs_overhead_disabled /    closed-loop scheduler throughput on packed
  obs_overhead_enabled       delta-gated weights with counters off vs on
                             (same prompts, same instance-warmed jits);
                             the enabled row carries ``overhead_pct`` —
                             the acceptance target is ≤ 5%.
  obs_counter_parity         fired_match: harvested fired-column gauges
                             == the drained cache's nx/nh sums (and the
                             scorecard's fired-weighted MACs ==
                             ``occupancy_report``'s). spec_match: spec
                             counters == ``spec_stats()``. Both exact.
  obs_scorecard              the effective-GOPS scorecard joined from the
                             enabled run: achieved/effective GOPS vs the
                             memory-roofline bound, bytes/token.
"""
import math
import time

import jax
import numpy as np

from repro.models import LSTMModel
from repro.obs import counters as OC
from repro.obs import scorecard as OS
from repro.serving import ContinuousBatchingEngine, SamplingConfig, \
    ServeEngine
from repro.sparse import (DeltaGateConfig, lstm_policy, occupancy_report,
                          use_backend)
from repro.spec import DraftModel
from .common import bench_lstm_cfg, smoke, row

SLOTS = smoke(4, 8)
GEN = smoke(8, 24)
CHUNK = 8
REPS = smoke(2, 5)
GREEDY = SamplingConfig(eos_id=-1)    # fixed token count per run
MAX_LEN = smoke(48, 96)


def _submit(sched, cfg, rng):
    lens = [max(4, MAX_LEN // 4 - 3 * i) for i in range(SLOTS)]
    for i, plen in enumerate(lens):
        prompt = jax.random.randint(jax.random.fold_in(rng, i), (1, plen),
                                    0, cfg.vocab_size)
        sched.submit(prompt, GEN)


def _serve(sched, cfg):
    _submit(sched, cfg, jax.random.key(1))
    t0 = time.perf_counter()
    results = sched.run()
    dt = time.perf_counter() - t0
    return dt, sum(len(v) for v in results.values())


def main():
    cfg = bench_lstm_cfg()
    model = LSTMModel(cfg)
    params = model.init(jax.random.key(0))
    pol = lstm_policy(0.875, 0.75, backend="ref",
                      delta=DeltaGateConfig(theta_x=0.1, theta_h=0.1))
    eng = ServeEngine(model, cfg, max_len=MAX_LEN, batch=SLOTS,
                      sparsity=pol)
    packed, _ = eng.prepare(params)

    with use_backend("ref"):
        # ---- enabled-vs-disabled overhead (per-instance warmed jits) --
        walls, scheds = {}, {}
        for label, flag in (("disabled", False), ("enabled", True)):
            sched = ContinuousBatchingEngine(
                eng.model, packed, slots=SLOTS, max_len=MAX_LEN,
                sampling=GREEDY, chunk=CHUNK, counters=flag)
            _serve(sched, cfg)                      # compile warmup
            ts = []
            for _ in range(REPS):
                dt, tokens = _serve(sched, cfg)
                ts.append(dt)
            ts.sort()
            walls[label] = (ts[len(ts) // 2], tokens)
            scheds[label] = sched
        dis, en = walls["disabled"], walls["enabled"]
        row("obs_overhead_disabled", dis[0] / dis[1] * 1e6,
            f"toks_per_s={dis[1] / dis[0]:.1f} tokens={dis[1]}")
        overhead = (en[0] - dis[0]) / dis[0] * 100.0
        row("obs_overhead_enabled", en[0] / en[1] * 1e6,
            f"toks_per_s={en[1] / en[0]:.1f} overhead_pct={overhead:.2f} "
            f"target_pct=5")

        # ---- exact parity: counters vs the offline reductions ---------
        sched = scheds["enabled"]
        c = sched.counters()
        fired_ok = all(
            c[f"fired_x_l{i}"] == float(np.asarray(lp["nx"]).sum())
            and c[f"fired_h_l{i}"] == float(np.asarray(lp["nh"]).sum())
            for i, lp in enumerate(sched.cache["layers"]))
        occ = occupancy_report(sched.cache, steps=sched.slot_steps,
                               packed=packed)
        card = OS.build(packed, c, en[0], batch=SLOTS,
                        step_sum=float(np.sum(sched.slot_steps)))
        fired_ok &= math.isclose(card["executed_macs"],
                                 occ["effective_macs"], rel_tol=1e-9)

        draft = DraftModel(model, params)           # target drafts itself
        ssched = ContinuousBatchingEngine(
            model, params, slots=SLOTS, max_len=MAX_LEN, sampling=GREEDY,
            chunk=CHUNK, draft=draft, spec_k=3, counters=True)
        _serve(ssched, cfg)
        st = ssched.spec_stats()
        sc = ssched.counters()
        spec_ok = (sc["spec_rounds"] == st["rounds"]
                   and sc["spec_drafted"] == st["drafted"]
                   and sc["spec_accepted"] == st["accepted"]
                   and st["drafted"] > 0)
        row("obs_counter_parity", 0.0,
            f"fired_match={int(fired_ok)} spec_match={int(spec_ok)} "
            f"occupancy_x={occ['occupancy_x']:.4f}")

        # ---- the scorecard itself, from the enabled run's harvest -----
        row("obs_scorecard", en[0] / en[1] * 1e6,
            f"effective_gops={card['effective_gops']:.4f} "
            f"bound_effective_gops={card['bound_effective_gops']:.1f} "
            f"bytes_per_token={card['bytes_per_token']} "
            f"roofline_gap={card['roofline_gap']:.1f}x")


if __name__ == "__main__":
    main()
