"""spec: speculative decoding with BRDS-packed recurrent drafts.

Measures the repro.spec composition on this host (jnp ref formulations —
the numbers track Python/dispatch structure, not hardware): a dense LSTM
target served through ``ServeEngine.generate(draft=...)`` with drafts
BUILT FROM THE SAME WEIGHTS by the sparsity stack, so the
acceptance-rate × draft-cost × tokens/s trade surfaces the fidelity cost
of each BRDS serving variant directly:

  spec_target_only      — the baseline: target-only greedy decode (the
                          row every speculative row's ``speedup`` divides
                          against).
  spec_k{K}_packed      — speculative decode at k ∈ {2, 4, 8} with the
                          row-balanced-packed draft (0.875/0.75 dual
                          ratio); derived columns carry acceptance_rate,
                          accepted_per_round, toks_per_s, speedup, k.
  spec_k{K}_packed_lo   — same k, LIGHTER pruning (0.5/0.25): the draft
                          sparsity axis — higher fidelity, higher
                          acceptance, higher per-proposal cost.
  spec_k{K}_q8          — calibrated int8 packed draft (0.875/0.75): the
                          quant point on the draft-cost curve.
  spec_draft_cost       — the draft side alone (packed LSTM decode
                          tok/s) and its cost ratio vs the target row.

Greedy speculative decode is bitwise lossless (tests/test_spec.py), so
every row emits exactly the baseline's tokens — only the wall clock and
the acceptance accounting differ.
"""
import jax
import numpy as np

from repro.models import LSTMModel
from repro.serving import ServeEngine
from repro.sparse import QuantConfig, lstm_policy, use_backend
from repro.spec import DraftModel
from .common import bench_lstm_cfg, bench_lstm_dims, row, smoke, \
    time_fn as _time

B, P, G = bench_lstm_dims()
KS = smoke((2, 4), (2, 4, 8))
K_MID = 4


def _packed_draft(model, cfg, params, a, b, quant=None, calib=None):
    """Prune/pack (optionally quantize) the TARGET's own weights into a
    draft — the engine's prepare path, so delta/quant rewiring applies."""
    eng = ServeEngine(model, cfg, max_len=P + G, batch=B,
                      sparsity=lstm_policy(a, b, quant=quant))
    dparams, _ = eng.prepare(params, calib=calib)
    return DraftModel(eng.model, dparams)


def _spec_row(name, eng, params, prompt, draft, k, t_base):
    state = {}

    def run():
        toks, st = eng.generate(params, prompt, G, draft=draft, spec_k=k,
                                return_state=True)
        state.update(st)
        return toks

    t = _time(run)
    toks = B * G
    drafted = int(np.sum(np.asarray(state["drafted"])))
    accepted = int(np.sum(np.asarray(state["accepted"])))
    rounds = int(np.sum(np.asarray(state["rounds"])))
    row(name, t / toks * 1e6,
        f"toks_per_s={toks / t:.0f} "
        f"acceptance_rate={accepted / max(drafted, 1):.3f} "
        f"accepted_per_round={accepted / max(rounds, 1):.2f} "
        f"speedup={t_base / t:.2f}x k={k}")


def main():
    cfg = bench_lstm_cfg()
    model = LSTMModel(cfg)
    params = model.init(jax.random.key(0))
    prompt = jax.random.randint(jax.random.key(1), (B, P), 0,
                                cfg.vocab_size)
    calib = jax.random.randint(jax.random.key(2), (B, P), 0, cfg.vocab_size)
    eng = ServeEngine(model, cfg, max_len=P + G, batch=B)

    with use_backend("ref"):
        toks = B * G
        t_base = _time(lambda: eng.generate(params, prompt, G))
        row("spec_target_only", t_base / toks * 1e6,
            f"toks_per_s={toks / t_base:.0f}")

        # ---- k sweep on the standard packed draft (same weights) ------
        draft_hi = _packed_draft(model, cfg, params, 0.875, 0.75)
        for k in KS:
            _spec_row(f"spec_k{k}_packed", eng, params, prompt, draft_hi,
                      k, t_base)

        # ---- draft-sparsity axis at fixed k ---------------------------
        draft_lo = _packed_draft(model, cfg, params, 0.5, 0.25)
        _spec_row(f"spec_k{K_MID}_packed_lo", eng, params, prompt,
                  draft_lo, K_MID, t_base)
        draft_q8 = _packed_draft(model, cfg, params, 0.875, 0.75,
                                 quant=QuantConfig("int8"), calib=calib)
        _spec_row(f"spec_k{K_MID}_q8", eng, params, prompt, draft_q8,
                  K_MID, t_base)

        # ---- the draft side alone: per-proposal cost ------------------
        deng = ServeEngine(draft_hi.model, cfg, max_len=P + G, batch=B)
        t_d = _time(lambda: deng.generate(draft_hi.params, prompt, G))
        row("spec_draft_cost", t_d / toks * 1e6,
            f"draft_toks_per_s={toks / t_d:.0f} "
            f"cost_ratio={t_d / t_base:.3f}")


if __name__ == "__main__":
    main()
