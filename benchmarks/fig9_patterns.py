"""Fig. 9 analogue: accuracy-vs-sparsity tradeoff per pruning pattern
(unstructured / block / bank-balanced / row-balanced) on a trained LSTM.
The paper's claim is the ORDERING: row-balanced tracks unstructured and
beats block sparsity, especially at high ratios."""
import jax
import jax.numpy as jnp

from repro.models import LSTMModel, LSTMConfig
from repro.sparse import get_format
from repro.training import OptConfig, init_state, CharCorpus
from repro.training.optim import apply_update
from repro.core.sparsity import apply_mask
from .common import row, smoke

# each pattern is a registered SparseFormat (+ its mask options)
PATTERNS = {
    "unstructured": ("unstructured", {}),
    "block4x4": ("block", {"block": (4, 4)}),
    "bank_balanced": ("bank_balanced", {"num_banks": 4}),
    "row_balanced": ("row_balanced", {}),
}


def main():
    cfg = LSTMConfig("fig9", input_size=16, hidden=64, num_layers=1,
                     vocab_size=30)
    model = LSTMModel(cfg)
    ds = CharCorpus()
    params = model.init(jax.random.key(3))
    oc = OptConfig(lr=5e-3, warmup_steps=2, total_steps=2000,
                   schedule="constant")
    st = init_state(oc, params)
    lg = jax.jit(jax.value_and_grad(lambda p, b: model.loss(p, b)))
    for i in range(smoke(6, 80)):
        t = ds.batch(i, 8, 24)["tokens"] % 30
        b = {"inputs": jnp.asarray(t), "labels": jnp.asarray(t)}
        _, g = lg(params, b)
        params, st, _ = apply_update(oc, params, g, st)

    t = ds.batch(9999, 16, 24)["tokens"] % 30
    eval_b = {"inputs": jnp.asarray(t), "labels": jnp.asarray(t)}
    base = float(model.loss(params, eval_b))
    row("fig9_dense_baseline", 0.0, f"loss={base:.4f}")

    for spar in smoke((0.5, 0.875), (0.25, 0.5, 0.75, 0.875)):
        line = {}
        for name, (fmt_name, kw) in PATTERNS.items():
            fmt = get_format(fmt_name)
            p2 = {**params, "layers": [
                {**lp,
                 "w_x": apply_mask(lp["w_x"], fmt.mask(lp["w_x"], spar, **kw)),
                 "w_h": apply_mask(lp["w_h"], fmt.mask(lp["w_h"], spar, **kw))}
                for lp in params["layers"]]}
            line[name] = float(model.loss(p2, eval_b))
        row(f"fig9_sparsity={spar}", 0.0,
            " ".join(f"{k}={v:.4f}" for k, v in line.items()))


if __name__ == "__main__":
    main()
