"""traffic: the serving latency curve under a production-style load.

Drives the continuous-batching scheduler with seeded Poisson arrival
traces (repro.traffic.loadgen) and records the latency curve the paper's
throughput tables cannot show: time-to-first-token and per-output-token
percentiles as a function of offered load, plus goodput (tokens from
requests that met their deadline).

Two families of rows:

  traffic_load_rN      — open-loop (arrival-paced) serving at N req/s on
                         the packed BRDS weights; derived columns carry
                         p50/p90/p99 TTFT, p50/p99 TPOT, goodput, drops.
                         At least two load points so the JSON captures a
                         curve, not a sample.
  traffic_steady_*     — closed-loop (submit-all, drain) throughput with
                         many slots: `ahead` keeps dispatch_depth decode
                         chunks in flight ahead of the host, `sync`
                         harvests each chunk before dispatching the next
                         (dispatch_depth=1). The `speedup` column is the
                         dispatch-ahead win — host-side bookkeeping and
                         token streaming overlapped with device compute.

Every trace is deterministic (seeded); wall-clock never enters the
arrival schedule, only the measurements.
"""
import jax

from repro.models import LSTMModel
from repro.serving import ContinuousBatchingEngine, SamplingConfig
from repro.sparse import lstm_policy, use_backend
from repro.traffic import LoadConfig, poisson_trace, make_prompts, \
    serve_trace
from .common import bench_lstm_cfg, smoke, row

SLOTS_LOAD = smoke(4, 16)          # slots for the load-sweep points
SLOTS_STEADY = smoke(8, 64)        # slots for the sync-vs-ahead compare
N_REQ = smoke(10, 64)              # requests per load point
MAX_LEN = smoke(48, 96)
CHUNK = 8
RATES = smoke((16.0, 64.0), (8.0, 32.0, 128.0))   # offered req/s points


def _load_cfg(rate, seed=0):
    hi = MAX_LEN // 2
    return LoadConfig(rate=rate, num_requests=N_REQ,
                      prompt_short=(4, max(5, hi // 4)),
                      prompt_long=(max(5, hi // 4), hi),
                      output_lens=(4, MAX_LEN // 4),
                      deadline=smoke(30.0, 10.0), seed=seed)


def _fresh(model, packed, slots, depth):
    return ContinuousBatchingEngine(model, packed, slots=slots,
                                    max_len=MAX_LEN,
                                    sampling=SamplingConfig(), chunk=CHUNK,
                                    dispatch_depth=depth)


def main():
    cfg = bench_lstm_cfg()
    model = LSTMModel(cfg)
    params = model.init(jax.random.key(0))
    plan = lstm_policy(0.875, 0.75, backend="ref").compile(params)
    pruned, masks = plan.prune(params)
    packed, _ = plan.pack(pruned, masks)

    with use_backend("ref"):
        # ---- open-loop latency curve: ≥2 offered-load points ----------
        for rate in RATES:
            lc = _load_cfg(rate)
            trace = poisson_trace(lc)
            prompts = make_prompts(trace, cfg.vocab_size, seed=lc.seed)
            sched = _fresh(model, packed, SLOTS_LOAD, 2)
            # warmup pass compiles every prompt bucket / chunk shape on
            # THIS scheduler instance (jits are per-instance), so the
            # timed pass measures serving, not compilation
            serve_trace(sched, trace, prompts, realtime=False)
            _, s = serve_trace(sched, trace, prompts, offered_rps=rate)
            mean_ttft_us = s["p50_ttft_ms"] * 1e3
            row(f"traffic_load_r{int(rate)}", mean_ttft_us,
                f"p50_ttft_ms={s['p50_ttft_ms']:.2f} "
                f"p90_ttft_ms={s['p90_ttft_ms']:.2f} "
                f"p99_ttft_ms={s['p99_ttft_ms']:.2f} "
                f"p50_tpot_ms={s['p50_tpot_ms']:.3f} "
                f"p99_tpot_ms={s['p99_tpot_ms']:.3f} "
                f"goodput_tps={s['goodput_tps']:.1f} "
                f"offered_rps={rate:.1f} "
                f"completed={s['completed']} expired={s['expired']} "
                f"rejected={s['rejected']}")

        # ---- closed-loop steady state: dispatch-ahead vs synchronous --
        lc = _load_cfg(RATES[-1], seed=1)
        trace = poisson_trace(lc)
        prompts = make_prompts(trace, cfg.vocab_size, seed=lc.seed)
        walls = {}
        for label, depth in (("sync", 1), ("ahead", 2)):
            sched = _fresh(model, packed, SLOTS_STEADY, depth)
            serve_trace(sched, trace, prompts, realtime=False)   # warmup
            _, s = serve_trace(sched, trace, prompts, realtime=False,
                               offered_rps=None)
            walls[label] = s
        for label in ("sync", "ahead"):
            s = walls[label]
            extra = ""
            if label == "ahead":
                extra = (f" speedup={walls['sync']['wall_s'] / max(s['wall_s'], 1e-9):.2f}x"
                         f" slots={SLOTS_STEADY}")
            row(f"traffic_steady_{label}",
                s["wall_s"] / max(s["tokens"], 1) * 1e6,
                f"toks_per_s={s['toks_per_s']:.1f} "
                f"wall_s={s['wall_s']:.3f}" + extra)


if __name__ == "__main__":
    main()
