"""decode_throughput: serving tok/s through the unified runtime.

Measures the paper's LSTM LM on this host (jnp ref formulations — Pallas
interpret mode measures Python, not hardware) across the serving matrix:

  dense  × lockstep        — ServeEngine on dense weights
  packed × lockstep        — ServeEngine on SparsityPlan.pack'd weights
                             (rb_dual_spmv + lstm_gates datapath)
  packed × python-loop     — the pre-runtime per-token host loop, for the
                             dispatch-overhead comparison
  packed × continuous      — ContinuousBatchingEngine over ragged requests
  packed × delta           — temporal delta sparsity (Θ=0.1) on top of the
                             packed weights; the derived column reports the
                             effective-ops reduction (fired-column MACs vs.
                             always-on packed MACs)
  packed × sharded         — repro.dist row-sharded decode over (data, model)
                             meshes of 8 FORCED host devices (a subprocess
                             sets --xla_force_host_platform_device_count; the
                             numbers track Python/dispatch overhead of the
                             sharded path, not real interconnects)
"""
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp

from repro.models import LSTMModel
from repro.serving import (ServeEngine, ContinuousBatchingEngine,
                          SamplingConfig)
from repro.sparse import (DeltaGateConfig, lstm_policy, occupancy_report,
                          use_backend)
from .common import bench_lstm_cfg, bench_lstm_dims, row, time_fn as _time

B, P, G = bench_lstm_dims()


def main():
    cfg = bench_lstm_cfg()
    model = LSTMModel(cfg)
    params = model.init(jax.random.key(0))
    plan = lstm_policy(0.875, 0.75, backend="ref").compile(params)
    pruned, masks = plan.prune(params)
    packed, _ = plan.pack(pruned, masks)
    prompt = jax.random.randint(jax.random.key(1), (B, P), 0, cfg.vocab_size)
    eng = ServeEngine(model, cfg, max_len=P + G, batch=B)

    with use_backend("ref"):
        toks = B * G
        t = _time(lambda: eng.generate(params, prompt, G))
        row("decode_dense_lockstep", t / toks * 1e6,
            f"toks_per_s={toks / t:.0f}")
        t = _time(lambda: eng.generate(packed, prompt, G))
        row("decode_packed_lockstep", t / toks * 1e6,
            f"toks_per_s={toks / t:.0f}")

        # temporal delta sparsity composed with the packed weights
        deng = ServeEngine(model, cfg, max_len=P + G, batch=B,
                           sparsity=lstm_policy(
                               0.875, 0.75,
                               delta=DeltaGateConfig(theta_x=0.1,
                                                     theta_h=0.1)))
        dpacked, _ = deng.prepare(params)
        # return_state only changes the Python-side return, not the
        # compiled computation — time it directly and reuse the state
        dstate = {}
        def delta_run():
            toks, st = deng.generate(dpacked, prompt, G, return_state=True)
            dstate.update(st)
            return toks
        t = _time(delta_run)
        occ = occupancy_report(dstate["cache"], steps=P + G, packed=dpacked)
        row("decode_packed_delta_lockstep", t / toks * 1e6,
            f"toks_per_s={toks / t:.0f} "
            f"eff_ops_reduction={occ['ops_reduction']:.2f}x")

        # pre-runtime baseline: one host dispatch per token
        dstep = jax.jit(model.decode_step)

        def pyloop():
            lp, cache = eng._prefill(packed, prompt, max_len=P + G)
            out = None
            for i in range(G):
                out = jnp.argmax(lp[:, -1], -1)[:, None].astype(jnp.int32)
                lp, cache = dstep(packed, cache, out, P + i)
            return out

        t = _time(pyloop)
        row("decode_packed_pyloop", t / toks * 1e6,
            f"toks_per_s={toks / t:.0f}")

        def continuous():
            sched = ContinuousBatchingEngine(model, packed, slots=4,
                                             max_len=P + G,
                                             sampling=SamplingConfig(),
                                             chunk=8)
            for i in range(B):
                plen = 4 + (3 * i) % P
                pr = jax.random.randint(jax.random.key(10 + i), (1, plen),
                                        0, cfg.vocab_size)
                sched.submit(pr, G)
            return sched.run()

        # budgets are capped at the cache capacity left after each prompt,
        # so count the actually emitted tokens (the count run doubles as
        # warmup for the timed run)
        emitted = sum(len(v) for v in continuous().values())
        t = _time(continuous, warmup=0, iters=1)
        row("decode_packed_continuous", t / emitted * 1e6,
            f"toks_per_s={emitted / t:.0f} ragged_over_4_slots")

    _sharded_rows()


# ------------------------------------------------------------- sharded rows
# jax locks the device count at first init, so the sharded measurements run
# in a child process with XLA_FLAGS=--xla_force_host_platform_device_count=8
# (same pattern as tests/test_distributed.py); the parent re-emits the
# child's CSV rows so they land in BENCH_decode_throughput.json too.

_MESHES = ((1, 8), (2, 4))


def _sharded_child():
    from repro.launch.mesh import make_host_mesh

    cfg = bench_lstm_cfg()
    model = LSTMModel(cfg)
    params = model.init(jax.random.key(0))
    prompt = jax.random.randint(jax.random.key(1), (B, P), 0, cfg.vocab_size)
    with use_backend("ref"):
        toks = B * G
        for d, m in _MESHES:
            eng = ServeEngine(model, cfg, max_len=P + G, batch=B,
                              sparsity=lstm_policy(0.875, 0.75),
                              mesh=make_host_mesh(d, m))
            packed, _ = eng.prepare(params)
            t = _time(lambda: eng.generate(packed, prompt, G))
            row(f"decode_packed_sharded_mesh{d}x{m}", t / toks * 1e6,
                f"toks_per_s={toks / t:.0f} devices=8")


def _sharded_rows():
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    src = os.path.join(repo, "src")
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (src, env.get("PYTHONPATH", "")) if p)
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.decode_throughput",
         "--sharded-child"],
        capture_output=True, text=True, cwd=repo, env=env, timeout=1800)
    if out.returncode != 0:
        raise RuntimeError("sharded decode benchmark child failed:\n"
                           + out.stderr[-2000:])
    for line in out.stdout.splitlines():
        parts = line.split(",", 2)
        if len(parts) == 3 and parts[0].startswith("decode_packed_sharded"):
            row(parts[0], float(parts[1]), parts[2])


if __name__ == "__main__":
    if "--sharded-child" in sys.argv:
        _sharded_child()
    else:
        main()
