"""decode_throughput: serving tok/s through the unified runtime.

Measures the paper's LSTM LM on this host (jnp ref formulations — Pallas
interpret mode measures Python, not hardware) across the serving matrix:

  dense  × lockstep        — ServeEngine on dense weights
  packed × lockstep        — ServeEngine on SparsityPlan.pack'd weights
                             (rb_dual_spmv + lstm_gates datapath)
  packed × python-loop     — the pre-runtime per-token host loop, for the
                             dispatch-overhead comparison
  packed × continuous      — ContinuousBatchingEngine over ragged requests
  packed × delta           — temporal delta sparsity (Θ=0.1) on top of the
                             packed weights; the derived column reports the
                             effective-ops reduction (fired-column MACs vs.
                             always-on packed MACs)
  packed × sharded         — repro.dist row-sharded decode over (data, model)
                             meshes of 8 FORCED host devices (a subprocess
                             sets --xla_force_host_platform_device_count; the
                             numbers track Python/dispatch overhead of the
                             sharded path, not real interconnects)
  packed × chained/fused   — the ISSUE-7 comparison: chained per-kernel
                             decode vs the single-launch fused step, each
                             with the HBM-roofline bound (B·HBM_BW /
                             per-step packed bytes) and its roofline_gap
  fused step vs scan       — kernel-level: T separate fused-step launches
                             vs one in-kernel scan launch at T ∈ {1, 8, 32}
"""
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp

from repro.models import LSTMModel
from repro.serving import (ServeEngine, ContinuousBatchingEngine,
                          SamplingConfig)
from repro.sparse import (DeltaGateConfig, lstm_policy, occupancy_report,
                          use_backend)
from .common import (bench_lstm_cfg, bench_lstm_dims, row, smoke,
                     time_fn as _time)

B, P, G = bench_lstm_dims()


def main():
    cfg = bench_lstm_cfg()
    model = LSTMModel(cfg)
    params = model.init(jax.random.key(0))
    plan = lstm_policy(0.875, 0.75, backend="ref").compile(params)
    pruned, masks = plan.prune(params)
    packed, pack_report = plan.pack(pruned, masks)
    prompt = jax.random.randint(jax.random.key(1), (B, P), 0, cfg.vocab_size)
    eng = ServeEngine(model, cfg, max_len=P + G, batch=B)

    with use_backend("ref"):
        toks = B * G
        t = _time(lambda: eng.generate(params, prompt, G))
        row("decode_dense_lockstep", t / toks * 1e6,
            f"toks_per_s={toks / t:.0f}")
        t = _time(lambda: eng.generate(packed, prompt, G))
        row("decode_packed_lockstep", t / toks * 1e6,
            f"toks_per_s={toks / t:.0f}")

        # temporal delta sparsity composed with the packed weights
        deng = ServeEngine(model, cfg, max_len=P + G, batch=B,
                           sparsity=lstm_policy(
                               0.875, 0.75,
                               delta=DeltaGateConfig(theta_x=0.1,
                                                     theta_h=0.1)))
        dpacked, _ = deng.prepare(params)
        # return_state only changes the Python-side return, not the
        # compiled computation — time it directly and reuse the state
        dstate = {}
        def delta_run():
            toks, st = deng.generate(dpacked, prompt, G, return_state=True)
            dstate.update(st)
            return toks
        t = _time(delta_run)
        occ = occupancy_report(dstate["cache"], steps=P + G, packed=dpacked)
        row("decode_packed_delta_lockstep", t / toks * 1e6,
            f"toks_per_s={toks / t:.0f} "
            f"eff_ops_reduction={occ['ops_reduction']:.2f}x")

        # pre-runtime baseline: one host dispatch per token
        dstep = jax.jit(model.decode_step)

        def pyloop():
            lp, cache = eng._prefill(packed, prompt, max_len=P + G)
            out = None
            for i in range(G):
                out = jnp.argmax(lp[:, -1], -1)[:, None].astype(jnp.int32)
                lp, cache = dstep(packed, cache, out, P + i)
            return out

        t = _time(pyloop)
        row("decode_packed_pyloop", t / toks * 1e6,
            f"toks_per_s={toks / t:.0f}")

        def continuous():
            sched = ContinuousBatchingEngine(model, packed, slots=4,
                                             max_len=P + G,
                                             sampling=SamplingConfig(),
                                             chunk=8)
            for i in range(B):
                plen = 4 + (3 * i) % P
                pr = jax.random.randint(jax.random.key(10 + i), (1, plen),
                                        0, cfg.vocab_size)
                sched.submit(pr, G)
            return sched.run()

        # budgets are capped at the cache capacity left after each prompt,
        # so count the actually emitted tokens (the count run doubles as
        # warmup for the timed run)
        emitted = sum(len(v) for v in continuous().values())
        t = _time(continuous, warmup=0, iters=1)
        row("decode_packed_continuous", t / emitted * 1e6,
            f"toks_per_s={emitted / t:.0f} ragged_over_4_slots")

    # ---- chained vs fused single-launch decode (ISSUE 7), on the Pallas
    # kernels (the ref twins are structurally identical between the two
    # paths — only the kernel datapath exposes the launch difference:
    # 2 pallas_calls per layer-step chained vs 1 fused). Each row carries
    # its distance from the HBM roofline: every decoded token streams all
    # packed weight bytes, so the bound is B·BW/bytes. Longer decode +
    # more iters than the rows above keep per-launch overhead above the
    # wall-clock noise of a shared CPU host.
    from repro import hw
    bound = B * hw.HBM_BW / pack_report["packed_bytes"]
    G2 = 4 * G
    toks2 = B * G2
    ceng = ServeEngine(model.with_fused(False), cfg, max_len=P + G2,
                       batch=B)
    feng = ServeEngine(model.with_fused(True), cfg, max_len=P + G2,
                       batch=B)
    run_c = lambda: ceng.generate(packed, prompt, G2)
    run_f = lambda: feng.generate(packed, prompt, G2)
    # interleaved sampling so a host-load drift between the two
    # measurements cannot masquerade as a chained/fused difference
    for r in (run_c, run_f):
        jax.block_until_ready(r())
        jax.block_until_ready(r())
    cs, fs = [], []
    for _ in range(9):
        for r, ts in ((run_c, cs), (run_f, fs)):
            t0 = time.perf_counter()
            jax.block_until_ready(r())
            ts.append(time.perf_counter() - t0)
    t_c = sorted(cs)[len(cs) // 2]
    t_f = sorted(fs)[len(fs) // 2]
    row("decode_packed_chained_lockstep", t_c / toks2 * 1e6,
        f"toks_per_s={toks2 / t_c:.0f} "
        f"roofline_bound_toks_per_s={bound:.0f} "
        f"roofline_gap={bound / (toks2 / t_c):.1f}x")
    row("decode_packed_fused_lockstep", t_f / toks2 * 1e6,
        f"toks_per_s={toks2 / t_f:.0f} "
        f"roofline_bound_toks_per_s={bound:.0f} "
        f"roofline_gap={bound / (toks2 / t_f):.1f}x "
        f"speedup_vs_chained={t_c / t_f:.2f}x")

    _fused_kernel_rows()
    _sharded_rows()


# ------------------------------------------------- fused step vs scan rows
# Kernel-level launch-amortisation curve: T separate fused-step calls vs
# ONE fused_brds_lstm_scan launch covering the same T tokens. The scan
# keeps (c, h) in VMEM scratch across the token axis; its rows carry a
# weights_fit_vmem flag (both packed families within a 16 MiB working
# budget — the regime where the single launch also never re-reads weights
# from HBM between tokens).

def _fused_kernel_rows():
    from repro import hw
    from repro.core.packing import pack
    from repro.core.sparsity import row_balanced_mask
    from repro.kernels import fused_brds_lstm_step, fused_brds_lstm_scan

    cfg = bench_lstm_cfg()
    X, H = cfg.input_size, cfg.hidden
    R = 4 * H
    kx, kh, kb, ks, kc, kh0 = jax.random.split(jax.random.key(2), 6)
    wx = jax.random.normal(kx, (R, X), jnp.float32)
    wh = jax.random.normal(kh, (R, H), jnp.float32)
    sx = pack(wx, row_balanced_mask(wx, 0.875))
    sh = pack(wh, row_balanced_mask(wh, 0.75))
    bias = jax.random.normal(kb, (R,), jnp.float32)
    wbytes = sum(int(x.nbytes) for x in jax.tree.leaves((sx, sh)))
    fits = int(wbytes <= 16 * 2 ** 20)
    h0 = jax.random.normal(kh0, (B, H), jnp.float32)
    c0 = jax.random.normal(kc, (B, H), jnp.float32)
    # Pallas path on purpose (interpret on CPU): one pallas_call for the
    # whole scan vs T step launches is the structural difference being
    # measured; the ref twins of step and scan are the same eager ops.
    for T in smoke((1, 8), (1, 8, 32)):
        xs = jax.random.normal(ks, (T, B, X), jnp.float32)

        def steps():
            c, h = c0, h0
            for t in range(T):
                c, h = fused_brds_lstm_step(sx, xs[t], sh, h, bias, c)
            return h

        t_s = _time(steps)
        row(f"fused_step_T{T}", t_s / (B * T) * 1e6,
            f"toks_per_s={B * T / t_s:.0f} launches={T}")
        t_c = _time(
            lambda: fused_brds_lstm_scan(sx, xs, sh, h0, bias, c0))
        row(f"fused_scan_T{T}", t_c / (B * T) * 1e6,
            f"toks_per_s={B * T / t_c:.0f} launches=1 "
            f"weights_fit_vmem={fits} "
            f"speedup_vs_steps={t_s / t_c:.2f}x")


# ------------------------------------------------------------- sharded rows
# jax locks the device count at first init, so the sharded measurements run
# in a child process with XLA_FLAGS=--xla_force_host_platform_device_count=8
# (same pattern as tests/test_distributed.py); the parent re-emits the
# child's CSV rows so they land in BENCH_decode_throughput.json too.

_MESHES = ((1, 8), (2, 4))


def _sharded_child():
    from repro.launch.mesh import make_host_mesh

    cfg = bench_lstm_cfg()
    model = LSTMModel(cfg)
    params = model.init(jax.random.key(0))
    prompt = jax.random.randint(jax.random.key(1), (B, P), 0, cfg.vocab_size)
    with use_backend("ref"):
        toks = B * G
        for d, m in _MESHES:
            eng = ServeEngine(model, cfg, max_len=P + G, batch=B,
                              sparsity=lstm_policy(0.875, 0.75),
                              mesh=make_host_mesh(d, m))
            packed, _ = eng.prepare(params)
            t = _time(lambda: eng.generate(packed, prompt, G))
            row(f"decode_packed_sharded_mesh{d}x{m}", t / toks * 1e6,
                f"toks_per_s={toks / t:.0f} devices=8")


def _sharded_rows():
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    src = os.path.join(repo, "src")
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (src, env.get("PYTHONPATH", "")) if p)
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.decode_throughput",
         "--sharded-child"],
        capture_output=True, text=True, cwd=repo, env=env, timeout=1800)
    if out.returncode != 0:
        raise RuntimeError("sharded decode benchmark child failed:\n"
                           + out.stderr[-2000:])
    for line in out.stdout.splitlines():
        parts = line.split(",", 2)
        if len(parts) == 3 and parts[0].startswith("decode_packed_sharded"):
            row(parts[0], float(parts[1]), parts[2])


if __name__ == "__main__":
    if "--sharded-child" in sys.argv:
        _sharded_child()
    else:
        main()
