"""fig_quant_tradeoff: quantization scheme × dual-ratio sparsity sweep.

The arithmetic-fidelity axis of the reproduction, crossed with the paper's
(Spar_x, Spar_h) axis: for each scheme (f32 baseline, symmetric int8,
paper-style q1.11 fixed point) at each sparsity tuple this serves the
LSTM LM through the engine and reports

  weight_bytes   packed gate-weight bytes (values + indices + scales) —
                 the decode hot path's HBM traffic, where int8 should cut
                 ≥2x vs the f32 packing at matched sparsity
  bytes_red      f32 packed bytes / quantized packed bytes (≥ 2x is the
                 acceptance bar; ~3.5x typical for int8)
  logit_mae      mean |logits_q − logits_f32| of the prefill logits on a
                 shared prompt, relative to mean |logits_f32| — the
                 fidelity cost of the narrowed arithmetic
  tok/s          wall-clock serving throughput on this host (jnp ref
                 formulations — interpret-mode Pallas measures Python)

Weight-side sparsity, activation deltas, and value precision are three
INDEPENDENT multipliers on effective bytes/ops; this figure isolates the
third against the first.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import LSTMModel
from repro.serving import ServeEngine
from repro.sparse import QuantConfig, lstm_policy, use_backend
from .common import bench_lstm_cfg, bench_lstm_dims, row, smoke, time_fn

B, P, G = bench_lstm_dims()
SCHEMES = (None, "int8", "q1.11")
SPARS = smoke(((0.875, 0.75),), ((0.875, 0.75), (0.75, 0.5)))


def _weight_bytes(packed) -> int:
    """Packed gate-weight storage across layers (values+indices+scales)."""
    return sum(lp[k].memory_bytes()["total"]
               for lp in packed["layers"] for k in ("w_x", "w_h"))


def main():
    cfg = bench_lstm_cfg()
    model = LSTMModel(cfg)
    params = model.init(jax.random.key(0))
    prompt = jax.random.randint(jax.random.key(1), (B, P), 0,
                                cfg.vocab_size)

    with use_backend("ref"):
        for spar_x, spar_h in SPARS:
            base_bytes = base_logits = None
            for scheme in SCHEMES:
                quant = QuantConfig(scheme) if scheme else None
                eng = ServeEngine(model, cfg, max_len=P + G, batch=B,
                                  sparsity=lstm_policy(spar_x, spar_h,
                                                       quant=quant))
                packed, _ = eng.prepare(params, calib=prompt)
                wb = _weight_bytes(packed)
                logits, _ = eng._prefill(packed, prompt, max_len=P + G)
                if scheme is None:
                    base_bytes, base_logits = wb, logits
                    derived = f"weight_bytes={wb} (f32 baseline)"
                else:
                    mae = float(jnp.mean(jnp.abs(logits - base_logits)))
                    ref = float(jnp.mean(jnp.abs(base_logits)))
                    derived = (f"weight_bytes={wb} "
                               f"bytes_red={base_bytes / wb:.2f}x "
                               f"logit_mae={mae / max(ref, 1e-9):.4f}")
                t = time_fn(lambda: eng.generate(packed, prompt, G))
                tps = B * G / t
                name = (f"quant_{scheme or 'f32'}"
                        f"_sx={spar_x:g}_sh={spar_h:g}")
                row(name, t / (B * G) * 1e6,
                    derived + f" toks_per_s={tps:.0f}")


if __name__ == "__main__":
    main()
