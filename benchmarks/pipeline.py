"""Quality × compression records from the closed accuracy loop.

Runs ``repro.launch.pipeline`` (train → prune → retrain → calibrate →
pack → serve) and re-emits its grid rows through the common sink, so the
quality trajectory — perplexity delta vs dense, packed weight bytes,
serving tokens/s per (Spar_x, Spar_h) × scheme × Θ point — is diffed
across PRs exactly like the perf benchmarks. Smoke shrinks the training
budget to CI size (the quality numbers are then meaningless; the CI
quality gate lives in the dedicated quality-smoke job, not here).
"""
from . import common


def main():
    from repro.launch.pipeline import PipelineConfig, run_pipeline
    cfg = PipelineConfig(
        train_steps=common.smoke(60, 300),
        retrain_steps=common.smoke(40, 200),
        eval_batches=common.smoke(2, 4),
        spar_grid=common.smoke(((0.75, 0.5),),
                               ((0.75, 0.5), (0.875, 0.625))),
    )
    payload = run_pipeline(cfg, smoke=common.SMOKE,
                           log=lambda *_a, **_k: None)
    for rec in payload["rows"]:
        rec = dict(rec)
        name = rec.pop("name")
        us = rec.pop("us_per_call")
        derived = " ".join(f"{k}={v}" for k, v in rec.items())
        common.row(name, us, derived)


if __name__ == "__main__":
    main()
