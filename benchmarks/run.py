"""Benchmark harness — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows."""
import sys


def main() -> None:
    from . import decode_throughput, fig4_dual_ratio, fig9_patterns, \
        fig_delta_occupancy, fig_quant_tradeoff, table1_resources, \
        table2_throughput
    print("name,us_per_call,derived")
    for mod in (table1_resources, table2_throughput, decode_throughput,
                fig9_patterns, fig4_dual_ratio, fig_delta_occupancy,
                fig_quant_tradeoff):
        mod.main()
        sys.stdout.flush()


if __name__ == "__main__":
    main()
