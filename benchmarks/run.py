"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows AND, per module, writes a
machine-readable ``BENCH_<module>.json`` record (wall time, the parsed
per-row fields — tok/s, effective-ops reductions, byte ratios) so the
perf trajectory can be diffed across PRs. ``REPRO_BENCH_DIR`` overrides
the output directory (default: the current working directory).
"""
import json
import os
import sys
import time

from . import common


def main() -> None:
    from . import decode_throughput, fig4_dual_ratio, fig9_patterns, \
        fig_delta_occupancy, fig_quant_tradeoff, obs, pipeline, spec, \
        table1_resources, table2_throughput, traffic
    out_dir = os.environ.get("REPRO_BENCH_DIR", ".")
    os.makedirs(out_dir, exist_ok=True)
    print("name,us_per_call,derived")
    for mod in (table1_resources, table2_throughput, decode_throughput,
                fig9_patterns, fig4_dual_ratio, fig_delta_occupancy,
                fig_quant_tradeoff, traffic, pipeline, spec, obs):
        common.drain_records()
        t0 = time.time()
        mod.main()
        wall = time.time() - t0
        name = mod.__name__.rsplit(".", 1)[-1]
        payload = {"benchmark": name, "smoke": common.SMOKE,
                   "wall_time_s": round(wall, 3),
                   "rows": common.drain_records()}
        path = os.path.join(out_dir, f"BENCH_{name}.json")
        with open(path, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        sys.stdout.flush()


if __name__ == "__main__":
    main()
