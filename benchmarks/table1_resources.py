"""Table 1 analogue: storage accounting for the paper's hardware
configuration — TIMIT model (X=153, H=1024) at OS=87.5%, fixed-16 data.

The paper reports BRAM/DSP utilization; the TPU-meaningful equivalents are
the packed-array bytes (values + relative-address indices) vs dense, and
the derived X_SP/H_SP row lengths (paper: X_SP=20, H_SP=64... H_SP=128 at
87.5% of 1024; the paper's 64 corresponds to its internal banking)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pack_from_dense, keep_count
from .common import row


def main():
    X, H = 153, 1024
    OS = 0.875
    rng = np.random.default_rng(0)
    wx = jnp.asarray(rng.normal(size=(4 * H, X)).astype(np.float32))
    wh = jnp.asarray(rng.normal(size=(4 * H, H)).astype(np.float32))
    sx = pack_from_dense(wx, OS)
    sh = pack_from_dense(wh, OS)
    x_sp, h_sp = sx.K, sh.K
    row("table1_row_lengths", 0.0,
        f"X_SP={x_sp} H_SP={h_sp} (paper: X_SP=20; keep_count says "
        f"{keep_count(X, OS)}/{keep_count(H, OS)})")
    # the accelerator's MA sizing rule: R_S/R_L = min/max(X_SP, H_SP)
    ratio = min(x_sp, h_sp) / max(x_sp, h_sp)
    row("table1_ma_ratio", 0.0,
        f"R_S/R_L={ratio:.4f} (paper used 80/256={80/256:.4f})")
    for name, s, dense_cols in (("Wx", sx, X), ("Wh", sh, H)):
        m = s.memory_bytes()
        # 16-bit values like the paper's fixed-16 + narrow delta indices
        v16 = s.values.size * 2
        idx = m["indices"]
        dense16 = 4 * H * dense_cols * 2
        row(f"table1_{name}_bytes", 0.0,
            f"values16={v16} indices={idx} total={v16+idx} dense16={dense16} "
            f"ratio={(v16+idx)/dense16:.4f} index_overhead="
            f"{idx/(v16+idx):.3f}")


if __name__ == "__main__":
    main()
