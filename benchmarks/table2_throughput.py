"""Table 2 analogue: dense vs BRDS-sparse LSTM inference step.

Measures wall time per step on this host (CPU; jit'd dense einsum vs jit'd
packed gather path — the kernels' ref formulations, since Pallas interpret
mode measures Python, not hardware), and derives the TPU-v5e roofline-model
step times + effective-throughput ratio = 1/(1-sparsity) that the paper's
headline numbers (GOPS, effective GOPS) correspond to."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import LSTMModel, LSTMConfig
from repro import hw
from .common import time_call, row, smoke


def main():
    # paper's TIMIT configuration (hidden shrunk under the CI smoke run)
    cfg = LSTMConfig("timit", input_size=153, hidden=smoke(128, 1024),
                     num_classes=61, framewise=True)
    model = LSTMModel(cfg)
    params = model.init(jax.random.key(0))
    OS = 0.875
    pruned, masks = model.prune(params, OS, OS)
    packed = model.pack(pruned, masks)
    B = 1
    x = jnp.asarray(np.random.default_rng(0).normal(size=(B, 153)),
                    jnp.float32)
    st = model.init_state(B)

    dense_fn = jax.jit(lambda xx, ss: model.dense_step(pruned, xx, ss))
    sparse_fn = jax.jit(
        lambda xx, ss: model.sparse_step(packed, xx, ss, backend="ref"))
    us_dense = time_call(dense_fn, x, st)
    us_sparse = time_call(sparse_fn, x, st)

    H, X = cfg.hidden, 153
    ops = 2 * 4 * H * (X + H)                       # dense MACs per step
    x_sp, h_sp = packed[0]["sx"].K, packed[0]["sh"].K
    ops_sp = 2 * 4 * H * (x_sp + h_sp)
    row("table2_cpu_dense_step", us_dense, f"GOPS={ops/us_dense/1e3:.2f}")
    row("table2_cpu_sparse_step", us_sparse,
        f"GOPS={ops_sp/us_sparse/1e3:.2f} "
        f"effGOPS={ops/us_sparse/1e3:.2f} speedup={us_dense/us_sparse:.2f}x")

    # TPU v5e roofline model (decode MxV is HBM-bound):
    bytes_dense = (4 * H * (X + H)) * 2             # bf16 weights
    bytes_sparse = sum(s.memory_bytes()["values"] // 2  # →16-bit values
                       + s.memory_bytes()["indices"]
                       for s in (packed[0]["sx"], packed[0]["sh"]))
    t_dense = bytes_dense / hw.HBM_BW
    t_sparse = bytes_sparse / hw.HBM_BW
    row("table2_v5e_model_dense", t_dense * 1e6,
        f"bytes={bytes_dense} effGOPS={ops/t_dense/1e9:.0f}")
    row("table2_v5e_model_sparse", t_sparse * 1e6,
        f"bytes={bytes_sparse} effGOPS={ops/t_sparse/1e9:.0f} "
        f"speedup={t_dense/t_sparse:.2f}x "
        f"(paper effective-throughput factor 1/(1-s)={1/(1-OS):.1f}x)")


if __name__ == "__main__":
    main()
