"""Fig. 4 analogue: dual-ratio sparsity beats the uniform split at fixed
overall sparsity. Sweeps (Spar_x, Spar_h) tuples at OS≈0.6 on a small
trained LSTM LM and reports eval loss per tuple (paper reports perplexity —
monotone in loss)."""
import jax
import jax.numpy as jnp

from repro.models import LSTMModel, LSTMConfig
from repro.sparse import lstm_policy, mask_grads
from repro.training import OptConfig, init_state, CharCorpus
from repro.training.optim import apply_update
from repro.core.metrics import perplexity
from .common import row, smoke


def _train(model, params, ds, steps, masks=None, off=0):
    oc = OptConfig(lr=5e-3, warmup_steps=2, total_steps=2000,
                   schedule="constant")
    st = init_state(oc, params)
    lg = jax.jit(jax.value_and_grad(lambda p, b: model.loss(p, b)))
    for i in range(steps):
        t = ds.batch(off + i, 8, 24)["tokens"] % 30
        b = {"inputs": jnp.asarray(t), "labels": jnp.asarray(t)}
        _, g = lg(params, b)
        if masks is not None:
            g = mask_grads(g, masks)
        params, st, _ = apply_update(oc, params, g, st)
    return params


def main():
    cfg = LSTMConfig("fig4", input_size=16, hidden=64, num_layers=1,
                     vocab_size=30)
    model = LSTMModel(cfg)
    ds = CharCorpus()
    params = model.init(jax.random.key(0))
    params = _train(model, params, ds, smoke(6, 80))

    t = ds.batch(9999, 16, 24)["tokens"] % 30
    eval_b = {"inputs": jnp.asarray(t), "labels": jnp.asarray(t)}

    # fixed overall sparsity: X and H sides have equal weight counts here
    # (4H×X vs 4H×H with X=16,H=64 → weights differ; tuples hold the
    # weighted overall ≈ 0.6)
    nx = 4 * 64 * 16
    nh = 4 * 64 * 64
    results = {}
    for sx in smoke((0.5, 0.7), (0.4, 0.5, 0.6, 0.7, 0.8)):
        sh = (0.6 * (nx + nh) - sx * nx) / nh
        if not (0.0 <= sh <= 0.95):
            continue
        plan = lstm_policy(sx, sh).compile(params)
        pruned, masks = plan.prune(params)
        retr = _train(model, pruned, ds, smoke(4, 40), masks=masks, off=500)
        loss = float(model.loss(retr, eval_b))
        results[(round(sx, 2), round(sh, 2))] = loss
        row(f"fig4_spar_x={sx:.2f}_spar_h={sh:.2f}", 0.0,
            f"loss={loss:.4f} ppl={perplexity(loss):.2f}")
    best = min(results, key=results.get)
    uniform = min(results, key=lambda k: abs(k[0] - k[1]))
    row("fig4_best_tuple", 0.0,
        f"best={best} uniform={uniform} "
        f"best_loss={results[best]:.4f} uniform_loss={results[uniform]:.4f}")


if __name__ == "__main__":
    main()
