"""fig_delta_occupancy: effective-ops reduction vs. delta threshold Θ.

The Spartus-style extension of the paper's Fig.-4 story: weight sparsity
fixes the packed MAC count; temporal delta sparsity then scales the
*executed* MACs by the fired-column occupancy. This sweep serves the
paper's LSTM LM through the engine at increasing Θ (plus one occupancy-
capped point) and reports, per Θ:

  occupancy      mean fired fraction across the x and h paths
  ops_reduction  packed MACs / effective MACs (≥ 1; multiplies with the
                 weight-side 1/(1-sparsity) gain)
  tok/s          wall-clock serving throughput on this host (jnp ref
                 formulations — interpret-mode Pallas measures Python)
"""
import jax

from repro.models import LSTMModel
from repro.serving import ServeEngine
from repro.sparse import (DeltaGateConfig, lstm_policy, occupancy_report,
                          use_backend)
from .common import bench_lstm_cfg, bench_lstm_dims, row, smoke, time_fn

B, P, G = bench_lstm_dims()
THETAS = smoke((0.0, 0.1), (0.0, 0.02, 0.05, 0.1, 0.2, 0.5))


def main():
    cfg = bench_lstm_cfg()
    model = LSTMModel(cfg)
    params = model.init(jax.random.key(0))
    prompt = jax.random.randint(jax.random.key(1), (B, P), 0, cfg.vocab_size)
    # the weight side is fixed across the sweep — prune and pack once;
    # each Θ point only rewires the activation rule (model.with_delta)
    plan = lstm_policy(0.875, 0.75).compile(params)
    pruned, masks = plan.prune(params)
    packed, _ = plan.pack(pruned, masks)

    def serve(delta):
        eng = ServeEngine(model.with_delta(delta), cfg, max_len=P + G,
                          batch=B)
        state = {}

        def run():
            toks, st = eng.generate(packed, prompt, G, return_state=True)
            state.update(st)
            return toks

        dt = time_fn(run)
        occ = occupancy_report(state["cache"], steps=P + G, packed=packed)
        return occ, B * G / dt

    with use_backend("ref"):
        for theta in THETAS:
            occ, tps = serve(DeltaGateConfig(theta_x=theta, theta_h=theta))
            row(f"delta_occupancy_theta_{theta:g}", 1e6 / max(tps, 1e-9),
                f"occupancy={occ['occupancy']:.3f} "
                f"ops_reduction={occ['ops_reduction']:.2f}x "
                f"toks_per_s={tps:.0f}")
        # the hardware-bound point: Θ=0.05 with a 25% occupancy cap
        occ, tps = serve(DeltaGateConfig(theta_x=0.05, theta_h=0.05,
                                         cap_x=0.25, cap_h=0.25))
        row("delta_occupancy_cap_0.25", 1e6 / max(tps, 1e-9),
            f"occupancy={occ['occupancy']:.3f} "
            f"ops_reduction={occ['ops_reduction']:.2f}x "
            f"toks_per_s={tps:.0f}")


if __name__ == "__main__":
    main()
