"""Shared benchmark utilities."""
import os
import time

import jax

# REPRO_BENCH_SMOKE=1 shrinks every module to CI-sized shapes/sweeps so
# `python -m benchmarks.run` doubles as a bit-rot smoke test (the numbers
# are meaningless at smoke size — only the code paths matter).
SMOKE = os.environ.get("REPRO_BENCH_SMOKE", "") not in ("", "0")


def smoke(small, full):
    """``small`` under REPRO_BENCH_SMOKE=1, else ``full``."""
    return small if SMOKE else full


def bench_lstm_dims():
    """(B, P, G) shared by the serving benchmarks (CI-shrunk in smoke)."""
    return smoke((2, 4, 8), (8, 16, 32))


def bench_lstm_cfg():
    """The shared small LSTM-LM benchmark model (CI-shrunk in smoke)."""
    from repro.models import LSTMConfig
    return LSTMConfig("bench", input_size=smoke(32, 128),
                      hidden=smoke(64, 256), num_layers=1,
                      vocab_size=smoke(64, 512))


def time_call(fn, *args, warmup=2, iters=5):
    """Median wall time per call in microseconds (jit-compiled fn)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def time_fn(fn, warmup=1, iters=3):
    """Median wall time per call in seconds for a no-arg callable
    (compiles on the warmup calls)."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


# Machine-readable record sink: every row() lands here too, and
# benchmarks/run.py drains it into BENCH_<module>.json after each module —
# the perf trajectory the harness diffs across PRs.
_RECORDS: list = []


def row(name, us, derived):
    print(f"{name},{us:.1f},{derived}")
    rec = {"name": name, "us_per_call": round(float(us), 3)}
    notes = []
    for part in str(derived).split():
        if "=" in part:
            k, v = part.split("=", 1)
            try:
                rec[k] = float(v.rstrip("x"))
            except ValueError:
                rec[k] = v
        else:
            notes.append(part)
    if notes:
        rec["notes"] = " ".join(notes)
    _RECORDS.append(rec)


def drain_records() -> list:
    """Pop all records accumulated by row() since the last drain."""
    out = list(_RECORDS)
    _RECORDS.clear()
    return out
