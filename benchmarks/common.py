"""Shared benchmark utilities."""
import time

import jax


def time_call(fn, *args, warmup=2, iters=5):
    """Median wall time per call in microseconds (jit-compiled fn)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def time_fn(fn, warmup=1, iters=3):
    """Median wall time per call in seconds for a no-arg callable
    (compiles on the warmup calls)."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def row(name, us, derived):
    print(f"{name},{us:.1f},{derived}")
