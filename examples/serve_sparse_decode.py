"""Serving example: batched decode with BRDS-sparse weights — the paper's
deployment scenario (inference on the pruned network), on a transformer.

Compares dense vs masked-sparse decode and prints the memory-traffic model
that drives the TPU speedup (decode is HBM-bound; packed weights move
(1-sparsity) of the bytes — the paper's effective-throughput argument).

  PYTHONPATH=src python examples/serve_sparse_decode.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.models import build_model
from repro.sparse import transformer_policy
from repro.serving import ServeEngine
from repro import hw


def main():
    cfg = smoke_config("minitron-8b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, P, G = 4, 32, 16
    prompt = jax.random.randint(jax.random.key(1), (B, P), 0, cfg.vocab_size)

    # dual-ratio policy: family A (feed-forward) 87.5%, family B (mixers) 75%
    eng = ServeEngine(model, cfg, max_len=P + G, batch=B,
                      sparsity=transformer_policy(0.875, 0.75))
    t0 = time.time()
    out_dense = eng.generate(params, prompt, steps=G)
    t_dense = time.time() - t0

    sparse_params, rep = eng.prepare(params)
    t0 = time.time()
    out_sparse = eng.generate(sparse_params, prompt, steps=G)
    t_sparse = time.time() - t0
    print(f"dense decode: {t_dense:.2f}s; sparse decode (masked): "
          f"{t_sparse:.2f}s; model sparsity {rep['sparsity']:.1%}")

    # TPU v5e traffic model for the FULL minitron-8b (decode, per token):
    from repro.configs import get_arch
    full = get_arch("minitron-8b")
    n = build_model(full).param_count()
    dense_bytes = n * 2
    packed_bytes = n * (1 - rep["sparsity"]) * 2 \
        + n * (1 - rep["sparsity"]) * 1          # values + int8 deltas
    print(f"v5e per-token weight traffic: dense {dense_bytes/1e9:.1f} GB "
          f"({dense_bytes/hw.HBM_BW*1e3:.2f} ms), packed "
          f"{packed_bytes/1e9:.1f} GB ({packed_bytes/hw.HBM_BW*1e3:.2f} ms) "
          f"→ {dense_bytes/packed_bytes:.1f}x decode speedup headroom")


if __name__ == "__main__":
    main()
