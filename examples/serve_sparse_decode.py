"""Serving example: BRDS-sparse decode through the unified runtime — the
paper's deployment scenario (inference on the pruned network).

Three stages:
1. The paper's LSTM served END-TO-END on the packed row-balanced kernels:
   SparsityPlan.pack'd params flow through ServeEngine's on-device decode
   loop, so every generated token runs rb_dual_spmv + lstm_gates.
2. A transformer served dense vs masked-sparse through the same engine
   (transformers keep dense matmul serving; packing is the LSTM datapath).
3. A ragged request stream through the continuous-batching scheduler.

Prints the memory-traffic model that drives the TPU speedup (decode is
HBM-bound; packed weights move (1-sparsity) of the bytes — the paper's
effective-throughput argument).

  PYTHONPATH=src python examples/serve_sparse_decode.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.models import build_model, LSTMModel, LSTMConfig
from repro.sparse import lstm_policy, transformer_policy, use_backend
from repro.serving import (ServeEngine, ContinuousBatchingEngine,
                          SamplingConfig)
from repro import hw


def serve_packed_lstm():
    """The headline path: BRDS-pruned LSTM decoding on packed kernels."""
    cfg = LSTMConfig("lstm_demo", input_size=128, hidden=256, vocab_size=512)
    model = LSTMModel(cfg)
    params = model.init(jax.random.key(0))
    B, P, G = 4, 16, 24
    prompt = jax.random.randint(jax.random.key(1), (B, P), 0, cfg.vocab_size)

    eng = ServeEngine(model, cfg, max_len=P + G, batch=B,
                      sparsity=lstm_policy(0.875, 0.75))
    packed, rep = eng.prepare(params)       # prune AND pack (LSTM decodes packed)
    with use_backend("ref"):                # jnp formulation of the kernels on CPU
        t0 = time.time()
        out = eng.generate(packed, prompt, steps=G)
        out.block_until_ready()
        dt = time.time() - t0
    print(f"packed LSTM decode: {B * G / dt:.0f} tok/s, "
          f"weights {rep['ratio']:.1%} of dense bytes "
          f"(sparsity {rep['sparsity']:.1%})")


def serve_transformer():
    cfg = smoke_config("minitron-8b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, P, G = 4, 32, 16
    prompt = jax.random.randint(jax.random.key(1), (B, P), 0, cfg.vocab_size)

    # dual-ratio policy: family A (feed-forward) 87.5%, family B (mixers) 75%
    eng = ServeEngine(model, cfg, max_len=P + G, batch=B,
                      sparsity=transformer_policy(0.875, 0.75))
    t0 = time.time()
    eng.generate(params, prompt, steps=G).block_until_ready()
    t_dense = time.time() - t0

    sparse_params, rep = eng.prepare(params)
    t0 = time.time()
    eng.generate(sparse_params, prompt, steps=G).block_until_ready()
    t_sparse = time.time() - t0
    print(f"dense decode: {t_dense:.2f}s; sparse decode (masked): "
          f"{t_sparse:.2f}s; model sparsity {rep['sparsity']:.1%}")

    # TPU v5e traffic model for the FULL minitron-8b (decode, per token):
    from repro.configs import get_arch
    full = get_arch("minitron-8b")
    n = build_model(full).param_count()
    dense_bytes = n * 2
    packed_bytes = n * (1 - rep["sparsity"]) * 2 \
        + n * (1 - rep["sparsity"]) * 1          # values + int8 deltas
    print(f"v5e per-token weight traffic: dense {dense_bytes/1e9:.1f} GB "
          f"({dense_bytes/hw.HBM_BW*1e3:.2f} ms), packed "
          f"{packed_bytes/1e9:.1f} GB ({packed_bytes/hw.HBM_BW*1e3:.2f} ms) "
          f"→ {dense_bytes/packed_bytes:.1f}x decode speedup headroom")
    return model, cfg, params


def serve_continuous(model, cfg, params):
    """Ragged request stream: admission/eviction over 2 shared slots."""
    sched = ContinuousBatchingEngine(model, params, slots=2, max_len=48,
                                     sampling=SamplingConfig(), chunk=8)
    for i, (plen, gen) in enumerate([(4, 12), (20, 6), (9, 16), (14, 4)]):
        prompt = jax.random.randint(jax.random.key(10 + i), (1, plen), 0,
                                    cfg.vocab_size)
        sched.submit(prompt, gen)
    t0 = time.time()
    results = sched.run()
    dt = time.time() - t0
    total = sum(len(v) for v in results.values())
    print(f"continuous batching: {len(results)} ragged requests, "
          f"{total} tokens in {dt:.2f}s over 2 slots "
          f"({sched.steps_dispatched} chunk dispatches)")


def main():
    serve_packed_lstm()
    model, cfg, params = serve_transformer()
    serve_continuous(model, cfg, params)


if __name__ == "__main__":
    main()
