"""Quickstart: the paper's technique in 40 lines.

Row-balanced dual-ratio pruning of an LSTM, packing to the accelerator
format, and running the sparse inference path (the Pallas rb_dual_spmv +
lstm_gates kernels, interpret mode on CPU).

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import LSTMModel, LSTMConfig

# the paper's TIMIT-shaped layer: X=153 inputs, H=1024 hidden
cfg = LSTMConfig("demo", input_size=153, hidden=1024, num_classes=61,
                 framewise=True)
model = LSTMModel(cfg)
params = model.init(jax.random.key(0))

# dual-ratio row-balanced pruning (paper's §3.2): the recurrent weights
# W_h are less sensitive here, so prune W_x harder
pruned, masks = model.prune(params, spar_x=0.875, spar_h=0.875)
packed = model.pack(pruned)
sx, sh = packed[0]["sx"], packed[0]["sh"]
print(f"W_x: {sx.rows}x{sx.ncols} -> {sx.K} nnz/row "
      f"({sx.memory_bytes()['ratio']:.1%} of dense)")
print(f"W_h: {sh.rows}x{sh.ncols} -> {sh.K} nnz/row "
      f"({sh.memory_bytes()['ratio']:.1%} of dense)")
print(f"MA sizing rule R_S/R_L = {min(sx.K, sh.K)}/{max(sx.K, sh.K)}")

# run one inference step on both paths — they agree to float tolerance
x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 153)), jnp.float32)
state = model.init_state(2)
h_dense, _ = model.dense_step(pruned, x, state)
h_sparse, _ = model.sparse_step(packed, x, state)   # Pallas kernels
print("dense vs packed-sparse max err:",
      float(jnp.abs(h_dense - h_sparse).max()))
