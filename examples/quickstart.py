"""Quickstart: the paper's technique through the repro.sparse API.

The flow is policy → plan → pack:

  1. declare a SparsityPolicy — per-weight-family (format, ratio) rules;
  2. compile it against the model's params into a SparsityPlan;
  3. plan.prune zeroes the pruned weights (masks freeze them in retraining);
  4. plan.pack converts the survivors to the accelerator's packed
     row-balanced format (values + relative-address deltas);
  5. the packed tree runs the sparse inference path (the Pallas
     rb_dual_spmv + lstm_gates kernels — the backend is configured once on
     the policy: "pallas" | "ref" | "auto");
  6. optionally, an activation rule (DeltaGateConfig) adds Spartus-style
     temporal sparsity on top: decode steps skip the matvec columns whose
     activation delta stayed under a threshold Θ.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import LSTMModel, LSTMConfig
from repro.sparse import DeltaGateConfig, SparsityPolicy, delta_threshold

# the paper's TIMIT-shaped layer: X=153 inputs, H=1024 hidden
cfg = LSTMConfig("demo", input_size=153, hidden=1024, num_classes=61,
                 framewise=True)
model = LSTMModel(cfg)
params = model.init(jax.random.key(0))

# dual-ratio row-balanced pruning (paper's §3.2): the input weights W_x
# tolerate harder pruning than the recurrent W_h (the paper's X_SP ≪ H_SP)
policy = SparsityPolicy.of({r"w_x$": ("row_balanced", 0.875),
                            r"w_h$": ("row_balanced", 0.75)},
                           layout="out_in", backend="auto")
plan = policy.compile(params)
pruned, masks = plan.prune(params)
print("plan:", plan, "—", plan.summary(masks))

packed, report = plan.pack(pruned, masks=masks)
sx, sh = packed["layers"][0]["w_x"], packed["layers"][0]["w_h"]
print(f"W_x: {sx.rows}x{sx.ncols} -> {sx.K} nnz/row "
      f"({sx.memory_bytes()['ratio']:.1%} of dense)")
print(f"W_h: {sh.rows}x{sh.ncols} -> {sh.K} nnz/row "
      f"({sh.memory_bytes()['ratio']:.1%} of dense)")
print(f"MA sizing rule R_S/R_L = {min(sx.K, sh.K)}/{max(sx.K, sh.K)}")
print(f"whole-tree packed/dense ratio: {report['ratio']:.1%}")

# run one inference step on both paths — they agree to float tolerance
x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 153)), jnp.float32)
state = model.init_state(2)
h_dense, _ = model.dense_step(pruned, x, state)
h_sparse, _ = model.sparse_step(packed, x, state,
                                backend=plan.backend)   # Pallas kernels
h_ref, _ = model.sparse_step(packed, x, state, backend="ref")
print("dense vs packed-sparse (pallas) max err:",
      float(jnp.abs(h_dense - h_sparse).max()))
print("pallas vs ref backend max err:",
      float(jnp.abs(h_sparse - h_ref).max()))

# temporal delta sparsity (Spartus-style): between steps, only the input
# components whose delta crossed Θ fire — their count is the occupancy the
# delta kernels' effective-ops reduction comes from. Declared on the policy
# (lstm_policy(..., delta=DeltaGateConfig(...))) and wired into serving by
# ServeEngine.prepare; shown here on a raw pair of steps.
x2 = x + jnp.asarray(np.random.default_rng(1).normal(scale=0.05,
                                                     size=x.shape),
                     jnp.float32)
cfgd = DeltaGateConfig(theta_x=0.05, theta_h=0.02, cap_x=0.5)
_, fired, _ = delta_threshold(x2, x, theta=cfgd.theta_x, cap=cfgd.cap_x)
print(f"delta config {cfgd}: step-2 input occupancy "
      f"{float(fired.mean()):.1%} (columns firing at Θ={cfgd.theta_x})")
