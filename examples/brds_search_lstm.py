"""Run the paper's Fig.-5 BRDS search on a small LSTM language model:
ramp to the overall-sparsity target, then walk (Spar_x, Spar_h) both ways,
retraining at each step, and report the best tuple.

  PYTHONPATH=src python examples/brds_search_lstm.py [--os 0.6]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.models import LSTMModel, LSTMConfig
from repro.sparse import brds_search, execution_time_model, lstm_policy
from repro.training import OptConfig, init_state, CharCorpus
from repro.training.optim import apply_update


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--os", type=float, default=0.6)
    ap.add_argument("--retrain-steps", type=int, default=20)
    args = ap.parse_args()

    ds = CharCorpus()
    cfg = LSTMConfig("search", input_size=24, hidden=64, num_layers=1,
                     vocab_size=ds.vocab_size)
    model = LSTMModel(cfg)
    params = model.init(jax.random.key(0))
    oc = OptConfig(lr=5e-3, warmup_steps=2, total_steps=5000,
                   schedule="constant")
    lg = jax.jit(jax.value_and_grad(lambda p, b: model.loss(p, b)))

    def batch(i):
        b = ds.batch(i, 8, 32)
        return {"inputs": jnp.asarray(b["tokens"]),
                "labels": jnp.asarray(b["labels"])}

    # pretrain
    st = init_state(oc, params)
    for i in range(60):
        _, g = lg(params, batch(i))
        params, st, _ = apply_update(oc, params, g, st)
    print("pretrained loss:", float(model.loss(params, batch(9999))))

    ctr = {"i": 100}

    # the search walks SparsityPolicy objects: one factory maps each
    # (Spar_x, Spar_h) tuple to the paper's dual-ratio row-balanced policy
    def retrain_fn(p, plan, masks):
        s = init_state(oc, p)
        for _ in range(args.retrain_steps):
            ctr["i"] += 1
            _, g = lg(p, batch(ctr["i"]))
            g = plan.mask_grads(g, masks)
            p, s, _ = apply_update(oc, p, g, s)
        return p

    def eval_fn(p):
        return -float(model.loss(p, batch(9999)))

    res = brds_search(params, overall_sparsity=args.os,
                      policy_at=lstm_policy, retrain_fn=retrain_fn,
                      eval_fn=eval_fn,
                      alpha=args.os / 2, delta_x=0.1, delta_h=0.1)
    print(f"\n{'phase':8s} {'Spar_x':>7s} {'Spar_h':>7s} {'loss':>9s}")
    for h in res.history:
        print(f"{h['phase']:8s} {h['spar_x']:7.2f} {h['spar_h']:7.2f} "
              f"{-h['accuracy']:9.4f}")
    print(f"\nbest: Spar_x={res.best_spar_x:.2f} Spar_h={res.best_spar_h:.2f} "
          f"loss={-res.best_accuracy:.4f}")
    t = execution_time_model(args.os, args.os / 2, 0.1, 0.1, ept=1.0,
                             n_re=args.retrain_steps)
    print("paper cost model (eq.3-6), retrain-epochs units:", t)


if __name__ == "__main__":
    main()
