"""End-to-end training driver example: train a transformer char-LM with the
full production stack (sharded step when devices allow, checkpointing,
BRDS sparse fine-tune phase), then sample from it.

Default is CPU-sized; --big selects a ~100M-parameter configuration (the
same code path a pod run uses via launch/train.py).

  PYTHONPATH=src python examples/train_charlm.py --steps 200
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.models import build_model
from repro.sparse import transformer_policy
from repro.training import (OptConfig, init_state, make_train_step,
                            CharCorpus, CheckpointManager)
from repro.serving import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--sparse-steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--big", action="store_true",
                    help="~100M params (pod-scale shapes, slow on CPU)")
    ap.add_argument("--ckpt", default="/tmp/charlm_ckpt")
    args = ap.parse_args()

    ds = CharCorpus()
    cfg = smoke_config("llama3.2-3b").with_(vocab_size=ds.vocab_size)
    if args.big:
        cfg = cfg.with_(num_layers=12, d_model=768, num_heads=12,
                        num_kv_heads=4, head_dim=64, d_ff=2048)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    print(f"params: {model.param_count()/1e6:.1f}M")

    oc = OptConfig(lr=3e-3, warmup_steps=10, total_steps=args.steps)
    st = init_state(oc, params)
    step = jax.jit(make_train_step(model, cfg, oc))
    ckpt = CheckpointManager(args.ckpt, keep=2)

    t0 = time.time()
    for i in range(args.steps):
        b = ds.batch(i, args.batch, args.seq)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        params, st, m = step(params, st, batch, jnp.int32(i))
        if i % 20 == 0:
            print(f"step {i:4d} loss {float(m['loss']):.4f} "
                  f"({(time.time()-t0):.1f}s)")
        if (i + 1) % 50 == 0:
            ckpt.save(i + 1, (params, st))
    ckpt.wait()

    # BRDS sparse fine-tune: prune FFN harder than attention, retrain
    print("\nBRDS dual-ratio sparse fine-tune (A=0.75, B=0.5)...")
    plan = transformer_policy(0.75, 0.5).compile(params)
    params, masks = plan.prune(params)
    b0 = {k: jnp.asarray(v) for k, v in ds.batch(777, args.batch, args.seq).items()}
    print("loss after prune:", float(model.loss(params, b0)))
    step_m = jax.jit(make_train_step(model, cfg, oc, masks=masks))
    for i in range(args.sparse_steps):
        b = ds.batch(args.steps + i, args.batch, args.seq)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        params, st, m = step_m(params, st, batch, jnp.int32(args.steps + i))
    print("loss after sparse retrain:", float(model.loss(params, b0)))

    # sample: greedy and temperature/top-k, each one on-device decode dispatch
    eng = ServeEngine(model, cfg, max_len=args.seq + 48, batch=1)
    prompt_txt = "the quick brown "
    itos = {v: k for k, v in ds.stoi.items()}
    prompt = jnp.asarray([[ds.stoi[c] for c in prompt_txt]], jnp.int32)
    out = eng.generate(params, prompt, steps=48)
    print("\ngreedy:", prompt_txt + "".join(itos[int(i)] for i in out[0]))
    out = eng.generate(params, prompt, steps=48, temperature=0.8, top_k=20,
                       rng=jax.random.key(7))
    print("t=0.8 k=20:", prompt_txt + "".join(itos[int(i)] for i in out[0]))


if __name__ == "__main__":
    main()
